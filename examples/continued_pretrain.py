"""Continued pretraining as SAMA-reweighted multitask learning (Sec. 4.2).

The auxiliary corpus mixes in-domain and harmful data; SAMA learns to keep
the former and suppress the latter, beating both ft-only and equal-weight
multitask (TARTAN-MT) baselines on held-out finetune loss.

    PYTHONPATH=src python examples/continued_pretrain.py [--steps 80]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, data, optim
from repro.core import Engine, EngineConfig, problems
from repro.core.meta_modules import apply_weight_net, weight_features
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    cfg = configs.get_smoke_config("gemma3-1b").replace(remat=False)
    model = Model(cfg)
    seq, batch = 32, 16

    lm = data.LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq, markov_strength=0.8)
    rng = np.random.default_rng(0)
    ft_train = data.lm_batch(lm, rng, 256)["tokens"]
    ft_meta = data.lm_batch(lm, rng, 128)["tokens"]
    ft_test = data.lm_batch(lm, rng, 256)["tokens"]
    aux_in = data.lm_batch(lm, rng, 256)["tokens"]
    aux_bad = rng.integers(0, cfg.vocab_size, size=(256, seq)).astype(np.int32)
    aux = np.concatenate([aux_in, aux_bad])

    spec = problems.make_auxiliary_spec(model.lm_loss, model.per_example)
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(5), reweight=True)
    eng = Engine(spec, base_opt=optim.adam(1e-3), meta_opt=optim.adam(3e-3),
                 cfg=EngineConfig(method="sama", unroll_steps=2))
    state = eng.init(model.init(jax.random.PRNGKey(0)), lam)

    def batches():
        while True:
            fi = rng.integers(0, len(ft_train), (2, batch))
            ai = rng.integers(0, len(aux), (2, batch))
            mi = rng.integers(0, len(ft_meta), batch)
            yield ({"ft": {"tokens": jnp.asarray(ft_train[fi])},
                    "pt": {"tokens": jnp.asarray(aux[ai])}},
                   {"ft": {"tokens": jnp.asarray(ft_meta[mi])}})

    state, hist = eng.run(state, batches(), num_meta_steps=args.steps, log_every=20)
    for h in hist:
        print({k: round(v, 4) for k, v in h.items()})

    pe = jax.jit(model.per_example)(state.theta, {"tokens": jnp.asarray(aux[::4])})
    w = apply_weight_net(state.lam["reweight"], weight_features(pe.loss))
    half = len(aux[::4]) // 2
    print(f"aux weights: in-domain={float(jnp.mean(w[:half])):.3f} "
          f"harmful={float(jnp.mean(w[half:])):.3f}")

    lm_loss = jax.jit(model.lm_loss)
    test = float(np.mean([float(lm_loss(state.theta, {"tokens": jnp.asarray(ft_test[i:i+64])}))
                          for i in range(0, 256, 64)]))
    print(f"held-out finetune loss after SAMA multitask: {test:.4f}")


if __name__ == "__main__":
    main()
