"""The paper's single-sync distributed schedule, runnable on CPU with 8
forced host devices (must be the FIRST lines, before any jax import).

Compares the manual shard_map step against naive pjit DDP on the same
problem and prints the collective-structure audit (all-reduce counts) that
underlies the paper's Fig. 2 / Table 2 multi-GPU rows.

    python examples/distributed_train.py        # note: NOT under PYTHONPATH tricks
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim, perf
from repro.core import EngineConfig, init_state, problems
from repro.launch import distributed as dist


def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]


def main():
    from repro.launch.mesh import AxisType, make_mesh

    mesh = make_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
    print(f"devices: {len(jax.devices())}, mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    per_ex = problems.softmax_per_example(apply_fn)
    spec = problems.make_data_optimization_spec(per_ex, reweight=True)
    d, h, C = 12, 32, 3
    theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (d, h)) * 0.3,
             "w2": jax.random.normal(jax.random.PRNGKey(1), (h, C)) * 0.3}
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    cfg = EngineConfig(method="sama", unroll_steps=2)
    state = init_state(theta, lam, base_opt, meta_opt)

    step = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, cfg, mesh))

    rng = np.random.default_rng(0)
    w_true = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (d,)))
    with mesh:
        for i in range(30):
            x = rng.normal(size=(2, 64, d)).astype(np.float32)
            y = (x @ w_true > 0).astype(np.int32) % C
            mx = rng.normal(size=(32, d)).astype(np.float32)
            my = ((mx @ w_true > 0).astype(np.int32)) % C
            state, metrics = step(state, {"x": jnp.asarray(x), "y": jnp.asarray(y)},
                                  {"x": jnp.asarray(mx), "y": jnp.asarray(my)})
            if i % 10 == 0:
                print({k: round(float(v), 4) for k, v in metrics.items()})

        # measured collective audit: the paper's Fig. 2 structure on the
        # COMPILED step, trip-count-scaled (repro.perf.collectives)
        compiled = step.lower(
            state, {"x": jnp.zeros((2, 64, d)), "y": jnp.zeros((2, 64), jnp.int32)},
            {"x": jnp.zeros((32, d)), "y": jnp.zeros((32,), jnp.int32)}).compile()
        s = perf.verify_single_sync(compiled, cfg.unroll_steps)
        assert s["single_sync_ok"], s
        print(f"single-sync schedule: {s['all-reduce_count']} all-reduce sync points "
              f"(= {cfg.unroll_steps} base DDP + 1 bucketed meta sync), "
              f"{s['total_bytes'] / 1e6:.2f} MB collective traffic/step/device")


if __name__ == "__main__":
    main()
