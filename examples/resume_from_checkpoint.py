"""Checkpoint/resume round-trip for the full bilevel EngineState (both
levels' parameters + optimizer moments + step counter).

    PYTHONPATH=src python examples/resume_from_checkpoint.py
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint, configs, data, optim
from repro.core import Engine, EngineConfig, problems
from repro.models import Model


def main():
    cfg = configs.get_smoke_config("qwen2-moe-a2.7b")  # exercise the MoE path
    model = Model(cfg)
    spec = problems.make_data_optimization_spec(model.per_example, reweight=True)
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1), reweight=True)
    eng = Engine(spec, base_opt=optim.adam(1e-3), meta_opt=optim.adam(1e-3),
                 cfg=EngineConfig(method="sama", unroll_steps=1))
    state = eng.init(model.init(jax.random.PRNGKey(0)), lam)

    lm = data.LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=32)
    rng = np.random.default_rng(0)

    def batches():
        while True:
            b = data.lm_batch(lm, rng, 8)["tokens"].reshape(1, 8, 32)
            m = data.lm_batch(lm, rng, 8)["tokens"]
            yield {"tokens": jnp.asarray(b)}, {"tokens": jnp.asarray(m)}

    it = batches()
    state, hist = eng.run(state, it, num_meta_steps=5, log_every=5)
    print("before save:", hist[-1])

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "step_000005")
        checkpoint.save(path, state, step=5, meta={"arch": cfg.name})
        print("saved to", path)

        restored, manifest = checkpoint.restore(path, state)
        print("restored step", manifest["step"], "meta", manifest["meta"])

        for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("bitwise round-trip OK; resuming training...")

        state2, hist2 = eng.run(restored, it, num_meta_steps=5, log_every=5)
        print("after resume:", hist2[-1], "step:", int(state2.step))


if __name__ == "__main__":
    main()
