"""Checkpoint/resume round-trip for the full bilevel EngineState (both
levels' parameters + optimizer moments + step counter), driven through the
MetaLearner facade's integrated save/load (DESIGN.md §5).

    PYTHONPATH=src python examples/resume_from_checkpoint.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, data
from repro.api import MetaLearner
from repro.core import problems
from repro.models import Model


def main():
    cfg = configs.get_smoke_config("qwen2-moe-a2.7b")  # exercise the MoE path
    model = Model(cfg)
    spec = problems.make_data_optimization_spec(model.per_example, reweight=True)
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1), reweight=True)

    lm = data.LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=32)
    rng = np.random.default_rng(0)

    def batches():
        while True:
            b = data.lm_batch(lm, rng, 8)["tokens"].reshape(1, 8, 32)
            m = data.lm_batch(lm, rng, 8)["tokens"]
            yield {"tokens": jnp.asarray(b)}, {"tokens": jnp.asarray(m)}

    it = batches()
    with tempfile.TemporaryDirectory() as tmp:
        learner = MetaLearner(
            spec, base_opt="adam", base_lr=1e-3, meta_opt="adam", meta_lr=1e-3,
            method="sama", unroll_steps=1, checkpoint_dir=tmp,
        )
        learner.init(model.init(jax.random.PRNGKey(0)), lam)
        hist = learner.fit(it, 5, log_every=5)
        print("before save:", hist[-1])

        path = learner.save()
        print("saved to", path)
        state_at_save = learner.state

        # a second learner (fresh params) resumes from the newest
        # checkpoint under the same directory
        resumed = MetaLearner(
            spec, base_opt="adam", base_lr=1e-3, meta_opt="adam", meta_lr=1e-3,
            method="sama", unroll_steps=1, checkpoint_dir=tmp,
        )
        resumed.init(model.init(jax.random.PRNGKey(42)), lam)  # structure template
        resumed.load()  # newest step_* in checkpoint_dir

        for a, b in zip(jax.tree_util.tree_leaves(state_at_save),
                        jax.tree_util.tree_leaves(resumed.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("bitwise round-trip OK; resuming training...")

        hist2 = resumed.fit(it, 5, log_every=5)
        print("after resume:", hist2[-1], "step:", int(resumed.state.step))


if __name__ == "__main__":
    main()
