"""Quickstart: SAMA data reweighting in ~60 lines, via the level-1 API
(repro.api.MetaLearner — see DESIGN.md §5).

40% of the training labels are flipped; a small clean meta set guides
MetaWeightNet to downweight the noise. Runs in under a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import MetaLearner
from repro.core import problems
from repro.core.meta_modules import apply_weight_net, weight_features

# --- a tiny noisy classification problem -----------------------------------
key = jax.random.PRNGKey(0)
d, n = 16, 512
w_true = jax.random.normal(key, (d,))
X = jax.random.normal(jax.random.PRNGKey(1), (n, d))
y_true = (X @ w_true > 0).astype(jnp.int32)
corrupted = jnp.arange(n) < int(0.4 * n)
y_noisy = jnp.where(corrupted, 1 - y_true, y_true)
Xm = jax.random.normal(jax.random.PRNGKey(2), (256, d))
ym = (Xm @ w_true > 0).astype(jnp.int32)

# --- base model: logistic regression; meta learner: MetaWeightNet ----------
def apply_fn(theta, x):
    return x @ theta["w"] + theta["b"]

spec = problems.make_data_optimization_spec(
    problems.softmax_per_example(apply_fn), reweight=True
)
theta0 = {"w": jnp.zeros((d, 2)), "b": jnp.zeros((2,))}
lam0 = problems.init_data_optimization_lam(jax.random.PRNGKey(3), reweight=True)

learner = MetaLearner(
    spec,
    base_opt="adam", base_lr=1e-2,
    meta_opt="adam", meta_lr=1e-2,
    method="sama", unroll_steps=2,  # the paper's algorithm
)
learner.init(theta0, lam0)

rng = np.random.default_rng(0)

def batches():
    while True:
        idx = rng.integers(0, n, (2, 64))
        midx = rng.integers(0, 256, 64)
        yield ({"x": X[idx], "y": y_noisy[idx]}, {"x": Xm[midx], "y": ym[midx]})

history = learner.fit(batches(), steps=200, log_every=50)
state = learner.state
for h in history:
    print({k: round(v, 4) for k, v in h.items()})

# --- measured telemetry of the step we just trained with -------------------
rec = learner.profile(*next(batches()), warmup=1, repeats=3)
peak = (rec.memory or {}).get("per_device", {}).get("peak_bytes")
peak_mib = f"{peak / 2**20:.1f}" if peak is not None else "n/a"
compile_s = f"{rec.compile_s:.2f}" if rec.compile_s is not None else "n/a"
print(f"measured: {rec.timing.median_us:.0f} us/step (compile {compile_s}s), "
      f"peak {peak_mib} MiB/device")

# --- inspect what the meta learner decided ---------------------------------
logits = apply_fn(state.theta, X)
loss_i = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1), y_noisy[:, None], 1)[:, 0]
w = apply_weight_net(state.lam["reweight"], weight_features(loss_i))
print(f"mean weight on clean samples:     {float(w[~corrupted].mean()):.3f}")
print(f"mean weight on corrupted samples: {float(w[corrupted].mean()):.3f}")
test_acc = float(jnp.mean((jnp.argmax(apply_fn(state.theta, Xm), -1) == ym)))
print(f"clean test accuracy: {test_acc:.3f}")
