"""Appendix D analog: does few-shot meta learning improve with model SCALE
under SAMA?

iMAML-style setup: base level solves a regularized adaptation problem
    theta*(task) = argmin L_task(theta) + (beta/2)||theta - lam||^2
(lam = shared initialization = the meta learner), meta level evaluates the
adapted model on the task's query set. We sweep the adapter width and report
query accuracy — the paper's Fig. 4 question ("can scale replace algorithmic
sophistication?") in miniature.

    PYTHONPATH=src python examples/few_shot_scaling.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import BilevelSpec, EngineConfig, init_state, make_meta_step

D_IN, N_WAY, K_SHOT, K_QUERY = 16, 5, 5, 10
BETA = 1.0


def sample_task(key):
    """A random linear multiclass task: class prototypes + noisy samples."""
    kp, ks, kq = jax.random.split(key, 3)
    protos = jax.random.normal(kp, (N_WAY, D_IN))
    ys = jnp.tile(jnp.arange(N_WAY), K_SHOT)
    yq = jnp.tile(jnp.arange(N_WAY), K_QUERY)
    xs = protos[ys] + 0.3 * jax.random.normal(ks, (N_WAY * K_SHOT, D_IN))
    xq = protos[yq] + 0.3 * jax.random.normal(kq, (N_WAY * K_QUERY, D_IN))
    return {"xs": xs, "ys": ys, "xq": xq, "yq": yq}


def make_net(width):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": jax.random.normal(k1, (D_IN, width)) / np.sqrt(D_IN),
            "w2": jax.random.normal(k2, (width, N_WAY)) / np.sqrt(width),
        }

    def apply(p, x):
        return jax.nn.relu(x @ p["w1"]) @ p["w2"]

    return init, apply


def run_width(width, meta_steps=150, seed=0):
    init, apply = make_net(width)

    def ce(p, x, y):
        logp = jax.nn.log_softmax(apply(p, x), -1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    # base: adapt on support with proximity to lam; meta: query loss
    spec = BilevelSpec(
        base_loss=lambda th, lam, b: ce(th, b["xs"], b["ys"])
        + 0.5 * BETA * sum(jnp.sum((th[k] - lam[k]) ** 2) for k in th),
        meta_loss=lambda th, lam, b: ce(th, b["xq"], b["yq"]),
    )
    base_opt = optim.adam(5e-2)
    meta_opt = optim.adam(5e-3)
    step = jax.jit(make_meta_step(spec, base_opt, meta_opt,
                                  EngineConfig(method="sama", unroll_steps=5)))
    lam = init(jax.random.PRNGKey(seed))
    state = init_state(lam, lam, base_opt, meta_opt)

    key = jax.random.PRNGKey(seed + 1)
    for i in range(meta_steps):
        key, kt = jax.random.split(key)
        task = sample_task(kt)
        batches = jax.tree_util.tree_map(lambda x: jnp.tile(x[None], (5,) + (1,) * x.ndim), task)
        # fresh adaptation each task: theta restarts from lam
        state = state._replace(theta=state.lam, base_opt_state=base_opt.init(state.lam))
        state, metrics = step(state, batches, task)

    # evaluate: adapt on 20 fresh tasks, measure query accuracy
    accs = []
    for t in range(20):
        task = sample_task(jax.random.PRNGKey(10_000 + t))
        th, st = state.lam, base_opt.init(state.lam)
        for _ in range(10):
            g = jax.grad(spec.base_scalar)(th, state.lam, task)
            upd, st = base_opt.update(g, st, th)
            th = optim.apply_updates(th, upd)
        pred = jnp.argmax(apply(th, task["xq"]), -1)
        accs.append(float(jnp.mean(pred == task["yq"])))
    return float(np.mean(accs))


def main():
    print(f"{N_WAY}-way {K_SHOT}-shot, SAMA meta-learned initialization (iMAML-style)")
    for width in (8, 32, 128):
        acc = run_width(width)
        print(f"  width {width:4d}: query accuracy {acc:.3f}")
    print("(the paper's Appendix D observation: accuracy grows with width)")


if __name__ == "__main__":
    main()
