"""Continuous-batching serving demo: N staggered mixed-length requests
through ``repro.serve`` (queue -> batcher -> paged cache -> executor),
with per-request latency and aggregate QPS (docs/serve.md).

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b
    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b --requests 12
"""

import argparse

import jax
import numpy as np

from repro import configs, serve
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    lens = rng.integers(4, 17, size=args.requests)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(L),)).astype(np.int32)
               for L in lens]

    ex = serve.ServeExecutor(model, params, serve.ServeConfig(
        slots=args.slots, page_size=8, max_len=64, max_new_tokens=args.gen))
    ids = [ex.submit(p) for p in prompts]  # staggered: admitted as slots free
    stats = ex.run()

    print(f"arch={cfg.name} requests={args.requests} slots={args.slots} "
          f"decode_steps={stats.steps}")
    for rid, L in zip(ids, lens):
        r = ex.results[rid]
        lat = "-" if r.latency_s is None else f"{r.latency_s * 1e3:8.1f}ms"
        print(f"  req {rid}: prompt_len={int(L):2d} status={r.status:<8s} "
              f"latency={lat} tokens={r.tokens[:6]}...")
    lat = stats.latency
    print(f"qps={stats.qps:.2f} p50={lat.p50_us / 1e3:.1f}ms "
          f"p99={lat.p99_us / 1e3:.1f}ms "
          f"cache_peak={stats.memory['peak_bytes'] / 1024:.1f}KiB "
          f"buckets={stats.memory['buckets']}")


if __name__ == "__main__":
    main()
