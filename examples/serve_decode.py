"""Batched serving demo: prefill + greedy decode through the per-family
serve_step (KV cache for attention archs, recurrent state for SSM archs).

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b
    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-9b
"""

import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    args = ap.parse_args()

    import sys
    sys.argv = ["serve", "--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "12", "--gen", "12"]
    serve.main()


if __name__ == "__main__":
    main()
