"""End-to-end driver (paper Sec. 4.1): noisy finetuning of a BERT-style
classifier under weak supervision, with SAMA data reweighting + label
correction — all through ``repro.dataopt.meta_train``.

Pipeline: synthetic corpus -> 5 noisy labeling functions -> majority vote
(WRENCH setup) -> SAMA bilevel training against a small clean dev set ->
test accuracy vs the plain-finetune baseline. Scales from --smoke (default,
CPU-sized) to the full bert-base config with --full.

    PYTHONPATH=src python examples/noisy_finetune.py [--steps 150] [--full]
"""

import argparse
import time

import numpy as np

from repro import configs, data
from repro.core import available_methods
from repro.dataopt import meta_train, model_accuracy, train_plain
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--full", action="store_true", help="full bert-base (needs accelerator)")
    ap.add_argument("--method", default="sama", choices=list(available_methods()))
    ap.add_argument("--label-correct", action="store_true")
    ap.add_argument("--baseline", action="store_true", help="also run plain finetuning")
    args = ap.parse_args()

    cfg = configs.get_config("bert-base") if args.full else configs.get_smoke_config("bert-base")
    model = Model(cfg)

    # --- weak supervision data (paper App. B.1: majority voting) ---
    ccfg = data.ClassificationConfig(num_classes=cfg.num_labels, vocab_size=cfg.vocab_size, seq_len=32)
    train = data.make_classification_dataset(ccfg, 1024, noise=0.0, seed=0)
    train["y"] = data.weak_labels(train["y_true"], cfg.num_labels, num_lfs=5, lf_accuracy=0.65, seed=1)
    dev = data.make_classification_dataset(ccfg, 128, noise=0.0, seed=2)  # small CLEAN dev set
    test = data.make_classification_dataset(ccfg, 1024, noise=0.0, seed=3)
    weak_acc = float(np.mean(train["y"] == train["y_true"]))
    print(f"weak-label accuracy after majority vote: {weak_acc:.3f}")

    t0 = time.time()
    learner = meta_train(
        model, train, dev,
        method=args.method, steps=args.steps, unroll=2,
        reweight=True, correct=args.label_correct, log_every=25,
    )
    print(f"meta-training took {time.time() - t0:.1f}s "
          f"({args.steps * 64 / (time.time() - t0):.0f} samples/s)")

    acc = model_accuracy(model, learner.state.theta, test)
    print(f"{args.method} test accuracy: {acc:.4f} "
          f"(weak-label ceiling without meta learning ~{weak_acc:.3f})")

    if args.baseline:
        theta = train_plain(model, train, steps=args.steps * 2)
        print(f"plain-finetune test accuracy: {model_accuracy(model, theta, test):.4f}")


if __name__ == "__main__":
    main()
