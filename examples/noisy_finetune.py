"""End-to-end driver (paper Sec. 4.1): noisy finetuning of a BERT-style
classifier under weak supervision, with SAMA data reweighting + label
correction.

Pipeline: synthetic corpus -> 5 noisy labeling functions -> majority vote
(WRENCH setup) -> SAMA bilevel training against a small clean dev set ->
test accuracy vs the plain-finetune baseline. Scales from --smoke (default,
CPU-sized) to the full bert-base config with --full.

    PYTHONPATH=src python examples/noisy_finetune.py [--steps 150] [--full]
"""

import argparse
import time

import jax
import numpy as np

from repro import configs, data
from repro.api import MetaLearner
from repro.core import available_methods, problems
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--full", action="store_true", help="full bert-base (needs accelerator)")
    ap.add_argument("--method", default="sama", choices=list(available_methods()))
    ap.add_argument("--label-correct", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_config("bert-base") if args.full else configs.get_smoke_config("bert-base")
    model = Model(cfg)

    # --- weak supervision data (paper App. B.1: majority voting) ---
    ccfg = data.ClassificationConfig(num_classes=cfg.num_labels, vocab_size=cfg.vocab_size, seq_len=32)
    train = data.make_classification_dataset(ccfg, 1024, noise=0.0, seed=0)
    train["y"] = data.weak_labels(train["y_true"], cfg.num_labels, num_lfs=5, lf_accuracy=0.65, seed=1)
    dev = data.make_classification_dataset(ccfg, 128, noise=0.0, seed=2)  # small CLEAN dev set
    test = data.make_classification_dataset(ccfg, 1024, noise=0.0, seed=3)
    weak_acc = float(np.mean(train["y"] == train["y_true"]))
    print(f"weak-label accuracy after majority vote: {weak_acc:.3f}")

    spec = problems.make_data_optimization_spec(
        model.classifier_per_example, reweight=True, correct=args.label_correct
    )
    lam = problems.init_data_optimization_lam(
        jax.random.PRNGKey(1), reweight=True, correct=args.label_correct,
        num_classes=cfg.num_labels,
    )
    learner = MetaLearner(
        spec, base_opt="adam", base_lr=1e-3, meta_opt="adam", meta_lr=1e-3,
        method=args.method, unroll_steps=2,
    )
    learner.init(model.init(jax.random.PRNGKey(0)), lam)

    it = data.BatchIterator(train, dev, batch_size=32, meta_batch_size=32, unroll=2, seed=0)
    t0 = time.time()
    hist = learner.fit(it, args.steps, log_every=25)
    state = learner.state
    for h in hist:
        print({k: round(v, 4) for k, v in h.items()})
    print(f"meta-training took {time.time() - t0:.1f}s "
          f"({args.steps * 64 / (time.time() - t0):.0f} samples/s)")

    # --- evaluation ---
    import jax.numpy as jnp

    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    correct = 0
    for i in range(0, len(test["tokens"]), 128):
        logits = fwd(state.theta, {"tokens": jnp.asarray(test["tokens"][i : i + 128])})
        correct += int((np.asarray(jnp.argmax(logits, -1)) == test["y_true"][i : i + 128]).sum())
    print(f"{args.method} test accuracy: {correct / len(test['tokens']):.4f} "
          f"(weak-label ceiling without meta learning ~{weak_acc:.3f})")


if __name__ == "__main__":
    main()
