"""Data pruning with meta-learned importance weights (paper Sec. 4.3),
through the ``repro.dataopt`` subsystem.

SAMA + MetaWeightNet(loss, uncertainty) learn per-sample importance using
train data in BOTH levels (no validation set), then the lowest-score
fraction is pruned and a model is retrained from scratch on the remainder.
``--scorer`` swaps the scoring arm (meta / el2n / grand / margin / loss /
random) with no other change — that's the point of the subsystem.

    PYTHONPATH=src python examples/data_pruning.py [--ratio 0.3] [--scorer meta]
"""

import argparse

from repro import configs, data
from repro.dataopt import DataOptimizer, available_scorers
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ratio", type=float, default=0.3)
    ap.add_argument("--scorer", default="meta", choices=list(available_scorers()))
    ap.add_argument("--meta-steps", type=int, default=80)
    ap.add_argument("--retrain-steps", type=int, default=150)
    ap.add_argument("--class-balanced", action="store_true")
    args = ap.parse_args()

    ccfg = data.ClassificationConfig(num_classes=4, vocab_size=512, seq_len=32)
    train = data.make_classification_dataset(ccfg, 512, noise=0.25, seed=0)
    test = data.make_classification_dataset(ccfg, 512, noise=0.0, seed=1)
    model = Model(configs.get_smoke_config("bert-base"))

    # the meta scorer's knobs are ignored by the heuristic scorers
    knobs = dict(method="sama", unroll=2, uncertainty="entropy",
                 steps=args.meta_steps, log_every=20) if args.scorer == "meta" else {}
    opt = DataOptimizer(model, train, meta=train, scorer=args.scorer, **knobs)

    w = opt.fit_scores()
    bad = train["corrupted"]
    print(f"{args.scorer} scores: clean={w[~bad].mean():.3f} noisy={w[bad].mean():.3f}")

    pruned, mask = opt.prune(args.ratio, class_balanced=args.class_balanced)
    print(f"pruned {args.ratio:.0%}; noisy fraction kept: "
          f"{pruned['corrupted'].mean():.3f} (before: {bad.mean():.3f})")

    acc_full = opt.evaluate(opt.retrain(steps=args.retrain_steps), test)
    acc_pruned = opt.evaluate(opt.retrain(steps=args.retrain_steps, mask=mask), test)
    rnd = DataOptimizer(model, train, scorer="random")
    _, rnd_mask = rnd.prune(args.ratio)
    acc_random = opt.evaluate(opt.retrain(steps=args.retrain_steps, mask=rnd_mask), test)
    print(f"test acc  full-data: {acc_full:.4f}  {args.scorer}-pruned: {acc_pruned:.4f}  "
          f"random-pruned: {acc_random:.4f}")

    path = opt.export(f"out/scores_{args.scorer}", mask=mask)
    print(f"scores + mask exported to {path}")


if __name__ == "__main__":
    main()
