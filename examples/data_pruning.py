"""Data pruning with meta-learned importance weights (paper Sec. 4.3).

SAMA + MetaWeightNet(loss, uncertainty) learn per-sample importance using
train data in BOTH levels (no validation set), then the lowest-weight
fraction is pruned and a model is retrained from scratch on the remainder.

    PYTHONPATH=src python examples/data_pruning.py [--ratio 0.3]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import data, optim
from repro.core import Engine, EngineConfig, problems
from repro.core.meta_modules import apply_weight_net, weight_features
from repro.models import Model
from repro import configs


def train_plain(model, train, steps, seed=0):
    theta = model.init(jax.random.PRNGKey(seed))
    opt = optim.adam(1e-3)
    st = opt.init(theta)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(p, s, b):
        g = jax.grad(lambda pp: jnp.mean(model.classifier_per_example(pp, b).loss))(p)
        upd, s = opt.update(g, s, p)
        return optim.apply_updates(p, upd), s

    for _ in range(steps):
        idx = rng.integers(0, len(train["tokens"]), 32)
        theta, st = step(theta, st, {"tokens": jnp.asarray(train["tokens"][idx]),
                                     "y": jnp.asarray(train["y"][idx])})
    return theta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ratio", type=float, default=0.3)
    ap.add_argument("--meta-steps", type=int, default=80)
    ap.add_argument("--retrain-steps", type=int, default=150)
    args = ap.parse_args()

    ccfg = data.ClassificationConfig(num_classes=4, vocab_size=512, seq_len=32)
    train = data.make_classification_dataset(ccfg, 512, noise=0.25, seed=0)
    test = data.make_classification_dataset(ccfg, 512, noise=0.0, seed=1)
    cfg = configs.get_smoke_config("bert-base")
    model = Model(cfg)

    # --- meta-learn importance (uncertainty-aware MWN, train data both levels)
    spec = problems.make_data_optimization_spec(
        model.classifier_per_example, reweight=True, use_uncertainty=True
    )
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1), reweight=True,
                                              use_uncertainty=True)
    eng = Engine(spec, base_opt=optim.adam(1e-3), meta_opt=optim.adam(1e-3),
                 cfg=EngineConfig(method="sama", unroll_steps=2))
    state = eng.init(model.init(jax.random.PRNGKey(0)), lam)
    it = data.BatchIterator(train, train, batch_size=32, meta_batch_size=32, unroll=2)
    state, _ = eng.run(state, it, num_meta_steps=args.meta_steps, log_every=20)

    pe = jax.jit(model.classifier_per_example)(
        state.theta, {"tokens": jnp.asarray(train["tokens"]), "y": jnp.asarray(train["y"])})
    w = np.asarray(apply_weight_net(
        state.lam["reweight"], weight_features(pe.loss, pe.uncertainty)))
    bad = train["corrupted"]
    print(f"learned weights: clean={w[~bad].mean():.3f} noisy={w[bad].mean():.3f}")

    # --- prune & retrain ---
    keep = np.argsort(-w)[: int(len(w) * (1 - args.ratio))]
    pruned = {k: v[keep] for k, v in train.items()}
    frac_noisy_kept = float(pruned["corrupted"].mean())
    print(f"pruned {args.ratio:.0%}; noisy fraction kept: {frac_noisy_kept:.3f} "
          f"(before: {bad.mean():.3f})")

    def evaluate(theta):
        fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
        preds = []
        for i in range(0, 512, 128):
            preds.append(np.asarray(jnp.argmax(
                fwd(theta, {"tokens": jnp.asarray(test["tokens"][i:i+128])}), -1)))
        return float((np.concatenate(preds) == test["y_true"]).mean())

    acc_full = evaluate(train_plain(model, train, args.retrain_steps))
    acc_pruned = evaluate(train_plain(model, pruned, args.retrain_steps))
    rng = np.random.default_rng(0)
    rnd = rng.permutation(len(w))[: len(keep)]
    acc_random = evaluate(train_plain(model, {k: v[rnd] for k, v in train.items()},
                                      args.retrain_steps))
    print(f"test acc  full-data: {acc_full:.4f}  sama-pruned: {acc_pruned:.4f}  "
          f"random-pruned: {acc_random:.4f}")


if __name__ == "__main__":
    main()
