"""ISSUE 10: request-level lifecycle tracing, TTFT/TPOT/goodput
accounting, the serving flight recorder + hang watchdog, and burn-rate
SLO alerting.

The load-bearing invariant pinned here: every request's timeline
reconstructs end-to-end from the event stream alone — first event
``enqueued``, monotone timestamps, lifecycle stages in order, and
exactly ONE terminal event per ``trace_id`` whose name is pinned
against ``ServeExecutor.TERMINAL_EVENT`` — including for mid-flight
deadline sheds and the nonfinite->serial-fallback path, where a lane
dies in ways the happy path never exercises.
"""

import dataclasses
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro import obs as obs_mod
from repro.obs import events as events_mod
from repro.obs import flight as flight_mod
from repro.obs import health as health_mod
from repro.obs import report as report_mod
from repro.obs import diff as diff_mod
from repro.models import Model


class FakeClock:
    """Deterministic auto-advancing clock for deadline tests."""

    def __init__(self, dt=0.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke_config(arch)
            m = Model(cfg)
            cache[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[arch]

    return get


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)


def ring_obs(capacity=4096, monitor=True):
    sink = events_mod.RingSink(capacity)
    return obs_mod.Obs(sink=sink, monitor=monitor), sink


def ev(kind, name, data=None, t=None):
    e = events_mod.make_event(kind, name, data=data)
    if t is not None:
        e = dataclasses.replace(e, t=t)
    return e


def _terminals(events, trace_id):
    return [e for e in events
            if e.kind == "serve" and e.name in report_mod.TERMINAL_NAMES
            and e.data.get("trace_id") == trace_id]


# ---------------------------------------------------------------------------
# lifecycle tracing: complete ordered timelines per trace_id
# ---------------------------------------------------------------------------


def test_ok_requests_have_complete_ordered_timelines(models):
    cfg, m, params = models("gemma3-1b")
    obs, sink = ring_obs()
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=16, max_new_tokens=3), obs=obs)
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(4)]
    ex.run()
    events = sink.events()

    assert report_mod.validate_timelines(events) == []
    timelines = report_mod.serve_timelines(events)
    assert len(timelines) == 4
    for i in ids:
        r = ex.results[i]
        assert r.trace_id in timelines
        names = [e.name for e in timelines[r.trace_id]]
        # happy path walks the full lifecycle
        assert names[0] == "enqueued"
        for stage in ("admitted", "prefill_start", "first_token", "token"):
            assert stage in names
        assert names[-1] == serve.ServeExecutor.TERMINAL_EVENT[r.status]
        assert len(_terminals(events, r.trace_id)) == 1
        # terminal event carries the derived latency splits
        term = timelines[r.trace_id][-1]
        for key in ("ttft_us", "tpot_us", "queue_wait_us", "resident_us"):
            assert term.data.get(key) is not None, key
        assert term.data["ttft_us"] <= term.data["resident_us"]


def test_deadline_shed_midflight_timelines(models):
    """A request shed mid-decode still ends in exactly one terminal
    (``deadline_miss``), and its partial lifecycle stays ordered."""

    cfg, m, params = models("gemma3-1b")
    obs, sink = ring_obs()
    clock = FakeClock(dt=1.0)
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16, max_new_tokens=4),
        clock=clock, obs=obs)
    first = ex.submit(_prompt(cfg, 4, seed=0))  # no deadline
    late = [ex.submit(_prompt(cfg, 4, seed=i), timeout_s=2.0)
            for i in range(1, 4)]
    ex.run()
    events = sink.events()

    assert report_mod.validate_timelines(events) == []
    assert ex.results[first].status == serve.STATUS_OK
    for i in late:
        r = ex.results[i]
        assert r.status == serve.STATUS_SHED_DEADLINE
        terms = _terminals(events, r.trace_id)
        assert [e.name for e in terms] == ["deadline_miss"]
        assert terms[0].name == serve.ServeExecutor.TERMINAL_EVENT[r.status]
        # resident time is recorded even though the request never finished
        assert terms[0].data.get("resident_us") is not None


def test_nonfinite_fallback_timelines(models):
    """The serial-fallback path retires lanes outside the normal decode
    loop — its requests must still close their timelines exactly once."""

    cfg, m, _ = models("gemma3-1b")
    params = m.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda x: jnp.full_like(x, jnp.inf),
                                    params)
    obs, sink = ring_obs()
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=16, max_new_tokens=3), obs=obs)
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(2)]
    ex.run()
    events = sink.events()

    assert report_mod.validate_timelines(events) == []
    for i in ids:
        r = ex.results[i]
        assert r.status in (serve.STATUS_FALLBACK, serve.STATUS_ERROR)
        terms = _terminals(events, r.trace_id)
        assert len(terms) == 1
        assert terms[0].name == serve.ServeExecutor.TERMINAL_EVENT[r.status]


def test_overflow_shed_timeline_reconstructs(models):
    """Requests shed at submit (queue overflow) never reach the executor
    loop, but the queue emits ``enqueued`` BEFORE the overflow check so
    even they have a reconstructible timeline."""

    cfg, m, params = models("gemma3-1b")
    obs, sink = ring_obs()
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16, max_new_tokens=2, queue_depth=2),
        obs=obs)
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(5)]
    ex.run()
    events = sink.events()

    assert report_mod.validate_timelines(events) == []
    shed = [ex.results[i] for i in ids
            if ex.results[i].status == serve.STATUS_SHED_OVERFLOW]
    assert len(shed) == 3
    for r in shed:
        names = [e.name for e in
                 report_mod.serve_timelines(events)[r.trace_id]]
        assert names[0] == "enqueued"
        assert names[-1] == "shed"


def test_validate_timelines_catches_broken_streams():
    tid = "aaaa000011112222"

    def serve_ev(name, t, **data):
        return ev("serve", name, data={"trace_id": tid, **data}, t=t)

    # missing enqueued
    errs = report_mod.validate_timelines(
        [serve_ev("admitted", 1.0), serve_ev("done", 2.0)])
    assert any("enqueued" in e for e in errs)

    # two terminals
    errs = report_mod.validate_timelines(
        [serve_ev("enqueued", 1.0), serve_ev("done", 2.0),
         serve_ev("done", 3.0)])
    assert any("terminal" in e for e in errs)

    # no terminal
    errs = report_mod.validate_timelines(
        [serve_ev("enqueued", 1.0), serve_ev("admitted", 2.0)])
    assert any("terminal" in e for e in errs)

    # non-monotone timestamps
    errs = report_mod.validate_timelines(
        [serve_ev("enqueued", 2.0), serve_ev("admitted", 1.0),
         serve_ev("done", 3.0)])
    assert any("monotone" in e or "timestamp" in e for e in errs)

    # stage order violated (first_token before prefill_start)
    errs = report_mod.validate_timelines(
        [serve_ev("enqueued", 1.0), serve_ev("admitted", 2.0),
         serve_ev("first_token", 3.0), serve_ev("prefill_start", 4.0),
         serve_ev("done", 5.0)])
    assert any("order" in e for e in errs)

    # a complete well-formed stream validates clean
    errs = report_mod.validate_timelines(
        [serve_ev("enqueued", 1.0), serve_ev("admitted", 2.0),
         serve_ev("prefill_start", 3.0), serve_ev("first_token", 4.0),
         serve_ev("token", 5.0), serve_ev("done", 6.0)])
    assert errs == []


def test_terminal_names_pin_executor_vocabulary():
    """report.TERMINAL_NAMES is the offline mirror of the executor's
    TERMINAL_EVENT values — drift blinds timeline validation."""

    assert set(serve.ServeExecutor.TERMINAL_EVENT.values()) \
        <= set(report_mod.TERMINAL_NAMES)


# ---------------------------------------------------------------------------
# TTFT / TPOT / queue-wait / resident accounting
# ---------------------------------------------------------------------------


def test_request_result_latency_properties():
    r = serve.RequestResult(
        id=0, status=serve.STATUS_OK, tokens=[1, 2, 3], submit_t=1.0,
        admitted_t=2.0, finish_t=7.0, resolved_t=7.0, first_token_t=3.0)
    assert r.ttft_s == pytest.approx(2.0)
    assert r.tpot_s == pytest.approx((7.0 - 3.0) / 2)
    assert r.resident_s == pytest.approx(6.0)
    assert r.queue_s == pytest.approx(1.0)

    # one token: inter-token latency is undefined, not div-by-zero
    one = serve.RequestResult(
        id=1, status=serve.STATUS_OK, tokens=[1], submit_t=0.0,
        resolved_t=2.0, first_token_t=1.0)
    assert one.tpot_s is None

    # never produced a token (e.g. shed while queued)
    shed = serve.RequestResult(
        id=2, status=serve.STATUS_SHED_DEADLINE, tokens=[], submit_t=0.0,
        resolved_t=4.0)
    assert shed.ttft_s is None and shed.tpot_s is None
    assert shed.resident_s == pytest.approx(4.0)


def test_resident_time_recorded_for_every_terminal(models):
    """The seed recorded ``serve_request_us`` only for requests carrying
    ``latency_s`` — sheds were invisible to the latency histogram. Now
    every terminal status records queue-resident time."""

    cfg, m, params = models("gemma3-1b")
    obs, _ = ring_obs()
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16, max_new_tokens=2, queue_depth=2),
        obs=obs)
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(5)]
    ex.run()
    # 2 ok + 3 overflow-shed: ALL five land in the histogram
    hist = obs.metrics.get("serve_request_us")
    assert hist.n == 5
    for i in ids:
        assert ex.results[i].resolved_t is not None
        assert ex.results[i].resident_s >= 0.0


def test_executor_stats_ttft_tpot_lanes(models):
    cfg, m, params = models("gemma3-1b")
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=16, max_new_tokens=3))
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(4)]
    stats = ex.run()
    assert stats.ttft.n == 4 and stats.tpot.n == 4
    assert stats.ttft.p50_us > 0 and stats.tpot.p50_us > 0
    assert len(stats.lanes) == 2
    for lane in stats.lanes:
        assert set(lane) == {"slot", "useful_ticks", "trash_ticks",
                             "tokens", "goodput"}
        assert lane["goodput"] is None or 0.0 <= lane["goodput"] <= 1.0
    # all lanes busy the whole run -> perfect goodput
    assert all(lane["goodput"] == 1.0 for lane in stats.lanes)
    assert all(ex.results[i].slot is not None for i in ids)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_flight_ring_bounds_and_counts_drops():
    fr = flight_mod.FlightRecorder(capacity=4)
    for i in range(10):
        fr.record("serve", "token", data={"i": i})
    evs = fr.events()
    assert len(evs) == 4
    assert [e.data["i"] for e in evs] == [6, 7, 8, 9]  # oldest evicted
    assert fr.dropped == 6


def test_flight_dump_bundle_and_throttle(tmp_path):
    clock = FakeClock(dt=0.0)
    fr = flight_mod.FlightRecorder(capacity=16, out_dir=str(tmp_path),
                                   min_interval_s=5.0, clock=clock)
    fr.record("serve", "enqueued", data={"trace_id": "t1"})
    fr.record_snapshot({"queue_depth": 3})
    fr.add_state_provider("queue", lambda: {"depth": 3})
    fr.add_state_provider("broken", lambda: 1 / 0)

    bundle = fr.dump(flight_mod.REASON_HANG, detail="no progress")
    assert bundle is not None
    assert flight_mod.validate_bundle(bundle) == []
    assert bundle["trigger"]["reason"] == "hang"
    assert [e["name"] for e in bundle["events"]] == ["enqueued"]
    assert bundle["metrics_snapshots"][0]["queue_depth"] == 3
    assert bundle["state"]["queue"] == {"depth": 3}
    # a raising provider degrades to an error string, not a failed dump
    assert "failed" in bundle["state"]["broken"]

    # throttled: same reason within min_interval_s
    assert fr.dump(flight_mod.REASON_HANG) is None
    # different reason and force both bypass the throttle
    assert fr.dump(flight_mod.REASON_EXCEPTION) is not None
    assert fr.dump(flight_mod.REASON_HANG, force=True) is not None
    clock.t = 100.0
    assert fr.dump(flight_mod.REASON_HANG) is not None

    # every dump landed as an atomic file the loader round-trips
    assert len(fr.dumps) == 4
    for path in fr.dumps:
        assert os.path.exists(path)
        loaded = flight_mod.load_bundle(path)
        assert flight_mod.validate_bundle(loaded) == []
    assert not glob.glob(str(tmp_path / "*.tmp"))


def test_flight_validate_bundle_rejects_garbage():
    assert flight_mod.validate_bundle([]) != []
    assert any("v" in e for e in flight_mod.validate_bundle(
        {"kind": "postmortem"}))
    bad_event = {"v": 1, "kind": "postmortem",
                 "trigger": {"reason": "hang", "t": 1.0},
                 "events": [{"nope": 1}], "dropped": 0,
                 "metrics_snapshots": [], "state": {}}
    assert any("events[0]" in e for e in flight_mod.validate_bundle(bad_event))


def test_flight_attach_dumps_on_degraded_alert():
    monitor = health_mod.ServeSLOMonitor(
        window=20, min_events=4, warn_rate=2.0, degraded_rate=0.5)
    obs = obs_mod.Obs(sink=events_mod.RingSink(64),
                      health=health_mod.HealthMonitor(monitors=[monitor]))
    fr = flight_mod.FlightRecorder(capacity=16)
    fr.attach(obs)
    for _ in range(6):
        obs.emit("serve", "deadline_miss", data={"trace_id": "x"})
    assert fr.last_bundle is not None
    assert fr.last_bundle["trigger"]["reason"] == flight_mod.REASON_ALERT
    assert "serve_slo" in fr.last_bundle["trigger"]["detail"]


def test_executor_flight_always_on_without_obs(models):
    """The postmortem ring runs with NO obs pipeline configured — the
    crashed run that never set up logging is the one that needs it."""

    cfg, m, params = models("gemma3-1b")
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=16, max_new_tokens=3))
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(3)]
    ex.run()
    assert ex.flight is not None
    ring = ex.flight.events()
    assert ring, "flight ring must capture lifecycle events without obs"
    # full timelines reconstruct from the ring alone
    assert report_mod.validate_timelines(ring) == []
    assert {ex.results[i].trace_id for i in ids} \
        <= set(report_mod.serve_timelines(ring))

    # and flight_capacity=0 opts out entirely
    ex2 = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16, flight_capacity=0))
    assert ex2.flight is None


def test_executor_inject_hang_produces_postmortem(models, tmp_path):
    """Fault injection end-to-end: a stalled tick loop trips the
    watchdog thread, which dumps a validatable bundle mid-hang."""

    cfg, m, params = models("gemma3-1b")
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16, max_new_tokens=3,
        flight_dir=str(tmp_path), hang_deadline_s=0.15))
    ex.inject_hang(0.7)
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(2)]
    ex.run()
    # the run still completes after the stall...
    assert all(ex.results[i].status == serve.STATUS_OK for i in ids)
    # ...but the watchdog fired and froze a bundle while it was stuck
    paths = glob.glob(str(tmp_path / "postmortem-hang-*.json"))
    assert len(paths) == 1
    bundle = flight_mod.load_bundle(paths[0])
    assert flight_mod.validate_bundle(bundle) == []
    assert bundle["trigger"]["reason"] == flight_mod.REASON_HANG
    assert bundle["events"], "bundle must carry the recent event ring"
    assert "queue" in bundle["state"] and "lanes" in bundle["state"]


# ---------------------------------------------------------------------------
# hang watchdog
# ---------------------------------------------------------------------------


def test_hang_watchdog_fires_once_and_rearms():
    fired = []
    t = [0.0]
    wd = flight_mod.HangWatchdog(1.0, fired.append, clock=lambda: t[0])
    assert not wd.check()          # fresh: no stall
    t[0] = 0.9
    assert not wd.check()          # within deadline
    t[0] = 1.5
    assert wd.check()              # stalled past deadline -> fires
    assert fired == [pytest.approx(1.5)]
    t[0] = 3.0
    assert not wd.check()          # same stall: at most one fire
    wd.beat()                      # progress re-arms
    t[0] = 5.0
    assert wd.check()              # second stall fires again
    assert wd.fires == 2 and wd.beats == 1


def test_hang_watchdog_rejects_bad_deadline():
    with pytest.raises(ValueError):
        flight_mod.HangWatchdog(0.0, lambda s: None)


# ---------------------------------------------------------------------------
# burn-rate SLO alerting
# ---------------------------------------------------------------------------


def _miss():
    return ev("serve", "deadline_miss", data={"trace_id": "x"})


def _done():
    return ev("serve", "done", data={"trace_id": "x"})


def test_slo_burn_rate_alerts_once_per_episode():
    # plain-rate thresholds disabled (rates can't exceed 2.0) so only
    # the burn-rate path fires
    mon = health_mod.ServeSLOMonitor(
        window=20, min_events=5, warn_rate=2.0, degraded_rate=2.0,
        budget=0.05, fast_window=5, burn_threshold=4.0)

    alerts = []
    for _ in range(5):
        alerts += mon.observe(_done())
    assert alerts == []  # healthy baseline

    for _ in range(5):
        alerts += mon.observe(_miss())
    burn = [a for a in alerts if "burn" in a.message]
    assert len(burn) == 1 and burn[0].severity == "degraded"
    assert burn[0].data["fast_rate"] >= 4.0 * 0.05
    assert burn[0].data["slow_rate"] >= 4.0 * 0.05

    # sustained burn: still one alert for the episode
    for _ in range(5):
        alerts += mon.observe(_miss())
    assert len([a for a in alerts if "burn" in a.message]) == 1

    # recovery drains the fast window below the burn line -> re-arm
    for _ in range(5):
        alerts += mon.observe(_done())
    # second episode alerts again
    for _ in range(5):
        alerts += mon.observe(_miss())
    assert len([a for a in alerts if "burn" in a.message]) == 2
    assert mon.burn_alerts == 2
    v = mon.verdict()
    assert v["budget"] == 0.05 and v["burn_alerts"] == 2


def test_slo_burn_rate_requires_budget():
    mon = health_mod.ServeSLOMonitor(window=20, min_events=5,
                                     warn_rate=2.0, degraded_rate=2.0)
    alerts = []
    for _ in range(30):
        alerts += mon.observe(_miss())
    assert alerts == []  # no budget -> burn mode off
    assert "budget" not in mon.verdict()


def test_make_obs_slo_budget_arms_burn_mode():
    obs = obs_mod.make_obs(ring=16, slo_budget=0.05)
    slo = [m for m in obs.health.monitors
           if isinstance(m, health_mod.ServeSLOMonitor)]
    assert len(slo) == 1 and slo[0].budget == 0.05
    # default monitors stay budget-less
    default = obs_mod.make_obs(ring=16)
    slo = [m for m in default.health.monitors
           if isinstance(m, health_mod.ServeSLOMonitor)]
    assert slo[0].budget is None


# ---------------------------------------------------------------------------
# emit_teed: one event, two destinations
# ---------------------------------------------------------------------------


def test_emit_teed_reuses_event_and_runs_without_obs():
    obs, sink = ring_obs(monitor=False)
    fr = flight_mod.FlightRecorder(capacity=8)
    flight_mod.emit_teed(obs, fr, "serve", "enqueued",
                         data={"trace_id": "t1"})
    assert len(sink.events()) == 1 and len(fr.events()) == 1
    assert sink.events()[0] is fr.events()[0]  # built once, teed

    # obs disabled: constructed only for the ring
    fr2 = flight_mod.FlightRecorder(capacity=8)
    flight_mod.emit_teed(obs_mod.NULL_OBS, fr2, "serve", "enqueued",
                         data={"trace_id": "t2"})
    assert len(fr2.events()) == 1
    assert fr2.events()[0].data["trace_id"] == "t2"

    # neither: a no-op
    flight_mod.emit_teed(obs_mod.NULL_OBS, None, "serve", "enqueued")


# ---------------------------------------------------------------------------
# report: latency percentiles, goodput table, postmortem rendering
# ---------------------------------------------------------------------------


def _lifecycle_events(n_ok=3, n_miss=1):
    """A synthetic, fully-formed serve stream with known latencies."""

    out = []
    t = 0.0
    for i in range(n_ok + n_miss):
        tid = f"{i:016x}"
        ok = i < n_ok
        out.append(ev("serve", "enqueued", {"trace_id": tid}, t=t))
        out.append(ev("serve", "admitted",
                      {"trace_id": tid, "queue_wait_us": 1000.0}, t=t + 0.001))
        out.append(ev("serve", "prefill_start", {"trace_id": tid}, t=t + 0.002))
        if ok:
            out.append(ev("serve", "first_token",
                          {"trace_id": tid, "slot": i % 2,
                           "ttft_us": 3000.0}, t=t + 0.003))
            out.append(ev("serve", "done",
                          {"trace_id": tid, "status": "ok", "tokens": 4,
                           "slot": i % 2, "ttft_us": 3000.0 + i,
                           "tpot_us": 500.0 + i, "queue_wait_us": 1000.0,
                           "resident_us": 5000.0 + i}, t=t + 0.005))
        else:
            out.append(ev("serve", "deadline_miss",
                          {"trace_id": tid, "status": "shed_deadline",
                           "resident_us": 2500.0}, t=t + 0.004))
        t += 0.01
    out.append(ev("serve", "lane_stats", {"lanes": [
        {"slot": 0, "useful_ticks": 8, "trash_ticks": 2, "tokens": 8,
         "goodput": 0.8},
        {"slot": 1, "useful_ticks": 6, "trash_ticks": 4, "tokens": 6,
         "goodput": 0.6}]}, t=t))
    return out


def test_report_serve_latency_and_goodput_sections():
    events = _lifecycle_events()
    summary = report_mod.summarize(events)
    sv = summary["serve"]
    assert sv["ttft_us"]["n"] == 3
    assert sv["tpot_us"]["n"] == 3
    assert sv["queue_wait_us"]["n"] == 3
    assert sv["resident_us"]["n"] == 4  # sheds counted too
    assert {"p50", "p90", "p99"} <= set(sv["ttft_us"])
    assert [lane["goodput"] for lane in sv["lanes"]] == [0.8, 0.6]
    assert sv["traces"] == 4 and sv["trace_errors"] == []

    text = report_mod.render(summary)
    assert "ttft" in text and "tpot" in text and "queue wait" in text
    assert "goodput" in text
    assert "4 request timelines (OK)" in text


def test_report_flags_broken_timelines_in_render():
    events = _lifecycle_events()
    # drop one terminal: that trace never closes
    events = [e for e in events
              if not (e.name == "done" and e.data["trace_id"] == f"{0:016x}")]
    summary = report_mod.summarize(events)
    assert summary["serve"]["trace_errors"] != []
    assert "BROKEN" in report_mod.render(summary)


def test_report_postmortem_render_and_cli(tmp_path, capsys):
    fr = flight_mod.FlightRecorder(capacity=16, out_dir=str(tmp_path))
    for e in _lifecycle_events(n_ok=1, n_miss=0)[:-2]:  # open trace
        fr.write(e)
    fr.record_snapshot({"queue_depth": 2})
    fr.add_state_provider("queue", lambda: {"depth": 2})
    fr.dump(flight_mod.REASON_EXCEPTION, detail="RuntimeError('boom')")
    path = fr.dumps[0]

    text = report_mod.render_postmortem(flight_mod.load_bundle(path))
    assert "exception" in text and "boom" in text
    assert "still open" in text  # the hang-suspect line
    assert "queue_depth" in text

    # CLI: --postmortem --validate exits 0 on a good bundle
    assert report_mod.main([path, "--postmortem", "--validate"]) == 0
    capsys.readouterr()
    # and non-zero on a corrupt one
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"v": 99}))
    assert report_mod.main([str(bad), "--postmortem", "--validate"]) == 1


# ---------------------------------------------------------------------------
# chrome trace: per-lane request tracks
# ---------------------------------------------------------------------------


def test_lane_chrome_events_render_request_tracks():
    events = _lifecycle_events(n_ok=3, n_miss=0)
    out = obs_mod.lane_chrome_events(events)
    meta = [e for e in out if e["ph"] == "M"]
    spans = [e for e in out if e["ph"] == "X"]
    assert {m["args"]["name"] for m in meta} \
        == {"serve lanes", "lane 0", "lane 1"}
    assert len(spans) == 3
    for s in spans:
        assert s["pid"] == 1 and s["tid"] in (0, 1)
        assert s["ts"] >= 0.0 and s["dur"] >= 0.0
        assert "trace_id" in s["args"]
    # lanes match what first_token reported
    assert sorted(s["tid"] for s in spans) == [0, 0, 1]

    # incomplete requests (no terminal) render nothing rather than lying
    assert obs_mod.lane_chrome_events(events[:3]) == []


def test_write_chrome_trace_merges_lane_events(tmp_path):
    span = obs_mod.Span(name="tick", start_s=0.0, dur_s=0.1, depth=0,
                        parent=None, traced=False)
    lane_events = obs_mod.lane_chrome_events(
        _lifecycle_events(n_ok=2, n_miss=0))
    path = obs_mod.write_chrome_trace(
        str(tmp_path / "trace.json"), [span], extra_events=lane_events)
    doc = json.load(open(path))
    assert len(doc["traceEvents"]) == 1 + len(lane_events)
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert pids == {0, 1}  # host spans + lane tracks


# ---------------------------------------------------------------------------
# diff: serve latency pseudo-phases
# ---------------------------------------------------------------------------


def test_diff_serve_latency_pseudophases(tmp_path):
    def stream(path, ttft, resident):
        evs = [ev("serve", "done", {"trace_id": "t", "status": "ok",
                                    "ttft_us": ttft, "tpot_us": 100.0,
                                    "queue_wait_us": 50.0,
                                    "resident_us": resident})]
        with open(path, "w") as f:
            for e in evs:
                f.write(json.dumps(e.as_dict()) + "\n")
        return str(path)

    base = stream(tmp_path / "base.jsonl", ttft=1000.0, resident=2000.0)
    cur = stream(tmp_path / "cur.jsonl", ttft=3000.0, resident=2000.0)

    costs = diff_mod.phase_costs_from_events(
        events_mod.read_jsonl(base))
    assert costs["serve:ttft"] == 1000.0
    assert costs["serve:resident"] == 2000.0

    rows, unit = diff_mod.diff_paths(base, cur)
    assert unit == "us"
    worst = diff_mod.top_regressor(rows)
    assert worst.phase == "serve:ttft" and worst.ratio == pytest.approx(3.0)

    # unit-mismatch refusal semantics unchanged: events vs FLOPs bench
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps({
        "records": [{"attribution": {"phases": {"x": {"flops": 1.0}}}}]}))
    with pytest.raises(ValueError, match="cannot diff"):
        diff_mod.diff_paths(base, str(bench))


# ---------------------------------------------------------------------------
# score API tracing
# ---------------------------------------------------------------------------


def test_score_api_emits_lifecycle_events(tmp_path):
    from repro.dataopt import export as dataopt_export

    scores = np.linspace(-1.0, 1.0, 10).astype(np.float32)
    path = dataopt_export.export_scores(str(tmp_path / "scores"), scores,
                                        scorer="sama")
    store = serve.ScoreStore.load(path, expect_n=10, expect_scorer="sama")

    obs, sink = ring_obs()
    api = serve.ScoreAPI(store, max_batch=8, obs=obs)
    api.submit([0, 1, 2])
    api.submit([5])
    api.run_pending()
    events = sink.events()
    done = [e for e in events if e.kind == "serve" and e.name == "done"]
    assert len(done) == 2
    assert all(e.data.get("trace_id") for e in done)
    # score requests have (enqueued -> done) timelines that validate
    assert report_mod.validate_timelines(events) == []

    # deadline shed surfaces as its own terminal
    clock = FakeClock()
    obs2, sink2 = ring_obs()
    api2 = serve.ScoreAPI(store, queue_depth=4, default_timeout_s=5.0,
                          clock=clock, obs=obs2)
    api2.submit([1])
    clock.t = 100.0
    api2.run_pending()
    misses = [e for e in sink2.events()
              if e.kind == "serve" and e.name == "deadline_miss"]
    assert len(misses) == 1
    assert misses[0].data.get("resident_us") is not None
    assert report_mod.validate_timelines(sink2.events()) == []
