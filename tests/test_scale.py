"""repro.scale tests: precision policies, dynamic loss scaling, microbatch
accumulation correctness (the ISSUE's acceptance property: M-microbatch
accumulated gradients and SAMA hypergradients equal the full-batch values —
exact in f32 up to summation order, tolerance-bounded in bf16), and the
HBM-budget memory planner. Distributed census pins live in
tests/test_scale_distributed.py (they need 8 forced host devices)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim, scale
from repro.core import EngineConfig, init_state, make_meta_step, problems
from repro.core.engine import EngineState
from repro.launch.distributed import cast_for_reduce
from repro.scale import (
    LossScaleState,
    PrecisionPolicy,
    ScaleConfig,
    accumulate_mean,
    microbatch_value_and_grad,
    split_batch,
)
from repro.scale import policy as policy_mod


# ---------------------------------------------------------------------------
# fixtures: the tiny classifier bilevel problem every core test uses
# ---------------------------------------------------------------------------


def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]


def make_problem(seed=0, d=6, h=16, C=3):
    per_ex = problems.softmax_per_example(apply_fn)
    spec = problems.make_data_optimization_spec(per_ex, reweight=True)
    theta = {
        "w1": jax.random.normal(jax.random.PRNGKey(seed), (d, h)) * 0.3,
        "w2": jax.random.normal(jax.random.PRNGKey(seed + 1), (h, C)) * 0.3,
    }
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(seed + 2), reweight=True)
    return spec, theta, lam


def make_batches(seed, K, B, MB, d=6, C=3):
    bb = {"x": jax.random.normal(jax.random.PRNGKey(seed + 3), (K, B, d)),
          "y": jax.random.randint(jax.random.PRNGKey(seed + 4), (K, B), 0, C)}
    mb = {"x": jax.random.normal(jax.random.PRNGKey(seed + 5), (MB, d)),
          "y": jax.random.randint(jax.random.PRNGKey(seed + 6), (MB,), 0, C)}
    return bb, mb


def leaves_allclose(a, b, rtol, atol):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float64),
                                   np.asarray(y, np.float64),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


def test_builtin_policies():
    f32 = scale.resolve_policy("f32")
    assert f32.is_identity and not f32.dynamic_scaling
    bf16 = scale.resolve_policy("bf16")
    assert bf16.compute_jnp == jnp.bfloat16
    assert bf16.param_jnp == jnp.float32  # master params stay f32
    assert not bf16.dynamic_scaling  # bf16 ships unscaled
    f16 = scale.resolve_policy("f16")
    assert f16.compute_jnp == jnp.float16 and f16.dynamic_scaling
    # growth cap: float16(2^16) == inf, and the backward seed IS the scale
    # cast through the f16 boundary — growing past 2^15 would skip a base
    # step deterministically every growth_interval
    assert f16.max_loss_scale == f16.loss_scale == 2.0 ** 15
    grown = scale.update_scale(scale.init_scale_state(
        dataclasses.replace(f16, growth_interval=1)), jnp.asarray(True),
        dataclasses.replace(f16, growth_interval=1))
    assert float(grown.scale) == 2.0 ** 15  # clamped, not doubled to inf-land
    with pytest.raises(ValueError, match="unknown precision policy"):
        scale.resolve_policy("f8")
    # instances pass through
    assert scale.resolve_policy(f16) is f16


def test_scale_config_validation():
    with pytest.raises(ValueError, match="microbatch"):
        ScaleConfig(microbatch=0)
    with pytest.raises(ValueError, match="unknown precision policy"):
        ScaleConfig(policy="nope")
    assert ScaleConfig().is_identity
    assert not ScaleConfig(microbatch=2).is_identity
    assert not ScaleConfig(policy="bf16").is_identity


def test_cast_floats_leaves_ints_alone():
    tree = {"w": jnp.ones((3,), jnp.float32), "ids": jnp.ones((3,), jnp.int32)}
    out = scale.cast_floats(tree, jnp.bfloat16)
    assert out["w"].dtype == jnp.bfloat16
    assert out["ids"].dtype == jnp.int32


def test_apply_to_spec_casts_compute_and_returns_f32_loss():
    spec, theta, lam = make_problem()
    bb, mb = make_batches(0, 1, 8, 8)
    batch = {"x": bb["x"][0], "y": bb["y"][0]}

    seen = {}

    def probe_loss(th, la, b):
        seen["theta_dtype"] = th["w1"].dtype
        seen["x_dtype"] = b["x"].dtype
        seen["y_dtype"] = b["y"].dtype
        return spec.base_loss(th, la, b)

    from repro.core.bilevel import BilevelSpec

    wrapped = scale.apply_to_spec(BilevelSpec(base_loss=probe_loss, meta_loss=probe_loss),
                                  scale.resolve_policy("bf16"))
    loss = wrapped.base_scalar(theta, lam, batch)
    assert seen["theta_dtype"] == jnp.bfloat16
    assert seen["x_dtype"] == jnp.bfloat16
    assert seen["y_dtype"] == jnp.int32  # labels untouched
    assert loss.dtype == jnp.float32
    # identity policy returns the SAME spec object (paper-exact path)
    assert scale.apply_to_spec(spec, scale.resolve_policy("f32")) is spec


def test_grads_under_policy_are_f32_master_grads():
    spec, theta, lam = make_problem()
    bb, _ = make_batches(0, 1, 8, 8)
    batch = {"x": bb["x"][0], "y": bb["y"][0]}
    wrapped = scale.apply_to_spec(spec, scale.resolve_policy("bf16"))
    g = jax.grad(wrapped.base_scalar)(theta, lam, batch)
    assert all(x.dtype == jnp.float32 for x in jax.tree_util.tree_leaves(g))


# ---------------------------------------------------------------------------
# dynamic loss scale automaton
# ---------------------------------------------------------------------------


def test_update_scale_backoff_and_growth():
    pol = dataclasses.replace(scale.resolve_policy("f16"), growth_interval=2)
    st = scale.init_scale_state(pol)
    assert float(st.scale) == 2.0 ** 15
    # non-finite step: halve, reset streak
    st2 = scale.update_scale(st, jnp.asarray(False), pol)
    assert float(st2.scale) == 2.0 ** 14 and int(st2.good_steps) == 0
    # two finite steps: double once
    st3 = scale.update_scale(st2, jnp.asarray(True), pol)
    st4 = scale.update_scale(st3, jnp.asarray(True), pol)
    assert float(st4.scale) == 2.0 ** 15 and int(st4.good_steps) == 0
    # clamped at the ceiling
    hi = LossScaleState(scale=jnp.asarray(pol.max_loss_scale, jnp.float32),
                        good_steps=jnp.asarray(pol.growth_interval, jnp.int32))
    st5 = scale.update_scale(hi, jnp.asarray(True), pol)
    assert float(st5.scale) == pol.max_loss_scale
    # clamped at the floor
    lo = LossScaleState(scale=jnp.asarray(pol.min_loss_scale, jnp.float32),
                        good_steps=jnp.zeros([], jnp.int32))
    st6 = scale.update_scale(lo, jnp.asarray(False), pol)
    assert float(st6.scale) == pol.min_loss_scale


def test_all_finite():
    assert bool(scale.all_finite({"a": jnp.ones(3), "i": jnp.ones(3, jnp.int32)}))
    assert not bool(scale.all_finite({"a": jnp.array([1.0, jnp.inf])}))
    assert not bool(scale.all_finite({"a": jnp.array([jnp.nan])}))


def test_f16_policy_requires_seeded_scale_state():
    spec, theta, lam = make_problem()
    bb, mb = make_batches(0, 2, 8, 8)
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    cfg = EngineConfig(method="sama", unroll_steps=2,
                       scale=ScaleConfig(policy="f16"))
    # state built WITHOUT the scale config -> clear trace-time error
    state = init_state(theta, lam, base_opt, meta_opt)
    step = make_meta_step(spec, base_opt, meta_opt, cfg)
    with pytest.raises(ValueError, match="LossScaleState"):
        step(state, bb, mb)


def test_f16_nonfinite_step_skips_update_and_backs_off():
    """A loss big enough to overflow the f16 backward pass must leave
    params/lam untouched, halve the scale, and keep metrics finite-free
    drama out of the next step."""

    spec, theta, lam = make_problem()
    bb, mb = make_batches(0, 1, 8, 8)
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    # scale far above f16 max (65504): the scaled cotangents overflow
    pol = dataclasses.replace(scale.resolve_policy("f16"),
                              loss_scale=float(2 ** 30), min_loss_scale=1.0,
                              max_loss_scale=float(2 ** 31))
    cfg = EngineConfig(method="sama", unroll_steps=1,
                       scale=ScaleConfig(policy=pol))
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    step = jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))
    new_state, _ = step(state, bb, mb)
    # scale halved TWICE: the base unroll skipped (2^30 -> 2^29) and the
    # hypergradient path — whose losses are scaled by ctx.loss_scale —
    # also overflowed, so the meta guard backed off again (2^29 -> 2^28)
    assert float(new_state.scale.scale) == float(2 ** 28)
    assert int(new_state.scale.good_steps) == 0
    leaves_allclose(new_state.theta, state.theta, rtol=0, atol=0)
    leaves_allclose(new_state.lam, state.lam, rtol=0, atol=0)


def test_backoff_on_halves_only_on_nonfinite():
    pol = scale.resolve_policy("f16")
    st = LossScaleState(scale=jnp.asarray(2.0 ** 14, jnp.float32),
                        good_steps=jnp.asarray(7, jnp.int32))
    same = scale.backoff_on(st, jnp.asarray(True), pol)
    assert float(same.scale) == 2.0 ** 14 and int(same.good_steps) == 7
    halved = scale.backoff_on(st, jnp.asarray(False), pol)
    assert float(halved.scale) == 2.0 ** 13 and int(halved.good_steps) == 0


def test_sama_local_terms_invariant_under_loss_scale():
    """The hypergradient path scales its meta/CD losses by ctx.loss_scale
    and unscales the results — in f32 the scaling must cancel exactly, so
    terms with and without a live scale agree (the f16 benefit is purely
    about cotangent representability)."""

    from repro.core.engine import make_context, _unroll_base
    from repro.core.methods import resolve_method

    spec, theta, lam = make_problem(11)
    bb, mb = make_batches(11, 2, 16, 8)
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    state = init_state(theta, lam, base_opt, meta_opt)
    th, _, g_base, st_at_g, _, _, _ = _unroll_base(
        spec, base_opt, theta, state.base_opt_state, lam, bb)
    method = resolve_method("sama", EngineConfig())

    def terms_with(ls):
        ctx = make_context(base_opt, state, bb, mb, theta=th,
                           base_opt_state=st_at_g, g_base=g_base,
                           loss_scale=ls)
        return method.local_terms(spec, ctx)

    ref = terms_with(None)
    scaled = terms_with(jnp.asarray(1024.0, jnp.float32))
    for k in ("hypergrad", "meta_loss", "v", "eps"):
        leaves_allclose(scaled[k], ref[k], rtol=1e-5, atol=1e-7)
    # and the staged micro path honors the scale identically
    ctx = make_context(base_opt, state, bb, mb, theta=th,
                       base_opt_state=st_at_g, g_base=g_base,
                       loss_scale=jnp.asarray(1024.0, jnp.float32))
    micro = method.micro_local_terms(spec, ctx, 4, jnp.float32)
    for k in ("hypergrad", "meta_loss", "v", "eps"):
        leaves_allclose(micro[k], ref[k], rtol=2e-5, atol=1e-7)


def test_guarded_meta_update_reports_gate_for_backoff():
    """A non-finite hypergradient must (a) skip lam/moments and (b) come
    back as finite=False so the caller backs the loss scale off —
    otherwise a persistently-overflowing meta path would skip forever."""

    from repro.core.engine import guarded_meta_update

    spec, theta, lam = make_problem()
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    state = init_state(theta, lam, base_opt, meta_opt,
                       scale=ScaleConfig(policy="f16"))
    bad_hyper = jax.tree_util.tree_map(
        lambda x: jnp.full_like(x, jnp.inf), lam)
    new_lam, _, theta_post, ok = guarded_meta_update(
        meta_opt, bad_hyper, theta, state, theta_pre=theta, guard=True)
    assert not bool(ok)
    leaves_allclose(new_lam, lam, rtol=0, atol=0)
    pol = scale.resolve_policy("f16")
    backed = scale.backoff_on(state.scale, ok, pol)
    assert float(backed.scale) == float(state.scale.scale) / 2


def test_f16_policy_trains_and_scale_state_advances():
    spec, theta, lam = make_problem()
    bb, mb = make_batches(0, 2, 8, 8)
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    cfg = EngineConfig(method="sama", unroll_steps=2, scale=ScaleConfig(policy="f16"))
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    step = jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))
    s, m = step(state, bb, mb)
    assert int(s.scale.good_steps) == 2  # both base steps finite
    assert all(np.isfinite(float(v)) for v in m.values())
    moved = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(s.lam), jax.tree_util.tree_leaves(state.lam)))
    assert moved > 0


# ---------------------------------------------------------------------------
# microbatch accumulation primitives
# ---------------------------------------------------------------------------


def test_split_batch_shapes_and_divisibility():
    b = {"x": jnp.zeros((8, 5)), "y": jnp.zeros((8,), jnp.int32)}
    s = split_batch(b, 4)
    assert s["x"].shape == (4, 2, 5) and s["y"].shape == (4, 2)
    with pytest.raises(ValueError, match="not divisible"):
        split_batch(b, 3)
    with pytest.raises(ValueError, match=">= 1"):
        split_batch(b, 0)


def test_accumulate_mean_matches_direct_mean():
    xs = jax.random.normal(jax.random.PRNGKey(0), (12, 7))
    split = split_batch(xs, 4)
    out = accumulate_mean(lambda mb: {"m": jnp.mean(mb, axis=0)}, split, 4, jnp.float32)
    np.testing.assert_allclose(np.asarray(out["m"]), np.asarray(jnp.mean(xs, axis=0)),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("m", [1, 2, 4, 8])
def test_microbatch_value_and_grad_equals_full_batch(m):
    spec, theta, lam = make_problem()
    bb, _ = make_batches(0, 1, 16, 8)
    batch = {"x": bb["x"][0], "y": bb["y"][0]}
    ref_loss, ref_g = jax.value_and_grad(spec.base_scalar)(theta, lam, batch)
    loss, g = microbatch_value_and_grad(spec.base_scalar, theta, lam, batch,
                                        m, jnp.float32)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-6)
    leaves_allclose(g, ref_g, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# the acceptance property: accumulated step == full-batch step
# ---------------------------------------------------------------------------


def run_sama_step(spec, theta, lam, bb, mb, *, m, policy="f32", unroll=2,
                  base_opt_name="adam"):
    base_opt = optim.get_optimizer(base_opt_name, 1e-2)
    meta_opt = optim.adam(1e-2)
    cfg = EngineConfig(method="sama", unroll_steps=unroll,
                       scale=ScaleConfig(policy=policy, microbatch=m))
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    step = jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))
    return step(state, bb, mb)


@pytest.mark.parametrize("m", [2, 4, 8])
@pytest.mark.parametrize("seed", [0, 1])
def test_sama_microbatch_exact_in_f32(m, seed):
    """The staged SAMA micro path (accumulate g_meta -> one v/eps ->
    accumulate the CD delta) reproduces the full-batch estimator exactly
    in f32, up to summation reorder noise — NOT just in expectation."""

    spec, theta, lam = make_problem(seed)
    bb, mb = make_batches(seed, 2, 16, 8)
    s_ref, m_ref = run_sama_step(spec, theta, lam, bb, mb, m=1)
    s_mic, m_mic = run_sama_step(spec, theta, lam, bb, mb, m=m)
    leaves_allclose(s_mic.lam, s_ref.lam, rtol=2e-5, atol=1e-7)
    leaves_allclose(s_mic.theta, s_ref.theta, rtol=2e-5, atol=1e-7)
    for k in ("base_loss", "meta_loss", "eps", "hypergrad_norm"):
        np.testing.assert_allclose(float(m_mic[k]), float(m_ref[k]), rtol=2e-4)


def test_sama_microbatch_exact_with_sgd_and_momentum():
    """The exactness property is optimizer-independent (the adaptation
    product only sees the ACCUMULATED g_meta)."""

    for opt_name in ("sgd", "momentum"):
        spec, theta, lam = make_problem(7)
        bb, mb = make_batches(7, 2, 12, 12)
        s_ref, _ = run_sama_step(spec, theta, lam, bb, mb, m=1,
                                 base_opt_name=opt_name)
        s_mic, _ = run_sama_step(spec, theta, lam, bb, mb, m=4,
                                 base_opt_name=opt_name)
        leaves_allclose(s_mic.lam, s_ref.lam, rtol=2e-5, atol=1e-7)


def test_hypothesis_property_microbatch_exactness():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 50),
           m=st.sampled_from([2, 3, 4, 6]),
           unroll=st.integers(1, 3))
    def prop(seed, m, unroll):
        spec, theta, lam = make_problem(seed)
        bb, mb = make_batches(seed, unroll, 12, 12)  # 12 divisible by 2/3/4/6
        s_ref, _ = run_sama_step(spec, theta, lam, bb, mb, m=1, unroll=unroll)
        s_mic, _ = run_sama_step(spec, theta, lam, bb, mb, m=m, unroll=unroll)
        leaves_allclose(s_mic.lam, s_ref.lam, rtol=5e-5, atol=1e-6)
        leaves_allclose(s_mic.theta, s_ref.theta, rtol=5e-5, atol=1e-6)

    prop()


def test_virtual_shard_fallback_identical_microbatches_exact():
    """t1t2 has no micro hook -> generic virtual-shard averaging. With
    IDENTICAL microbatches (tiled) the average of per-microbatch terms
    must equal the single-microbatch value bit-for-bit-ish — the same
    equality the distributed schedule pins under tiled shards."""

    spec, theta, lam = make_problem(3)
    K, b = 2, 4
    bb1 = {"x": jax.random.normal(jax.random.PRNGKey(9), (K, b, 6)),
           "y": jax.random.randint(jax.random.PRNGKey(10), (K, b), 0, 3)}
    mb1 = {"x": jax.random.normal(jax.random.PRNGKey(11), (b, 6)),
           "y": jax.random.randint(jax.random.PRNGKey(12), (b,), 0, 3)}
    M = 4
    bb_t = {"x": jnp.tile(bb1["x"], (1, M, 1)), "y": jnp.tile(bb1["y"], (1, M))}
    mb_t = {"x": jnp.tile(mb1["x"], (M, 1)), "y": jnp.tile(mb1["y"], (M,))}

    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)

    def run(bb, mb, m):
        cfg = EngineConfig(method="t1t2", unroll_steps=K,
                           scale=ScaleConfig(microbatch=m))
        state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
        return jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))(state, bb, mb)

    s_ref, _ = run(bb1, mb1, 1)
    s_mic, _ = run(bb_t, mb_t, M)
    leaves_allclose(s_mic.lam, s_ref.lam, rtol=1e-5, atol=1e-7)


def test_nonlinear_method_refuses_microbatching():
    spec, theta, lam = make_problem()
    bb, mb = make_batches(0, 2, 8, 8)
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    cfg = EngineConfig(method="cg", unroll_steps=2, scale=ScaleConfig(microbatch=2))
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    step = make_meta_step(spec, base_opt, meta_opt, cfg)
    with pytest.raises(ValueError, match="nonlinear reduce"):
        step(state, bb, mb)


# ---------------------------------------------------------------------------
# precision-policy loss trajectories (pinned tolerance, acceptance criterion)
# ---------------------------------------------------------------------------


def run_trajectory(policy, steps=8, seed=0):
    spec, theta, lam = make_problem(seed)
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    cfg = EngineConfig(method="sama", unroll_steps=2,
                       scale=ScaleConfig(policy=policy))
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    step = jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))
    traj = []
    for i in range(steps):
        bb, mb = make_batches(seed + 100 * i, 2, 16, 8)
        state, m = step(state, bb, mb)
        traj.append((float(m["base_loss"]), float(m["meta_loss"])))
    return np.asarray(traj)


@pytest.mark.parametrize("policy,tol", [("bf16", 0.05), ("f16", 0.02)])
def test_low_precision_loss_trajectory_matches_f32(policy, tol):
    """Documented tolerance (docs/scale.md): over 8 meta steps on the
    smoke problem, bf16 tracks the f32 loss trajectory within 5% relative
    per step and f16 (loss-scaled, more mantissa than bf16) within 2%."""

    ref = run_trajectory("f32")
    low = run_trajectory(policy)
    rel = np.abs(low - ref) / np.maximum(np.abs(ref), 1e-3)
    assert rel.max() < tol, f"{policy} trajectory diverged: max rel {rel.max():.4f}"


# ---------------------------------------------------------------------------
# cast_for_reduce (the bf16-variadic-AllReduce workaround, pinned)
# ---------------------------------------------------------------------------


def test_cast_for_reduce_promotes_only_sub_f32():
    f32 = jnp.ones((3,), jnp.float32)
    tree = {"bf16": jnp.ones((3,), jnp.bfloat16),
            "f16": jnp.ones((3,), jnp.float16),
            "f32": f32,
            "i32": jnp.ones((3,), jnp.int32)}
    out = cast_for_reduce(tree)
    assert out["bf16"].dtype == jnp.float32
    assert out["f16"].dtype == jnp.float32
    assert out["f32"] is f32  # untouched, not copied
    assert out["i32"].dtype == jnp.int32


# ---------------------------------------------------------------------------
# EngineState compatibility
# ---------------------------------------------------------------------------


def test_engine_state_scale_default_none_checkpoint_compatible(tmp_path):
    """scale=None adds no pytree leaves, so pre-repro.scale checkpoints
    restore into new states unchanged."""

    from repro import checkpoint

    spec, theta, lam = make_problem()
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    state = init_state(theta, lam, base_opt, meta_opt)
    assert state.scale is None
    # simulate an old 5-field checkpoint: same leaves, saved from a tree
    # without the scale field at all
    old_style = {"theta": state.theta, "base_opt_state": state.base_opt_state,
                 "lam": state.lam, "meta_opt_state": state.meta_opt_state,
                 "step": state.step}
    new_style = {"theta": state.theta, "base_opt_state": state.base_opt_state,
                 "lam": state.lam, "meta_opt_state": state.meta_opt_state,
                 "step": state.step, "scale": None}
    assert (jax.tree_util.tree_structure(old_style)
            != jax.tree_util.tree_structure(new_style))  # differ as trees...
    assert len(jax.tree_util.tree_leaves(old_style)) == len(
        jax.tree_util.tree_leaves(new_style))  # ...but same leaf count
    path = str(tmp_path / "ck")
    checkpoint.save(path, state, step=0)
    restored, _ = checkpoint.restore(path, state)
    assert restored.scale is None


# ---------------------------------------------------------------------------
# the memory planner
# ---------------------------------------------------------------------------


def planner_args(batch=16, meta=8, unroll=2):
    spec, theta, lam = make_problem()
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    cfg = EngineConfig(method="sama", unroll_steps=unroll)
    state = init_state(theta, lam, base_opt, meta_opt)
    bb, mb = make_batches(0, unroll, batch, meta)
    return spec, base_opt, meta_opt, cfg, state, bb, mb


def test_candidate_microbatches_common_divisors():
    _, _, _, _, _, bb, mb = planner_args(batch=16, meta=8)
    cands = scale.candidate_microbatches(bb, mb)
    assert cands == (1, 2, 4, 8)  # divisors of both 16 and 8
    assert scale.candidate_microbatches(bb, mb, max_microbatch=2) == (1, 2)
    # manual schedule: candidates divide the per-device shard, not the global
    assert scale.candidate_microbatches(bb, mb, shard_divisor=4) == (1, 2)
    with pytest.raises(ValueError, match="shard evenly"):
        scale.candidate_microbatches(bb, mb, shard_divisor=3)


def test_plan_microbatch_huge_budget_picks_m1():
    args = planner_args()
    plan = scale.plan_microbatch(*args, hbm_budget=int(1e12))
    assert plan.microbatch == 1 and plan.fits
    assert plan.scale.microbatch == 1
    assert plan.peak_bytes is not None and plan.peak_bytes < 1e12


def test_plan_microbatch_tiny_budget_does_not_fit():
    args = planner_args()
    plan = scale.plan_microbatch(*args, hbm_budget=1)
    assert not plan.fits
    assert plan.microbatch == 8  # the least-bad (largest) candidate
    # candidates recorded for the audit trail, peaks non-increasing in M
    ms = [m for m, _ in plan.candidates]
    assert ms == sorted(ms)


def test_plan_microbatch_intermediate_budget_binary_search():
    """Set the budget between the M=1 and max-M peaks: the plan must pick
    the SMALLEST M that fits (the largest fitting microbatch), and its
    measured peak must actually fit."""

    args = planner_args(batch=32, meta=16)
    # probe the endpoints through the public API
    hi = scale.plan_microbatch(*args, hbm_budget=int(1e12))
    lo = scale.plan_microbatch(*args, hbm_budget=1)
    peak_m1 = dict(hi.candidates)[1]
    peak_mmax = [p for m, p in lo.candidates if m == max(m for m, _ in lo.candidates)][0]
    assert peak_mmax < peak_m1, "peak must decrease with M for this test to bite"
    budget = (peak_m1 + peak_mmax) // 2
    plan = scale.plan_microbatch(*args, hbm_budget=budget)
    assert plan.fits
    assert 1 < plan.microbatch
    assert plan.peak_bytes <= budget
    # minimality: every tried candidate below the chosen M busted the budget
    for m, peak in plan.candidates:
        if m < plan.microbatch:
            assert peak > budget


def test_plan_microbatch_rejects_bad_budget():
    args = planner_args()
    with pytest.raises(ValueError, match="hbm_budget"):
        scale.plan_microbatch(*args, hbm_budget=0)


def test_exec_plan_feeds_back_into_engine_config():
    args = planner_args()
    plan = scale.plan_microbatch(*args, hbm_budget=int(1e12))
    cfg = dataclasses.replace(args[3], scale=plan.scale)
    assert cfg.scale.microbatch == plan.microbatch


# ---------------------------------------------------------------------------
# the ScaleConfig surfaces: MetaLearner and DataOptimizer scoring
# ---------------------------------------------------------------------------


def test_metalearner_scale_knob_end_to_end():
    from repro.api import MetaLearner

    spec, theta, lam = make_problem(5)
    bb, mb = make_batches(5, 2, 16, 8)
    learner = MetaLearner(spec, base_opt="adam", base_lr=1e-2,
                          meta_opt="adam", meta_lr=1e-2,
                          method="sama", unroll_steps=2,
                          scale=ScaleConfig(policy="f16", microbatch=4))
    learner.init(theta, lam)
    assert learner.state.scale is not None  # LossScaleState seeded
    metrics = learner.step(bb, mb)
    assert all(np.isfinite(float(v)) for v in metrics.values())


def test_dataopt_meta_scorer_accepts_scale_knob():
    """scale= flows DataOptimizer -> meta scorer -> fit_meta -> MetaLearner
    and scoring stays finite with accumulation active."""

    from repro.dataopt import DataOptimizer

    rng = np.random.default_rng(0)
    n = 64
    train = {"x": rng.normal(size=(n, 6)).astype(np.float32),
             "y": rng.integers(0, 3, n).astype(np.int32)}

    per_ex = problems.softmax_per_example(apply_fn)

    def init_fn(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (6, 16)) * 0.3,
                "w2": jax.random.normal(k2, (16, 3)) * 0.3}

    opt = DataOptimizer(train=train, per_example_fn=per_ex, init_fn=init_fn,
                        fields=("x", "y"), num_classes=3, scorer="meta",
                        batch_size=32, steps=2, unroll=2, batch=32,
                        meta_batch=32, uncertainty="none",
                        scale=scale.ScaleConfig(microbatch=4))
    s = opt.fit_scores()
    assert s.shape == (n,) and np.all(np.isfinite(s))
