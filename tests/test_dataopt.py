"""repro.dataopt subsystem tests: scorer registry round-trip, heuristic
scorers vs hand-rolled oracles, prune invariants, EMA machinery, reweighted
sampling, export/import manifest validation — plus the subsystem's
distributed claim (sharded scoring bitwise-equal to single-device, and the
reweighted iterator producing data-sharded batches), which needs >1 host
device and therefore runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
keeps 1 device, per the dry-run isolation rule).
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems
from repro.dataopt import (
    DataOptimizer,
    EMATracker,
    ReweightedIterator,
    ScoreContext,
    available_scorers,
    class_balanced_mask,
    apply_mask,
    ema_disagreement,
    export_scores,
    import_scores,
    keep_mask,
    fit_plain,
    register_scorer,
    resolve_scorer,
    sampling_probs,
    unregister_scorer,
)

# ---------------------------------------------------------------------------
# a tiny MLP classification problem shared by the tests
# ---------------------------------------------------------------------------

D, H, C, N = 6, 16, 3, 90


def _apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]


PER_EX = problems.softmax_per_example(_apply_fn)


def _init_fn(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (D, H)) * 0.3,
            "w2": jax.random.normal(k2, (H, C)) * 0.3}


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    return {"x": rng.normal(size=(N, D)).astype(np.float32),
            "y": rng.integers(0, C, N).astype(np.int32)}


@pytest.fixture(scope="module")
def theta():
    return _init_fn(jax.random.PRNGKey(42))


def _optimizer(dataset, scorer, theta=None, **knobs):
    return DataOptimizer(train=dataset, per_example_fn=PER_EX, init_fn=_init_fn,
                         fields=("x", "y"), num_classes=C, scorer=scorer,
                         theta=theta, batch_size=32, **knobs)


# ---------------------------------------------------------------------------
# registry round-trip
# ---------------------------------------------------------------------------


def test_scorer_registry_roundtrip(dataset):
    assert {"meta", "el2n", "grand", "margin", "loss", "random"} <= set(available_scorers())

    @register_scorer("test_constant")
    def _make(value=1.0):
        return lambda ctx: np.full(ctx.n, value, np.float32)

    try:
        assert "test_constant" in available_scorers()
        with pytest.raises(ValueError):
            register_scorer("test_constant", _make)  # duplicate refused
        scorer = resolve_scorer("test_constant", value=3.0)
        opt = _optimizer(dataset, "test_constant", value=3.0)
        s = opt.fit_scores()
        np.testing.assert_array_equal(s, np.full(N, 3.0, np.float32))
        np.testing.assert_array_equal(scorer(opt.ctx), s)
    finally:
        unregister_scorer("test_constant")
    assert "test_constant" not in available_scorers()
    with pytest.raises(ValueError):
        resolve_scorer("test_constant")


def test_resolve_scorer_rejects_knobs_on_callable():
    with pytest.raises(TypeError):
        resolve_scorer(lambda ctx: None, train_steps=3)


# ---------------------------------------------------------------------------
# heuristic scorers vs hand-rolled oracles
# ---------------------------------------------------------------------------


def test_el2n_matches_oracle(dataset, theta):
    s = _optimizer(dataset, "el2n", theta=theta).fit_scores()
    logits = np.asarray(_apply_fn(theta, jnp.asarray(dataset["x"])))
    p = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
    onehot = np.eye(C)[dataset["y"]]
    oracle = np.linalg.norm(p - onehot, axis=-1)
    np.testing.assert_allclose(s, -oracle, rtol=1e-5)  # keep-easy orientation


def test_grand_matches_oracle(dataset, theta):
    s = _optimizer(dataset, "grand", theta=theta).fit_scores()
    oracle = np.empty(N)
    for i in range(N):
        b = {"x": jnp.asarray(dataset["x"][i:i + 1]), "y": jnp.asarray(dataset["y"][i:i + 1])}
        g = jax.grad(lambda p: jnp.sum(PER_EX(p, b).loss))(theta)
        oracle[i] = np.sqrt(sum(float(jnp.sum(jnp.square(x)))
                                for x in jax.tree_util.tree_leaves(g)))
    np.testing.assert_allclose(s, -oracle, rtol=1e-4)


def test_margin_and_loss_orientation(dataset, theta):
    margin = _optimizer(dataset, "margin", theta=theta).fit_scores()
    loss = _optimizer(dataset, "loss", theta=theta).fit_scores()
    pe = PER_EX(theta, {"x": jnp.asarray(dataset["x"]), "y": jnp.asarray(dataset["y"])})
    np.testing.assert_allclose(loss, -np.asarray(pe.loss), rtol=1e-5)
    # margin and loss must broadly agree on the keep-priority ordering
    assert np.corrcoef(margin, loss)[0, 1] > 0.5


def test_meta_scorer_end_to_end(dataset):
    opt = _optimizer(dataset, "meta", steps=4, unroll=2, uncertainty="entropy")
    s = opt.fit_scores()
    assert s.shape == (N,) and np.all(np.isfinite(s))
    assert np.all((s >= 0) & (s <= 1))  # MWN outputs are sigmoid weights


# ---------------------------------------------------------------------------
# prune invariants
# ---------------------------------------------------------------------------


def test_keep_mask_counts_and_order():
    scores = np.array([0.1, 0.9, 0.5, 0.7, 0.3])
    mask = keep_mask(scores, ratio=0.4)
    assert mask.sum() == 3
    assert mask[[1, 3, 2]].all() and not mask[[0, 4]].any()
    with pytest.raises(ValueError):
        keep_mask(scores, ratio=1.0)


def test_class_balanced_prune_ratio_honored_per_class(dataset):
    rng = np.random.default_rng(1)
    scores = rng.random(N).astype(np.float32)
    labels = dataset["y"]
    ratio = 0.3
    mask = class_balanced_mask(scores, labels, ratio)
    for c in np.unique(labels):
        in_class = labels == c
        expected = max(int(round(in_class.sum() * (1 - ratio))), 1)
        assert mask[in_class].sum() == expected, f"class {c}"
        # within the class, exactly the top-scored survive
        kept_scores = scores[in_class & mask]
        dropped_scores = scores[in_class & ~mask]
        if len(dropped_scores):
            assert kept_scores.min() >= dropped_scores.max()


def test_prune_and_iterative_prune(dataset):
    opt = _optimizer(dataset, "random")
    pruned, mask = opt.prune(0.5)
    assert mask.sum() == len(pruned["y"]) == max(int(round(N * 0.5)), 1)
    # iterative: same final budget, monotone shrinking keep set
    opt2 = _optimizer(dataset, "random")
    _, mask2 = opt2.prune(0.5, rounds=2)
    assert mask2.sum() == mask.sum()
    assert len(apply_mask(dataset, mask2)["x"]) == mask2.sum()


def test_iterative_prune_forwards_theta_every_round(dataset, theta):
    """rounds > 1 re-scores via per-round sub-optimizers; a user-supplied
    pre-trained theta must reach EVERY round, not just the first."""

    seen = []

    @register_scorer("test_theta_probe")
    def _make():
        def score(ctx):
            seen.append(ctx.theta)
            return np.linspace(0.0, 1.0, ctx.n, dtype=np.float32)
        return score

    try:
        opt = _optimizer(dataset, "test_theta_probe", theta=theta)
        opt.prune(0.5, rounds=2)
    finally:
        unregister_scorer("test_theta_probe")
    assert len(seen) == 2
    assert all(t is theta for t in seen), "a round dropped the supplied theta"


def test_retrain_improves_over_init(dataset):
    theta0 = _init_fn(jax.random.PRNGKey(0))
    theta = fit_plain(PER_EX, theta0, dataset, steps=60, fields=("x", "y"))
    batch = {"x": jnp.asarray(dataset["x"]), "y": jnp.asarray(dataset["y"])}
    assert float(jnp.mean(PER_EX(theta, batch).loss)) < float(jnp.mean(PER_EX(theta0, batch).loss))


# ---------------------------------------------------------------------------
# EMA machinery
# ---------------------------------------------------------------------------


def test_ema_tracker():
    t = EMATracker(decay=0.5)
    np.testing.assert_array_equal(t.update(np.ones(4)), np.ones(4))  # init, no zero-bias
    np.testing.assert_allclose(t.update(np.zeros(4)), 0.5 * np.ones(4))
    with pytest.raises(ValueError):
        t.update(np.ones(5))
    with pytest.raises(ValueError):
        EMATracker(decay=1.0)


def test_ema_disagreement_bounds():
    p = np.array([[1.0, 0.0], [0.5, 0.5]])
    np.testing.assert_allclose(ema_disagreement(p, p), [0.0, 0.5])
    flipped = p[:, ::-1]
    np.testing.assert_allclose(ema_disagreement(p, flipped), [1.0, 0.5])


# ---------------------------------------------------------------------------
# reweighted iteration
# ---------------------------------------------------------------------------


def test_sampling_probs_temperature_limits():
    s = np.array([0.0, 1.0, 2.0])
    hot = sampling_probs(s, temperature=1e6)  # ~uniform
    np.testing.assert_allclose(hot, np.full(3, 1 / 3), atol=1e-3)
    cold = sampling_probs(s, temperature=1e-6)  # ~argmax
    assert cold[2] > 0.99
    uniform = sampling_probs(np.zeros(3), temperature=1.0)
    np.testing.assert_allclose(uniform, np.full(3, 1 / 3))


def test_reweighted_iterator_respects_scores(dataset):
    scores = np.zeros(N, np.float32)
    scores[:10] = 1.0  # only the first 10 examples should ever be drawn (cold T)
    it = ReweightedIterator(dataset, dataset, scores, batch_size=8,
                            meta_batch_size=4, unroll=2, fields=("x", "y"),
                            temperature=1e-3, seed=0)
    base, meta = next(it)
    assert base["x"].shape == (2, 8, D) and meta["x"].shape == (4, D)
    drawn = np.asarray(base["x"]).reshape(-1, D)
    allowed = dataset["x"][:10]
    for row in drawn:
        assert np.any(np.all(np.isclose(row, allowed), axis=-1))
    # online update: flip the mass and the draws must follow
    flipped = np.zeros(N, np.float32)
    flipped[-10:] = 1.0
    it.update_scores(flipped)
    base2, _ = next(it)
    drawn2 = np.asarray(base2["x"]).reshape(-1, D)
    allowed2 = dataset["x"][-10:]
    for row in drawn2:
        assert np.any(np.all(np.isclose(row, allowed2), axis=-1))


def test_reweighted_iterator_curriculum_anneal(dataset):
    it = ReweightedIterator(dataset, dataset, np.arange(N, dtype=np.float32),
                            batch_size=4, meta_batch_size=2, unroll=1,
                            fields=("x", "y"), temperature=(10.0, 0.1, 5), seed=0)
    temps = [it.temperature_fn(i) for i in range(7)]
    assert temps[0] == 10.0
    assert abs(temps[5] - 0.1) < 1e-9
    assert temps[6] == temps[5]  # anneal clamps at the end temperature
    next(it)


# ---------------------------------------------------------------------------
# export / import manifest validation
# ---------------------------------------------------------------------------


def test_export_import_roundtrip(tmp_path, dataset):
    opt = _optimizer(dataset, "random")
    s = opt.fit_scores()
    mask = keep_mask(s, 0.3)
    path = opt.export(str(tmp_path / "scores"), mask=mask, meta={"note": "t"})
    s2, m2, meta = import_scores(path)
    np.testing.assert_array_equal(s, s2)
    np.testing.assert_array_equal(mask, m2)
    assert meta["scorer"] == "random" and meta["n"] == N and meta["note"] == "t"
    # a second optimizer adopts the export
    opt2 = _optimizer(dataset, "random")
    s3 = opt2.load(path, expect_scorer="random")
    np.testing.assert_array_equal(s, s3)


def test_export_import_validation_failures(tmp_path, dataset):
    with pytest.raises(ValueError):
        export_scores(str(tmp_path / "bad"), np.array([np.nan, 1.0]), scorer="x")
    with pytest.raises(ValueError):
        export_scores(str(tmp_path / "bad2"), np.ones((2, 2)), scorer="x")
    with pytest.raises(ValueError):  # reserved meta keys
        export_scores(str(tmp_path / "bad3"), np.ones(4), scorer="x", meta={"n": 9})

    path = export_scores(str(tmp_path / "ok"), np.ones(4, np.float32), scorer="el2n")
    with pytest.raises(ValueError):
        import_scores(path, expect_n=5)
    with pytest.raises(ValueError):
        import_scores(path, expect_scorer="meta")

    # a foreign checkpoint is refused (wrong manifest kind)
    from repro import checkpoint
    foreign = str(tmp_path / "foreign")
    checkpoint.save(foreign, {"scores": np.ones(4)}, meta={"kind": "model"})
    with pytest.raises(ValueError):
        import_scores(foreign)


# ---------------------------------------------------------------------------
# distributed: sharded scoring bitwise == single device; sharded reweighted
# batches (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import problems
from repro.launch.mesh import AxisType, make_mesh
from repro.dataopt import DataOptimizer, score_dataset
from repro.dataopt.reweight import ReweightedIterator

def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

per_ex = problems.softmax_per_example(apply_fn)
d, h, C, n = 6, 16, 3, 100   # n NOT a multiple of the batch: exercises padding
def init_fn(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
            "w2": jax.random.normal(k2, (h, C)) * 0.3}

theta = init_fn(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
train = {"x": rng.normal(size=(n, d)).astype(np.float32),
         "y": rng.integers(0, C, n).astype(np.int32)}

mesh = make_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)

pe_1 = score_dataset(per_ex, theta, train, fields=("x", "y"), batch_size=16)
pe_8 = score_dataset(per_ex, theta, train, fields=("x", "y"), batch_size=16, mesh=mesh)
bitwise = all(
    np.array_equal(np.asarray(getattr(pe_1, f)), np.asarray(getattr(pe_8, f)))
    for f in ("loss", "logits", "uncertainty")
)

# full scorer path through the facade, sharded vs not
s_1 = DataOptimizer(train=train, per_example_fn=per_ex, init_fn=init_fn,
                    fields=("x", "y"), num_classes=C, scorer="el2n",
                    theta=theta, batch_size=16).fit_scores()
s_8 = DataOptimizer(train=train, per_example_fn=per_ex, init_fn=init_fn,
                    fields=("x", "y"), num_classes=C, scorer="el2n",
                    theta=theta, batch_size=16, mesh=mesh).fit_scores()
scorer_bitwise = np.array_equal(s_1, s_8)

# reweighted iterator under the mesh: batches must come out data-sharded —
# the meta batch over dim 0, the base batches over dim 1 (dim 0 is unroll)
it = ReweightedIterator(train, train, np.abs(s_1) + 1e-3, batch_size=16,
                        meta_batch_size=16, unroll=2, fields=("x", "y"),
                        mesh=mesh, seed=0)
base, meta = it.__next__()

def shard_dim(x, dim):
    return (len(x.sharding.device_set) == 8
            and x.sharding.shard_shape(x.shape)[dim] == x.shape[dim] // 8)

shardings_ok = shard_dim(meta["x"], 0) and shard_dim(base["x"], 1)

print(json.dumps({"bitwise": bitwise, "scorer_bitwise": scorer_bitwise,
                  "shardings_ok": shardings_ok}))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_scoring_bitwise_identical(dist_result):
    assert dist_result["bitwise"]
    assert dist_result["scorer_bitwise"]


def test_reweighted_iterator_shards_over_mesh(dist_result):
    assert dist_result["shardings_ok"]
