"""repro.perf: timers / memory / record schema / regression gate /
MetaLearner.profile, plus the acceptance pin — the MEASURED
(compiled-HLO, trip-scaled) all-reduce census of the manual SAMA step is
exactly unroll_steps + 1 on a forced 8-device CPU mesh.
"""

import json
import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import perf
from repro.api import MetaLearner
from repro.core import problems
from repro.perf import gate as gate_mod


# ---------------------------------------------------------------------------
# timers
# ---------------------------------------------------------------------------


def test_timing_stats_robust_summary():
    stats = perf.TimingStats.from_samples([1e-3, 2e-3, 3e-3, 4e-3, 100e-3], warmup=2)
    assert stats.median_us == pytest.approx(3000.0)
    assert stats.min_us == pytest.approx(1000.0)
    assert stats.max_us == pytest.approx(100000.0)
    assert stats.repeats == 5 and stats.warmup == 2
    assert stats.iqr_us > 0
    # the median shrugs off the 100ms outlier the mean absorbs
    assert stats.mean_us > 5 * stats.median_us


def test_measure_splits_compile_from_run():
    m = perf.measure(jax.jit(lambda x: (x * 2).sum()), jnp.ones((32,)),
                     warmup=1, repeats=3)
    assert m.timing.repeats == 3
    assert m.timing.median_us > 0
    assert m.compile_s is not None and m.compile_s >= 0
    assert m.lower_s is not None and m.lower_s >= 0
    assert m.compiled is not None
    # compile happened once, up front: run-phase medians are far below it
    assert m.timing.median_us / 1e6 < m.compile_s + m.lower_s
    assert m.samples_per_s(32) == pytest.approx(32 / (m.timing.median_us / 1e6))


def test_measure_non_loweable_callable_still_times():
    def host_loop(x):
        # host-side concretization: traceable drivers this is not
        return jnp.asarray(float(jnp.asarray(x) + 1))

    m = perf.measure(host_loop, 1.0, warmup=1, repeats=2)
    assert m.compiled is None and m.compile_s is None and m.lower_s is None
    assert m.timing.repeats == 2 and m.timing.median_us > 0


def test_time_callable_rejects_zero_repeats():
    with pytest.raises(ValueError, match="repeats"):
        perf.time_callable(lambda: jnp.zeros(()), repeats=0)


# ---------------------------------------------------------------------------
# memory
# ---------------------------------------------------------------------------


def test_compiled_memory_breakdown():
    compiled = jax.jit(lambda x: x @ x.T).lower(jnp.ones((16, 16))).compile()
    stats = perf.compiled_memory(compiled)
    assert stats.source == "memory_analysis"
    assert stats.argument_bytes == 16 * 16 * 4
    assert stats.output_bytes == 16 * 16 * 4
    assert stats.peak_bytes is not None
    assert stats.peak_bytes >= stats.argument_bytes + stats.output_bytes - (stats.alias_bytes or 0)


def test_memory_aval_fallback_when_analysis_unavailable():
    class NoAnalysis:
        def memory_analysis(self):
            raise NotImplementedError("backend without buffer assignment")

    args = ({"w": jnp.ones((8, 4)), "b": jnp.ones((4,), jnp.bfloat16)},)
    stats = perf.compiled_memory(NoAnalysis(), example_args=args,
                                 example_out=jnp.ones((8,)))
    assert stats.source == "aval_fallback"
    assert stats.argument_bytes == 8 * 4 * 4 + 4 * 2
    assert stats.output_bytes == 8 * 4
    assert stats.temp_bytes is None and stats.peak_bytes is None


def test_memory_report_shape():
    compiled = jax.jit(lambda x: x + 1).lower(jnp.ones((4,))).compile()
    rep = perf.memory_report(compiled)
    assert rep["n_devices"] == jax.device_count()
    assert "peak_bytes" in rep["per_device"]
    # CPU container: no allocator stats -> no device_stats section
    if perf.device_memory() is None:
        assert "device_stats" not in rep


# ---------------------------------------------------------------------------
# record schema
# ---------------------------------------------------------------------------


def _timing_dict():
    return perf.TimingStats.from_samples([1e-3, 2e-3, 3e-3], warmup=1).as_dict()


def test_record_roundtrip_and_validation():
    rec = perf.PerfRecord(name="probe", us_per_step=_timing_dict(),
                          samples_per_s=10.0, compile_s=0.5)
    d = rec.as_dict()
    assert perf.validate_record(d) == []
    assert d["schema_version"] == perf.SCHEMA_VERSION
    assert rec.timing.median_us == pytest.approx(2000.0)


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.pop("name"), "name"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d["us_per_step"].pop("median_us"), "us_per_step"),
    (lambda d: d.update(samples_per_s=-1), "samples_per_s"),
    (lambda d: d.update(us_per_step=None), "no measured section"),
])
def test_record_validation_catches(mutate, needle):
    d = perf.PerfRecord(name="probe", us_per_step=_timing_dict()).as_dict()
    d.setdefault("us_per_step", None)
    mutate(d)
    errors = perf.validate_record(d)
    assert errors and any(needle in e for e in errors), errors


def test_write_bench_atomic_and_validated(tmp_path):
    payload = perf.bench_payload(
        "bench_probe", fast=True, elapsed_s=1.0,
        rows=[{"name": "r", "us_per_call": 1.0, "derived": {}}],
        records=[perf.PerfRecord(name="probe", us_per_step=_timing_dict())],
    )
    path = str(tmp_path / "BENCH_probe.json")
    perf.write_bench(path, payload)
    loaded = perf.load_bench(path)
    assert loaded["bench"] == "bench_probe"
    assert loaded["records"][0]["name"] == "probe"
    assert loaded["env"]["jax_version"] == jax.__version__
    # no tmp litter from the atomic write
    assert [f for f in os.listdir(tmp_path) if f.startswith(".tmp_")] == []

    bad = dict(payload, records=[{"name": "x", "schema_version": perf.SCHEMA_VERSION}])
    with pytest.raises(ValueError, match="no measured section"):
        perf.write_bench(str(tmp_path / "BENCH_bad.json"), bad)
    assert not (tmp_path / "BENCH_bad.json").exists()


# ---------------------------------------------------------------------------
# the regression gate
# ---------------------------------------------------------------------------


def _bench_file(tmp_path, subdir, *, median_us=1000.0, samples_per_s=100.0,
                peak_bytes=1 << 20, ar_count=3, total_bytes=4096.0):
    t = _timing_dict()
    t["median_us"] = median_us
    rec = perf.PerfRecord(
        name="step", us_per_step=t, samples_per_s=samples_per_s,
        memory={"per_device": {"argument_bytes": 1, "output_bytes": 1,
                               "temp_bytes": 1, "generated_code_bytes": 0,
                               "alias_bytes": 0, "peak_bytes": peak_bytes,
                               "source": "memory_analysis"},
                "n_devices": 1},
        collectives={"all-reduce_count": ar_count, "total_count": ar_count,
                     "total_bytes": total_bytes},
    )
    payload = perf.bench_payload("bench_x", fast=True, elapsed_s=0.1,
                                 rows=[], records=[rec])
    d = tmp_path / subdir
    d.mkdir(exist_ok=True)
    perf.write_bench(str(d / "BENCH_x.json"), payload)
    return str(d)


def test_gate_passes_within_bands(tmp_path):
    base = _bench_file(tmp_path, "base")
    cur = _bench_file(tmp_path, "cur", median_us=1800.0,  # < 2.5x
                      samples_per_s=60.0, peak_bytes=int(1.1 * (1 << 20)))
    report = perf.compare_dirs(cur, base)
    assert report.compared == 1
    assert report.violations == []
    assert gate_mod.main(["--records", cur, "--baselines", base]) == 0


def test_gate_improvements_never_fail(tmp_path):
    base = _bench_file(tmp_path, "base")
    cur = _bench_file(tmp_path, "cur", median_us=10.0, samples_per_s=1e5,
                      peak_bytes=1024, total_bytes=16.0)
    assert perf.compare_dirs(cur, base).violations == []


@pytest.mark.parametrize("knobs,metric", [
    (dict(median_us=3000.0), "us_per_step.median_us"),
    (dict(samples_per_s=10.0), "samples_per_s"),
    (dict(peak_bytes=2 << 20), "memory.peak_bytes"),
    (dict(ar_count=4), "collectives.all-reduce_count"),
    (dict(total_bytes=8192.0), "collectives.total_bytes"),
])
def test_gate_flags_each_regression_axis(tmp_path, knobs, metric):
    base = _bench_file(tmp_path, "base")
    cur = _bench_file(tmp_path, "cur", **knobs)
    report = perf.compare_dirs(cur, base)
    assert any(v.metric == metric for v in report.violations), report.violations
    assert gate_mod.main(["--records", cur, "--baselines", base]) == 1


def test_gate_collective_count_is_exact_even_when_lower(tmp_path):
    # one FEWER all-reduce is still a structural change worth a look
    base = _bench_file(tmp_path, "base", ar_count=3)
    cur = _bench_file(tmp_path, "cur", ar_count=2)
    report = perf.compare_dirs(cur, base)
    assert any(v.metric == "collectives.all-reduce_count" for v in report.violations)


def test_gate_new_and_missing_benches(tmp_path):
    base = _bench_file(tmp_path, "base")
    cur = tmp_path / "cur"
    cur.mkdir()
    payload = perf.bench_payload("bench_y", fast=True, elapsed_s=0.1, rows=[],
                                 records=[perf.PerfRecord(name="other",
                                                          us_per_step=_timing_dict())])
    perf.write_bench(str(cur / "BENCH_y.json"), payload)
    report = perf.compare_dirs(str(cur), base)
    assert report.compared == 0
    assert report.missing_benches == ["x"]
    assert any("bench_y" in n for n in report.new_records)
    # subset runs pass by default; --strict-missing turns lost coverage into failure
    assert gate_mod.main(["--records", str(cur), "--baselines", base]) == 0
    assert gate_mod.main(["--records", str(cur), "--baselines", base,
                          "--strict-missing"]) == 1


def test_gate_strict_missing_records_catches_dropped_record(tmp_path):
    """Subset-CI strictness: a RE-RUN bench that silently dropped a
    baselined record fails under --strict-missing-records, while whole
    non-run benches still pass (unlike --strict-missing)."""

    base = _bench_file(tmp_path, "base")
    # baseline gains a second record the current run does not reproduce
    base_payload = perf.load_bench(str(tmp_path / "base" / "BENCH_x.json"))
    base_payload["records"].append(
        perf.PerfRecord(name="dropped", us_per_step=_timing_dict()).as_dict())
    perf.write_bench(str(tmp_path / "base" / "BENCH_x.json"), base_payload)
    cur = _bench_file(tmp_path, "cur")
    report = perf.compare_dirs(cur, base)
    assert report.missing_records == ["bench_x/dropped"]
    assert gate_mod.main(["--records", cur, "--baselines", base]) == 0
    assert gate_mod.main(["--records", cur, "--baselines", base,
                          "--strict-missing-records"]) == 1
    # an extra never-run baselined bench must NOT trip record-level strictness
    shutil.copy(str(tmp_path / "base" / "BENCH_x.json"),
                str(tmp_path / "base" / "BENCH_z.json"))
    report = perf.compare_dirs(cur, base)
    assert report.missing_benches == ["z"]
    assert report.ok(strict_missing_records=True) is False  # dropped record still fails
    # with only the whole-bench gap (record restored), subset mode passes
    cur2 = _bench_file(tmp_path, "cur2")
    cur2_payload = perf.load_bench(str(tmp_path / "cur2" / "BENCH_x.json"))
    cur2_payload["records"].append(
        perf.PerfRecord(name="dropped", us_per_step=_timing_dict()).as_dict())
    perf.write_bench(str(tmp_path / "cur2" / "BENCH_x.json"), cur2_payload)
    assert gate_mod.main(["--records", cur2, "--baselines", base,
                          "--strict-missing-records"]) == 0
    assert gate_mod.main(["--records", cur2, "--baselines", base,
                          "--strict-missing"]) == 1  # full-run mode still strict


def test_gate_warns_on_env_mismatch(tmp_path, capsys):
    base = _bench_file(tmp_path, "base")
    base_payload = perf.load_bench(str(tmp_path / "base" / "BENCH_x.json"))
    base_payload["env"]["jax_version"] = "0.0.0-minted-elsewhere"
    perf.write_bench(str(tmp_path / "base" / "BENCH_x.json"), base_payload)
    cur = _bench_file(tmp_path, "cur")
    report = perf.compare_dirs(cur, base)
    assert report.env_mismatches and "0.0.0-minted-elsewhere" in report.env_mismatches[0]
    assert gate_mod.main(["--records", cur, "--baselines", base]) == 0  # warn, not fail
    assert "WARNING env mismatch" in capsys.readouterr().out


def test_gate_custom_tolerance(tmp_path):
    base = _bench_file(tmp_path, "base")
    cur = _bench_file(tmp_path, "cur", median_us=1800.0)
    assert gate_mod.main(["--records", cur, "--baselines", base,
                          "--tol-time", "1.5"]) == 1


# ---------------------------------------------------------------------------
# MetaLearner.profile
# ---------------------------------------------------------------------------


def test_metalearner_profile_emits_valid_record():
    def apply_fn(theta, x):
        return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

    spec = problems.make_data_optimization_spec(
        problems.softmax_per_example(apply_fn), reweight=True)
    theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (6, 16)) * 0.3,
             "w2": jax.random.normal(jax.random.PRNGKey(1), (16, 3)) * 0.3}
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)
    base = {"x": jax.random.normal(jax.random.PRNGKey(3), (2, 8, 6)),
            "y": jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, 3)}
    meta = {"x": jax.random.normal(jax.random.PRNGKey(5), (4, 6)),
            "y": jax.random.randint(jax.random.PRNGKey(6), (4,), 0, 3)}

    learner = MetaLearner(spec, method="sama", unroll_steps=2)
    learner.init(theta, lam)
    state_before = learner.state
    rec = learner.profile(base, meta, warmup=1, repeats=2)
    assert perf.validate_record(rec.as_dict()) == []
    assert rec.name == "sama_pjit"
    assert rec.timing.median_us > 0 and rec.timing.repeats == 2
    assert rec.memory["per_device"]["peak_bytes"] > 0
    assert rec.collectives["total_count"] == 0  # single device: no collectives
    assert rec.extra == {"method": "sama", "schedule": "pjit", "unroll_steps": 2,
                         "microbatch": 1, "policy": "f32"}
    # profiling is a probe, not training: state untouched
    assert learner.state is state_before
    with pytest.raises(RuntimeError, match="before profile"):
        MetaLearner(spec, method="sama", unroll_steps=2).profile(base, meta)


# ---------------------------------------------------------------------------
# ACCEPTANCE: measured all-reduce census of the manual SAMA step
# ---------------------------------------------------------------------------

CENSUS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro import optim, perf
from repro.core import EngineConfig, init_state, problems
from repro.launch import distributed as dist
from repro.launch.mesh import make_mesh

UNROLL = 2
mesh = make_mesh((8, 1), ("data", "model"))

def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

spec = problems.make_data_optimization_spec(
    problems.softmax_per_example(apply_fn), reweight=True)
theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (6, 16)) * 0.3,
         "w2": jax.random.normal(jax.random.PRNGKey(1), (16, 3)) * 0.3}
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)
base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
state = init_state(theta, lam, base_opt, meta_opt)
step = dist.make_manual_step(
    spec, base_opt, meta_opt, EngineConfig(method="sama", unroll_steps=UNROLL), mesh)
base = {"x": jax.random.normal(jax.random.PRNGKey(3), (UNROLL, 8, 6)),
        "y": jax.random.randint(jax.random.PRNGKey(4), (UNROLL, 8), 0, 3)}
meta = {"x": jax.random.normal(jax.random.PRNGKey(5), (8, 6)),
        "y": jax.random.randint(jax.random.PRNGKey(6), (8,), 0, 3)}
with mesh:
    compiled = jax.jit(step).lower(state, base, meta).compile()
    census = perf.verify_single_sync(compiled, UNROLL)
print(json.dumps({"unroll": UNROLL, "census": census}))
"""


def test_measured_manual_sama_census_is_unroll_plus_one():
    """The paper's single-sync claim, verified on the COMPILED step: the
    trip-scaled all-reduce count of the manual SAMA schedule on an
    8-device CPU mesh is exactly unroll_steps (per-step base DDP syncs)
    + 1 (the one flat meta bucket)."""

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", CENSUS_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    census = r["census"]
    assert census["expected_all_reduces"] == r["unroll"] + 1 == 3
    assert census["all-reduce_count"] == r["unroll"] + 1
    assert census["single_sync_ok"] is True
    assert isinstance(census["all-reduce_count"], int)
    # the single-sync schedule introduces no other collective kinds
    assert census["total_count"] == census["all-reduce_count"]
