"""The beyond-paper perf variants must be numerically equivalent to the
baseline paths (they are pure re-expressions for better sharding/memory).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model
from repro.models.model import token_cross_entropy


def test_sharded_ce_equals_baseline():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 16, 97)) * 5
    targets = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    a = token_cross_entropy(logits, targets)
    b = token_cross_entropy(logits, targets, sharded=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_sharded_ce_grad_equals_baseline():
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 33))
    targets = jax.random.randint(jax.random.PRNGKey(3), (4,), 0, 33)
    w = jax.random.uniform(jax.random.PRNGKey(4), (4,))
    ga = jax.grad(lambda l: jnp.sum(token_cross_entropy(l[None], targets[None])[0] * w))(logits)
    gb = jax.grad(lambda l: jnp.sum(token_cross_entropy(l[None], targets[None], sharded=True)[0] * w))(logits)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("arch", ["gemma3-1b", "gemma2-9b"])
def test_chunked_attention_equals_full(arch):
    """Blockwise online-softmax == full-score attention, incl. sliding-window
    local layers and logit softcap (forward and full-model gradient)."""

    cfg = configs.get_smoke_config(arch).replace(attn_chunk=8)
    cfg_full = cfg.replace(attn_chunk=0)
    model_c, model_f = Model(cfg), Model(cfg_full)
    params = model_f.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)}

    lf, _ = model_f.forward(params, batch)
    lc, _ = model_c.forward(params, batch)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lf), rtol=2e-3, atol=2e-3)

    gf = jax.grad(model_f.lm_loss)(params, batch)
    gc = jax.grad(model_c.lm_loss)(params, batch)
    for a, b in zip(jax.tree_util.tree_leaves(gf), jax.tree_util.tree_leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)


def test_chunked_attention_respects_window():
    """A token beyond the sliding window must not influence a local layer's
    output (chunked path)."""

    cfg = configs.get_smoke_config("gemma2-9b").replace(
        attn_chunk=8, sliding_window=8, attn_pattern=("local",), num_layers=1
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, cfg.vocab_size)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)  # perturb far-past token
    la, _ = model.forward(params, {"tokens": toks})
    lb, _ = model.forward(params, {"tokens": toks2})
    # last position is > window away from position 0: logits must match
    np.testing.assert_allclose(np.asarray(la[0, -1]), np.asarray(lb[0, -1]), rtol=1e-5, atol=1e-5)
