"""Docs link hygiene: every repo-relative path and internal anchor in the
markdown docs must resolve (tools/check_links.py — the same checker the CI
``docs`` job runs, so a dangling link fails locally before it fails there).
"""

import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_links  # noqa: E402

DOC_FILES = ["README.md", "DESIGN.md", "ROADMAP.md"] + sorted(
    str(p.relative_to(ROOT)) for p in (ROOT / "docs").glob("*.md"))


def test_docs_exist():
    assert (ROOT / "docs" / "kernels.md").exists()
    assert (ROOT / "docs" / "api.md").exists()


@pytest.mark.parametrize("name", DOC_FILES)
def test_no_dangling_links(name):
    problems = check_links.check_file(ROOT / name)
    assert problems == [], f"{name}: {problems}"


def test_checker_catches_dangling(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("[a](missing.md) and [b](#ghost)\n# Only Heading\n")
    problems = check_links.check_file(bad)
    assert len(problems) == 2


def test_slugger_matches_github_conventions():
    seen = {}
    assert check_links.github_slug("§10 The kernel dispatch registry", seen) \
        == "10-the-kernel-dispatch-registry"
    assert check_links.github_slug("register_method", seen) == "register_method"
    assert check_links.github_slug("Dup", seen) == "dup"
    assert check_links.github_slug("Dup", seen) == "dup-1"
