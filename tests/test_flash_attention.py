"""ISSUE 9 oracle-parity battery for the flash attention kernels.

The pallas kernels (interpret mode — CPU container, TPU is the compile
target) are pinned against the ``ref`` twins, which are by construction
the literal pre-kernel ``models/attention.py`` ops. Coverage:

* forward parity across GQA group sizes, S/T not multiples of the
  block, causal x sliding-window x softcap combinations, bf16/f32
  (f32 forward <= 1e-5);
* VJP parity on the q/k/v cotangents (recompute-based backward);
* the traced ``local_flag`` riding into the kernel inside a jitted
  ``lax.scan`` over heterogeneous local/global layers;
* split-KV decode: two-stage LSE merge == single-pass softmax for
  uneven/single/lane-masked splits, ragged per-lane positions;
* the ``_chunked_sdpa`` ragged-T fix (T % chunk != 0 pads + masks
  instead of asserting);
* dispatch eligibility fall-through to ``ref``;
* decode-through-``qo_indptr``: continuous batching with the interpret
  kernel forced is token-identical to the serial ``greedy_generate``
  reference on mixed-length staggered lanes.

A deterministic parametrized core always runs; a hypothesis section
widens the sweep where hypothesis is installed (same skip idiom as
tests/test_kernels.py, but without skipping the deterministic core).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro.kernels import dispatch, flash_attn
from repro.models import attention as attn
from repro.models.model import Model

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

FWD_TOL = 1e-5   # ISSUE 9 acceptance: f32 forward parity
GRAD_TOL = 5e-5  # f32 VJP parity on q/k/v cotangents
BF16_TOL = 2e-2


def _mk(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


def _inputs(seed, B, S, H, KV, Dh, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = _mk(rng, (B, S, H, Dh), dtype)
    k = _mk(rng, (B, S, KV, Dh), dtype)
    v = _mk(rng, (B, S, KV, Dh), dtype)
    q_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    kv_pos = jnp.arange(S)
    return q, k, v, q_pos, kv_pos


# (B, S, H, KV, Dh, softcap, window, causal) — S deliberately not a
# multiple of the forced block_q/block_k = 8 in most rows, group sizes
# G = H/KV in {1, 2, 3, 4}.
CASES = [
    (2, 7, 4, 2, 16, 0.0, 0, True),      # G=2, ragged S
    (1, 13, 8, 2, 32, 30.0, 5, True),    # G=4, softcap + window
    (2, 5, 2, 2, 8, 0.0, 3, True),       # G=1, window only
    (1, 9, 6, 3, 16, 0.0, 0, False),     # G=2, non-causal (encoder)
    (1, 16, 4, 1, 16, 50.0, 0, True),    # G=4, MQA, block-aligned S
    (2, 11, 4, 4, 8, 20.0, 4, True),     # G=1, everything on, ragged
]


def _run_pair(case, dtype):
    B, S, H, KV, Dh, softcap, window, causal = case
    q, k, v, q_pos, kv_pos = _inputs(hash(case) % 2**31, B, S, H, KV, Dh, dtype)
    lf = jnp.asarray(True) if window else None
    kw = dict(softcap=softcap, window=window, causal=causal)
    ref = flash_attn.flash_attention_ref(q, k, v, q_pos, kv_pos, lf, **kw)
    got = flash_attn.flash_attention(q, k, v, q_pos, kv_pos, lf,
                                     interpret=True, block_q=8, block_k=8, **kw)
    return ref, got, (q, k, v, q_pos, kv_pos, lf, kw)


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_ref_f32(case):
    ref, got, _ = _run_pair(case, jnp.float32)
    assert got.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=FWD_TOL,
                               rtol=0)


@pytest.mark.parametrize("case", [CASES[0], CASES[1], CASES[5]])
def test_forward_matches_ref_bf16(case):
    ref, got, _ = _run_pair(case, jnp.bfloat16)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=BF16_TOL, rtol=BF16_TOL)


@pytest.mark.parametrize("case", CASES)
def test_vjp_matches_ref(case):
    _, _, (q, k, v, q_pos, kv_pos, lf, kw) = _run_pair(case, jnp.float32)
    cot = _mk(np.random.default_rng(1), q.shape)

    def loss(fn, interpret):
        extra = dict(interpret=True, block_q=8, block_k=8) if interpret else {}
        return lambda q, k, v: jnp.sum(
            fn(q, k, v, q_pos, kv_pos, lf, **kw, **extra) * cot)

    g_ref = jax.grad(loss(flash_attn.flash_attention_ref, False),
                     argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(loss(flash_attn.flash_attention, True),
                     argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_got, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=GRAD_TOL, rtol=1e-4,
                                   err_msg=f"d{name} cotangent mismatch")


def test_local_flag_traced_in_scan():
    """Heterogeneous local/global layers inside one jitted lax.scan: the
    window gate must ride into the kernel as a traced scalar (no retrace,
    no concretization error)."""

    B, S, H, KV, Dh, window = 1, 9, 4, 2, 16, 4
    q, k, v, q_pos, kv_pos = _inputs(3, B, S, H, KV, Dh)
    flags = jnp.asarray([True, False, True, True])

    def run(fn, **extra):
        def body(x, flag):
            out = fn(q + x, k, v, q_pos, kv_pos, flag, softcap=0.0,
                     window=window, causal=True, **extra)
            return x + jnp.mean(out), jnp.sum(out)
        return jax.jit(lambda: jax.lax.scan(body, 0.0, flags))()

    _, ref = run(flash_attn.flash_attention_ref)
    _, got = run(flash_attn.flash_attention, interpret=True,
                 block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# _chunked_sdpa ragged-T regression (satellite 3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,chunk", [(13, 4), (7, 8), (9, 4)])
def test_chunked_sdpa_ragged_t(t, chunk):
    """T % chunk != 0 pads + masks instead of the old hard assert."""

    B, KV, G, Dh = 2, 2, 2, 16
    rng = np.random.default_rng(t * chunk)
    q5 = _mk(rng, (B, t, KV, G, Dh))
    k = _mk(rng, (B, t, KV, Dh))
    v = _mk(rng, (B, t, KV, Dh))
    q_pos = jnp.broadcast_to(jnp.arange(t), (B, t))
    kv_pos = jnp.arange(t)
    got = attn._chunked_sdpa(q5, k, v, q_pos, kv_pos, chunk=chunk)
    mask = attn.make_mask(q_pos, kv_pos, causal=True)
    ref = attn._sdpa(q5.reshape(B, t, KV * G, Dh), k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


def test_self_attention_ragged_seq_with_chunk():
    """The chunk gate no longer requires S % chunk == 0: a ragged prefill
    length routes through the padded chunked path and matches the
    unchunked config."""

    cfg = configs.get_smoke_config("gemma3-1b")
    m = Model(cfg.replace(attn_chunk=4))
    m0 = Model(cfg.replace(attn_chunk=0))
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 13)), jnp.int32)
    a = m.forward(params, {"tokens": toks})
    b = m0.forward(params, {"tokens": toks})
    a = a[0] if isinstance(a, tuple) else a
    b = b[0] if isinstance(b, tuple) else b
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# split-KV decode (satellite 2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_splits", [1, 2, 3, 5])
@pytest.mark.parametrize("softcap,window", [(0.0, 0), (25.0, 0), (0.0, 3)])
def test_decode_matches_ref_across_splits(n_splits, softcap, window):
    """Uneven splits (T=11 over 1/2/3/5 spans, incl. fully-padded tail
    spans) reproduce the single-pass softmax; staggered per-lane
    positions include a pos=0 lane (the trash-lane shape)."""

    B, T, H, KV, Dh = 3, 11, 4, 2, 16
    rng = np.random.default_rng(n_splits)
    q = _mk(rng, (B, 1, H, Dh))
    k = _mk(rng, (B, T, KV, Dh))
    v = _mk(rng, (B, T, KV, Dh))
    pos = jnp.asarray([[10], [4], [0]], jnp.int32)  # staggered; lane 2 ~ trash
    lf = jnp.asarray(True) if window else None
    ref = flash_attn.flash_decode_ref(q, k, v, pos, lf, softcap=softcap,
                                      window=window)
    got = flash_attn.flash_decode(q, k, v, pos, lf, softcap=softcap,
                                  window=window, interpret=True,
                                  n_splits=n_splits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=FWD_TOL,
                               rtol=0)


def test_merge_partials_is_single_pass_softmax():
    """Stage-2 LSE combine over a hand-built uneven decomposition equals
    the one-shot softmax."""

    G, Dh, T = 4, 8, 10
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.standard_normal((G, T)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((T, Dh)), jnp.float32)
    full = jax.nn.softmax(s, axis=-1) @ vv

    spans = [(0, 3), (3, 4), (4, 10)]  # uneven
    o_parts, lse_parts = [], []
    for lo, hi in spans:
        sl = s[:, lo:hi]
        m = jnp.max(sl, axis=-1)
        p = jnp.exp(sl - m[:, None])
        l = jnp.sum(p, axis=-1)
        o_parts.append((p @ vv[lo:hi]) / l[:, None])
        lse_parts.append(m + jnp.log(l))
    got = flash_attn.merge_partials(jnp.stack(o_parts, 0), jnp.stack(lse_parts, 0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=1e-6,
                               rtol=1e-6)

    # single split: identity
    one = flash_attn.merge_partials(got[None], jnp.zeros((1, G)))
    np.testing.assert_allclose(np.asarray(one), np.asarray(got), atol=0, rtol=0)

    # lane-masked (empty/trash) splits carry lse = NEG and contribute 0
    o_pad = jnp.concatenate([jnp.stack(o_parts, 0),
                             jnp.full((2, G, Dh), 123.0)], 0)
    lse_pad = jnp.concatenate([jnp.stack(lse_parts, 0),
                               jnp.full((2, G), flash_attn.NEG)], 0)
    masked = flash_attn.merge_partials(o_pad, lse_pad)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(full), atol=1e-6,
                               rtol=1e-6)


def test_pick_splits_occupancy():
    assert flash_attn.pick_splits(64, 1) == 1           # short KV: no split
    assert flash_attn.pick_splits(4096, 1) >= 8         # one lane: fan out
    assert flash_attn.pick_splits(4096, 256) == 1       # grid already full
    assert flash_attn.pick_splits(10**6, 1) <= 16       # merge cost cap
    for t in (1, 100, 1000):
        assert flash_attn.pick_splits(t, 8) >= 1


# ---------------------------------------------------------------------------
# dispatch seam
# ---------------------------------------------------------------------------


def test_ineligible_dtype_falls_through_to_ref():
    """A dtype the f32-accumulating kernel doesn't support (int32) is
    ineligible: even a forced pallas backend degrades to ref (never an
    error)."""

    B, S, H, KV, Dh = 1, 6, 2, 2, 8
    _, _, _, q_pos, kv_pos = _inputs(0, B, S, H, KV, Dh)
    q = jnp.ones((B, S, H, Dh), jnp.int32)
    k = jnp.ones((B, S, KV, Dh), jnp.int32)
    v = jnp.ones((B, S, KV, Dh), jnp.int32)
    fn = dispatch.get_kernel("flash_attention", backend="pallas-interpret")
    dispatch.clear_dispatch_log()
    out = fn(q, k, v, q_pos, kv_pos)
    assert out.shape == (B, S, H, Dh)
    log = [e for e in dispatch.dispatch_log() if e[0] == "flash_attention"]
    assert log and log[-1][1] == "ref" and "ineligible" in log[-1][2]


def test_default_cpu_dispatch_is_ref():
    q, k, v, q_pos, kv_pos = _inputs(0, 1, 6, 2, 2, 8)
    dispatch.clear_dispatch_log()
    fn = dispatch.get_kernel("flash_attention")
    fn(q, k, v, q_pos, kv_pos)
    log = [e for e in dispatch.dispatch_log() if e[0] == "flash_attention"]
    assert log and log[-1][1] == "ref"


# ---------------------------------------------------------------------------
# decode-through-qo_indptr: continuous batching vs serial reference with
# the interpret kernel forced (satellite 2, the serving pin)
# ---------------------------------------------------------------------------


def test_continuous_batching_token_identical_with_flash_forced(monkeypatch):
    """Mixed-length staggered lanes through queue -> batcher (per-lane pos
    from ``PagedCache.qo_indptr()``) -> split-KV decode, with
    REPRO_KERNEL_BACKEND=pallas-interpret forced at trace time, emit
    EXACTLY the serial greedy_generate token ids (itself running the
    interpret kernel on its dense cache)."""

    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    cfg = configs.get_smoke_config("gemma3-1b")
    m = Model(cfg)  # fresh Model: identity-keyed jit caches retrace under the env
    params = m.init(jax.random.PRNGKey(0))

    lens, gens = [5, 9, 2], [4, 3, 5]
    prompts = [np.random.default_rng(i).integers(
        0, cfg.vocab_size, (L,)).astype(np.int32) for i, L in enumerate(lens)]
    ref = [serve.greedy_generate(m, params, jnp.asarray(p[None]), g, 24)[0]
           for p, g in zip(prompts, gens)]

    dispatch.clear_dispatch_log()
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=24, max_new_tokens=8))
    ids = [ex.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    stats = ex.run()

    for rid, r in zip(ids, ref):
        res = ex.results[rid]
        assert res.status == serve.STATUS_OK
        assert res.tokens == [int(t) for t in r]
    assert stats.completed == len(lens) and stats.errors == 0
    # the one-token path actually lowered the split-KV interpret kernel
    decode_picks = {e[1] for e in dispatch.dispatch_log()
                    if e[0] == "flash_decode"}
    assert "pallas-interpret" in decode_picks


# ---------------------------------------------------------------------------
# hypothesis sweep (widens the deterministic grid where installed)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(
        s=st.integers(2, 17),
        g=st.sampled_from([1, 2, 4]),
        kv=st.sampled_from([1, 2]),
        dh=st.sampled_from([8, 16]),
        softcap=st.sampled_from([0.0, 30.0]),
        window=st.sampled_from([0, 3]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_forward_parity_property(s, g, kv, dh, softcap, window, causal, seed):
        q, k, v, q_pos, kv_pos = _inputs(seed, 1, s, g * kv, kv, dh)
        lf = jnp.asarray(True) if window else None
        kw = dict(softcap=softcap, window=window, causal=causal)
        ref = flash_attn.flash_attention_ref(q, k, v, q_pos, kv_pos, lf, **kw)
        got = flash_attn.flash_attention(q, k, v, q_pos, kv_pos, lf,
                                         interpret=True, block_q=8,
                                         block_k=8, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=FWD_TOL, rtol=0)

    @settings(max_examples=8, deadline=None)
    @given(
        t=st.integers(1, 23),
        n_splits=st.integers(1, 6),
        pos0=st.integers(0, 22),
        softcap=st.sampled_from([0.0, 25.0]),
        seed=st.integers(0, 2**16),
    )
    def test_decode_parity_property(t, n_splits, pos0, softcap, seed):
        rng = np.random.default_rng(seed)
        q = _mk(rng, (2, 1, 4, 8))
        k = _mk(rng, (2, t, 2, 8))
        v = _mk(rng, (2, t, 2, 8))
        pos = jnp.asarray([[min(pos0, t - 1)], [0]], jnp.int32)
        ref = flash_attn.flash_decode_ref(q, k, v, pos, softcap=softcap)
        got = flash_attn.flash_decode(q, k, v, pos, softcap=softcap,
                                      interpret=True, n_splits=n_splits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=FWD_TOL, rtol=0)
