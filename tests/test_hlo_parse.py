"""roofline.hlo_parse trip-count correction against hand-built HLO.

perf.collectives (the measured single-sync audit) builds directly on this
parser, so the multiplier propagation — collectives inside while (scan)
bodies scaled by ``known_trip_count``, nested loops multiplying — is
pinned here on a fixture whose right answers are computable by hand.
"""

import pytest

from repro.roofline import hlo_parse

# ENTRY carries one all-reduce-start/-done pair (counted ONCE) and a
# while loop with trip count 4; the loop body carries one all-reduce and
# a nested while (trip 2) whose body carries one all-gather. Multipliers:
# entry x1, %body x4, %inner x(4*2)=8.
FIXTURE = """\
HloModule manual_step

%inner (q: f32[8]) -> f32[8] {
  %ag = f32[64] all-gather(%q), dimensions={0}
  ROOT %ri = f32[8] add(%q, %q)
}

%body (p: f32[8]) -> f32[8] {
  %ar1 = f32[256] all-reduce(%p), to_apply=%sum
  %w2 = f32[8] while(%p), condition=%cond2, body=%inner, backend_config={"known_trip_count":{"n":"2"}}
  ROOT %rb = f32[8] add(%p, %p)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %ar0 = f32[128] all-reduce-start(%a), to_apply=%sum
  %ard = f32[128] all-reduce-done(%ar0)
  %w = f32[8] while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %r = f32[8] add(%a, %a)
}
"""


def test_multipliers_propagate_through_nested_loops():
    comps = hlo_parse.split_computations(FIXTURE)
    mult = hlo_parse.computation_multipliers(comps)
    assert mult["main"] == 1.0
    assert mult["body"] == 4.0
    assert mult["inner"] == 8.0


def test_collectives_scaled_by_trip_count():
    stats = hlo_parse.collective_stats(FIXTURE)
    # 1 entry all-reduce (start/done pair counted once) + 4x the body's
    assert stats["all-reduce_count"] == 1 + 4
    assert stats["all-reduce_bytes"] == 128 * 4 + 4 * (256 * 4)
    # nested: the inner all-gather runs 4*2 times
    assert stats["all-gather_count"] == 8
    assert stats["all-gather_bytes"] == 8 * (64 * 4)
    assert stats["total_count"] == 13
    assert stats["total_bytes"] == stats["all-reduce_bytes"] + stats["all-gather_bytes"]


def test_while_without_trip_count_defaults_to_once():
    text = FIXTURE.replace(', backend_config={"known_trip_count":{"n":"4"}}', "")
    stats = hlo_parse.collective_stats(text)
    # outer loop now x1: 1 entry + 1 body all-reduce; inner loop still x2
    assert stats["all-reduce_count"] == 2
    assert stats["all-gather_count"] == 2


def test_scalar_and_unknown_dtypes_in_shape_bytes():
    assert hlo_parse.shape_bytes("f32[]") == 4
    assert hlo_parse.shape_bytes("bf16[2,3]") == 12
    assert hlo_parse.shape_bytes("token[]") == 0  # unknown dtype ignored
    assert hlo_parse.shape_bytes("(f32[4], s32[2])") == 16 + 8


@pytest.mark.parametrize("collective", ["all-reduce", "reduce-scatter", "all-to-all"])
def test_start_done_pairs_counted_once(collective):
    text = f"""\
HloModule pairs
ENTRY %main (a: f32[4]) -> f32[4] {{
  %c0 = f32[16] {collective}-start(%a), to_apply=%sum
  %c1 = f32[16] {collective}-done(%c0)
  ROOT %r = f32[4] add(%a, %a)
}}
"""
    stats = hlo_parse.collective_stats(text)
    assert stats[f"{collective}_count"] == 1
    assert stats[f"{collective}_bytes"] == 16 * 4
