"""Launch-layer tests: sharding rules, roofline HLO parser, and a smoke-scale
dry-run (subprocess with 512 forced host devices) proving two cheap
(arch x shape) combos lower+compile on the production mesh inside CI.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as sh


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})


@pytest.mark.parametrize(
    "path,shape,expect",
    [
        ("['layers']['attn']['wq']", (26, 1152, 1024), P(None, None, "model")),
        ("['layers']['attn']['wo']", (26, 1024, 1152), P(None, "model", None)),
        ("['layers']['mlp']['down']", (26, 6912, 1152), P(None, "model", None)),
        ("['embed']", (262144, 1152), P("model", None)),
        ("['embed']", (51865, 768), P(None, None)),  # whisper: indivisible vocab
        ("['layers']['moe']['experts']['up']", (60, 384, 7168, 2048), P(None, "model", None, None)),
        # qwen: 60 experts don't divide 16 -> tensor-parallel within experts
        ("['layers']['moe']['experts']['up']", (24, 60, 2048, 1408), P(None, None, None, "model")),
        ("['layers']['moe']['router']", (24, 2048, 60), P(None, None, None)),
        ("['layers']['ln1']['scale']", (26, 1152), P()),
        ("['layers']['tmix']['wv']", (24, 2048, 2048), P(None, None, "model")),
        ("['layers']['cmix']['wv']", (24, 7168, 2048), P(None, "model", None)),
    ],
)
def test_param_spec_rules(path, shape, expect):
    assert sh.param_spec(path, shape, MESH) == expect


def test_head_alignment_replicates_unaligned_attention():
    from repro import configs

    cfg = configs.get_config("gemma3-1b")  # 4 heads, kv=1: neither divides 16
    assert sh.param_spec("['layers']['attn']['wq']", (26, 1152, 1024), MESH, cfg) == P()
    assert sh.param_spec("['layers']['attn']['wk']", (26, 1152, 256), MESH, cfg) == P()
    cfg2 = configs.get_config("kimi-k2-1t-a32b")  # 64 heads: aligned
    assert sh.param_spec("['layers']['attn']['wq']", (60, 7168, 8192), MESH, cfg2) == P(
        None, None, "model"
    )


def test_cache_spec_long_context_shards_sequence():
    # B=1 (long_500k): sequence axis goes to data, kv heads to model
    spec = sh.cache_spec("['kv']['k']", (62, 1, 524288, 16, 128), MESH)
    assert spec == P(None, None, "data", "model", None)
    # batch-shardable decode: batch to data
    spec = sh.cache_spec("['kv']['k']", (62, 128, 32768, 16, 128), MESH)
    assert spec[1] == "data"


def test_hlo_parser_trip_counts():
    """The micro-case from EXPERIMENTS §Method: exact collective accounting."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline import hlo_parse
from repro.launch.mesh import AxisType, make_mesh

mesh = make_mesh((4, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
L, B, D = 8, 16, 64
def f(x, ws):
    def body(c, w):
        return c @ w, None
    out, _ = jax.lax.scan(body, x, ws)
    return out
x = jax.ShapeDtypeStruct((B, D), jnp.float32, sharding=NamedSharding(mesh, P("data", None)))
ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32, sharding=NamedSharding(mesh, P(None, "model", None)))
with mesh:
    c = jax.jit(f).lower(x, ws).compile()
s = hlo_parse.collective_stats(c.as_text())
print(json.dumps({"bytes": s["all-reduce_bytes"], "count": s["all-reduce_count"]}))
"""
    out = _run_subprocess(script)
    r = json.loads(out)
    assert r["count"] == 8  # one per scan iteration
    assert r["bytes"] == 8 * (16 // 4) * 64 * 4  # L x (B_loc, D) f32


@pytest.mark.slow
def test_dryrun_smoke_production_mesh():
    """Two cheap jobs must lower+compile on the real 16x16 mesh (512 forced
    host devices, subprocess)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_job
ok = []
for arch, shape in [("gemma3-1b", "decode_32k"), ("zamba2-7b", "long_500k")]:
    r = run_job(arch, shape, save=False)
    ok.append(r["status"])
print(json.dumps(ok))
"""
    out = _run_subprocess(script, timeout=500)
    assert json.loads(out) == ["ok", "ok"]


def _run_subprocess(script: str, timeout: int = 300) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=root, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout.strip().splitlines()[-1]
