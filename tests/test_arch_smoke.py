"""Per-architecture smoke tests on REDUCED configs (<=2 layers, d_model<=512,
<=4 experts): one forward pass + one full SAMA train step (bilevel data
reweighting) + one decode step on CPU; asserts shapes and finiteness.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.core import EngineConfig, init_state, make_meta_step, problems
from repro.models import Model

ARCHS = list(configs.ASSIGNED_ARCHS) + ["bert-base"]

B, S = 2, 32


def _batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(ks[1], (batch, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(ks[1], (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "encoder":
        b["y"] = jax.random.randint(ks[2], (batch,), 0, cfg.num_labels)
    return b


@pytest.fixture(scope="module")
def models():
    return {}


def _get(models, arch):
    if arch not in models:
        cfg = configs.get_smoke_config(arch)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        models[arch] = (cfg, m, params)
    return models[arch]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(models, arch):
    cfg, m, params = _get(models, arch)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = m.forward(params, batch)
    if cfg.family == "encoder":
        assert logits.shape == (B, cfg.num_labels)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    assert np.isfinite(float(aux))
    if cfg.family == "moe":
        assert float(aux) > 0.0  # load-balance loss must be alive


@pytest.mark.parametrize("arch", ARCHS)
def test_one_sama_train_step(models, arch):
    """One full bilevel SAMA meta step (the paper's technique) per arch."""

    cfg, m, params = _get(models, arch)
    if cfg.family == "encoder":
        per_ex = m.classifier_per_example
    else:
        per_ex = m.per_example
    spec = problems.make_data_optimization_spec(per_ex, reweight=True)
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)

    base_opt = optim.adam(1e-3)
    meta_opt = optim.adam(1e-3)
    step = make_meta_step(spec, base_opt, meta_opt, EngineConfig(method="sama", unroll_steps=1))
    state = init_state(params, lam, base_opt, meta_opt)

    one = _batch(cfg, jax.random.PRNGKey(3))
    base_batches = jax.tree_util.tree_map(lambda x: x[None], one)  # unroll axis K=1
    if cfg.family == "encoder":
        # the paper's WRENCH setting: same inputs, noisy (base) vs clean
        # (meta) labels. Disjoint token support would park the adaptation-
        # weighted perturbation on base-dead embedding rows (see DESIGN.md).
        meta_batch = dict(one)
        meta_batch["y"] = jax.random.randint(jax.random.PRNGKey(4), (B,), 0, cfg.num_labels)
    else:
        meta_batch = _batch(cfg, jax.random.PRNGKey(4))
    new_state, metrics = jax.jit(step)(state, base_batches, meta_batch)

    assert np.isfinite(float(metrics["base_loss"])), metrics
    assert np.isfinite(float(metrics["meta_loss"])), metrics
    assert np.isfinite(float(metrics["hypergrad_norm"])), metrics
    # both levels must move
    moved_theta = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        new_state.theta, state.theta,
    )
    assert max(jax.tree_util.tree_leaves(moved_theta)) > 0
    moved_lam = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), new_state.lam, state.lam
    )
    assert max(jax.tree_util.tree_leaves(moved_lam)) > 0


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_decode_step(models, arch):
    cfg, m, params = _get(models, arch)
    cache_len = 64
    cache = m.init_cache(B, cache_len, dtype=jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, new_cache = jax.jit(m.decode_step)(params, cache, tok, jnp.asarray(5, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache must be updated somewhere
    changed = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        new_cache, cache,
    )
    assert max(jax.tree_util.tree_leaves(changed)) > 0


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-7b"])
def test_ssm_decode_matches_forward(models, arch):
    """Recurrent decode must agree with the chunkwise training forward on the
    same token prefix (the chunked scan == naive recurrence invariant)."""

    cfg, m, params = _get(models, arch)
    seq = 16
    tokens = jax.random.randint(jax.random.PRNGKey(9), (1, seq), 0, cfg.vocab_size)
    logits_train, _ = m.forward(params, {"tokens": tokens})

    cache = m.init_cache(1, seq, dtype=jnp.float32)
    outs = []
    step = jax.jit(m.decode_step)
    for t in range(seq):
        lg, cache = step(params, cache, tokens[:, t : t + 1], jnp.asarray(t, jnp.int32))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32), np.asarray(logits_train, np.float32),
        rtol=2e-2, atol=2e-2,
    )
