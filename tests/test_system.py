"""End-to-end behaviour tests for the meta-learning system.

A small noisy logistic-regression data-optimization problem: 40% of base
labels are flipped, the meta set is clean. After a few hundred SAMA meta
steps the MetaWeightNet must assign lower weights to corrupted samples than
to clean ones — the paper's central claim in miniature — and every
hypergradient method must run end-to-end through the Engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import Engine, EngineConfig, problems
from repro.core.meta_modules import apply_weight_net, weight_features


def _make_problem(key, n=256, d=8, flip=0.4):
    kx, kw, kf, kmx = jax.random.split(key, 4)
    w_true = jax.random.normal(kw, (d,))
    X = jax.random.normal(kx, (n, d))
    y = (X @ w_true > 0).astype(jnp.int32)
    n_flip = int(n * flip)
    flip_idx = jnp.arange(n) < n_flip  # first n_flip are corrupted
    y_noisy = jnp.where(flip_idx, 1 - y, y)
    Xm = jax.random.normal(kmx, (128, d))
    ym = (Xm @ w_true > 0).astype(jnp.int32)
    return X, y_noisy, flip_idx, Xm, ym


def _apply(theta, x):
    return x @ theta["w"] + theta["b"]


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(42)
    X, y_noisy, flip_idx, Xm, ym = _make_problem(key)
    per_ex = problems.softmax_per_example(_apply)
    spec = problems.make_data_optimization_spec(per_ex, reweight=True)
    d = X.shape[1]
    theta0 = {"w": jnp.zeros((d, 2)), "b": jnp.zeros((2,))}
    lam0 = problems.init_data_optimization_lam(jax.random.PRNGKey(1), reweight=True)
    return spec, theta0, lam0, X, y_noisy, flip_idx, Xm, ym


def _batch_iter(X, y, Xm, ym, key, k_unroll, bs=64, mbs=64):
    n, nm = X.shape[0], Xm.shape[0]
    while True:
        key, k1, k2 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (k_unroll, bs), 0, n)
        midx = jax.random.randint(k2, (mbs,), 0, nm)
        base = {"x": X[idx], "y": y[idx]}
        meta = {"x": Xm[midx], "y": ym[midx]}
        yield base, meta


def test_sama_downweights_corrupted_samples(setup):
    """L2RW-style free per-sample weights: SAMA's hypergradient must push the
    weights of label-flipped samples below those of clean samples (the sign
    of the meta gradient, end to end)."""

    _, theta0, _, X, y_noisy, flip_idx, Xm, ym = setup
    from repro.core import BilevelSpec

    onehot_base = jax.nn.one_hot(y_noisy, 2)
    onehot_meta = jax.nn.one_hot(ym, 2)

    def base_loss(theta, lam, batch):
        logits = _apply(theta, X)
        loss_i = -jnp.sum(onehot_base * jax.nn.log_softmax(logits, -1), axis=-1)
        return jnp.mean(jax.nn.sigmoid(lam["s"]) * loss_i)

    def meta_loss(theta, lam, batch):
        logits = _apply(theta, Xm)
        return jnp.mean(-jnp.sum(onehot_meta * jax.nn.log_softmax(logits, -1), axis=-1))

    spec = BilevelSpec(base_loss=base_loss, meta_loss=meta_loss)
    lam0 = {"s": jnp.zeros((X.shape[0],))}
    eng = Engine(
        spec, base_opt=optim.adam(1e-2), meta_opt=optim.adam(1e-2),
        cfg=EngineConfig(method="sama", unroll_steps=2),
    )
    state = eng.init(theta0, lam0)

    def full_batch_iter():
        while True:
            yield jnp.zeros((2, 1)), None  # losses close over the full data

    state, hist = eng.run(state, full_batch_iter(), num_meta_steps=200, log_every=100)
    w = jax.nn.sigmoid(state.lam["s"])
    w_bad = float(jnp.mean(w[flip_idx]))
    w_good = float(jnp.mean(w[~flip_idx]))
    assert w_bad < w_good - 0.005, (w_bad, w_good)
    assert hist[-1]["meta_loss"] < 0.2 * hist[0]["meta_loss"]


def test_sama_mwn_improves_meta_loss(setup):
    """MetaWeightNet variant (paper Sec. 4.1 parametrization): the meta
    objective must improve by orders of magnitude under SAMA."""

    spec, theta0, lam0, X, y_noisy, flip_idx, Xm, ym = setup
    eng = Engine(
        spec,
        base_opt=optim.adam(1e-2),
        meta_opt=optim.adam(1e-2),
        cfg=EngineConfig(method="sama", unroll_steps=2),
    )
    state = eng.init(theta0, lam0)
    it = _batch_iter(X, y_noisy, Xm, ym, jax.random.PRNGKey(7), k_unroll=2)
    state, hist = eng.run(state, it, num_meta_steps=150, log_every=50)
    assert hist[-1]["meta_loss"] < 0.1 * hist[0]["meta_loss"]

    # weights must be non-degenerate (net is actually using its input)
    logits = _apply(state.theta, X)
    onehot = jax.nn.one_hot(y_noisy, 2)
    loss_i = -jnp.sum(onehot * jax.nn.log_softmax(logits, -1), axis=-1)
    w = apply_weight_net(state.lam["reweight"], weight_features(loss_i))
    assert float(jnp.std(w)) > 1e-3


@pytest.mark.parametrize("method", ["sama", "sama_na", "t1t2", "neumann", "cg", "iterdiff"])
def test_engine_runs_all_methods(setup, method):
    spec, theta0, lam0, X, y_noisy, flip_idx, Xm, ym = setup
    eng = Engine(
        spec,
        base_opt=optim.adam(1e-2),
        meta_opt=optim.adam(1e-2),
        cfg=EngineConfig(method=method, unroll_steps=2),
    )
    state = eng.init(theta0, lam0)
    it = _batch_iter(X, y_noisy, Xm, ym, jax.random.PRNGKey(3), k_unroll=2)
    state, hist = eng.run(state, it, num_meta_steps=5, log_every=1)
    for h in hist:
        assert np.isfinite(h["base_loss"]) and np.isfinite(h["meta_loss"]), h
    # lam must actually move
    diff = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a - b))), state.lam, lam0)
    assert max(jax.tree_util.tree_leaves(diff)) > 0


def test_label_correction_spec_runs(setup):
    spec_, theta0, _, X, y_noisy, flip_idx, Xm, ym = setup
    per_ex = problems.softmax_per_example(_apply)
    spec = problems.make_data_optimization_spec(per_ex, reweight=True, correct=True)
    lam0 = problems.init_data_optimization_lam(
        jax.random.PRNGKey(5), reweight=True, correct=True, num_classes=2
    )
    eng = Engine(
        spec, base_opt=optim.adam(1e-2), meta_opt=optim.adam(1e-2),
        cfg=EngineConfig(method="sama", unroll_steps=1),
    )
    state = eng.init(theta0, lam0)
    it = _batch_iter(X, y_noisy, Xm, ym, jax.random.PRNGKey(11), k_unroll=1)
    state, hist = eng.run(state, it, num_meta_steps=10, log_every=5)
    assert np.isfinite(hist[-1]["meta_loss"])
