"""Data pipeline + checkpoint substrate tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, data, optim
from repro.core import init_state


def test_lm_batch_structure():
    cfg = data.LMStreamConfig(vocab_size=1000, seq_len=64)
    rng = np.random.default_rng(0)
    b = data.lm_batch(cfg, rng, batch=8)
    assert b["tokens"].shape == (8, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000
    # markov structure: chain transitions must be over-represented
    t = b["tokens"].astype(np.int64)
    chain_hits = np.mean((t[:, :-1] * 31 + 7) % 1000 == t[:, 1:])
    assert chain_hits > 0.3


def test_classification_dataset_noise_bookkeeping():
    cfg = data.ClassificationConfig(num_classes=4, vocab_size=256, seq_len=16)
    d = data.make_classification_dataset(cfg, 500, noise=0.3, seed=1)
    flipped = d["y"] != d["y_true"]
    assert flipped.sum() > 0
    assert np.all(flipped <= d["corrupted"])  # flips only where corrupted
    # class-token bands must be informative
    c0 = d["tokens"][d["y_true"] == 0]
    band = 256 // 4
    frac = np.mean((c0 >= 0) & (c0 < band))
    assert frac > 0.3


def test_weak_labels_majority_better_than_single():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 4, 1000)
    wl = data.weak_labels(y, 4, num_lfs=7, lf_accuracy=0.6, seed=2)
    acc = np.mean(wl == y)
    assert acc > 0.6  # majority vote beats one LF


def test_batch_iterator_shapes():
    cfg = data.ClassificationConfig()
    dtr = data.make_classification_dataset(cfg, 100, noise=0.2, seed=0)
    dme = data.make_classification_dataset(cfg, 40, noise=0.0, seed=1)
    it = data.BatchIterator(dtr, dme, batch_size=8, meta_batch_size=4, unroll=3)
    base, meta = next(it)
    assert base["tokens"].shape == (3, 8, cfg.seq_len)
    assert meta["y"].shape == (4,)


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.int32)}}
    lam = {"w": jnp.zeros((3,))}
    opt = optim.adam(1e-3)
    state = init_state(params, lam, opt, opt)
    path = str(tmp_path / "ck")
    checkpoint.save(path, state, step=7, meta={"note": "test"})
    restored, manifest = checkpoint.restore(path, state)
    assert manifest["step"] == 7
    flat_a = jax.tree_util.tree_leaves(state)
    flat_b = jax.tree_util.tree_leaves(restored)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = {"w": jnp.ones((3,))}
    path = str(tmp_path / "ck2")
    checkpoint.save(path, tree)
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.ones((4,))})
