"""The kernel backend-dispatch registry (DESIGN.md §10, docs/kernels.md).

Covers the registry semantics, the selection precedence (explicit backend >
$REPRO_KERNEL_BACKEND > platform default), safe fallback for unavailable /
ineligible backends, a parity sweep of EVERY registered kernel against its
ref.py oracle on every backend available on CPU CI (pallas-interpret + ref)
including ragged/non-tile-aligned shapes, and the ISSUE acceptance pins:
``adam.adaptation`` lowers through the dispatched fused kernel when enabled
(and through ref when forced), numerics within 1e-5 of the oracle, and the
manual SAMA step's measured collective census stays exactly unroll+1
all-reduces with dispatch active in the hot path.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import problems, sama
from repro.kernels import dispatch, ops, ref


@pytest.fixture(autouse=True)
def _clean_log():
    dispatch.clear_dispatch_log()
    yield
    dispatch.clear_dispatch_log()


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_builtin_matrix():
    assert dispatch.available_kernels() == (
        "adafactor_adapt", "adam_adapt", "flash_attention", "flash_decode",
        "lion_adapt", "weighted_ce")
    for name in dispatch.available_kernels():
        assert dispatch.kernel_backends(name) == dispatch.BACKENDS  # all three


def test_register_duplicate_refused_and_overwrite():
    def impl(x):
        return x

    dispatch.register_kernel("_tmp_kernel", "ref", impl)
    try:
        with pytest.raises(ValueError, match="already has"):
            dispatch.register_kernel("_tmp_kernel", "ref", impl)
        dispatch.register_kernel("_tmp_kernel", "ref", impl, overwrite=True)
        with pytest.raises(ValueError, match="unknown backend"):
            dispatch.register_kernel("_tmp_kernel", "cuda", impl)
    finally:
        dispatch.unregister_kernel("_tmp_kernel")
    with pytest.raises(ValueError, match="unknown kernel"):
        dispatch.get_kernel("_tmp_kernel")


def test_backend_order_precedence(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    assert dispatch.backend_order() == (
        ("pallas-tpu", "ref") if jax.default_backend() == "tpu" else ("ref",))
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    assert dispatch.backend_order() == ("pallas-interpret", "ref")
    # explicit argument beats the env var
    assert dispatch.backend_order("ref") == ("ref",)
    monkeypatch.setenv(dispatch.ENV_VAR, "nonsense")
    with pytest.raises(ValueError, match="must be one of"):
        dispatch.backend_order()


# ---------------------------------------------------------------------------
# parity: every registered kernel vs its ref.py oracle, every CPU backend,
# aligned and ragged shapes
# ---------------------------------------------------------------------------

CPU_BACKENDS = ("pallas-interpret", "ref")


def _flat_case(n, k):
    keys = [jax.random.PRNGKey(100 * n + i) for i in range(k)]
    return [jax.random.normal(kk, (n,)) for kk in keys]


def _kernel_cases(name, n):
    """(args, kwargs, oracle_fn) triples exercising kernel ``name``."""

    if name == "adam_adapt":
        g, m, v_raw, gm = _flat_case(n, 4)
        kw = dict(t=4, b1=0.9, b2=0.999, eps=1e-8, lr=0.3)
        return (g, m, jnp.abs(v_raw), gm), kw, ref.adam_adapt_product
    if name == "lion_adapt":
        g, m, gm = _flat_case(n, 3)
        kw = dict(lr=0.2, b1=0.9, delta=1e-3)
        return (g, m, gm), kw, ref.lion_adapt_product
    if name == "adafactor_adapt":
        vhat_raw, gm = _flat_case(n, 2)
        kw = dict(lr=0.2, eps=1e-8)
        return (jnp.abs(vhat_raw) + 1e-3, gm), kw, ref.adafactor_adapt_product
    raise AssertionError(name)


@pytest.mark.parametrize("backend", CPU_BACKENDS)
@pytest.mark.parametrize("n", [128, 8 * 1024, 1000, 37])  # incl. ragged tails
@pytest.mark.parametrize("name", ["adam_adapt", "lion_adapt", "adafactor_adapt"])
def test_flat_kernel_parity(name, n, backend):
    args, kw, oracle = _kernel_cases(name, n)
    out, ss = dispatch.get_kernel(name, backend=backend)(*args, **kw)
    out_r, ss_r = oracle(*args, **kw)
    # rtol 3e-5 (not 1e-5): lion's surrogate peaks near |c|=0 where f32
    # op-ordering between the fused kernel and the oracle is visible
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=3e-5, atol=1e-7)
    np.testing.assert_allclose(float(ss), float(ss_r), rtol=1e-4, atol=1e-8)
    assert dispatch.dispatch_log()[-1][:2] == (name, backend)


@pytest.mark.parametrize("backend", CPU_BACKENDS)
@pytest.mark.parametrize("shape", [(8, 256), (5, 384), (3, 100)])  # incl. ragged
def test_weighted_ce_parity(shape, backend):
    r_, v_ = shape
    logits = jax.random.normal(jax.random.PRNGKey(r_ * v_), shape) * 4
    targets = jax.random.randint(jax.random.PRNGKey(1), (r_,), 0, v_)
    ce = dispatch.get_kernel("weighted_ce", backend=backend)(logits, targets)
    ce_r = ref.cross_entropy(logits, targets)
    np.testing.assert_allclose(np.asarray(ce), np.asarray(ce_r), rtol=1e-5, atol=1e-5)
    # the weighted backward must agree across backends too
    w = jax.random.uniform(jax.random.PRNGKey(2), (r_,))
    grad = jax.grad(lambda l: jnp.sum(
        dispatch.get_kernel("weighted_ce", backend=backend)(l, targets) * w))(logits)
    grad_r = ref.cross_entropy_grad(logits, targets, w)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(grad_r), rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# fallback semantics
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.default_backend() == "tpu", reason="CPU/GPU-only fallback")
def test_forced_pallas_tpu_falls_back_safely(monkeypatch):
    """Forcing the compiled-TPU backend on a host without a TPU must degrade
    to ref (with the fallback recorded), never crash in lowering."""

    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-tpu")
    g, m, gm = _flat_case(64, 3)
    v = jnp.abs(gm)
    out, _ = dispatch.get_kernel("adam_adapt")(g, m, v, gm, t=1, b1=0.9, b2=0.999,
                                               eps=1e-8, lr=1.0)
    out_r, _ = ref.adam_adapt_product(g, m, v, gm, t=1, b1=0.9, b2=0.999, eps=1e-8, lr=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=1e-5, atol=1e-7)
    kernel, backend, reason = dispatch.dispatch_log()[-1]
    assert (kernel, backend) == ("adam_adapt", "ref")
    assert "pallas-tpu:unavailable" in reason


def test_ineligible_shape_falls_back():
    """A kernel whose eligibility predicate rejects the call falls through
    to the next backend in the order."""

    calls = []
    dispatch.register_kernel(
        "_tmp_picky", "pallas-interpret",
        lambda x: calls.append("pallas") or x + 1,
        eligible=lambda x: x.shape[0] % 8 == 0,
    )
    dispatch.register_kernel("_tmp_picky", "ref", lambda x: x + 1)
    try:
        kern = dispatch.get_kernel("_tmp_picky", backend="pallas-interpret")
        kern(jnp.zeros((16,)))
        assert dispatch.dispatch_log()[-1][:2] == ("_tmp_picky", "pallas-interpret")
        kern(jnp.zeros((7,)))  # ragged: ineligible -> ref
        kernel, backend, reason = dispatch.dispatch_log()[-1]
        assert (kernel, backend) == ("_tmp_picky", "ref")
        assert "pallas-interpret:ineligible" in reason
        assert calls == ["pallas"]
    finally:
        dispatch.unregister_kernel("_tmp_picky")


def test_ce_tpu_eligibility_is_lane_aligned():
    """The compiled blockwise-CE kernel only claims lane-aligned vocabularies."""

    ok = jnp.zeros((4, 256))
    ragged = jnp.zeros((4, 300))
    tg = jnp.zeros((4,), jnp.int32)
    assert dispatch._ce_tiles_ok(ok, tg)
    assert not dispatch._ce_tiles_ok(ragged, tg)


# ---------------------------------------------------------------------------
# hot-path wiring
# ---------------------------------------------------------------------------


def _warm_adam(n=512, lr=0.5):
    opt = optim.adam(lr)
    params = {"w": jnp.zeros((n,))}
    state = opt.init(params)
    for i in range(2):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (n,))}
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    return opt, params, state


def test_acceptance_adaptation_lowers_through_dispatched_kernel(monkeypatch):
    """ISSUE acceptance: adam.adaptation lowers through the dispatched fused
    kernel when enabled, through ref when forced, numerics within 1e-5."""

    opt, params, state = _warm_adam()
    grads = {"w": jax.random.normal(jax.random.PRNGKey(9), (512,))}

    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    dispatch.clear_dispatch_log()
    jaxpr_kernel = str(jax.make_jaxpr(lambda g: opt.adaptation(g, state, params))(grads))
    assert "pallas_call" in jaxpr_kernel
    assert ("adam_adapt", "pallas-interpret") in [e[:2] for e in dispatch.dispatch_log()]
    diag_kernel = opt.adaptation(grads, state, params)

    monkeypatch.setenv(dispatch.ENV_VAR, "ref")
    dispatch.clear_dispatch_log()
    jaxpr_ref = str(jax.make_jaxpr(lambda g: opt.adaptation(g, state, params))(grads))
    assert "pallas_call" not in jaxpr_ref
    assert ("adam_adapt", "ref") in [e[:2] for e in dispatch.dispatch_log()]
    diag_ref = opt.adaptation(grads, state, params)

    # both backends agree with the ref.py oracle to <= 1e-5
    ones = jnp.ones((512,))
    oracle, _ = ref.adam_adapt_product(
        grads["w"], state.mu["w"], state.nu["w"], ones,
        t=int(state.count) + 1, b1=0.9, b2=0.999, eps=1e-8, lr=0.5)
    for got in (diag_kernel["w"], diag_ref["w"]):
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle), rtol=1e-5, atol=1e-5)


def test_sama_fused_path_matches_unfused():
    """The fused adapt_product hot path must be a pure optimization: same
    hypergradient, perturbation direction and eps as the adaptation-then-
    multiply-then-norm fallback."""

    def apply_fn(theta, x):
        return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

    spec = problems.make_data_optimization_spec(
        problems.softmax_per_example(apply_fn), reweight=True)
    theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (6, 16)) * 0.3,
             "w2": jax.random.normal(jax.random.PRNGKey(1), (16, 3)) * 0.3}
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(3), (8, 6)),
             "y": jax.random.randint(jax.random.PRNGKey(4), (8,), 0, 3)}

    opt = optim.adam(1e-2)
    assert opt.adapt_product is not None
    state = opt.init(theta)
    g_base = jax.grad(spec.base_scalar)(theta, lam, batch)
    upd, state2 = opt.update(g_base, state, theta)

    kwargs = dict(base_opt_state=state, g_base=g_base, cfg=sama.SAMAConfig())
    fused = sama.sama_hypergrad(spec, theta, lam, batch, batch, base_opt=opt, **kwargs)
    unfused_opt = dataclasses.replace(opt, adapt_product=None)
    unfused = sama.sama_hypergrad(spec, theta, lam, batch, batch,
                                  base_opt=unfused_opt, **kwargs)

    np.testing.assert_allclose(float(fused.eps), float(unfused.eps), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(fused.hypergrad),
                    jax.tree_util.tree_leaves(unfused.hypergrad)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)
    for a, b in zip(jax.tree_util.tree_leaves(fused.v),
                    jax.tree_util.tree_leaves(unfused.v)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("opt_name", ["lion", "adafactor"])
def test_sama_runs_on_new_adaptive_optimizers(opt_name):
    """The paper's "broad range of adaptive optimizers" claim: SAMA composes
    with lion and adafactor end to end through the fused path."""

    def apply_fn(theta, x):
        return x @ theta["w"]

    spec = problems.make_data_optimization_spec(
        problems.softmax_per_example(apply_fn), reweight=True)
    theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (5, 3)) * 0.3}
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1), reweight=True)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(2), (6, 5)),
             "y": jax.random.randint(jax.random.PRNGKey(3), (6,), 0, 3)}

    opt = optim.get_optimizer(opt_name, 1e-2)
    state = opt.init(theta)
    g_base = jax.grad(spec.base_scalar)(theta, lam, batch)
    res = sama.sama_hypergrad(spec, theta, lam, batch, batch, base_opt=opt,
                              base_opt_state=state, g_base=g_base,
                              cfg=sama.SAMAConfig())
    assert float(res.eps) > 0
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(res.hypergrad))


def test_large_vocab_ce_routes_through_dispatch():
    from repro.models.model import token_cross_entropy

    V = dispatch.CE_VOCAB_THRESHOLD
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 3, V))
    targets = jax.random.randint(jax.random.PRNGKey(1), (2, 3), 0, V)
    dispatch.clear_dispatch_log()
    ce = token_cross_entropy(logits, targets)
    assert ("weighted_ce" in [e[0] for e in dispatch.dispatch_log()])
    ce_r = ref.cross_entropy(logits.reshape(-1, V), targets.reshape(-1)).reshape(2, 3)
    np.testing.assert_allclose(np.asarray(ce), np.asarray(ce_r), rtol=1e-5, atol=1e-5)

    dispatch.clear_dispatch_log()
    token_cross_entropy(logits[..., :64], jnp.clip(targets, 0, 63))
    assert dispatch.dispatch_log() == []  # small vocab: plain log_softmax


# ---------------------------------------------------------------------------
# ACCEPTANCE: measured census of the manual SAMA step with dispatch active
# ---------------------------------------------------------------------------

CENSUS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro import optim, perf
from repro.core import EngineConfig, init_state, problems
from repro.kernels import dispatch
from repro.launch import distributed as dist
from repro.launch.mesh import make_mesh

UNROLL = 2
mesh = make_mesh((8, 1), ("data", "model"))

def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

spec = problems.make_data_optimization_spec(
    problems.softmax_per_example(apply_fn), reweight=True)
theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (6, 16)) * 0.3,
         "w2": jax.random.normal(jax.random.PRNGKey(1), (16, 3)) * 0.3}
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)
base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
assert base_opt.adapt_product is not None  # fused dispatch path is live
state = init_state(theta, lam, base_opt, meta_opt)
step = dist.make_manual_step(
    spec, base_opt, meta_opt, EngineConfig(method="sama", unroll_steps=UNROLL), mesh)
base = {"x": jax.random.normal(jax.random.PRNGKey(3), (UNROLL, 8, 6)),
        "y": jax.random.randint(jax.random.PRNGKey(4), (UNROLL, 8), 0, 3)}
meta = {"x": jax.random.normal(jax.random.PRNGKey(5), (8, 6)),
        "y": jax.random.randint(jax.random.PRNGKey(6), (8,), 0, 3)}
with mesh:
    compiled = jax.jit(step).lower(state, base, meta).compile()
    census = perf.verify_single_sync(compiled, UNROLL)
dispatched = sorted(set(e[:2] for e in dispatch.dispatch_log()))
print(json.dumps({"unroll": UNROLL, "census": census, "dispatched": dispatched}))
"""


def test_acceptance_census_unroll_plus_one_with_dispatch_active():
    """ISSUE acceptance: the measured (trip-scaled, compiled-HLO) collective
    census of the manual SAMA step stays exactly unroll+1 all-reduces with
    the kernel-dispatched fused adaptation product in the hot path."""

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.pop(dispatch.ENV_VAR, None)
    out = subprocess.run(
        [sys.executable, "-c", CENSUS_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    # the fused kernel path really was dispatched while tracing the step
    assert ["adam_adapt", "ref"] in r["dispatched"]
    census = r["census"]
    assert census["expected_all_reduces"] == r["unroll"] + 1 == 3
    assert census["all-reduce_count"] == r["unroll"] + 1
    assert census["single_sync_ok"] is True
    assert census["total_count"] == census["all-reduce_count"]
