"""Optimizer substrate tests.

The critical invariant for SAMA is that ``Optimizer.adaptation`` returns the
exact diagonal of du/dg of the *actual* update rule. We pin that against
jax.jacfwd of the scalarized step function, per optimizer, at random
(g, state) points.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


@pytest.fixture(autouse=True)
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def _rand_params(key, shapes=((3,), (2, 4))):
    keys = jax.random.split(key, len(shapes))
    return {f"w{i}": jax.random.normal(k, s, dtype=jnp.float64) for i, (k, s) in enumerate(zip(keys, shapes))}


OPTS = [
    ("sgd", dict(lr=0.1)),
    ("sgd", dict(lr=0.05, weight_decay=0.01)),
    ("momentum", dict(lr=0.1, beta=0.9)),
    ("adam", dict(lr=1e-3)),
    ("adam", dict(lr=1e-3, weight_decay=0.1)),
    ("adamw", dict(lr=1e-3, weight_decay=0.01)),
    ("rmsprop", dict(lr=1e-3)),
]


@pytest.mark.parametrize("name,kwargs", OPTS)
def test_adaptation_matches_jacfwd(name, kwargs):
    opt = optim.get_optimizer(name, **kwargs)
    key = jax.random.PRNGKey(0)
    params = _rand_params(key)
    state = opt.init(params)

    # warm the state with a couple of real steps so moments are non-trivial
    for i in range(3):
        g = _rand_params(jax.random.PRNGKey(10 + i))
        step, state = opt.update(g, state, params)
        params = optim.apply_updates(params, step)

    grads = _rand_params(jax.random.PRNGKey(99))

    # autodiff du/dg of the true update rule, leaf by leaf, elementwise
    def step_of_g(flat_g, treedef, shapes):
        leaves = []
        off = 0
        for s in shapes:
            n = int(np.prod(s))
            leaves.append(flat_g[off : off + n].reshape(s))
            off += n
        g = jax.tree_util.tree_unflatten(treedef, leaves)
        step, _ = opt.update(g, state, params)
        return jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(step)])

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = [l.shape for l in leaves]
    flat_g = jnp.concatenate([l.ravel() for l in leaves])
    jac = jax.jacfwd(step_of_g)(flat_g, treedef, shapes)

    # the update must be elementwise => jacobian diagonal
    off_diag = jac - jnp.diag(jnp.diag(jac))
    np.testing.assert_allclose(np.asarray(off_diag), 0.0, atol=1e-12)

    ad = opt.adaptation(grads, state, params)
    flat_ad = jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(ad)])
    np.testing.assert_allclose(np.asarray(jnp.diag(jac)), np.asarray(flat_ad), rtol=1e-9, atol=1e-12)


def test_sgd_adaptation_is_lr_identity():
    opt = optim.sgd(0.25)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    ad = opt.adaptation({"w": jnp.arange(4.0)}, state, params)
    np.testing.assert_allclose(np.asarray(ad["w"]), 0.25)


def test_apply_updates_subtracts():
    params = {"w": jnp.ones((3,))}
    new = optim.apply_updates(params, {"w": jnp.full((3,), 0.5)})
    np.testing.assert_allclose(np.asarray(new["w"]), 0.5)


def test_schedules_monotone_and_bounds():
    s = optim.schedules.linear_warmup_cosine(1.0, warmup_steps=10, decay_steps=100)
    vals = [float(s(jnp.asarray(t))) for t in range(0, 101, 10)]
    assert vals[0] == 0.0
    assert max(vals) <= 1.0 + 1e-6
    assert vals[-1] <= vals[2]

    d = optim.schedules.linear_decay_with_warmup(2e-5, total_steps=100, warmup_proportion=0.6)
    assert float(d(jnp.asarray(0))) == 0.0
    assert abs(float(d(jnp.asarray(60))) - 2e-5) < 1e-9
    assert float(d(jnp.asarray(100))) <= 1e-12


def test_adam_first_step_matches_reference():
    # reference: step1 of Adam with zero init moments => u = lr * g/(|g|+eps)
    opt = optim.adam(1e-2, b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.zeros((3,), jnp.float64)}
    g = {"w": jnp.asarray([1.0, -2.0, 0.5], jnp.float64)}
    state = opt.init(params)
    step, _ = opt.update(g, state, params)
    expect = 1e-2 * np.asarray([1.0, -2.0, 0.5]) / (np.abs([1.0, -2.0, 0.5]) + 1e-8)
    np.testing.assert_allclose(np.asarray(step["w"]), expect, rtol=1e-6)


# ---------------------------------------------------------------------------
# lion / adafactor: the "broad range of adaptive optimizers" extensions.
# Their adaptation contracts are surrogates (see the optimizer docstrings),
# so they are pinned against the declared surrogate's jacfwd / formula
# rather than the raw update rule.
# ---------------------------------------------------------------------------


def test_lion_update_is_sign_momentum():
    opt = optim.lion(0.1, b1=0.9, b2=0.99)
    params = {"w": jnp.zeros((4,), jnp.float64)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1.0, -2.0, 0.5, -0.1], jnp.float64)}
    step, state2 = opt.update(g, state, params)
    # cold momentum: c = (1-b1) g, so the step is lr * sign(g)
    np.testing.assert_allclose(np.asarray(step["w"]), 0.1 * np.sign([1.0, -2.0, 0.5, -0.1]))
    # momentum advances with b2 (not b1)
    np.testing.assert_allclose(np.asarray(state2.mu["w"]),
                               0.01 * np.asarray([1.0, -2.0, 0.5, -0.1]), rtol=1e-12)


def test_lion_adaptation_matches_surrogate_jacfwd():
    """adaptation == jacfwd of the DECLARED smoothed-sign surrogate
    u = lr * c/(|c|+delta) — not of the a.e.-zero hard sign."""

    lr, b1, delta = 0.05, 0.9, 1e-2
    opt = optim.lion(lr, b1=b1, adapt_delta=delta)
    params = _rand_params(jax.random.PRNGKey(0), shapes=((5,),))
    state = opt.init(params)
    for i in range(2):
        _, state = opt.update(_rand_params(jax.random.PRNGKey(i + 1), shapes=((5,),)),
                              state, params)
    grads = _rand_params(jax.random.PRNGKey(7), shapes=((5,),))

    step_lr = optim.schedules.resolve(lr)(state.count)  # f32, as the optimizer sees it

    def surrogate(g):
        c = b1 * state.mu["w0"] + (1.0 - b1) * g
        return step_lr * c / (jnp.abs(c) + delta)

    jac = jax.jacfwd(surrogate)(grads["w0"])
    ad = opt.adaptation(grads, state, params)
    # rtol 1e-6, not 1e-9: the f32 schedule constant rounds the
    # lr*(1-b1)*delta product differently on the two sides (~3e-8)
    np.testing.assert_allclose(np.asarray(jnp.diag(jac)), np.asarray(ad["w0"]),
                               rtol=1e-6, atol=1e-12)


def test_adafactor_state_is_factored():
    opt = optim.adafactor(1e-3)
    params = {"mat": jnp.zeros((6, 4)), "vec": jnp.zeros((5,))}
    state = opt.init(params)
    assert set(state.nu["mat"]) == {"r", "c"}
    assert state.nu["mat"]["r"].shape == (6,)
    assert state.nu["mat"]["c"].shape == (4,)
    assert set(state.nu["vec"]) == {"v"}
    assert state.nu["vec"]["v"].shape == (5,)


def test_adafactor_adaptation_matches_frozen_statistics_diagonal():
    """adaptation == lr/(sqrt(vhat)+eps) with vhat the factored,
    bias-corrected reconstruction at the post-update statistics — the
    frozen-statistics contract the docstring declares."""

    lr, b2, eps, eps1 = 1e-2, 0.999, 1e-8, 1e-30
    opt = optim.adafactor(lr, b2=b2, eps=eps, eps1=eps1)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 3), jnp.float64)}
    state = opt.init(params)
    for i in range(3):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i + 1), (4, 3), jnp.float64)}
        _, state = opt.update(g, state, params)
    g = {"w": jax.random.normal(jax.random.PRNGKey(9), (4, 3), jnp.float64)}

    t = 4.0
    g2 = g["w"] ** 2 + eps1
    bc2 = 1.0 - b2**t
    r1 = b2 * state.nu["w"]["r"] + (1 - b2) * jnp.mean(g2, axis=1)
    c1 = b2 * state.nu["w"]["c"] + (1 - b2) * jnp.mean(g2, axis=0)
    rhat, chat = r1 / bc2, c1 / bc2
    vhat = rhat[:, None] * chat[None, :] / jnp.mean(rhat)
    want = lr / (jnp.sqrt(vhat) + eps)

    ad = opt.adaptation(g, state, params)
    np.testing.assert_allclose(np.asarray(ad["w"]), np.asarray(want), rtol=1e-6)

    # and the update uses the same vhat: u = lr * g / (sqrt(vhat) + eps)
    step, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(step["w"]),
                               np.asarray(lr * g["w"] / (jnp.sqrt(vhat) + eps)),
                               rtol=1e-6)


@pytest.mark.parametrize("name", ["lion", "adafactor"])
def test_new_optimizers_registered_with_fused_product(name):
    opt = optim.get_optimizer(name, 1e-3)
    assert opt.name == name
    assert opt.adapt_product is not None
    params = {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))}
    state = opt.init(params)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    gm = jax.tree_util.tree_map(lambda p: jnp.full_like(p, 0.5), params)
    v, ss = opt.adapt_product(g, state, params, gm)
    diag = opt.adaptation(g, state, params)
    want = jax.tree_util.tree_map(lambda d, m: d * m, diag, gm)
    for a, b in zip(jax.tree_util.tree_leaves(v), jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    total = sum(float(jnp.sum(x * x)) for x in jax.tree_util.tree_leaves(v))
    np.testing.assert_allclose(float(ss), total, rtol=1e-6)


def test_adam_bias_corrections_finite_in_bf16():
    """Regression: 1 - 0.999^t rounds to 0.0 in bf16 (8 mantissa bits), so
    computing the Adam bias corrections in the gradient dtype made
    vhat = 0/0 = NaN on exactly-zero gradient coordinates (and silently
    zeroed early updates). Both the update rule and the ref adaptation
    kernel must compute the corrections in at-least-f32."""

    opt = optim.adam(1e-2)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    # one exactly-zero coordinate — what microbatch accumulation's
    # f32 -> bf16 round-trip produces on cancelling slices
    g = {"w": jnp.asarray([0.0, 0.1, -0.2, 0.05], jnp.bfloat16)}
    state = opt.init(params)

    upd, state2 = opt.update(g, state, params)
    assert np.all(np.isfinite(np.asarray(upd["w"], np.float32)))
    assert float(jnp.abs(upd["w"][1])) > 0  # not silently zeroed by bc2==0

    diag = opt.adaptation(g, state, params)  # ref kernel path off-TPU
    assert np.all(np.isfinite(np.asarray(diag["w"], np.float32)))
