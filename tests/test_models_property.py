"""Property-based tests on model-layer invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -r requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


class _Cfg:
    """Minimal attention config stub."""

    def __init__(self, H, KV, Dh, D, window=0, softcap=0.0):
        self.num_heads, self.num_kv_heads, self.head_dim, self.d_model = H, KV, Dh, D
        self.sliding_window = window
        self.attn_logit_softcap = softcap
        self.use_rope = False
        self.rope_theta = 1e4
        self.attn_chunk = 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), g=st.sampled_from([1, 2, 4]))
def test_gqa_equals_mha_with_tiled_kv(seed, g):
    """GQA with KV heads tiled G times == MHA: grouping must be exact."""
    B, S, KV, Dh = 2, 8, 2, 16
    H = KV * g
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, S, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, KV, Dh))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, KV, Dh))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = attn.make_mask(pos, jnp.arange(S))
    out_gqa = attn._sdpa(q, k, v, mask)
    k_t = jnp.repeat(k, g, axis=2)
    v_t = jnp.repeat(v, g, axis=2)
    out_mha = attn._sdpa(q, k_t, v_t, mask)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), window=st.sampled_from([2, 4, 8]))
def test_local_mask_matches_global_when_window_covers(seed, window):
    """A local mask with window >= S equals the global causal mask."""
    S = window  # queries see at most `window` positions => same as causal
    pos = jnp.broadcast_to(jnp.arange(S), (1, S))
    m_local = attn.make_mask(pos, jnp.arange(S), local_flag=jnp.asarray(True), window=window)
    m_global = attn.make_mask(pos, jnp.arange(S))
    np.testing.assert_array_equal(np.asarray(m_local), np.asarray(m_global))


def test_softcap_bounds_and_monotone():
    x = jnp.linspace(-500, 500, 101)
    y = cm.softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    assert bool(jnp.all(jnp.diff(y) >= 0))
    np.testing.assert_allclose(np.asarray(cm.softcap(x, 0.0)), np.asarray(x))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100))
def test_moe_uniform_router_keeps_token_norms(seed):
    """With capacity ample and top-k normalized gates, MoE output is a convex
    combination of expert outputs — finite and batch-shape preserving."""
    cfg = configs.get_smoke_config("qwen2-moe-a2.7b")
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model)) * 0.5
    out, aux = moe_mod.apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))
    assert float(aux) > 0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50), chunk=st.sampled_from([4, 8, 16]))
def test_mamba_chunk_invariance(seed, chunk):
    """Chunked SSD must be invariant to the chunk size (== the recurrence)."""
    cfg = configs.get_smoke_config("zamba2-7b")
    p = ssm_mod.init_mamba(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 16, cfg.d_model)) * 0.3
    outs = []
    for q in (chunk, 16):
        c = cfg.replace(ssm_chunk=q)
        outs.append(np.asarray(ssm_mod.apply_mamba(c, p, x)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-3, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50), chunk=st.sampled_from([2, 4, 8]))
def test_rwkv_chunk_invariance(seed, chunk):
    cfg = configs.get_smoke_config("rwkv6-1.6b")
    p = ssm_mod.init_rwkv_time_mix(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 16, cfg.d_model)) * 0.3
    outs = []
    for q in (chunk, 16):
        c = cfg.replace(ssm_chunk=q)
        outs.append(np.asarray(ssm_mod.apply_rwkv_time_mix(c, p, x)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-3, atol=2e-4)


def test_causal_depthwise_conv_is_causal():
    x = jnp.zeros((1, 8, 3)).at[0, 4, :].set(1.0)
    w = jnp.ones((3, 4))
    out = ssm_mod.causal_depthwise_conv(x, w, jnp.zeros((3,)))
    assert np.all(np.asarray(out[0, :4]) == 0)  # nothing before the impulse
    assert np.all(np.asarray(out[0, 4:]) >= 0)
