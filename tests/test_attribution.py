"""repro.obs.profile / repro.obs.diff pins (ISSUE 8).

Three layers:

* hand-built HLO fixtures whose right answers are computable on paper —
  the FLOP model, innermost-phase matching, trip-count scaling through
  fusions called from scanned bodies, fusion-boundary byte accounting,
  per-phase collectives, and the entry liveness watermark;
* the schema (``perf.record.validate_attribution``) and the per-phase
  gate bands (``attribution.{phase}.flops`` / ``.wall_us``);
* real compiled steps: the acceptance pins (coverage >= 0.90 on the
  SAMA step, single-device and manual 8-device schedule, with
  ``models/attention.py`` the top FLOP sink on transformer configs) and
  the family smokes (gemma / qwen-moe / whisper) asserting phase FLOP
  fractions sum to ~1.

Plus the diff CLI: an injected phase regression must rank top.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, obs as obs_mod, optim
from repro.core import EngineConfig, init_state, make_meta_step, problems
from repro.models import Model
from repro.obs import diff as diff_mod
from repro.obs import events as events_mod
from repro.obs import profile as profile_mod
from repro.obs import report as report_mod
from repro.perf import gate as gate_mod
from repro.perf.record import validate_attribution

# ---------------------------------------------------------------------------
# synthetic HLO: every number below is hand-computable
# ---------------------------------------------------------------------------

# Entry runs a while loop (trip 3) whose body calls a fused dot
# (2*8*4*16 = 1024 FLOPs, x3 = 3072) and a reduce (8*16 = 128, x3 = 384),
# then a meta dot nested under local_terms/meta_pass (innermost wins:
# 2*4*4*16 = 512), a cd multiply (128), an all-reduce (f32[128] = 512 B),
# an UNannotated add (128 -> "other") and the finalize root add (128).
# The while condition contributes 1 unannotated compare FLOP.
SYN = """\
HloModule syn_step

%fused_computation.1 (fp0: f32[8,16], fp1: f32[16,4]) -> f32[8,4] {
  %fp0 = f32[8,16] parameter(0)
  %fp1 = f32[16,4] parameter(1)
  ROOT %fdot = f32[8,4] dot(f32[8,16] %fp0, f32[16,4] %fp1), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(step)/base_unroll/scan/mm" source_file="/repo/src/repro/models/attention.py" source_line=10}
}

%wcond (pc: (f32[8,16], f32[16,4])) -> pred[] {
  %pc = (f32[8,16], f32[16,4]) parameter(0)
  ROOT %lt = pred[] compare(f32[] %z, f32[] %z), direction=LT
}

%wbody (p: (f32[8,16], f32[16,4])) -> (f32[8,16], f32[16,4]) {
  %p = (f32[8,16], f32[16,4]) parameter(0)
  %g0 = f32[8,16] get-tuple-element((f32[8,16], f32[16,4]) %p), index=0, metadata={op_name="jit(step)/base_unroll/scan" source_file="/repo/src/repro/core/engine.py" source_line=1}
  %g1 = f32[16,4] get-tuple-element((f32[8,16], f32[16,4]) %p), index=1, metadata={op_name="jit(step)/base_unroll/scan" source_file="/repo/src/repro/core/engine.py" source_line=1}
  %fu = f32[8,4] fusion(f32[8,16] %g0, f32[16,4] %g1), kind=kOutput, calls=%fused_computation.1, metadata={op_name="jit(step)/base_unroll/scan/mm" source_file="/repo/src/repro/models/attention.py" source_line=10}
  %red = f32[8] reduce(f32[8,16] %g0, f32[] %c0), dimensions={1}, metadata={op_name="jit(step)/base_unroll/scan/sum" source_file="/repo/src/repro/models/mlp.py" source_line=5}
  ROOT %rt = (f32[8,16], f32[16,4]) tuple(f32[8,16] %g0, f32[16,4] %g1), metadata={op_name="jit(step)/base_unroll/scan" source_file="/repo/src/repro/core/engine.py" source_line=1}
}

ENTRY %syn_step.main (a: f32[8,16], w: f32[16,4], m: f32[128]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %w = f32[16,4] parameter(1)
  %m = f32[128] parameter(2)
  %t0 = (f32[8,16], f32[16,4]) tuple(f32[8,16] %a, f32[16,4] %w), metadata={op_name="jit(step)/base_unroll" source_file="/repo/src/repro/core/engine.py" source_line=1}
  %loop = (f32[8,16], f32[16,4]) while((f32[8,16], f32[16,4]) %t0), condition=%wcond, body=%wbody, backend_config={"known_trip_count":{"n":"3"}}, metadata={op_name="jit(step)/base_unroll/scan" source_file="/repo/src/repro/core/engine.py" source_line=1}
  %g = f32[8,16] get-tuple-element((f32[8,16], f32[16,4]) %loop), index=0, metadata={op_name="jit(step)/base_unroll" source_file="/repo/src/repro/core/engine.py" source_line=1}
  %md = f32[4,4] dot(f32[16,4] %w, f32[16,4] %w), lhs_contracting_dims={0}, rhs_contracting_dims={0}, metadata={op_name="jit(step)/local_terms/meta_pass/proj" source_file="/repo/src/repro/models/attention.py" source_line=20}
  %cd = f32[8,16] multiply(f32[8,16] %g, f32[8,16] %g), metadata={op_name="jit(step)/local_terms/cd_passes/mul" source_file="/repo/src/repro/core/sama.py" source_line=30}
  %ar = f32[128] all-reduce(f32[128] %m), metadata={op_name="jit(step)/allreduce_flat/ar" source_file="/repo/src/repro/launch/distributed.py" source_line=40}
  %un = f32[8,16] add(f32[8,16] %g, f32[8,16] %g)
  ROOT %out = f32[8,16] add(f32[8,16] %cd, f32[8,16] %un), metadata={op_name="jit(step)/finalize/out" source_file="/repo/src/repro/core/engine.py" source_line=50}
}
"""


def test_synthetic_flops_per_phase_hand_computed():
    attr = profile_mod.attribute(SYN)
    ph = attr["phases"]
    assert ph["base_unroll"]["flops"] == 3072 + 384
    assert ph["meta_pass"]["flops"] == 512      # innermost beats local_terms
    assert "local_terms" not in ph              # nothing charged to the outer scope
    assert ph["cd_passes"]["flops"] == 128
    assert ph["finalize"]["flops"] == 128
    assert ph[profile_mod.OTHER]["flops"] == 128 + 1
    assert attr["total"]["flops"] == 4353
    assert attr["coverage"] == pytest.approx(1.0 - 129 / 4353)
    fracs = sum(b["flop_frac"] for b in ph.values())
    assert fracs == pytest.approx(1.0)
    # ranked: the table iterates phases largest-FLOPs first
    assert next(iter(ph)) == "base_unroll"


def test_synthetic_modules_and_top_sink():
    attr = profile_mod.attribute(SYN)
    mods = attr["modules"]
    assert mods["attention.py"]["flops"] == 3072 + 512
    assert mods["mlp.py"]["flops"] == 384
    assert attr["top_module"] == "attention.py"
    assert mods["attention.py"]["flop_frac"] == pytest.approx(3584 / 4353)


def test_synthetic_collectives_charged_to_phase():
    attr = profile_mod.attribute(SYN)
    arf = attr["phases"]["allreduce_flat"]
    assert arf["collective_count"] == 1
    assert arf["collective_bytes"] == 128 * 4
    # no other phase carries collectives
    assert attr["total"]["collective_count"] == 1
    assert attr["total"]["collective_bytes"] == 512


def test_fusion_interior_traffic_not_charged():
    # renaming the fused computation so it no longer looks fused makes
    # its interior operand/result traffic count -> bytes grow, FLOPs
    # identical (the FLOP model never depended on the fusion boundary)
    unfused = SYN.replace("fused_computation.1", "computation.1")
    a, b = profile_mod.attribute(SYN), profile_mod.attribute(unfused)
    assert a["phases"]["base_unroll"]["flops"] == b["phases"]["base_unroll"]["flops"]
    assert a["phases"]["base_unroll"]["bytes"] < b["phases"]["base_unroll"]["bytes"]


def test_trip_count_scales_through_fusion_call():
    # drop the trip count -> the fused dot and body reduce count once
    once = SYN.replace(', backend_config={"known_trip_count":{"n":"3"}}', "")
    attr = profile_mod.attribute(once)
    assert attr["phases"]["base_unroll"]["flops"] == 1024 + 128


def test_phase_of_innermost_and_other():
    phases = ("base_unroll", "meta_pass", "cd_passes")
    assert profile_mod.phase_of("jit(s)/base_unroll/mm", phases) == "base_unroll"
    assert profile_mod.phase_of(
        "jit(s)/base_unroll/meta_pass/x", phases) == "meta_pass"
    assert profile_mod.phase_of("jit(s)/transpose/x", phases) == profile_mod.OTHER
    assert profile_mod.phase_of("", phases) == profile_mod.OTHER


# Watermark fixture: broadcast a big temp (4 KiB), slice it down (the
# temp dies at the slice), then a dead 32 KiB result (never used, freed
# immediately), then two chained 1 KiB ops. Liveness peaks: base_unroll
# 33792 B (slice + dead live together), meta_pass 2048 B (dead already
# freed — THE pin that dead results don't haunt later phases), finalize
# 2048 B.
WM = """\
HloModule wm

ENTRY %wm.main (p0: f32[256]) -> f32[256] {
  %p0 = f32[256] parameter(0)
  %big = f32[1024] broadcast(f32[256] %p0), dimensions={0}, metadata={op_name="jit(step)/base_unroll/b"}
  %r = f32[256] slice(f32[1024] %big), slice={[0:256]}, metadata={op_name="jit(step)/base_unroll/s"}
  %dead = f32[8192] broadcast(f32[256] %r), dimensions={0}, metadata={op_name="jit(step)/base_unroll/d"}
  %m = f32[256] multiply(f32[256] %r, f32[256] %r), metadata={op_name="jit(step)/meta_pass/m"}
  ROOT %o = f32[256] add(f32[256] %m, f32[256] %m), metadata={op_name="jit(step)/finalize/o"}
}
"""


def test_entry_watermark_liveness():
    attr = profile_mod.attribute(WM)
    ph = attr["phases"]
    assert ph["base_unroll"]["peak_live_bytes"] == 1024 + 32768
    assert ph["meta_pass"]["peak_live_bytes"] == 1024 + 1024
    assert ph["finalize"]["peak_live_bytes"] == 1024 + 1024
    assert attr["memory_source"] == "hlo_entry_walk"


def test_wall_join_computes_utilization():
    spans = [{"name": "base_unroll", "dur_us": 100.0, "traced": False},
             {"name": "base_unroll", "dur_us": 100.0, "traced": False},
             {"name": "meta_pass", "dur_us": 50.0, "traced": False},
             {"name": "meta_pass", "dur_us": 999.0, "traced": True}]

    class S:
        def __init__(self, d):
            self.__dict__.update(d)
    attr = profile_mod.attribute(SYN, spans=[S(d) for d in spans],
                                 peak_flops=1e9, n_devices=2)
    bu = attr["phases"]["base_unroll"]
    assert bu["wall_us"] == 200.0                        # traced span excluded
    assert bu["achieved_flops_per_s"] == pytest.approx(3456 / 200e-6)
    assert bu["utilization"] == pytest.approx(3456 / 200e-6 / 2e9)
    assert "wall_us" not in attr["phases"]["cd_passes"]  # no span, no join
    assert attr["wall_source"] == "tracer_runtime_spans"
    assert attr["n_devices"] == 2


# ---------------------------------------------------------------------------
# schema + gate bands
# ---------------------------------------------------------------------------


def test_validate_attribution_accepts_real_section():
    assert validate_attribution(profile_mod.attribute(SYN)) == []


def test_validate_attribution_catalogs_errors():
    assert validate_attribution([]) != []                     # not a dict
    assert any("phases" in e for e in validate_attribution({"phases": {}}))
    bad = profile_mod.attribute(SYN)
    bad["phases"]["base_unroll"]["flops"] = -1.0
    assert any(".flops" in e for e in validate_attribution(bad))
    off = profile_mod.attribute(SYN)
    off["phases"]["base_unroll"]["flop_frac"] += 0.5          # fracs no longer ~1
    assert any("sum" in e for e in validate_attribution(off))
    cov = profile_mod.attribute(SYN)
    cov["coverage"] = 1.5
    assert any("coverage" in e for e in validate_attribution(cov))
    wall = profile_mod.attribute(SYN)
    wall["phases"]["base_unroll"]["wall_us"] = 0.0
    assert any("wall_us" in e for e in validate_attribution(wall))


def _attr_record(flops=1000.0, wall_us=None):
    b = {"flops": flops, "flop_frac": 1.0}
    if wall_us is not None:
        b["wall_us"] = wall_us
    return {"name": "step",
            "attribution": {"phases": {"base_unroll": b},
                            "total": {"flops": flops}, "coverage": 1.0}}


def test_gate_attribution_flops_band_is_tight():
    tol = gate_mod.Tolerance()
    base = _attr_record(flops=1000.0)
    ok = gate_mod.compare_record("b", _attr_record(flops=1050.0), base, tol)
    assert ok == []                                           # within 1.10x
    bad = gate_mod.compare_record("b", _attr_record(flops=1200.0), base, tol)
    assert [v.metric for v in bad] == ["attribution.base_unroll.flops"]
    # improvements never fail
    assert gate_mod.compare_record("b", _attr_record(flops=10.0), base, tol) == []


def test_gate_attribution_wall_uses_time_band():
    tol = gate_mod.Tolerance()  # time_ratio 2.5
    base = _attr_record(wall_us=100.0)
    assert gate_mod.compare_record(
        "b", _attr_record(wall_us=200.0), base, tol) == []
    bad = gate_mod.compare_record("b", _attr_record(wall_us=300.0), base, tol)
    assert [v.metric for v in bad] == ["attribution.base_unroll.wall_us"]


# ---------------------------------------------------------------------------
# the diff CLI: injected regression must rank top
# ---------------------------------------------------------------------------


def _span_log(path, walls):
    """Write a run log whose phase spans have the given mean durations."""

    sink = events_mod.JsonlSink(path)
    for name, durs in walls.items():
        for d in durs:
            sink.write(events_mod.make_event(
                "span", name, data={"dur_us": float(d), "traced": False}))
    sink.close()
    return path


def test_diff_ranks_injected_phase_top(tmp_path):
    base = _span_log(str(tmp_path / "base.jsonl"),
                     {"base_unroll": [400.0, 400.0], "meta_pass": [100.0],
                      "cd_passes": [80.0]})
    cur = _span_log(str(tmp_path / "cur.jsonl"),
                    {"base_unroll": [410.0, 410.0], "meta_pass": [300.0],
                     "cd_passes": [60.0]})
    rows, unit = diff_mod.diff_paths(base, cur)
    assert unit == "us"
    assert rows[0].phase == "meta_pass"          # injected +200 beats +10
    assert rows[0].delta == pytest.approx(200.0)
    assert rows[0].ratio == pytest.approx(3.0)
    worst = diff_mod.top_regressor(rows)
    assert worst is not None and worst.phase == "meta_pass"
    text = diff_mod.render_diff(rows, unit)
    assert "top regressor is meta_pass" in text
    assert "-20us" in text                       # improvements keep their sign


def test_diff_main_fail_over_and_json(tmp_path, capsys):
    base = _span_log(str(tmp_path / "base.jsonl"), {"meta_pass": [100.0]})
    cur = _span_log(str(tmp_path / "cur.jsonl"), {"meta_pass": [300.0]})
    assert diff_mod.main([base, cur]) == 0       # report-only: no gate
    capsys.readouterr()
    assert diff_mod.main([base, cur, "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["unit"] == "us"
    assert out["top_regressor"]["phase"] == "meta_pass"
    assert diff_mod.main([base, cur, "--fail-over", "50"]) == 1
    assert diff_mod.main([cur, base, "--fail-over", "50"]) == 0  # improvement
    assert diff_mod.main([base, str(tmp_path / "nope.jsonl")]) == 2


def test_diff_bench_records_prefer_wall_else_flops(tmp_path):
    with_wall = {"records": [
        {"name": "a", "attribution": {
            "phases": {"base_unroll": {"flops": 100.0, "wall_us": 5.0},
                       "meta_pass": {"flops": 50.0, "wall_us": 2.0}}}},
        {"name": "b", "attribution": {
            "phases": {"base_unroll": {"flops": 10.0, "wall_us": 1.0}}}},
    ]}
    costs, unit = diff_mod.phase_costs_from_bench(with_wall)
    assert unit == "us" and costs == {"base_unroll": 6.0, "meta_pass": 2.0}
    no_wall = {"records": [{"name": "a", "attribution": {
        "phases": {"base_unroll": {"flops": 100.0}}}}]}
    costs, unit = diff_mod.phase_costs_from_bench(no_wall)
    assert unit == "flops" and costs == {"base_unroll": 100.0}


def test_diff_refuses_unit_mismatch(tmp_path):
    jl = _span_log(str(tmp_path / "a.jsonl"), {"meta_pass": [100.0]})
    bench = tmp_path / "b.json"
    bench.write_text(json.dumps({"records": [{"name": "x", "attribution": {
        "phases": {"meta_pass": {"flops": 9.0}}}}]}))
    with pytest.raises(ValueError, match="cannot diff"):
        diff_mod.diff_paths(jl, str(bench))
    assert diff_mod.main([jl, str(bench)]) == 2


def test_report_diff_hook(tmp_path, capsys):
    base = _span_log(str(tmp_path / "base.jsonl"), {"meta_pass": [100.0]})
    cur = _span_log(str(tmp_path / "cur.jsonl"), {"meta_pass": [250.0]})
    assert report_mod.main([cur, "--diff", base, "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["diff"]["unit"] == "us"
    assert out["diff"]["phases"][0]["phase"] == "meta_pass"
    assert report_mod.main([cur, "--diff", base]) == 0
    assert "top regressor is meta_pass" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# real compiled steps: the ISSUE acceptance pins
# ---------------------------------------------------------------------------


def _mini_bert_problem():
    cfg = configs.get_smoke_config("bert-base").replace(
        d_model=128, num_layers=2, num_labels=4, num_heads=2, num_kv_heads=2,
        head_dim=64, d_ff=256, remat=False)
    model = Model(cfg)
    spec = problems.make_data_optimization_spec(model.classifier_per_example,
                                                reweight=True)
    theta = model.init(jax.random.PRNGKey(0))
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1),
                                              reweight=True)
    rng = np.random.default_rng(0)
    K, B, S, MB = 2, 16, 32, 8
    bb = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (K, B, S)),
                                jnp.int32),
          "y": jnp.zeros((K, B), jnp.int32)}
    mb = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (MB, S)),
                                jnp.int32),
          "y": jnp.zeros((MB,), jnp.int32)}
    return spec, theta, lam, bb, mb


@pytest.fixture(scope="module")
def sama_attr():
    """Compiled single-device SAMA step on a 2-layer transformer + one
    eager step under the tracer for measured phase walls."""

    spec, theta, lam, bb, mb = _mini_bert_problem()
    base_opt, meta_opt = optim.adam(1e-3), optim.adam(1e-3)
    cfg = EngineConfig(method="sama", unroll_steps=2)
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    step = make_meta_step(spec, base_opt, meta_opt, cfg)
    tracer = obs_mod.Tracer()
    with obs_mod.activate(tracer):
        jax.block_until_ready(step(state, bb, mb))
    compiled = jax.jit(step).lower(state, bb, mb).compile()
    return profile_mod.attribute(compiled, spans=tracer.runtime_spans())


def test_sama_step_coverage_and_attention_top(sama_attr):
    # ISSUE 8 acceptance: >= 90% of the compiled step's FLOPs land on a
    # named phase, and attention is the top FLOP sink on a transformer
    assert sama_attr["coverage"] >= 0.90
    assert sama_attr["top_module"] == "attention.py"
    assert sama_attr["modules"]["attention.py"]["flop_frac"] > 0.3
    ph = sama_attr["phases"]
    for needed in ("base_unroll", "meta_pass", "cd_passes"):
        assert ph[needed]["flops"] > 0
    assert next(iter(ph)) == "base_unroll"       # the unroll dominates
    assert sum(b["flop_frac"] for b in ph.values()) == pytest.approx(1.0)
    assert validate_attribution(sama_attr) == []


def test_sama_step_single_device_has_no_collectives(sama_attr):
    assert sama_attr["total"]["collective_count"] == 0


def test_sama_step_watermark_and_walls(sama_attr):
    ph = sama_attr["phases"]
    assert any(b.get("peak_live_bytes", 0) > 0 for b in ph.values())
    bu = ph["base_unroll"]
    assert bu["wall_us"] > 0 and 0 < bu["utilization"]
    assert bu["achieved_flops_per_s"] == pytest.approx(
        bu["flops"] / (bu["wall_us"] * 1e-6))


# family smokes: fractions sum to ~1 everywhere; attention dominates the
# configs whose smoke dims keep real head counts (qwen-moe, whisper) —
# gemma's tiny smoke collapses to common.py ops, which is itself pinned
# so a FLOP-model change that flips it shows up here.
@pytest.mark.parametrize("arch,attention_top", [
    ("gemma3-1b", False),
    ("qwen2-moe-a2.7b", True),
    ("whisper-small", True),
])
def test_family_attribution_smoke(arch, attention_top):
    attr = profile_mod._smoke_attribution(arch)["attribution"]
    assert sum(b["flop_frac"]
               for b in attr["phases"].values()) == pytest.approx(1.0)
    assert attr["coverage"] >= 0.85
    assert validate_attribution(attr) == []
    if attention_top:
        assert attr["top_module"] == "attention.py"
    else:
        assert "attention.py" in attr["modules"]


# manual single-sync schedule on 8 forced host devices: attribution must
# keep the paper's collective story — unroll all-reduces inside
# base_unroll, exactly ONE in allreduce_flat, meta/cd collective-free.
MANUAL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.core import EngineConfig, init_state, problems
from repro.launch import distributed as dist
from repro.launch.mesh import AxisType, make_mesh
from repro.models import Model
from repro.obs import profile as profile_mod

UNROLL = 2
mesh = make_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
cfg = configs.get_smoke_config("bert-base").replace(
    d_model=128, num_layers=2, num_labels=4, num_heads=2, num_kv_heads=2,
    head_dim=64, d_ff=256, remat=False)
model = Model(cfg)
spec = problems.make_data_optimization_spec(model.classifier_per_example,
                                            reweight=True)
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1), reweight=True)
theta = model.init(jax.random.PRNGKey(0))
base_opt, meta_opt = optim.adam(1e-3), optim.adam(1e-3)
K, B, S, MB = UNROLL, 32, 32, 16
bb = {"tokens": jnp.zeros((K, B, S), jnp.int32), "y": jnp.zeros((K, B), jnp.int32)}
mb = {"tokens": jnp.zeros((MB, S), jnp.int32), "y": jnp.zeros((MB,), jnp.int32)}
ecfg = EngineConfig(method="sama", unroll_steps=K)
state = init_state(theta, lam, base_opt, meta_opt, scale=ecfg.scale)
with mesh:
    manual = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, ecfg, mesh))
    compiled = manual.lower(state, bb, mb).compile()
attr = profile_mod.attribute(compiled, n_devices=8)
print(json.dumps({"unroll": UNROLL, "attribution": attr}))
"""


@pytest.fixture(scope="module")
def manual_attr():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", MANUAL_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_manual_schedule_coverage_and_attention(manual_attr):
    attr = manual_attr["attribution"]
    assert attr["coverage"] >= 0.90              # the ISSUE acceptance pin
    assert attr["top_module"] == "attention.py"
    assert attr["n_devices"] == 8
    assert validate_attribution(attr) == []


def test_manual_schedule_collectives_by_phase(manual_attr):
    attr = manual_attr["attribution"]
    unroll = manual_attr["unroll"]
    ph = attr["phases"]
    # unroll+1 single-sync story, now phase-localized
    assert ph["base_unroll"]["collective_count"] == unroll
    assert ph["allreduce_flat"]["collective_count"] == 1
    assert attr["total"]["collective_count"] == unroll + 1
    for quiet in ("meta_pass", "cd_passes"):
        assert ph[quiet]["collective_count"] == 0
    assert ph["allreduce_flat"]["collective_bytes"] > 0


# ISSUE 9: the single-sync census must be invariant to the attention
# backend. With the flash Pallas kernel (interpret mode) forcibly
# dispatched, the 8-device manual schedule still shows EXACTLY unroll+1
# all-reduces and the attribution/event streams stay obs-clean
# (schema-valid, fractions summing to 1). Dims are tiny: interpret mode
# unrolls the kernel grid into the HLO, so this pins structure, not speed.
FLASH_MANUAL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_KERNEL_BACKEND"] = "pallas-interpret"
import json
import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.core import EngineConfig, init_state, problems
from repro.kernels import dispatch
from repro.launch import distributed as dist
from repro.launch.mesh import AxisType, make_mesh
from repro.models import Model
from repro.obs import profile as profile_mod

UNROLL = 2
mesh = make_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
cfg = configs.get_smoke_config("bert-base").replace(
    d_model=64, num_layers=1, num_labels=4, num_heads=2, num_kv_heads=2,
    head_dim=32, d_ff=128, remat=False)
model = Model(cfg)
spec = problems.make_data_optimization_spec(model.classifier_per_example,
                                            reweight=True)
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1), reweight=True)
theta = model.init(jax.random.PRNGKey(0))
base_opt, meta_opt = optim.adam(1e-3), optim.adam(1e-3)
K, B, S, MB = UNROLL, 16, 8, 8
bb = {"tokens": jnp.zeros((K, B, S), jnp.int32), "y": jnp.zeros((K, B), jnp.int32)}
mb = {"tokens": jnp.zeros((MB, S), jnp.int32), "y": jnp.zeros((MB,), jnp.int32)}
ecfg = EngineConfig(method="sama", unroll_steps=K)
state = init_state(theta, lam, base_opt, meta_opt, scale=ecfg.scale)
with mesh:
    manual = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, ecfg, mesh))
    compiled = manual.lower(state, bb, mb).compile()
attr = profile_mod.attribute(compiled, n_devices=8)
picks = sorted({(k, b) for k, b, _ in dispatch.dispatch_log()
                if k == "flash_attention"})
print(json.dumps({"unroll": UNROLL, "attribution": attr,
                  "flash_picks": picks}))
"""


@pytest.fixture(scope="module")
def manual_attr_flash():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", FLASH_MANUAL_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_manual_census_invariant_under_flash_dispatch(manual_attr_flash):
    # the kernel actually lowered (not a silent ref fallback)
    assert ["flash_attention", "pallas-interpret"] in [
        list(p) for p in manual_attr_flash["flash_picks"]]
    attr = manual_attr_flash["attribution"]
    unroll = manual_attr_flash["unroll"]
    ph = attr["phases"]
    assert ph["base_unroll"]["collective_count"] == unroll
    assert ph["allreduce_flat"]["collective_count"] == 1
    assert attr["total"]["collective_count"] == unroll + 1
    for quiet in ("meta_pass", "cd_passes"):
        assert ph[quiet]["collective_count"] == 0


def test_manual_flash_attribution_stays_obs_clean(manual_attr_flash):
    attr = manual_attr_flash["attribution"]
    assert validate_attribution(attr) == []
    assert sum(b["flop_frac"]
               for b in attr["phases"].values()) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# the profile CLI
# ---------------------------------------------------------------------------


def test_profile_cli_validate(tmp_path, capsys):
    good = tmp_path / "attr.json"
    good.write_text(json.dumps(profile_mod.attribute(SYN)))
    assert profile_mod.main(["--validate", str(good)]) == 0
    assert "valid" in capsys.readouterr().out
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"phases": {}}))
    assert profile_mod.main(["--validate", str(bad)]) == 1
    empty = tmp_path / "none.json"
    empty.write_text(json.dumps({"rows": []}))
    assert profile_mod.main(["--validate", str(empty)]) == 1


def test_render_mentions_top_sink():
    text = profile_mod.render(profile_mod.attribute(SYN))
    assert "top FLOP sink: attention.py" in text
    assert "base_unroll" in text and "coverage" in text
