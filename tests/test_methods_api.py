"""Tests for the first-class HypergradMethod API (DESIGN.md §2-5).

1. A toy estimator registered HERE (never touching src/repro/core) runs
   end-to-end through Engine, make_manual_step and repro.api.MetaLearner.
2. Registry/contract validation errors are loud and early.
3. Subprocess (8 forced host devices): for EVERY registered method with a
   linear reduce contract, the manual single-sync schedule equals the pjit
   step under identical per-device batches, and the lowered module carries
   exactly ONE meta-level all-reduce (count_data_allreduces audit: one
   textual all-reduce inside the scanned base unroll + one meta bucket;
   trip-scaled: unroll_steps + 1).
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.api import MetaLearner
from repro.core import EngineConfig, Engine, init_state, problems
from repro.core.methods import (
    HypergradMethod,
    ReduceContract,
    available_methods,
    register_method,
    resolve_method,
    unregister_method,
)
from repro.launch import distributed as dist
from repro.launch.mesh import make_host_mesh


# ---------------------------------------------------------------------------
# a self-contained toy estimator (exact mixed VJP, no core imports)
# ---------------------------------------------------------------------------


class ToyMixedVJP(HypergradMethod):
    """T1-T2-style exact mixed second derivative, written from scratch
    against the protocol only — the "third-party estimator" scenario."""

    name = "toy_mixed_vjp"
    reduce_contract = ReduceContract(terms=("hypergrad", "meta_loss"), linear=True)

    def local_terms(self, spec, ctx):
        meta_loss, g_meta = jax.value_and_grad(spec.meta_scalar, argnums=0)(
            ctx.theta, ctx.lam, ctx.meta_batch
        )

        def inner(lam):
            g = jax.grad(spec.base_scalar, argnums=0)(ctx.theta, lam, ctx.last_batch)
            return sum(
                jnp.vdot(a, b)
                for a, b in zip(jax.tree_util.tree_leaves(g), jax.tree_util.tree_leaves(g_meta))
            )

        hyper = jax.tree_util.tree_map(jnp.negative, jax.grad(inner)(ctx.lam))
        return {"hypergrad": hyper, "meta_loss": meta_loss}


@pytest.fixture
def toy_problem():
    def apply_fn(theta, x):
        return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

    per_ex = problems.softmax_per_example(apply_fn)
    spec = problems.make_data_optimization_spec(per_ex, reweight=True)
    d, h, C = 6, 16, 3
    theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (d, h)) * 0.3,
             "w2": jax.random.normal(jax.random.PRNGKey(1), (h, C)) * 0.3}
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)
    base = {"x": jax.random.normal(jax.random.PRNGKey(3), (2, 8, d)),
            "y": jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, C)}
    meta = {"x": jax.random.normal(jax.random.PRNGKey(5), (4, d)),
            "y": jax.random.randint(jax.random.PRNGKey(6), (4,), 0, C)}
    return spec, theta, lam, base, meta


@pytest.fixture
def custom_registered():
    register_method("toy_mixed_vjp", ToyMixedVJP())
    yield "toy_mixed_vjp"
    unregister_method("toy_mixed_vjp")


def _lam_moved(state, lam0):
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree_util.tree_leaves(state.lam),
                             jax.tree_util.tree_leaves(lam0))]
    return max(diffs)


def test_custom_method_through_engine(toy_problem, custom_registered):
    spec, theta, lam, base, meta = toy_problem
    eng = Engine(spec, optim.adam(1e-2), optim.adam(1e-2),
                 EngineConfig(method=custom_registered, unroll_steps=2))
    state = eng.init(theta, lam)
    state, metrics = eng.step_fn(state, base, meta)
    assert np.isfinite(float(metrics["meta_loss"]))
    assert np.isfinite(float(metrics["hypergrad_norm"]))
    assert _lam_moved(state, lam) > 0


def test_custom_method_through_manual_step(toy_problem, custom_registered):
    spec, theta, lam, base, meta = toy_problem
    mesh = make_host_mesh()
    step = jax.jit(dist.make_manual_step(
        spec, optim.adam(1e-2), optim.adam(1e-2),
        EngineConfig(method=custom_registered, unroll_steps=2), mesh,
    ))
    state = init_state(theta, lam, optim.adam(1e-2), optim.adam(1e-2))
    with mesh:
        state, metrics = step(state, base, meta)
    assert np.isfinite(float(metrics["meta_loss"]))
    assert _lam_moved(state, lam) > 0


def test_custom_method_through_metalearner(toy_problem, custom_registered, tmp_path):
    """Acceptance: a method registered from test code runs end-to-end through
    repro.api.MetaLearner — including checkpoint save/load — without editing
    any src/repro/core file."""

    spec, theta, lam, base, meta = toy_problem
    learner = MetaLearner(spec, base_opt="adam", base_lr=1e-2, meta_opt="adam", meta_lr=1e-2,
                          method=custom_registered, unroll_steps=2,
                          checkpoint_dir=str(tmp_path))
    learner.init(theta, lam)
    hist = learner.fit(iter([(base, meta)] * 3), 3, log_every=1)
    assert len(hist) == 3
    assert np.isfinite(hist[-1]["meta_loss"])
    assert _lam_moved(learner.state, lam) > 0

    path = learner.save()
    assert os.path.basename(path) == "step_000003"
    moved_state = learner.state
    learner.init(theta, lam)  # reset
    learner.load()  # newest under checkpoint_dir
    for a, b in zip(jax.tree_util.tree_leaves(moved_state),
                    jax.tree_util.tree_leaves(learner.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_refuses_mismatched_method(toy_problem, tmp_path):
    spec, theta, lam, base, meta = toy_problem
    saver = MetaLearner(spec, method="sama", unroll_steps=2, checkpoint_dir=str(tmp_path))
    saver.init(theta, lam)
    saver.fit(iter([(base, meta)]), 1)
    saver.save()

    other = MetaLearner(spec, method="t1t2", unroll_steps=2, checkpoint_dir=str(tmp_path))
    other.init(theta, lam)
    with pytest.raises(ValueError, match="saved with method='sama'"):
        other.load()


def test_custom_method_instance_without_registration(toy_problem):
    """A HypergradMethod instance is accepted directly as EngineConfig.method."""

    spec, theta, lam, base, meta = toy_problem
    eng = Engine(spec, optim.adam(1e-2), optim.adam(1e-2),
                 EngineConfig(method=ToyMixedVJP(), unroll_steps=1))
    state = eng.init(theta, lam)
    base1 = jax.tree_util.tree_map(lambda x: x[:1], base)
    state, metrics = eng.step_fn(state, base1, meta)
    assert np.isfinite(float(metrics["meta_loss"]))


# ---------------------------------------------------------------------------
# registry / contract validation
# ---------------------------------------------------------------------------


def test_unknown_method_rejected_at_config_time():
    with pytest.raises(ValueError, match="not registered"):
        EngineConfig(method="definitely_not_a_method")


def test_duplicate_registration_rejected():
    register_method("dup_probe", ToyMixedVJP())
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_method("dup_probe", ToyMixedVJP())
    finally:
        unregister_method("dup_probe")


def test_contract_must_include_mandatory_terms():
    with pytest.raises(ValueError, match="must include"):
        ReduceContract(terms=("hypergrad",))  # no meta_loss


def test_nonlinear_contract_refused_by_manual_schedule(toy_problem):
    spec, *_ = toy_problem
    mesh = make_host_mesh()
    for name in ("cg", "neumann", "iterdiff"):
        assert not resolve_method(name, EngineConfig(method=name)).reduce_contract.linear
        with pytest.raises(ValueError, match="nonlinear reduce contract"):
            dist.make_manual_step(spec, optim.adam(1e-2), optim.adam(1e-2),
                                  EngineConfig(method=name), mesh)


def test_builtin_methods_all_registered():
    assert set(available_methods()) >= {"sama", "sama_na", "t1t2", "neumann", "cg", "iterdiff"}


# ---------------------------------------------------------------------------
# pjit-vs-manual equality + single-sync audit for every linear method
# ---------------------------------------------------------------------------

LINEAR_METHODS = ("sama", "sama_na", "t1t2")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import EngineConfig, init_state, problems, methods
from repro.launch import distributed as dist
from repro.launch.mesh import make_mesh
from repro.roofline import hlo_parse

mesh = make_mesh((8, 1), ("data", "model"))

def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

per_ex = problems.softmax_per_example(apply_fn)
spec = problems.make_data_optimization_spec(per_ex, reweight=True)

d, h, C, K = 6, 16, 3, 2
theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (d, h)) * 0.3,
         "w2": jax.random.normal(jax.random.PRNGKey(1), (h, C)) * 0.3}
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)

x_shard = jax.random.normal(jax.random.PRNGKey(3), (K, 4, d))
y_shard = jax.random.randint(jax.random.PRNGKey(4), (K, 4), 0, C)
mx_shard = jax.random.normal(jax.random.PRNGKey(5), (2, d))
my_shard = jax.random.randint(jax.random.PRNGKey(6), (2,), 0, C)
base_tiled = {"x": jnp.tile(x_shard, (1, 8, 1)), "y": jnp.tile(y_shard, (1, 8))}
meta_tiled = {"x": jnp.tile(mx_shard, (8, 1)), "y": jnp.tile(my_shard, (8,))}

results = {}
for name in methods.available_methods():
    cfg = EngineConfig(method=name, unroll_steps=K)
    if not cfg.resolve().reduce_contract.linear:
        continue
    base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
    state = init_state(theta, lam, base_opt, meta_opt)
    pjit_step = jax.jit(dist.make_pjit_step(spec, base_opt, meta_opt, cfg))
    manual = dist.make_manual_step(spec, base_opt, meta_opt, cfg, mesh)
    with mesh:
        s_ref, _ = pjit_step(state, {"x": x_shard, "y": y_shard},
                             {"x": mx_shard, "y": my_shard})
        s_man, _ = jax.jit(manual)(state, base_tiled, meta_tiled)
        hlo = jax.jit(manual).lower(state, base_tiled, meta_tiled).compile().as_text()
    equal = True
    for part in ("lam", "theta"):
        for a, b in zip(jax.tree_util.tree_leaves(getattr(s_ref, part)),
                        jax.tree_util.tree_leaves(getattr(s_man, part))):
            if not np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6):
                equal = False
    results[name] = {
        "equal": equal,
        "text_allreduces": dist.count_data_allreduces(hlo),
        "trip_scaled_allreduces": hlo_parse.collective_stats(hlo)["all-reduce_count"],
    }
print(json.dumps(results))
"""


@pytest.fixture(scope="module")
def linear_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_every_linear_method_covered(linear_results):
    assert set(linear_results) == set(LINEAR_METHODS)


@pytest.mark.parametrize("method", LINEAR_METHODS)
def test_pjit_vs_manual_equality(linear_results, method):
    assert linear_results[method]["equal"], linear_results[method]


@pytest.mark.parametrize("method", LINEAR_METHODS)
def test_exactly_one_meta_level_allreduce(linear_results, method):
    # textual: 1 all-reduce inside the scanned base-unroll body + exactly 1
    # meta bucket; trip-scaled: K per-step base syncs + that same 1 bucket.
    r = linear_results[method]
    assert r["text_allreduces"] == 2, r
    assert r["trip_scaled_allreduces"] == 2 + 1, r


TP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import EngineConfig, init_state, problems
from repro.launch import distributed as dist
from repro.launch.mesh import make_mesh

# model axis LIVE (4 data x 2 model): the bucket must fall back to the
# per-leaf reduce so tensor-parallel sharding survives.
mesh = make_mesh((4, 2), ("data", "model"))

def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

spec = problems.make_data_optimization_spec(problems.softmax_per_example(apply_fn), reweight=True)
theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (6, 16)) * 0.3,
         "w2": jax.random.normal(jax.random.PRNGKey(1), (16, 3)) * 0.3}
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)
base_opt, meta_opt = optim.adam(1e-2), optim.adam(1e-2)
state = init_state(theta, lam, base_opt, meta_opt)
step = jax.jit(dist.make_manual_step(
    spec, base_opt, meta_opt, EngineConfig(method="sama", unroll_steps=2), mesh))
base = {"x": jax.random.normal(jax.random.PRNGKey(3), (2, 8, 6)),
        "y": jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, 3)}
meta = {"x": jax.random.normal(jax.random.PRNGKey(5), (4, 6)),
        "y": jax.random.randint(jax.random.PRNGKey(6), (4,), 0, 3)}
with mesh:
    state2, metrics = step(state, base, meta)
moved = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree_util.tree_leaves(state2.lam),
                            jax.tree_util.tree_leaves(state.lam)))
print(json.dumps({"finite": all(np.isfinite(float(v)) for v in metrics.values()),
                  "lam_moved": moved}))
"""


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="jax 0.4.x partial-manual shard_map + lax.scan aborts in the XLA "
           "partitioner (hlo_sharding_util IsManualSubgroup check) on meshes "
           "with a live auto axis — pre-existing version limitation, the "
           "per-leaf bucket path is exercised on modern jax",
)
def test_manual_step_with_live_model_axis():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", TP_SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["finite"]
    assert r["lam_moved"] > 0
