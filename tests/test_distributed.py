"""Distributed SAMA tests. Needs >1 host device, so the real work runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main
pytest process keeps 1 device, per the dry-run isolation rule).

Pins:
1. with identical per-device batches, the manual single-sync schedule equals
   the single-device Engine step bit-for-bit (same math, different comms);
2. with genuinely sharded batches, both paths produce finite, close-in-norm
   hypergradient steps (same estimator in expectation);
3. collective structure: the manual path lowers to exactly
   unroll_steps + 1 all-reduces (K base DDP syncs + ONE meta bucket),
   while the naive pjit path emits more (it syncs the meta pass too).
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import BilevelSpec, EngineConfig, init_state, make_meta_step, problems
from repro.launch import distributed as dist
from repro.launch.mesh import AxisType, make_mesh

mesh = make_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)

def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

per_ex = problems.softmax_per_example(apply_fn)
spec = problems.make_data_optimization_spec(per_ex, reweight=True)

d, h, C = 6, 16, 3
key = jax.random.PRNGKey(0)
theta = {"w1": jax.random.normal(key, (d, h)) * 0.3,
         "w2": jax.random.normal(jax.random.PRNGKey(1), (h, C)) * 0.3}
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)

base_opt = optim.adam(1e-2)
meta_opt = optim.adam(1e-2)
cfg = EngineConfig(method="sama", unroll_steps=2)
state = init_state(theta, lam, base_opt, meta_opt)

K, B, MB = 2, 32, 16  # per-device 4 / 2
kx = jax.random.PRNGKey(3)
x_shard = jax.random.normal(kx, (K, 4, d))
y_shard = jax.random.randint(jax.random.PRNGKey(4), (K, 4), 0, C)
mx_shard = jax.random.normal(jax.random.PRNGKey(5), (2, d))
my_shard = jax.random.randint(jax.random.PRNGKey(6), (2,), 0, C)

# identical per-device batches: tile the shard 8x
base_tiled = {"x": jnp.tile(x_shard, (1, 8, 1)), "y": jnp.tile(y_shard, (1, 8))}
meta_tiled = {"x": jnp.tile(mx_shard, (8, 1)), "y": jnp.tile(my_shard, (8,))}

pjit_step = jax.jit(dist.make_pjit_step(spec, base_opt, meta_opt, cfg))
manual_step = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, cfg, mesh))

with mesh:
    s_ref, m_ref = pjit_step(state, {"x": x_shard, "y": y_shard},
                             {"x": mx_shard, "y": my_shard})
    s_man, m_man = manual_step(state, base_tiled, meta_tiled)

# 1. bitwise-ish equality under identical shards
ok_equal = True
for a, b in zip(jax.tree_util.tree_leaves(s_ref.lam), jax.tree_util.tree_leaves(s_man.lam)):
    if not np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6):
        ok_equal = False
for a, b in zip(jax.tree_util.tree_leaves(s_ref.theta), jax.tree_util.tree_leaves(s_man.theta)):
    if not np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6):
        ok_equal = False

# 2. genuinely sharded run: finite and lam moves
xg = jax.random.normal(jax.random.PRNGKey(7), (K, B, d))
yg = jax.random.randint(jax.random.PRNGKey(8), (K, B), 0, C)
mxg = jax.random.normal(jax.random.PRNGKey(9), (MB, d))
myg = jax.random.randint(jax.random.PRNGKey(10), (MB,), 0, C)
with mesh:
    s2, m2 = manual_step(state, {"x": xg, "y": yg}, {"x": mxg, "y": myg})
ok_finite = all(np.isfinite(float(v)) for v in m2.values())
moved = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree_util.tree_leaves(s2.lam), jax.tree_util.tree_leaves(state.lam)))

# 3. collective structure audit on optimized HLO
with mesh:
    man_hlo = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, cfg, mesh)) \
        .lower(state, {"x": xg, "y": yg}, {"x": mxg, "y": myg}).compile().as_text()
    pjit_hlo = jax.jit(dist.make_pjit_step(spec, base_opt, meta_opt, cfg)) \
        .lower(
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype,
                    sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())), state),
            {"x": jax.ShapeDtypeStruct((K, B, d), jnp.float32,
                 sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(None, "data"))),
             "y": jax.ShapeDtypeStruct((K, B), jnp.int32,
                 sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(None, "data")))},
            {"x": jax.ShapeDtypeStruct((MB, d), jnp.float32,
                 sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))),
             "y": jax.ShapeDtypeStruct((MB,), jnp.int32,
                 sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data")))},
        ).compile().as_text()

from repro.roofline import hlo_parse
man_ar = hlo_parse.collective_stats(man_hlo)
pjit_ar = hlo_parse.collective_stats(pjit_hlo)

print(json.dumps({
    "equal_under_tiling": ok_equal,
    "finite": ok_finite,
    "lam_moved": moved,
    "manual_allreduce_count": man_ar["all-reduce_count"],
    "manual_total_collectives": man_ar["total_count"],
    "pjit_allreduce_count": pjit_ar["all-reduce_count"],
    "manual_collective_bytes": man_ar["total_bytes"],
    "pjit_collective_bytes": pjit_ar["total_bytes"],
}))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_manual_equals_pjit_under_identical_shards(result):
    assert result["equal_under_tiling"]


def test_manual_step_finite_and_learning(result):
    assert result["finite"]
    assert result["lam_moved"] > 0


def test_single_sync_schedule_collective_structure(result):
    # K=2 base DDP flat-bucket pmeans + 1 meta flat bucket = EXACTLY 3
    # all-reduces. The flat bucket (distributed.flat_pmean) makes this
    # structural rather than dependent on XLA's all-reduce combiner.
    assert result["manual_allreduce_count"] == 3, result
    assert result["manual_allreduce_count"] < result["pjit_allreduce_count"], result
    assert result["manual_collective_bytes"] < result["pjit_collective_bytes"], result
