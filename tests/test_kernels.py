"""Pallas kernel validation: shape/dtype sweeps vs the ref.py jnp oracles
(interpret mode — CPU container, TPU is the compile target), plus
property-based tests on kernel invariants.

Every ops call here forces ``backend="pallas-interpret"`` — on CPU the
dispatch registry would otherwise (correctly) select the pure-jnp ``ref``
implementation and these parity tests would compare the oracle against
itself. The registry's own selection/fallback behavior is covered by
tests/test_kernel_dispatch.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -r requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

CE_SHAPES = [
    (8, 128),
    (16, 512),
    (24, 2048),  # BR=8, BV=2048 path
    (4, 256),  # BR<8 fallback
    (2, 384),  # BV=128 path
    (64, 4096),
]


@pytest.mark.parametrize("shape", CE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ce_forward_matches_ref(shape, dtype):
    R, V = shape
    key = jax.random.PRNGKey(R * V)
    logits = (jax.random.normal(key, (R, V), jnp.float32) * 4).astype(dtype)
    targets = jax.random.randint(jax.random.PRNGKey(1), (R,), 0, V)
    ce_k = ops.cross_entropy(logits, targets, backend="pallas-interpret")
    ce_r = ref.cross_entropy(logits, targets)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(ce_k), np.asarray(ce_r), rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(8, 512), (16, 2048)])
def test_ce_backward_matches_ref(shape):
    R, V = shape
    logits = jax.random.normal(jax.random.PRNGKey(0), (R, V)) * 3
    targets = jax.random.randint(jax.random.PRNGKey(1), (R,), 0, V)
    w = jax.random.uniform(jax.random.PRNGKey(2), (R,))
    g_k = jax.grad(lambda l: jnp.sum(ops.cross_entropy(l, targets, backend="pallas-interpret") * w))(logits)
    g_r = ref.cross_entropy_grad(logits, targets, w)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-4, atol=1e-6)


def test_ce_batched_shape():
    B, S, V = 2, 8, 256
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, S, V))
    targets = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
    ce = ops.cross_entropy(logits, targets, backend="pallas-interpret")
    assert ce.shape == (B, S)
    ce_r = ref.cross_entropy(logits.reshape(-1, V), targets.reshape(-1)).reshape(B, S)
    np.testing.assert_allclose(np.asarray(ce), np.asarray(ce_r), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    r=st.sampled_from([4, 8, 16]),
    v=st.sampled_from([128, 256, 512]),
    scale=st.floats(0.1, 30.0),
    shift=st.floats(-50.0, 50.0),
)
def test_ce_shift_invariance(r, v, scale, shift):
    """CE is invariant to a constant shift of the logits row — the online
    max/sum-exp accumulator must preserve this exactly enough."""
    logits = jax.random.normal(jax.random.PRNGKey(r * v), (r, v)) * scale
    targets = jax.random.randint(jax.random.PRNGKey(7), (r,), 0, v)
    a = ops.cross_entropy(logits, targets, backend="pallas-interpret")
    b = ops.cross_entropy(logits + shift, targets, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [128, 1000, 8 * 1024, 50_000])
@pytest.mark.parametrize("t", [1, 7])
def test_adam_adapt_matches_ref(n, t):
    gs = [jax.random.normal(jax.random.PRNGKey(i + n), (n,)) for i in range(4)]
    gs[2] = jnp.abs(gs[2])  # v >= 0
    out_k, ss_k = ops.adam_adapt_product(*gs, t=t, lr=0.3, backend="pallas-interpret")
    out_r, ss_r = ref.adam_adapt_product(*gs, t=t, b1=0.9, b2=0.999, eps=1e-8, lr=0.3)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(ss_k), float(ss_r), rtol=1e-4)


def test_adam_adapt_matches_optimizer_adaptation():
    """The kernel must agree with the Optimizer.adaptation diagonal that the
    rest of the system uses (same math, two implementations)."""
    from repro import optim

    n = 4096
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    gm = jax.random.normal(jax.random.PRNGKey(1), (n,))
    opt = optim.adam(0.5)
    params = {"w": jnp.zeros((n,))}
    state = opt.init(params)
    # two warm steps so m, v nonzero
    for i in range(2):
        upd, state = opt.update({"w": jax.random.normal(jax.random.PRNGKey(i + 2), (n,))}, state, params)
        params = optim.apply_updates(params, upd)
    diag = opt.adaptation({"w": g}, state, params)["w"]
    out_k, _ = ops.adam_adapt_product(
        g, state.mu["w"], state.nu["w"], gm, t=int(state.count) + 1, lr=0.5,
        backend="pallas-interpret",
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(diag * gm), rtol=1e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 3000), seed=st.integers(0, 100))
def test_adam_adapt_padding_safe(n, seed):
    """Arbitrary (non-tile-aligned) lengths must round-trip through padding."""
    gs = [jax.random.normal(jax.random.PRNGKey(seed + i), (n,)) for i in range(4)]
    out_k, ss_k = ops.adam_adapt_product(*gs, t=2, backend="pallas-interpret")
    out_r, ss_r = ref.adam_adapt_product(*gs, t=2, b1=0.9, b2=0.999, eps=1e-8, lr=1.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(ss_k), float(ss_r), rtol=1e-4)


@pytest.mark.parametrize("n", [128, 1000, 8 * 1024])
def test_lion_adapt_matches_ref(n):
    g = jax.random.normal(jax.random.PRNGKey(n), (n,))
    m = jax.random.normal(jax.random.PRNGKey(n + 1), (n,))
    gm = jax.random.normal(jax.random.PRNGKey(n + 2), (n,))
    out_k, ss_k = ops.lion_adapt_product(g, m, gm, lr=0.2, backend="pallas-interpret")
    out_r, ss_r = ref.lion_adapt_product(g, m, gm, lr=0.2)
    # rtol 3e-5: near |c|=0 the surrogate peaks at ~lr(1-b1)/delta and f32
    # op-ordering between the fused kernel and the oracle shows up there
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=3e-5, atol=1e-8)
    np.testing.assert_allclose(float(ss_k), float(ss_r), rtol=1e-4)


@pytest.mark.parametrize("n", [128, 1000, 8 * 1024])
def test_adafactor_adapt_matches_ref(n):
    vhat = jnp.abs(jax.random.normal(jax.random.PRNGKey(n), (n,))) + 1e-3
    gm = jax.random.normal(jax.random.PRNGKey(n + 1), (n,))
    out_k, ss_k = ops.adafactor_adapt_product(vhat, gm, lr=0.2, backend="pallas-interpret")
    out_r, ss_r = ref.adafactor_adapt_product(vhat, gm, lr=0.2)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(float(ss_k), float(ss_r), rtol=1e-4)


def test_adapt_kernels_accept_traced_scalars():
    """t and lr ride a scalar input block, so a jitted caller with a traced
    step count / scheduled lr must not retrace or fail."""
    n = 256
    gs = [jax.random.normal(jax.random.PRNGKey(i), (n,)) for i in range(4)]
    gs[2] = jnp.abs(gs[2])

    @jax.jit
    def f(t, lr):
        return ops.adam_adapt_product(*gs, t=t, lr=lr, backend="pallas-interpret")

    out, ss = f(jnp.asarray(3), jnp.asarray(0.3))
    out_r, ss_r = ref.adam_adapt_product(*gs, t=3, b1=0.9, b2=0.999, eps=1e-8, lr=0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(ss), float(ss_r), rtol=1e-4)
