"""Hypergradient correctness, pinned on problems with analytic solutions.

1. Biased regression (paper Appendix E): closed-form base Jacobian, meta
   gradient and optimal meta solution. Exact second-order baselines (CG,
   Neumann, T1-T2 building block) must match the closed form tightly; SAMA
   must be directionally aligned and must *converge* to lambda*.
2. A quadratic bilevel problem where the identity approximation is exact
   (SGD, lr=1, Hessian=I) — SAMA's central difference must equal the exact
   hypergradient to numerical precision.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BilevelSpec, SAMAConfig, sama_hypergrad, baselines
from repro import optim


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def _flat(tree):
    return jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(tree)])


def _cos(a, b):
    a, b = _flat(a), _flat(b)
    return float(jnp.vdot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))


class BiasedRegression:
    """lam* = argmin ||X' w*(lam) - y'||^2 ;  w*(lam) = argmin ||Xw-y||^2 + beta ||w-lam||^2."""

    def __init__(self, key, n=64, n_meta=48, d=10, beta=0.1):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        self.X = jax.random.normal(k1, (n, d), jnp.float64) / np.sqrt(d)
        self.Xp = jax.random.normal(k2, (n_meta, d), jnp.float64) / np.sqrt(d)
        w_true = jax.random.normal(k3, (d,), jnp.float64)
        self.y = self.X @ w_true + 0.1 * jax.random.normal(k4, (n,), jnp.float64)
        self.yp = self.Xp @ w_true
        self.beta = beta
        self.d = d

        self.spec = BilevelSpec(
            base_loss=lambda th, lam, batch: jnp.sum((self.X @ th["w"] - self.y) ** 2)
            + beta * jnp.sum((th["w"] - lam["w"]) ** 2),
            meta_loss=lambda th, lam, batch: jnp.sum((self.Xp @ th["w"] - self.yp) ** 2),
        )

    def w_star(self, lam):
        A = self.X.T @ self.X + self.beta * jnp.eye(self.d)
        return jnp.linalg.solve(A, self.X.T @ self.y + self.beta * lam)

    def true_hypergrad(self, lam):
        A = self.X.T @ self.X + self.beta * jnp.eye(self.d)
        w = self.w_star(lam)
        r = self.Xp @ w - self.yp
        return 2.0 * self.beta * jnp.linalg.solve(A, self.Xp.T @ r)

    def lam_star(self):
        Ainv = jnp.linalg.inv(self.X.T @ self.X + self.beta * jnp.eye(self.d))
        A = self.beta * self.Xp @ Ainv
        b = self.yp - self.Xp @ Ainv @ (self.X.T @ self.y)
        return jnp.linalg.lstsq(A, b)[0]


@pytest.fixture(scope="module")
def prob():
    return BiasedRegression(jax.random.PRNGKey(0))


def test_cg_matches_closed_form(prob):
    lam = {"w": jnp.ones((prob.d,), jnp.float64)}
    theta = {"w": prob.w_star(lam["w"])}
    g = baselines.cg_hypergrad(prob.spec, theta, lam, None, None, num_iters=50, damping=0.0)
    np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(prob.true_hypergrad(lam["w"])), rtol=1e-6)


def test_neumann_matches_closed_form(prob):
    lam = {"w": jnp.full((prob.d,), 0.5, jnp.float64)}
    theta = {"w": prob.w_star(lam["w"])}
    # scale must satisfy ||I - scale*H|| < 1 for convergence
    g = baselines.neumann_hypergrad(prob.spec, theta, lam, None, None, num_terms=3000, scale=0.05)
    np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(prob.true_hypergrad(lam["w"])), rtol=1e-3)


def test_sama_directionally_aligned(prob):
    """Fig. 5 (left): SAMA keeps high cosine similarity to the true meta
    gradient despite the identity approximation."""

    lam = {"w": jnp.ones((prob.d,), jnp.float64)}
    theta = {"w": prob.w_star(lam["w"])}
    opt = optim.sgd(0.01)
    st = opt.init(theta)
    g_base = jax.grad(prob.spec.base_scalar)(theta, lam, None)
    res = sama_hypergrad(
        prob.spec, theta, lam, None, None,
        base_opt=opt, base_opt_state=st, g_base=g_base, cfg=SAMAConfig(alpha=1.0),
    )
    c = _cos(res.hypergrad, {"w": prob.true_hypergrad(lam["w"])})
    assert c > 0.5, c


def test_sama_converges_to_lam_star(prob):
    """Fig. 5 (right): ||lam_t - lam*|| shrinks under SAMA meta updates."""

    lam = {"w": jnp.zeros((prob.d,), jnp.float64)}
    lam_star = prob.lam_star()
    opt = optim.sgd(0.01)
    meta_opt = optim.adam(0.05)
    m_state = meta_opt.init(lam)
    d0 = float(jnp.linalg.norm(lam["w"] - lam_star))
    for _ in range(200):
        theta = {"w": prob.w_star(lam["w"])}
        st = opt.init(theta)
        g_base = jax.grad(prob.spec.base_scalar)(theta, lam, None)
        res = sama_hypergrad(
            prob.spec, theta, lam, None, None,
            base_opt=opt, base_opt_state=st, g_base=g_base, cfg=SAMAConfig(),
        )
        upd, m_state = meta_opt.update(res.hypergrad, m_state, lam)
        lam = optim.apply_updates(lam, upd)
    d_end = float(jnp.linalg.norm(lam["w"] - lam_star))
    assert d_end < 0.2 * d0, (d0, d_end)


def test_sama_exact_when_identity_holds():
    """Base loss 0.5||theta-lam||^2, SGD lr=1: base Jacobian is exactly I, so
    SAMA == exact hypergradient == (lam - t) at theta* = lam."""

    t = jnp.asarray([0.3, -1.2, 2.0], jnp.float64)
    spec = BilevelSpec(
        base_loss=lambda th, lam, b: 0.5 * jnp.sum((th["x"] - lam["x"]) ** 2),
        meta_loss=lambda th, lam, b: 0.5 * jnp.sum((th["x"] - t) ** 2),
    )
    lam = {"x": jnp.asarray([1.0, 0.0, -0.5], jnp.float64)}
    theta = {"x": lam["x"]}  # exact argmin
    opt = optim.sgd(1.0)
    st = opt.init(theta)
    g_base = jax.grad(spec.base_scalar)(theta, lam, None)
    res = sama_hypergrad(
        spec, theta, lam, None, None,
        base_opt=opt, base_opt_state=st, g_base=g_base, cfg=SAMAConfig(alpha=1.0),
    )
    np.testing.assert_allclose(np.asarray(res.hypergrad["x"]), np.asarray(lam["x"] - t), rtol=1e-6, atol=1e-8)


def test_t1t2_equals_sama_na_direction_quadratic(prob):
    """On a quadratic, the central difference is exact, so SAMA-NA's
    hypergradient equals T1-T2's exact mixed VJP."""

    lam = {"w": jnp.ones((prob.d,), jnp.float64) * 0.3}
    theta = {"w": prob.w_star(lam["w"])}
    opt = optim.sgd(1.0)
    st = opt.init(theta)
    g_base = jax.grad(prob.spec.base_scalar)(theta, lam, None)
    res = sama_hypergrad(
        prob.spec, theta, lam, None, None,
        base_opt=opt, base_opt_state=st, g_base=g_base,
        cfg=SAMAConfig(alpha=1.0, adapt=False),
    )
    g_t1t2 = baselines.t1t2_hypergrad(prob.spec, theta, lam, None, None)
    np.testing.assert_allclose(np.asarray(res.hypergrad["w"]), np.asarray(g_t1t2["w"]), rtol=1e-5)


def test_iterdiff_runs_and_descends(prob):
    lam = {"w": jnp.zeros((prob.d,), jnp.float64)}
    theta = {"w": jnp.zeros((prob.d,), jnp.float64)}
    opt = optim.sgd(0.05)
    batches = jnp.zeros((8, 1))  # unused by the closures; leading axis = K
    g = baselines.iterdiff_hypergrad(prob.spec, theta, lam, batches, None, base_opt=opt)
    assert np.all(np.isfinite(np.asarray(g["w"])))
    # descent direction check: moving lam along -g reduces meta loss at w*(lam)
    def meta_at(lam_w):
        return float(prob.spec.meta_scalar({"w": prob.w_star(lam_w)}, None, None))
    l0 = meta_at(lam["w"])
    l1 = meta_at(lam["w"] - 0.05 * g["w"])
    assert l1 <= l0 + 1e-9
