"""repro.serve: queue admission/deadline/shed, paged-cache allocator,
single-call chunked prefill (pinned bitwise vs the seed's per-token
loop), continuous-batched decode (pinned token-exact vs the serial
dense-cache reference), graceful degradation, the dataopt score API,
and the perf-layer latency extensions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, perf, serve
from repro.dataopt import export as dataopt_export
from repro.models import Model
from repro.models import common as cm

# dense-GQA (paged KV), pure-recurrent (state-only), hybrid (both)
E2E_ARCHS = ["gemma3-1b", "rwkv6-1.6b", "zamba2-7b"]


class FakeClock:
    """Deterministic auto-advancing clock for deadline tests."""

    def __init__(self, dt=0.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke_config(arch)
            m = Model(cfg)
            cache[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[arch]

    return get


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)


def _seed_greedy(model, params, prompt, gen, cache_len, dtype):
    """The seed repo's loop: P separate jitted prefill calls — the
    reference the chunked prefill is pinned against."""

    B, P = prompt.shape
    cache = model.init_cache(B, cache_len, dtype=dtype)
    step = jax.jit(model.decode_step)
    logits = None
    for t in range(P):
        logits, cache = step(params, cache, prompt[:, t:t + 1],
                             jnp.asarray(t, jnp.int32))
    toks = [jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)]
    for t in range(P, P + gen - 1):
        logits, cache = step(params, cache, toks[-1][:, None],
                             jnp.asarray(t, jnp.int32))
        toks.append(jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32))
    return jnp.stack(toks, axis=1), cache


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------


def test_queue_fifo_and_overflow_shed():
    clock = FakeClock()
    q = serve.RequestQueue(max_depth=2, clock=clock)
    r1 = q.submit({"a": 1})
    r2 = q.submit({"a": 2})
    with pytest.raises(serve.QueueFull) as ei:
        q.submit({"a": 3})
    assert ei.value.event.reason == serve.STATUS_SHED_OVERFLOW
    assert [r.id for r in q.pop(5)] == [r1.id, r2.id]
    st = q.stats()
    assert (st.submitted, st.admitted, st.shed_overflow) == (3, 2, 1)
    assert len(q.drain_shed()) == 1 and not q.drain_shed()


def test_queue_deadline_shed_on_pop():
    clock = FakeClock()
    q = serve.RequestQueue(max_depth=8, default_timeout_s=5.0, clock=clock)
    q.submit({"a": 1})
    keeper = q.submit({"a": 2}, timeout_s=100.0)
    clock.t = 50.0
    got = q.pop(5)
    assert [r.id for r in got] == [keeper.id]
    assert q.stats().shed_deadline == 1
    assert q.drain_shed()[0].reason == serve.STATUS_SHED_DEADLINE


def test_queue_close_rejects():
    q = serve.RequestQueue(max_depth=2)
    q.close()
    with pytest.raises(serve.QueueClosed):
        q.submit({})


# ---------------------------------------------------------------------------
# paged cache
# ---------------------------------------------------------------------------


def test_build_spec_classifies_time_vs_state_axes(models):
    cfg, m, _ = models("gemma3-1b")
    spec = serve.build_spec(m, page_size=4, dtype=jnp.float32)
    assert spec.paged_idx and spec.token_view_bytes() > 0
    cfg, m, _ = models("rwkv6-1.6b")
    spec = serve.build_spec(m, page_size=4, dtype=jnp.float32)
    assert not spec.paged_idx and spec.state_idx  # pure recurrent: state only
    cfg, m, _ = models("zamba2-7b")
    spec = serve.build_spec(m, page_size=4, dtype=jnp.float32)
    assert spec.paged_idx and spec.state_idx  # hybrid: both


def test_paged_cache_allocator(models):
    _, m, _ = models("gemma3-1b")
    pc = serve.PagedCache(m, slots=3, page_size=4, max_len=32,
                          dtype=jnp.float32)
    s0 = pc.alloc_slot()
    pc.set_len(s0, 10)  # 3 pages
    s1 = pc.alloc_slot()
    pc.set_len(s1, 4)  # 1 page
    assert pc.live_tokens() == 14
    assert list(pc.qo_indptr()) == [0, 10, 14, 14]
    used = set(pc.table[s0, :3]) | {pc.table[s1, 0]}
    assert len(used) == 4 and 0 not in used  # page 0 is the trash page
    base = pc.allocated_bytes()
    pc.free(s0)
    s2 = pc.alloc_slot()
    pc.set_len(s2, 12)  # reuses freed pages: no growth
    assert pc.allocated_bytes() == base and pc.grow_events >= 0
    with pytest.raises(serve.PagedCacheError):
        pc.set_len(s2, 33)  # > max_len
    pc.free(s1)
    pc.free(s2)
    assert pc.free_slot_count() == 3 and pc.live_tokens() == 0


def test_paged_cache_grows_and_respects_max_pages(models):
    _, m, _ = models("gemma3-1b")
    pc = serve.PagedCache(m, slots=2, page_size=4, max_len=16,
                          dtype=jnp.float32, initial_pages=1, max_pages=3)
    s0 = pc.alloc_slot()
    pc.set_len(s0, 8)  # needs 2 pages, pool has 1 free -> grow
    assert pc.grow_events == 1
    s1 = pc.alloc_slot()
    with pytest.raises(serve.PagedCacheError):
        pc.set_len(s1, 8)  # pool capped at max_pages=3 (incl. trash)


def test_paged_allocation_below_dense(models):
    """The design claim: allocated bytes track live tokens, not
    slots x max_len."""

    _, m, _ = models("gemma3-1b")
    slots, max_len = 4, 128
    pc = serve.PagedCache(m, slots=slots, page_size=8, max_len=max_len,
                          dtype=jnp.float32)
    for n in (10, 24, 7, 40):
        pc.set_len(pc.alloc_slot(), n)
    dense = serve.dense_cache_bytes(m, slots, max_len, jnp.float32)
    assert pc.allocated_bytes() < dense
    assert pc.peak_bytes < dense


def test_decode_buckets_and_hbm_budget(models):
    _, m, _ = models("gemma3-1b")
    spec = serve.build_spec(m, page_size=4, dtype=jnp.float32)
    cfg = serve.ServeConfig(slots=2, page_size=4, max_len=32)
    assert serve.decode_buckets(spec, cfg) == (4, 8, 16, 32)
    per_token = spec.token_view_bytes() * cfg.slots
    ok = serve.ServeConfig(slots=2, page_size=4, max_len=32,
                           hbm_budget_bytes=32 * per_token)
    assert serve.decode_buckets(spec, ok) == (4, 8, 16, 32)
    with pytest.raises(ValueError, match="hbm_budget"):
        serve.decode_buckets(spec, serve.ServeConfig(
            slots=2, page_size=4, max_len=32,
            hbm_budget_bytes=8 * per_token))


# ---------------------------------------------------------------------------
# chunked prefill (satellite: single call, pinned bitwise)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", E2E_ARCHS)
def test_scan_prefill_bitwise_vs_seed_loop(models, arch):
    """One jitted scan-prefill call == P separate jitted calls, to the bit
    (logits AND every cache leaf)."""

    cfg, m, params = models(arch)
    dt = cm.dtype_of(cfg.dtype)
    B, P, CL = 2, 9, 16
    prompt = jnp.stack([_prompt(cfg, P, seed=i) for i in range(B)])
    ref_cache = m.init_cache(B, CL, dtype=dt)
    step = jax.jit(m.decode_step)
    logits = None
    for t in range(P):
        logits, ref_cache = step(params, ref_cache, prompt[:, t:t + 1],
                                 jnp.asarray(t, jnp.int32))
    last, cache = serve.chunked_prefill(m, params, prompt,
                                        m.init_cache(B, CL, dtype=dt),
                                        mode="scan")
    assert bool(jnp.all(last == logits[:, 0]))
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all(a == b)), cache, ref_cache))


def test_block_prefill_bitwise_for_gqa(models):
    cfg, m, params = models("gemma3-1b")
    dt = cm.dtype_of(cfg.dtype)
    B, P, CL = 2, 12, 16
    prompt = jnp.stack([_prompt(cfg, P, seed=i) for i in range(B)])
    ref, _ = _seed_greedy(m, params, prompt, 1, CL, dt)
    last, _ = serve.chunked_prefill(m, params, prompt,
                                    m.init_cache(B, CL, dtype=dt),
                                    mode="block")
    assert bool(jnp.all(jnp.argmax(last, -1).astype(jnp.int32) == ref[:, 0]))


def test_block_prefill_rejected_for_recurrent(models):
    cfg, m, params = models("rwkv6-1.6b")
    prompt = jnp.stack([_prompt(cfg, 4)])
    with pytest.raises(ValueError, match="order-unsafe"):
        serve.chunked_prefill(m, params, prompt,
                              m.init_cache(1, 8, dtype=jnp.float32),
                              mode="block")


@pytest.mark.parametrize("arch", E2E_ARCHS + ["minicpm3-4b"])
def test_greedy_generate_matches_seed_loop(models, arch):
    """The rewritten greedy_generate (single-call prefill, configured
    dtype) emits the seed loop's exact token ids."""

    cfg, m, params = models(arch)
    dt = cm.dtype_of(cfg.dtype)
    B, P, gen, CL = 2, 9, 6, 16
    prompt = jnp.stack([_prompt(cfg, P, seed=i) for i in range(B)])
    ref, _ = _seed_greedy(m, params, prompt, gen, CL, dt)
    got = serve.greedy_generate(m, params, prompt, gen, CL)
    assert got.shape == (B, gen)
    assert bool(jnp.all(got == ref))


def test_serve_dtype_follows_config(models):
    """Satellite fix: cache dtype routes through models.common.dtype_of
    instead of hard-coded f32."""

    cfg, _, _ = models("gemma3-1b")
    m = Model(cfg.replace(dtype="bfloat16"))
    params = m.init(jax.random.PRNGKey(0))
    batcher = serve.ContinuousBatcher(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=16))
    assert batcher.dtype == jnp.bfloat16
    assert all(p.dtype == jnp.bfloat16 for p in batcher.cache.pools)
    toks = serve.greedy_generate(m, params,
                                 jnp.stack([_prompt(cfg, 5)]), 4, 16)
    assert toks.shape == (1, 4)


# ---------------------------------------------------------------------------
# continuous batching end-to-end (the tentpole pin)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", E2E_ARCHS)
def test_continuous_batched_matches_serial(models, arch):
    """Mixed-length staggered arrivals with early finishers through the
    queue -> batcher -> paged cache -> executor stack produce EXACTLY the
    serial dense-cache greedy_generate token ids."""

    cfg, m, params = models(arch)
    lens = [5, 9, 3, 12, 7, 1]
    gens = [6, 4, 8, 5, 7, 1]  # early finishers + a prefill-only request
    prompts = [_prompt(cfg, L, seed=i) for i, L in enumerate(lens)]
    ref = [serve.greedy_generate(m, params, jnp.asarray(p[None]), g, 32)[0]
           for p, g in zip(prompts, gens)]

    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=32, max_new_tokens=8))
    ids = [ex.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    stats = ex.run()

    for rid, r in zip(ids, ref):
        res = ex.results[rid]
        assert res.status == serve.STATUS_OK
        assert res.tokens == [int(t) for t in r]
    assert stats.completed == len(lens) and stats.errors == 0
    assert stats.latency is not None and stats.latency.n == len(lens)
    assert stats.qps > 0
    # paged allocation stayed below the dense slots x max_len equivalent
    dense = serve.dense_cache_bytes(m, 2, 32, ex.batcher.dtype)
    if ex.batcher.cache.spec.paged_idx:
        assert stats.memory["peak_bytes"] < dense


def test_executor_rejects_encoder_family(models):
    cfg, m, params = models("bert-base")
    with pytest.raises(ValueError, match="encoder-only"):
        serve.ServeExecutor(m, params, serve.ServeConfig())


def test_executor_overflow_shed(models):
    cfg, m, params = models("gemma3-1b")
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16, max_new_tokens=2, queue_depth=2))
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(5)]
    stats = ex.run()
    statuses = [ex.results[i].status for i in ids]
    assert statuses.count(serve.STATUS_SHED_OVERFLOW) == 3
    assert stats.completed == 2 and stats.shed_overflow == 3
    # shed results resolve with empty output, not a crash or a hang
    assert all(ex.results[i].tokens == [] for i in ids
               if ex.results[i].status == serve.STATUS_SHED_OVERFLOW)


def test_executor_deadline_shed(models):
    cfg, m, params = models("gemma3-1b")
    clock = FakeClock(dt=1.0)
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16, max_new_tokens=4), clock=clock)
    first = ex.submit(_prompt(cfg, 4, seed=0))  # no deadline
    late = [ex.submit(_prompt(cfg, 4, seed=i), timeout_s=2.0)
            for i in range(1, 4)]
    stats = ex.run()
    assert ex.results[first].status == serve.STATUS_OK
    assert all(ex.results[i].status == serve.STATUS_SHED_DEADLINE
               for i in late)
    assert stats.shed_deadline == 3


def test_executor_submit_validation(models):
    cfg, m, params = models("gemma3-1b")
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=1, page_size=4, max_len=16))
    with pytest.raises(ValueError, match="empty prompt"):
        ex.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="exceeds"):
        ex.submit(_prompt(cfg, 10), max_new_tokens=10)  # 20 > max_len


def test_executor_nonfinite_falls_back_to_serial(models):
    """Poisoned params make the batched path emit nonfinite logits; the
    lane must retire into the serial fallback, not crash the loop."""

    cfg, m, _ = models("gemma3-1b")
    params = m.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(lambda x: jnp.full_like(x, jnp.inf),
                                    params)
    ex = serve.ServeExecutor(m, params, serve.ServeConfig(
        slots=2, page_size=4, max_len=16, max_new_tokens=3))
    ids = [ex.submit(_prompt(cfg, 4, seed=i)) for i in range(2)]
    stats = ex.run()
    assert all(ex.results[i].status in
               (serve.STATUS_FALLBACK, serve.STATUS_ERROR) for i in ids)
    assert stats.completed + stats.errors == 2  # every request resolved


# ---------------------------------------------------------------------------
# score API
# ---------------------------------------------------------------------------


def _store(tmp_path, n=10):
    scores = np.linspace(-1.0, 1.0, n).astype(np.float32)
    mask = scores > 0
    path = dataopt_export.export_scores(str(tmp_path / "scores"), scores,
                                        scorer="sama", mask=mask)
    return serve.ScoreStore.load(path, expect_n=n, expect_scorer="sama"), scores, mask


def test_score_store_roundtrip_and_views(tmp_path):
    store, scores, mask = _store(tmp_path)
    ids = np.array([0, 3, 9])
    assert np.array_equal(store.lookup(ids), scores[ids])
    assert np.array_equal(store.keep(ids), mask[ids])
    w = store.weight(np.arange(10), temperature=0.5)
    full = np.exp(scores.astype(np.float64) / 0.5)
    np.testing.assert_allclose(w, full / full.sum(), rtol=1e-5)
    with pytest.raises(IndexError):
        store.lookup([10])


def test_score_api_coalesces_ragged_batches(tmp_path):
    store, scores, _ = _store(tmp_path)
    api = serve.ScoreAPI(store, max_batch=8)
    batches = [[0, 1, 2], [5], [9, 8, 7, 6]]
    futs = [api.submit(b) for b in batches]
    answered = api.run_pending()
    assert answered == 3
    for b, f in zip(batches, futs):
        np.testing.assert_array_equal(f.result(timeout=0), scores[b])
    st = api.stats()
    assert st.batches == 1  # one coalesced lookup, split by qo_indptr
    assert st.latency is not None and st.latency.n == 3


def test_score_api_sheds(tmp_path):
    store, _, _ = _store(tmp_path)
    clock = FakeClock()
    api = serve.ScoreAPI(store, queue_depth=1, default_timeout_s=5.0,
                         clock=clock)
    f1 = api.submit([1])
    f2 = api.submit([2])  # overflow
    with pytest.raises(serve.QueueFull):
        f2.result(timeout=0)
    clock.t = 100.0  # f1's deadline passes while queued
    api.run_pending()
    with pytest.raises(TimeoutError):
        f1.result(timeout=0)
    with pytest.raises(ValueError):
        api.submit([1], kind="nope")


# ---------------------------------------------------------------------------
# perf latency extensions
# ---------------------------------------------------------------------------


def test_latency_stats_percentiles():
    s = perf.LatencyStats.from_samples([0.001 * (i + 1) for i in range(100)])
    assert s.n == 100
    assert s.p50_us == pytest.approx(50500.0, rel=0.01)
    assert s.p99_us <= s.max_us == pytest.approx(100000.0, rel=1e-6)
    assert s.p50_us <= s.p90_us <= s.p99_us
    # zero samples (every request shed before decode) is a reportable
    # value, not a crash: explicit empty stats with n == 0
    empty = perf.LatencyStats.from_samples([])
    assert empty == perf.LatencyStats.empty()
    assert empty.n == 0 and empty.p99_us == 0.0 and empty.mean_us == 0.0


def test_perf_record_latency_section():
    lat = perf.LatencyStats.from_samples([0.01, 0.02, 0.03]).as_dict()
    rec = perf.PerfRecord(name="serve_x", latency=lat).as_dict()
    assert perf.validate_record(rec) == []
    bad = dict(rec, latency={"p50_us": 1.0})
    assert any("latency" in e for e in perf.validate_record(bad))
    # latency alone counts as a measured section
    none = perf.PerfRecord(name="empty").as_dict()
    assert any("no measured section" in e for e in perf.validate_record(none))


def test_gate_bands_latency():
    lat = perf.LatencyStats.from_samples([0.01] * 4).as_dict()
    base = perf.PerfRecord(name="serve_x", latency=lat).as_dict()
    slow = dict(lat, p99_us=lat["p99_us"] * 10)
    cur = perf.PerfRecord(name="serve_x", latency=slow).as_dict()
    tol = perf.Tolerance()
    bad = perf.compare_record("serve", cur, base, tol)
    assert [v.metric for v in bad] == ["latency.p99_us"]
    assert perf.compare_record("serve", base, base, tol) == []
