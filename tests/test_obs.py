"""repro.obs tests (ISSUE 7 acceptance pins).

The two load-bearing guarantees:

1. **Zero overhead when disabled** — a run without observability emits
   zero events and performs zero per-event work (NULL_OBS short-circuits
   before building Event objects).
2. **Byte-identical HLO** — the engine's phase annotations are
   unconditional metadata-only ``jax.named_scope``, so the lowered step
   is bitwise identical whether or not a tracer/obs pipeline is active
   during tracing.

Plus: event schema + sinks (ring eviction order, JSONL round-trip
through the report CLI), metric instruments, span tracing, every health
monitor on a synthetic stream, kernel-dispatch counter mirroring, and
the serve-plane queue/executor instrumentation hooks.
"""

import io
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs as obs_mod
from repro import optim
from repro.core import EngineConfig, init_state, make_meta_step, problems
from repro.core.engine import run_loop
from repro.obs import events as events_mod
from repro.obs import health as health_mod
from repro.obs import metrics as metrics_mod
from repro.obs import report as report_mod
from repro.obs import trace as trace_mod


# ---------------------------------------------------------------------------
# fixtures: the tiny classifier bilevel problem every core test uses
# ---------------------------------------------------------------------------


def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]


def make_problem(seed=0, d=6, h=8, C=3):
    per_ex = problems.softmax_per_example(apply_fn)
    spec = problems.make_data_optimization_spec(per_ex, reweight=True)
    theta = {
        "w1": jax.random.normal(jax.random.PRNGKey(seed), (d, h)) * 0.3,
        "w2": jax.random.normal(jax.random.PRNGKey(seed + 1), (h, C)) * 0.3,
    }
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(seed + 2),
                                              reweight=True)
    return spec, theta, lam


def make_batches(seed, K, B, MB, d=6, C=3):
    bb = {"x": jax.random.normal(jax.random.PRNGKey(seed + 3), (K, B, d)),
          "y": jax.random.randint(jax.random.PRNGKey(seed + 4), (K, B), 0, C)}
    mb = {"x": jax.random.normal(jax.random.PRNGKey(seed + 5), (MB, d)),
          "y": jax.random.randint(jax.random.PRNGKey(seed + 6), (MB,), 0, C)}
    return bb, mb


def ring_obs(capacity=256, monitor=True):
    sink = events_mod.RingSink(capacity)
    return obs_mod.Obs(sink=sink, monitor=monitor), sink


def ev(kind, name, data=None, step=None):
    return events_mod.make_event(kind, name, data=data, step=step)


# ---------------------------------------------------------------------------
# events + sinks
# ---------------------------------------------------------------------------


def test_make_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown event kind"):
        events_mod.make_event("nonsense", "x")


def test_validate_event_catalogs_errors():
    good = ev("log", "hello", data={"text": "hi"}).as_dict()
    assert events_mod.validate_event(good) == []
    bad = {"v": 99, "kind": "nope", "name": "", "t": "later",
           "step": 1.5, "data": None}
    errors = events_mod.validate_event(bad)
    assert len(errors) == 6
    assert events_mod.validate_event("not a dict")


def test_ring_sink_eviction_order():
    ring = events_mod.RingSink(capacity=3)
    for i in range(5):
        ring.write(ev("log", f"e{i}"))
    names = [e.name for e in ring.events()]
    assert names == ["e2", "e3", "e4"]  # FIFO eviction, oldest-first read
    assert ring.dropped == 2
    with pytest.raises(ValueError, match="capacity"):
        events_mod.RingSink(0)


def test_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    sink = events_mod.JsonlSink(path)
    wrote = [ev("run", "run_start", data={"cli": "test"}),
             ev("metrics", "step", data={"loss": 1.5}, step=0),
             ev("alert", "nonfinite", data={"severity": "warn"})]
    for e in wrote:
        sink.write(e)
    sink.close()
    assert events_mod.validate_jsonl(path) == []
    back = list(events_mod.read_jsonl(path))
    assert [(e.kind, e.name, e.step, e.data) for e in back] == \
        [(e.kind, e.name, e.step, e.data) for e in wrote]


def test_read_jsonl_skips_torn_line(tmp_path):
    path = str(tmp_path / "torn.jsonl")
    sink = events_mod.JsonlSink(path)
    sink.write(ev("log", "whole"))
    sink.close()
    with open(path, "a") as f:
        f.write('{"v": 1, "kind": "log", "na')  # crashed writer
    assert [e.name for e in events_mod.read_jsonl(path)] == ["whole"]
    with pytest.raises(ValueError, match="not JSON"):
        list(events_mod.read_jsonl(path, strict=True))
    assert events_mod.validate_jsonl(path)  # non-strict validation reports it


def test_console_sink_renders_legacy_lines():
    buf = io.StringIO()
    console = events_mod.ConsoleSink(stream=buf)
    console.write(ev("log", "header", data={"text": "arch=x params=3"}))
    console.write(ev("metrics", "step", data={"loss": 1.25}, step=4))
    console.write(ev("metrics", "registry_snapshot", data={"big": "dump"}))
    console.write(ev("span", "base_unroll", data={"dur_us": 5.0}))
    console.write(ev("alert", "nonfinite",
                     data={"severity": "warn", "message": "skipped"}))
    lines = buf.getvalue().splitlines()
    assert lines[0] == "arch=x params=3"
    assert json.loads(lines[1]) == {"loss": 1.25, "step": 4}  # the train.py shape
    assert lines[2] == "[obs:warn] nonfinite: skipped"
    assert len(lines) == 3  # snapshots and span chatter stay off the console


# ---------------------------------------------------------------------------
# metric instruments
# ---------------------------------------------------------------------------


def test_counter_monotone_and_labeled():
    c = metrics_mod.Counter("dispatch_total")
    c.inc()
    c.inc(2, labels={"backend": "ref"})
    c.inc(labels={"backend": "ref"})
    assert c.value() == 1.0
    assert c.value(labels={"backend": "ref"}) == 3.0
    assert c.total() == 4.0
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_gauge_tracks_excursions():
    g = metrics_mod.Gauge("queue_depth")
    for v in (3, 9, 1):
        g.set(v)
    assert g.value() == 1.0
    snap = g.snapshot()["values"][0]
    assert (snap["min"], snap["max"]) == (1.0, 9.0)


def test_histogram_quantiles_and_snapshot():
    h = metrics_mod.Histogram("lat_us", bounds=[10.0, 100.0, 1000.0])
    for v in [5.0] * 50 + [50.0] * 40 + [5000.0] * 10:
        h.observe(v)
    assert h.n == 100
    assert h.quantile(0.5) <= 10.0          # median in the first bucket
    assert 10.0 < h.quantile(0.9) <= 100.0
    assert h.quantile(1.0) == 5000.0        # overflow bucket reports the max
    assert h.quantile(0.0) == 0.0 or h.quantile(0.0) <= 10.0
    snap = h.snapshot()
    assert snap["n"] == 100 and snap["max"] == 5000.0
    assert metrics_mod.Histogram("empty").quantile(0.99) == 0.0
    with pytest.raises(ValueError, match="sorted"):
        metrics_mod.Histogram("bad", bounds=[2.0, 1.0])


def test_registry_get_or_create_and_kind_conflict():
    reg = metrics_mod.MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("a")
    reg.gauge("b").set(1.0)
    assert set(reg.snapshot()) == {"a", "b"}


def test_packed_read_unwraps_device_scalars():
    tree = {"loss": jnp.float32(1.5), "n": jnp.int32(3), "plain": 2.0}
    out = metrics_mod.packed_read(tree)
    assert out == {"loss": 1.5, "n": 3, "plain": 2.0}
    assert isinstance(out["loss"], float) and isinstance(out["n"], int)


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------


def test_phase_without_tracer_records_nothing():
    with trace_mod.phase("base_unroll"):
        pass
    assert trace_mod.active_tracer() is None


def test_tracer_nested_spans_and_chrome_trace():
    tracer = trace_mod.Tracer()
    with trace_mod.activate(tracer):
        with trace_mod.phase("meta_update"):
            with trace_mod.phase("cd_passes"):
                pass
    inner, outer = tracer.spans  # completion order: inner first
    assert (inner.name, inner.depth, inner.parent) == ("cd_passes", 1, "meta_update")
    assert (outer.name, outer.depth, outer.parent) == ("meta_update", 0, None)
    assert not inner.traced and outer.dur_s >= inner.dur_s
    doc = trace_mod.chrome_trace(tracer.spans)
    assert {e["name"] for e in doc["traceEvents"]} == {"cd_passes", "meta_update"}
    assert all(e["ph"] == "X" and e["tid"] == 0 for e in doc["traceEvents"])
    rows = trace_mod.span_tree_summary(tracer.spans)
    assert [r["name"] for r in rows] == ["cd_passes", "meta_update"]  # PHASES order


def test_tracer_marks_trace_time_spans():
    tracer = trace_mod.Tracer()

    @jax.jit
    def f(x):
        with trace_mod.phase("base_unroll"):
            return x * 2

    with trace_mod.activate(tracer):
        f(jnp.ones(3)).block_until_ready()
    assert [s.traced for s in tracer.spans] == [True]
    assert tracer.runtime_spans() == []
    doc = trace_mod.chrome_trace(tracer.spans)
    assert doc["traceEvents"][0]["tid"] == 1  # trace-time spans on their own row


def test_tracer_mirrors_spans_into_obs():
    obs, sink = ring_obs()
    tracer = trace_mod.Tracer(obs=obs)
    with trace_mod.activate(tracer):
        with trace_mod.phase("finalize"):
            pass
    spans = [e for e in sink.events() if e.kind == "span"]
    assert [e.name for e in spans] == ["finalize"]
    assert spans[0].data["dur_us"] > 0


# ---------------------------------------------------------------------------
# the acceptance pins: zero events when disabled, byte-identical HLO
# ---------------------------------------------------------------------------


def test_null_obs_is_inert():
    before = obs_mod.NULL_OBS.sink
    assert obs_mod.NULL_OBS.emit("log", "x", data={"a": 1}) is None
    obs_mod.NULL_OBS.log("x", "text")
    obs_mod.NULL_OBS.observe_step(0, {"loss": float("nan")})
    obs_mod.NULL_OBS.observe_census(5, 3)
    obs_mod.NULL_OBS.flush()
    assert obs_mod.NULL_OBS.sink is before
    assert not obs_mod.NULL_OBS.enabled


def test_disabled_run_emits_zero_events():
    spec, theta, lam = make_problem()
    base_opt, meta_opt = optim.sgd(0.1), optim.sgd(0.1)
    cfg = EngineConfig(method="sama", unroll_steps=2)
    step = jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    bb, mb = make_batches(0, K=2, B=8, MB=4)
    batches = iter([(bb, mb)] * 3)
    obs, sink = ring_obs()
    obs.enabled = False  # the off switch, not a different wiring
    _, history = run_loop(step, state, batches, 3, log_every=1, obs=obs)
    assert len(history) == 3
    assert sink.events() == []


def test_hlo_identical_with_and_without_tracer():
    """The tentpole guarantee: activating the span tracer (what an enabled
    obs does) cannot change what the step compiles to."""

    spec, theta, lam = make_problem()
    base_opt, meta_opt = optim.sgd(0.1), optim.sgd(0.1)
    cfg = EngineConfig(method="sama", unroll_steps=2)
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    bb, mb = make_batches(0, K=2, B=8, MB=4)

    def lowered():
        step = make_meta_step(spec, base_opt, meta_opt, cfg)
        return jax.jit(step).lower(state, bb, mb)

    plain = lowered()
    with trace_mod.activate(trace_mod.Tracer(obs=ring_obs()[0])):
        traced = lowered()
    assert plain.as_text() == traced.as_text()
    # named_scope metadata is ALWAYS present (it lives in the location info,
    # which the default as_text strips — invisible to the byte-compare above)
    debug_asm = plain.compiler_ir().operation.get_asm(enable_debug_info=True)
    assert "base_unroll" in debug_asm


def test_run_loop_emits_metrics_events():
    spec, theta, lam = make_problem()
    base_opt, meta_opt = optim.sgd(0.1), optim.sgd(0.1)
    cfg = EngineConfig(method="sama", unroll_steps=2)
    step = jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))
    state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)
    bb, mb = make_batches(0, K=2, B=8, MB=4)
    obs, sink = ring_obs()
    _, history = run_loop(step, state, iter([(bb, mb)] * 4), 4,
                          log_every=2, obs=obs)
    steps = [e for e in sink.events() if e.kind == "metrics"]
    assert [e.step for e in steps] == [0, 2, 3]  # log cadence + final step
    assert steps[0].data.keys() == {k for k in history[0] if k != "step"}
    assert all(math.isfinite(v) for v in steps[0].data.values())


# ---------------------------------------------------------------------------
# the Obs facade: derived scale/gate events, census, alerts
# ---------------------------------------------------------------------------


def test_observe_step_derives_scale_and_gate_events():
    obs, sink = ring_obs()
    obs.observe_step(0, {"loss": 1.0, "loss_scale": 1024.0, "meta_skipped": 0.0})
    obs.observe_step(1, {"loss": 1.1, "loss_scale": 512.0, "meta_skipped": 1.0})
    obs.observe_step(2, {"loss": 1.2, "loss_scale": 1024.0, "meta_skipped": 0.0})
    kinds = [(e.kind, e.name, e.step) for e in sink.events()
             if e.kind in ("scale", "gate")]
    assert ("scale", "backoff", 1) in kinds
    assert ("scale", "growth", 2) in kinds
    assert ("gate", "meta_update", 1) in kinds
    assert obs.counter("loss_scale_transitions").value(
        labels={"kind": "backoff"}) == 1.0
    assert obs.counter("meta_updates_skipped").value() == 1.0


def test_observe_census_and_monitor_trip():
    obs, sink = ring_obs()
    obs.observe_census(3, 3, detail={"schedule": "single_sync"})
    assert obs.health.status == "ok"
    obs.observe_census(5, 3)
    assert obs.health.status == "degraded"
    alerts = [e for e in sink.events() if e.kind == "alert"]
    assert alerts and alerts[0].name == "census"


def test_alerts_reach_sink_and_callbacks():
    fired = []
    obs, sink = ring_obs()
    obs.health.add_callback(fired.append)
    for s in range(3):
        obs.emit("gate", "meta_update", data={"finite": False}, step=s)
    severities = [e.data["severity"] for e in sink.events() if e.kind == "alert"]
    assert severities == ["warn", "degraded"]
    assert [a.severity for a in fired] == ["warn", "degraded"]
    assert obs.health.status == "degraded"


def test_make_obs_sink_selection(tmp_path):
    path = str(tmp_path / "log.jsonl")
    multi = obs_mod.make_obs(log_path=path, console=True, ring=8)
    assert isinstance(multi.sink, events_mod.TeeSink)
    assert len(multi.sink.sinks) == 3
    solo = obs_mod.make_obs()
    assert isinstance(solo.sink, events_mod.RingSink)
    multi.close()


def test_default_obs_process_global():
    assert obs_mod.get_default() is obs_mod.NULL_OBS
    obs, _ = ring_obs()
    try:
        obs_mod.set_default(obs)
        assert obs_mod.get_default() is obs
    finally:
        obs_mod.set_default(None)
    assert obs_mod.get_default() is obs_mod.NULL_OBS


# ---------------------------------------------------------------------------
# health monitors on synthetic streams
# ---------------------------------------------------------------------------


def test_nonfinite_monitor_consecutive_and_rate():
    m = health_mod.NonfiniteMonitor(consecutive_limit=3, window=10,
                                    rate_limit=0.25)
    alerts = []
    for s in range(3):
        alerts += m.observe(ev("gate", "meta_update",
                               data={"finite": False}, step=s))
    assert [a.severity for a in alerts] == ["warn", "degraded"]
    assert m.verdict()["status"] == "degraded"
    # rate path: 4 bad of 10 in the window trips the 25% limit
    m2 = health_mod.NonfiniteMonitor(consecutive_limit=99, window=10,
                                     rate_limit=0.25)
    out = []
    for s in range(10):
        bad = s % 3 == 0  # 4/10
        out += m2.observe(ev("metrics", "step",
                             data={"meta_skipped": 1.0 if bad else 0.0}, step=s))
    assert any(a.severity == "degraded" for a in out)


def test_nonfinite_monitor_ignores_gate_echo_of_metrics_step():
    """Live streams emit metrics/step AND a gate event for the same skipped
    step; the step must count once."""

    m = health_mod.NonfiniteMonitor()
    m.observe(ev("metrics", "step", data={"meta_skipped": 1.0}, step=0))
    m.observe(ev("gate", "meta_update", data={"finite": False}, step=0))
    m.observe(ev("metrics", "registry_snapshot", data={}))  # not a step
    assert m.total_steps == 1 and m.total_bad == 1


def test_loss_scale_thrash_monitor():
    m = health_mod.LossScaleThrashMonitor(window_steps=200, warn_backoffs=3,
                                          degraded_backoffs=6)
    alerts = []
    scale = 2.0 ** 15
    for s in range(6):
        alerts += m.observe(ev("scale", "backoff",
                               data={"scale": scale / 2, "prev": scale},
                               step=s * 10))
        scale /= 2
    assert [a.severity for a in alerts] == ["warn", "degraded"]
    assert m.total_backoffs == 6
    # backoffs spread far apart never accumulate in the window
    m2 = health_mod.LossScaleThrashMonitor(window_steps=200)
    for s in range(6):
        assert m2.observe(ev("scale", "backoff", data={"scale": 1.0},
                             step=s * 500)) == []
    assert m2.verdict()["status"] == "ok"


def test_serve_slo_monitor():
    m = health_mod.ServeSLOMonitor(window=100, min_events=10)
    alerts = []
    for i in range(10):
        name = "deadline_miss" if i < 4 else "done"
        alerts += m.observe(ev("serve", name, data={}))
    assert alerts and alerts[-1].severity == "degraded"  # 40% > 30%
    assert m.observe(ev("serve", "rejected", data={})) == []  # not load
    v = m.verdict()
    assert v["deadline_miss"] == 4 and v["done"] == 6


def test_queue_depth_monitor_needs_sustained_saturation():
    m = health_mod.QueueDepthMonitor(sustain=5)
    tick = lambda d: ev("serve", "tick", data={"queue_depth": d, "capacity": 100})
    for _ in range(4):
        assert m.observe(tick(96)) == []
    assert m.observe(tick(50)) == []  # run broken before sustain
    alerts = []
    for _ in range(5):
        alerts += m.observe(tick(96))
    assert [a.severity for a in alerts] == ["degraded"]
    assert m.max_frac == 0.96


def test_replay_equals_live():
    stream = [ev("gate", "meta_update", data={"finite": False}, step=s)
              for s in range(3)]
    stream.append(ev("census", "all_reduce",
                     data={"observed": 4, "expected": 3, "ok": False}))
    live = health_mod.HealthMonitor()
    for e in stream:
        live.observe(e)
    offline = health_mod.replay(stream)
    assert live.status == offline.status == "degraded"
    assert [a.monitor for a in live.alerts] == [a.monitor for a in offline.alerts]


# ---------------------------------------------------------------------------
# report CLI
# ---------------------------------------------------------------------------


def _write_run_log(path):
    sink = events_mod.JsonlSink(path)
    sink.write(ev("run", "run_start", data={"cli": "test"}))
    sink.write(ev("span", "base_unroll", data={"dur_us": 100.0, "traced": False}))
    sink.write(ev("span", "meta_pass", data={"dur_us": 40.0, "traced": False}))
    sink.write(ev("metrics", "step", data={"loss": 2.0}, step=0))
    sink.write(ev("scale", "backoff", data={"scale": 512.0, "prev": 1024.0},
                  step=1))
    sink.write(ev("metrics", "step", data={"loss": 1.0}, step=9))
    sink.write(ev("dispatch", "adam_adapt",
                  data={"backend": "ref", "reason": "selected"}))
    sink.write(ev("census", "all_reduce",
                  data={"observed": 3, "expected": 3, "ok": True}))
    sink.write(ev("serve", "done", data={}))
    sink.write(ev("run", "run_end", data={}))
    sink.close()


def test_report_summarize_and_render(tmp_path):
    path = str(tmp_path / "run.jsonl")
    _write_run_log(path)
    events = list(events_mod.read_jsonl(path))
    s = report_mod.summarize(events)
    assert s["events"] == 10
    assert [p["name"] for p in s["phases"]] == ["base_unroll", "meta_pass"]
    assert s["steps"]["first"]["loss"] == 2.0 and s["steps"]["last"]["step"] == 9
    assert s["scale_history"][0]["event"] == "backoff"
    assert s["dispatch"][0] == {"kernel": "adam_adapt", "backend": "ref",
                                "reason": "selected", "n": 1}
    assert s["census"]["ok"] is True
    assert s["health"]["status"] == "ok"
    text = report_mod.render(s)
    for needle in ("base_unroll", "backoff", "adam_adapt", "health: OK"):
        assert needle in text


def test_report_main_validate_and_json(tmp_path, capsys):
    path = str(tmp_path / "run.jsonl")
    _write_run_log(path)
    assert report_mod.main([path, "--validate"]) == 0
    capsys.readouterr()
    assert report_mod.main([path, "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["events"] == 10
    # a schema-violating line fails --validate but not the lenient path
    with open(path, "a") as f:
        f.write(json.dumps({"v": 1, "kind": "bogus", "name": "x", "t": 0.0,
                            "step": None, "data": {}}) + "\n")
    assert report_mod.main([path, "--validate"]) == 1
    assert report_mod.main([path]) == 0
    missing = str(tmp_path / "empty.jsonl")
    open(missing, "w").close()
    assert report_mod.main([missing]) == 1


# ---------------------------------------------------------------------------
# kernel dispatch mirroring + serve queue hooks
# ---------------------------------------------------------------------------


def test_dispatch_decisions_mirrored_to_obs():
    from repro.kernels import dispatch

    obs, sink = ring_obs()
    n = 64
    args = tuple(jnp.ones((n,), jnp.float32) for _ in range(4))
    kw = dict(t=1, b1=0.9, b2=0.999, eps=1e-8, lr=1e-3)
    try:
        obs_mod.set_default(obs)
        dispatch.get_kernel("adam_adapt")(*args, **kw)
        dispatch.get_kernel("adam_adapt", backend="ref")(*args, **kw)
    finally:
        obs_mod.set_default(None)
        dispatch.clear_dispatch_log()
    decisions = [e for e in sink.events() if e.kind == "dispatch"]
    assert len(decisions) == 2
    assert decisions[0].name == "adam_adapt"
    assert decisions[0].data["backend"] == "ref"
    total = obs.counter("dispatch_total")
    assert total.value(labels={"kernel": "adam_adapt", "backend": "ref",
                               "reason": "selected"}) == 2.0
    # and with no default installed, dispatch observes nothing
    dispatch.get_kernel("adam_adapt")(*args, **kw)
    assert len([e for e in sink.events() if e.kind == "dispatch"]) == 2


def test_request_queue_emits_shed_events():
    from repro.serve.queue import RequestQueue

    obs, sink = ring_obs()
    t = [0.0]
    q = RequestQueue(max_depth=1, clock=lambda: t[0], obs=obs)
    q.submit({"p": 1}, timeout_s=1.0)
    with pytest.raises(Exception):
        q.submit({"p": 2})  # overflow shed
    t[0] = 5.0
    q.pop(4)  # p1's deadline passed -> deadline shed at pop
    serve_events = [e for e in sink.events() if e.kind == "serve"]
    # every submit mints a trace and emits "enqueued" BEFORE the overflow
    # check, so even an overflow-shed request has a reconstructible
    # enqueued -> queue_shed timeline
    assert [e.name for e in serve_events] == [
        "enqueued", "enqueued", "queue_shed", "queue_shed"]
    sheds = [e for e in serve_events if e.name == "queue_shed"]
    reasons = {e.data["reason"] for e in sheds}
    assert reasons == {"shed_overflow", "shed_deadline"}
    assert all(e.data.get("trace_id") for e in serve_events)
    assert obs.counter("queue_sheds").total() == 2.0


def test_executor_terminal_vocabulary_matches_monitor():
    """The executor's event names ARE the SLO monitor's vocabulary —
    renaming either side silently blinds the health check."""

    from repro.serve.executor import ServeExecutor

    names = set(ServeExecutor.TERMINAL_EVENT.values())
    assert set(health_mod.ServeSLOMonitor.TERMINAL) <= names


# ---------------------------------------------------------------------------
# ISSUE 8 satellites: histogram percentile edges, log-loss surfacing
# ---------------------------------------------------------------------------


def test_histogram_single_sample_quantiles():
    # one sample: every percentile IS that sample, not a bucket-interior
    # interpolation below/above the only value ever seen
    h = metrics_mod.Histogram("one", bounds=[10.0, 100.0, 1000.0])
    h.observe(50.0)
    assert h.quantile(0.5) == 50.0
    assert h.quantile(0.99) == 50.0
    assert h.quantile(0.0) == 50.0
    snap = h.snapshot()
    assert snap["min"] == 50.0 and snap["max"] == 50.0
    assert snap["p50"] == 50.0 and snap["p99"] == 50.0


def test_histogram_value_exactly_on_bucket_bound():
    # a value landing exactly on a bound goes to the bucket it closes,
    # and quantiles stay clamped inside [min, max] observed
    h = metrics_mod.Histogram("edge", bounds=[10.0, 100.0, 1000.0])
    for _ in range(3):
        h.observe(1000.0)               # exactly the last finite bound
    assert h.quantile(0.5) == 1000.0
    assert h.quantile(0.99) == 1000.0
    h2 = metrics_mod.Histogram("edge2", bounds=[10.0, 100.0, 1000.0])
    h2.observe(10.0)
    h2.observe(100.0)
    assert h2.quantile(0.0) >= 10.0     # never below the observed min
    assert h2.quantile(1.0) <= 100.0    # never above the observed max
    assert h2.snapshot()["min"] == 10.0


def test_read_jsonl_stats_counts_torn_and_invalid(tmp_path):
    path = str(tmp_path / "run.jsonl")
    sink = events_mod.JsonlSink(path)
    sink.write(ev("run", "run_start", data={}))
    sink.write(ev("span", "base_unroll", data={"dur_us": 5.0, "traced": False}))
    sink.close()
    with open(path, "a") as f:
        f.write(json.dumps({"v": 1, "kind": "bogus", "name": "x", "t": 0.0,
                            "step": None, "data": {}}) + "\n")
        f.write('{"v": 1, "kind": "log", "na')  # torn tail
    events, stats = events_mod.read_jsonl_stats(path)
    assert len(events) == 2
    assert stats == {"torn_lines": 1, "invalid_lines": 1}


def test_report_surfaces_log_loss(tmp_path, capsys):
    path = str(tmp_path / "run.jsonl")
    sink = events_mod.JsonlSink(path)
    sink.write(ev("run", "run_start", data={}))
    sink.write(ev("span", "meta_pass", data={"dur_us": 9.0, "traced": False}))
    sink.write(ev("run", "run_end", data={"ring_dropped": 7}))
    sink.close()
    with open(path, "a") as f:
        f.write('{"torn":')               # torn tail from a crashed writer
    assert report_mod.main([path, "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["io"] == {"torn_lines": 1, "invalid_lines": 0,
                         "ring_dropped": 7}
    assert report_mod.main([path]) == 0
    text = capsys.readouterr().out
    assert "torn_lines=1" in text and "ring_dropped=7" in text


def test_report_io_silent_when_clean(tmp_path, capsys):
    path = str(tmp_path / "run.jsonl")
    _write_run_log(path)
    events, stats = events_mod.read_jsonl_stats(path)
    s = report_mod.summarize(events, io=stats)
    assert s["io"] == {"torn_lines": 0, "invalid_lines": 0, "ring_dropped": 0}
    assert "torn_lines" not in report_mod.render(s)  # no noise when clean


def test_obs_sink_dropped_recurses_tee():
    ring = events_mod.RingSink(capacity=2)
    obs = obs_mod.Obs(sink=events_mod.TeeSink([events_mod.NullSink(), ring]),
                      monitor=False)
    for i in range(5):
        obs.emit("log", f"m{i}", data={"msg": "x"})
    assert ring.dropped == 3
    assert obs.sink_dropped() == 3
    assert obs_mod.Obs(sink=events_mod.NullSink(),
                       monitor=False).sink_dropped() == 0
