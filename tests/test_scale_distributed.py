"""Distributed pins for repro.scale (8 forced host devices, subprocess per
the dry-run isolation rule — same harness as tests/test_distributed.py):

1. CENSUS: the manual single-sync schedule with M-way microbatch
   accumulation still lowers to EXACTLY unroll_steps + 1 all-reduces — the
   accumulation scans are collective-free and the per-base-step DDP pmean
   fires on the accumulated gradient (ISSUE acceptance criterion).
2. EQUALITY: with identical per-device batches, the microbatched manual
   step equals the microbatched single-device Engine step (the linear
   reduce contract commutes with both the shard mean and the microbatch
   mean).
3. BUCKET DTYPES: the flat reduce bucket never carries sub-f32 leaves —
   with bf16 base params (grads, v and the SAMA bucket all bf16 at the
   source) the manual step still compiles and runs on the CPU backend,
   which crashes in XLA's AllReducePromotion on bf16 variadic all-reduce
   without ``cast_for_reduce``; and f32 buckets are NOT pointlessly
   round-tripped (the census bytes pin below would catch a double cast).
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import EngineConfig, init_state, make_meta_step, problems
from repro.launch import distributed as dist
from repro.launch.mesh import AxisType, make_mesh
from repro.scale import ScaleConfig

mesh = make_mesh((8, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)

def apply_fn(theta, x):
    return jnp.tanh(x @ theta["w1"]) @ theta["w2"]

per_ex = problems.softmax_per_example(apply_fn)
spec = problems.make_data_optimization_spec(per_ex, reweight=True)

d, h, C = 6, 16, 3
theta = {"w1": jax.random.normal(jax.random.PRNGKey(0), (d, h)) * 0.3,
         "w2": jax.random.normal(jax.random.PRNGKey(1), (h, C)) * 0.3}
lam = problems.init_data_optimization_lam(jax.random.PRNGKey(2), reweight=True)

base_opt = optim.adam(1e-2)
meta_opt = optim.adam(1e-2)
K, M = 2, 4
cfg = EngineConfig(method="sama", unroll_steps=K, scale=ScaleConfig(microbatch=M))
state = init_state(theta, lam, base_opt, meta_opt, scale=cfg.scale)

# per-shard batches sized so every shard splits into M microbatches
pb, pmb = 8, 8  # per-device base / meta batch (divisible by M=4)
kx = jax.random.PRNGKey(3)
x_shard = jax.random.normal(kx, (K, pb, d))
y_shard = jax.random.randint(jax.random.PRNGKey(4), (K, pb), 0, C)
mx_shard = jax.random.normal(jax.random.PRNGKey(5), (pmb, d))
my_shard = jax.random.randint(jax.random.PRNGKey(6), (pmb,), 0, C)

base_tiled = {"x": jnp.tile(x_shard, (1, 8, 1)), "y": jnp.tile(y_shard, (1, 8))}
meta_tiled = {"x": jnp.tile(mx_shard, (8, 1)), "y": jnp.tile(my_shard, (8,))}

engine_step = jax.jit(make_meta_step(spec, base_opt, meta_opt, cfg))
manual_step = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, cfg, mesh))

s_ref, m_ref = engine_step(state, {"x": x_shard, "y": y_shard},
                           {"x": mx_shard, "y": my_shard})
with mesh:
    s_man, m_man = manual_step(state, base_tiled, meta_tiled)

ok_equal = True
for a, b in zip(jax.tree_util.tree_leaves((s_ref.lam, s_ref.theta)),
                jax.tree_util.tree_leaves((s_man.lam, s_man.theta))):
    if not np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6):
        ok_equal = False

# census: the microbatched manual step on genuinely sharded batches
B, MB = 64, 64
xg = jax.random.normal(jax.random.PRNGKey(7), (K, B, d))
yg = jax.random.randint(jax.random.PRNGKey(8), (K, B), 0, C)
mxg = jax.random.normal(jax.random.PRNGKey(9), (MB, d))
myg = jax.random.randint(jax.random.PRNGKey(10), (MB,), 0, C)
from repro.roofline import hlo_parse
census = {}
with mesh:
    for m_count in (1, 4):
        cfg_m = EngineConfig(method="sama", unroll_steps=K,
                             scale=ScaleConfig(microbatch=m_count))
        hlo = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, cfg_m, mesh)) \
            .lower(state, {"x": xg, "y": yg}, {"x": mxg, "y": myg}).compile().as_text()
        census[m_count] = hlo_parse.collective_stats(hlo)

# bf16 params end-to-end: grads/v/bucket are bf16 at the source; without
# cast_for_reduce this CRASHES in XLA AllReducePromotion on CPU. (Raw
# bf16 MASTER params also hit the cold-state Adam adaptation pathology —
# eps can be NaN on step 0 regardless of schedule, pre-existing and the
# reason the PrecisionPolicy keeps masters f32 — so the numeric pin here
# is base_loss + dtype preservation, not the SAMA terms.)
theta16 = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), theta)
cfg16 = EngineConfig(method="sama", unroll_steps=K, scale=ScaleConfig(microbatch=M))
state16 = init_state(theta16, lam, base_opt, meta_opt, scale=cfg16.scale)
with mesh:
    s16, m16 = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, cfg16, mesh))(
        state16, base_tiled, meta_tiled)
bf16_ok = bool(np.isfinite(float(m16["base_loss"])))
bf16_dtypes_kept = all(
    a.dtype == b.dtype for a, b in zip(jax.tree_util.tree_leaves(state16.theta),
                                       jax.tree_util.tree_leaves(s16.theta)))

# the POLICY route (f32 masters, bf16 compute) is the supported way to run
# bf16 — every metric finite on the manual schedule
cfg_pol = EngineConfig(method="sama", unroll_steps=K,
                       scale=ScaleConfig(policy="bf16", microbatch=M))
state_pol = init_state(theta, lam, base_opt, meta_opt, scale=cfg_pol.scale)
with mesh:
    _, m_pol = jax.jit(dist.make_manual_step(spec, base_opt, meta_opt, cfg_pol, mesh))(
        state_pol, base_tiled, meta_tiled)
policy_bf16_finite = all(np.isfinite(float(v)) for v in m_pol.values())

# planner under the manual schedule: candidates must divide the PER-DEVICE
# shard (64/8 = 8), not the global batch — a global-batch candidate (e.g.
# 64) would crash split_batch inside shard_map at trace time
from repro.scale import plan_microbatch
plan = plan_microbatch(
    spec, base_opt, meta_opt, EngineConfig(method="sama", unroll_steps=K),
    state, {"x": xg, "y": yg}, {"x": mxg, "y": myg},
    hbm_budget=10**12, mesh=mesh, schedule="single_sync")
plan_info = {"microbatch": plan.microbatch, "fits": plan.fits,
             "max_tried": max(m for m, _ in plan.candidates)}

print(json.dumps({
    "plan": plan_info,
    "equal_under_tiling": ok_equal,
    "allreduce_m1": census[1]["all-reduce_count"],
    "allreduce_m4": census[4]["all-reduce_count"],
    "bytes_m1": census[1]["total_bytes"],
    "bytes_m4": census[4]["total_bytes"],
    "unroll": K,
    "bf16_ok": bf16_ok,
    "bf16_dtypes_kept": bf16_dtypes_kept,
    "policy_bf16_finite": policy_bf16_finite,
}))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_microbatched_manual_equals_engine_under_identical_shards(result):
    assert result["equal_under_tiling"]


def test_census_exactly_unroll_plus_one_under_accumulation(result):
    # the single-sync invariant survives microbatching: K base DDP
    # flat-bucket pmeans (on ACCUMULATED grads) + 1 meta bucket
    expected = result["unroll"] + 1
    assert result["allreduce_m1"] == expected, result
    assert result["allreduce_m4"] == expected, result


def test_census_bytes_unchanged_by_accumulation(result):
    # accumulation moves compute, not communication: same buckets, same
    # bytes (also pins that no extra f32 round-trip snuck into the bucket)
    assert result["bytes_m4"] == result["bytes_m1"], result


def test_bf16_bucket_compiles_and_trains(result):
    # the cast_for_reduce regression pin: bf16 leaves in the flat bucket
    # must be promoted before the variadic all-reduce (XLA CPU crashes
    # otherwise) and params keep their bf16 dtype through the step
    assert result["bf16_ok"]
    assert result["bf16_dtypes_kept"]


def test_policy_bf16_all_metrics_finite_on_manual_schedule(result):
    # the supported bf16 route (f32 masters + bf16 compute) stays finite
    # end-to-end under the single-sync schedule with accumulation active
    assert result["policy_bf16_finite"]


def test_planner_on_manual_schedule_uses_per_shard_candidates(result):
    # global batch 64 over 8 data-parallel devices -> the planner may only
    # try divisors of the 8-example shard; with an effectively unlimited
    # budget it must land on M=1 and never touch a global-batch candidate
    assert result["plan"]["fits"]
    assert result["plan"]["microbatch"] == 1
    assert result["plan"]["max_tried"] <= 8
