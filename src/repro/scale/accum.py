"""Microbatch gradient accumulation for the bilevel step (DESIGN.md §11).

Splits a batch with leading dim B into M microbatches of B/M and runs the
backward pass once per microbatch under ``lax.scan``, accumulating in the
policy's ``accum_dtype`` — activation memory becomes O(B/M) while the
arithmetic stays the full-batch mean. Three accumulation sites:

1. the base unroll's per-step gradient (``microbatch_value_and_grad`` —
   also where dynamic loss scaling applies: each microbatch loss is
   multiplied by the live scale before its backward pass, the accumulated
   gradient is unscaled once);
2. the hypergradient stage (``microbatch_local_terms``): a method that
   implements ``micro_local_terms`` gets the exact staged decomposition
   (SAMA: accumulate g_meta over meta microbatches -> v/eps once ->
   accumulate the central difference over last-batch microbatches, which
   reproduces the full-batch estimator exactly in f32); otherwise a
   LINEAR-contract method falls back to virtual-shard averaging — each
   microbatch is treated as one more data shard and the contract terms
   are averaged, the SAME estimator family the single-sync schedule's
   bucketed pmean already applies across devices. Nonlinear contracts
   (CG, Neumann, iterdiff) are refused, mirroring
   ``launch.distributed.make_manual_step``.

Every scan here is collective-free, so on the manual schedule the one
pmean per base step fires AFTER accumulation and the meta bucket stays
single: the collective census is ``unroll_steps + 1`` for every M —
pinned by tests/test_scale_distributed.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.scale.policy import LossScaleState

PyTree = Any


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def split_batch(batch: PyTree, m: int) -> PyTree:
    """Reshape every leaf [B, ...] -> [m, B//m, ...]. Shape-checked at
    trace time: every leading dim must be divisible by m (the planner only
    proposes divisors; hand-picked Ms fail loudly here)."""

    if m < 1:
        raise ValueError(f"microbatch count must be >= 1, got {m}")

    def one(x):
        b = x.shape[0]
        if b % m:
            raise ValueError(
                f"batch dim {b} not divisible by microbatch count {m}; "
                "pick M from repro.scale.plan_microbatch (it only proposes "
                "divisors) or pad the batch"
            )
        return x.reshape((m, b // m) + x.shape[1:])

    return _tmap(one, batch)


def accumulate_mean(
    term_fn: Callable[[PyTree], PyTree],
    split: PyTree,
    m: int,
    accum_dtype,
) -> PyTree:
    """mean_m term_fn(microbatch_m), accumulated in ``accum_dtype`` under
    one collective-free ``lax.scan``. ``split`` carries the leading m axis
    (from ``split_batch``); the result keeps accum_dtype — callers cast
    back where the consumer is dtype-sensitive."""

    def body(acc, mb):
        term = term_fn(mb)
        acc = _tmap(lambda a, t: a + t.astype(accum_dtype), acc, term)
        return acc, None

    zeros = jax.eval_shape(term_fn, _tmap(lambda x: x[0], split))
    acc0 = _tmap(lambda s: jnp.zeros(s.shape, accum_dtype), zeros)
    acc, _ = jax.lax.scan(body, acc0, split)
    return _tmap(lambda a: a / m, acc)


def microbatch_value_and_grad(
    loss_fn: Callable,  # (theta, lam, batch) -> scalar
    theta: PyTree,
    lam: PyTree,
    batch: PyTree,
    m: int,
    accum_dtype,
    *,
    scale: Optional[LossScaleState] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """(loss, dloss/dtheta) over the full batch via M accumulated
    microbatch backward passes. With a live ``scale`` each microbatch loss
    is multiplied by ``scale.scale`` before its backward pass (so
    low-precision cotangents stay representable) and the accumulated
    gradient is unscaled once at the end — callers check finiteness and
    run the skip/backoff automaton (``policy.update_scale``)."""

    s = scale.scale if scale is not None else None

    def scaled_loss(th, la, mb):
        loss = loss_fn(th, la, mb)
        return loss * s if s is not None else loss

    if m <= 1:
        loss, g = jax.value_and_grad(scaled_loss, argnums=0)(theta, lam, batch)
        if s is not None:
            loss = loss / s
            g = _tmap(lambda x: x / s, g)
        return loss.astype(jnp.float32), g

    split = split_batch(batch, m)

    def term(mb):
        loss, g = jax.value_and_grad(scaled_loss, argnums=0)(theta, lam, mb)
        return {"loss": loss.astype(jnp.float32), "grad": g}

    acc = accumulate_mean(term, split, m, accum_dtype)
    loss, g = acc["loss"], acc["grad"]
    if s is not None:
        loss = loss / s
        g = _tmap(lambda x: x / s, g)
    # restore the native gradient dtype (= the param leaf's, e.g. bf16
    # master params) so the M>1 path is a drop-in for the direct one
    g = _tmap(lambda x, t: x.astype(t.dtype), g, theta)
    return loss.astype(jnp.float32), g


def microbatch_local_terms(method, spec, ctx, m: int, accum_dtype) -> PyTree:
    """Stage-1 ``local_terms`` under M-way microbatching (see module
    docstring for the exact-vs-virtual-shard split). M <= 1 is the plain
    call."""

    if m <= 1:
        return method.local_terms(spec, ctx)

    hook = getattr(method, "micro_local_terms", None)
    if hook is not None:
        return hook(spec, ctx, m, accum_dtype)

    contract = method.reduce_contract
    if not contract.linear:
        raise ValueError(
            f"hypergrad method {method.name!r} declares a nonlinear reduce "
            "contract: averaging its per-microbatch estimates is not the "
            "method's own estimator on the full batch (the same reason "
            "make_manual_step refuses it). Run it with microbatch=1, or "
            "implement micro_local_terms on the method."
        )

    meta_split = split_batch(ctx.meta_batch, m)
    last_split = split_batch(ctx.last_batch, m)

    def term(mb):
        meta_mb, last_mb = mb
        ctx_m = dataclasses.replace(ctx, meta_batch=meta_mb, last_batch=last_mb)
        terms = method.local_terms(spec, ctx_m)
        extra = set(terms) - set(contract.terms)
        if extra:
            raise ValueError(
                f"{method.name}: local_terms produced non-contract terms "
                f"{sorted(extra)} — the generic virtual-shard accumulator "
                "only knows how to mean-reduce contract terms; implement "
                "micro_local_terms to handle method-private state"
            )
        return terms

    return accumulate_mean(term, (meta_split, last_split), m, accum_dtype)
