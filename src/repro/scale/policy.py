"""Mixed-precision policies for the SAMA hot path (DESIGN.md §11).

A ``PrecisionPolicy`` names three dtypes and (optionally) a dynamic loss
scale:

* ``param_dtype``  — the MASTER copy of the base parameters. EngineState
  keeps theta (and therefore the optimizer moments ``OptState`` derives
  from it) in this dtype; the paper's "exploit first-order machinery"
  memory claim rests on the usual f32-master / low-precision-compute
  split, so it is f32 in every built-in policy.
* ``compute_dtype`` — the dtype the loss (and its backward pass) runs in.
  ``apply_to_spec`` installs the cast boundary: theta's float leaves and
  the batch's float leaves are cast to ``compute_dtype`` on the way into
  ``BilevelSpec.base_loss`` / ``meta_loss``, and the scalar loss comes
  back f32. Because the cast is the first traced op, its VJP casts the
  low-precision cotangents back up — gradients w.r.t. the master params
  arrive in ``param_dtype`` with no extra bookkeeping. The SAME wrapped
  spec feeds the base unroll and the hypergradient path (SAMA's meta
  pass and both central-difference passes), so the cast boundary is
  uniform across both levels.
* ``accum_dtype``  — the dtype microbatch accumulators (``repro.scale.
  accum``) and reduction buffers run in; f32 everywhere built-in (bf16
  accumulation loses the benefit of bf16's range for no memory win on
  the accumulator, which is parameter-sized, not batch-sized).

``loss_scale > 0`` turns on DYNAMIC loss scaling (the f16 policy):
the base loss is multiplied by the live scale before the backward pass so
f16 cotangents stay representable, gradients are unscaled after
accumulation, and a non-finite unscaled gradient SKIPS that base update
(params + optimizer state untouched) and halves the scale; every
``growth_interval`` consecutive finite steps the scale doubles. bf16 has
f32's exponent range and ships unscaled (``loss_scale=0``).

lam (the meta parameters) stays in its native dtype: meta modules are
tiny (MWN is a 2-layer MLP), so down-casting them saves nothing and
perturbs the hypergradient for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Dtype triple + loss-scale knobs. ``jnp`` dtypes are stored as their
    canonical string names so the policy is hashable/JSON-able and safe as
    a static jit argument."""

    name: str = "f32"
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    accum_dtype: str = "float32"
    # 0.0 = no loss scaling; > 0 = initial DYNAMIC scale (doubles every
    # growth_interval finite steps, halves on a non-finite gradient).
    loss_scale: float = 0.0
    growth_interval: int = 200
    max_loss_scale: float = float(2 ** 24)
    min_loss_scale: float = 1.0

    @property
    def param_jnp(self):
        return jnp.dtype(self.param_dtype)

    @property
    def compute_jnp(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def accum_jnp(self):
        return jnp.dtype(self.accum_dtype)

    @property
    def dynamic_scaling(self) -> bool:
        return self.loss_scale > 0.0

    @property
    def is_identity(self) -> bool:
        """True when the policy changes nothing (the f32 default) — callers
        skip the spec wrapper entirely so paper-exact paths stay untouched."""
        return (self.compute_jnp == jnp.float32
                and self.param_jnp == jnp.float32
                and not self.dynamic_scaling)


#: the built-in policies (DESIGN.md §11): f32 master params everywhere;
#: bf16 computes unscaled (f32 exponent range), f16 computes under a
#: dynamic loss scale with skip-on-nonfinite. The f16 scale is CAPPED at
#: 2^15: the backward seed is the scale itself cast through the f16
#: boundary, and float16(2^16) == inf — growth past the cap would skip a
#: base step deterministically (model-independent) every growth_interval.
POLICIES = {
    "f32": PrecisionPolicy(name="f32"),
    "bf16": PrecisionPolicy(name="bf16", compute_dtype="bfloat16"),
    "f16": PrecisionPolicy(name="f16", compute_dtype="float16",
                           loss_scale=float(2 ** 15),
                           max_loss_scale=float(2 ** 15)),
}


def resolve_policy(policy: Union[str, PrecisionPolicy]) -> PrecisionPolicy:
    if isinstance(policy, PrecisionPolicy):
        return policy
    if isinstance(policy, str):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown precision policy {policy!r}; built-ins: {sorted(POLICIES)}"
            )
        return POLICIES[policy]
    raise TypeError(
        f"policy must be a name or PrecisionPolicy, got {type(policy).__name__}"
    )


def cast_floats(tree: PyTree, dtype) -> PyTree:
    """Cast the inexact (float) leaves of ``tree`` to ``dtype``; integer /
    bool leaves (token ids, labels) pass through untouched."""

    def one(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(one, tree)


def apply_to_spec(spec: "Any", policy: PrecisionPolicy) -> "Any":
    """Install the policy's cast boundary on a BilevelSpec: theta and batch
    float leaves go down to ``compute_dtype`` on entry, the scalar loss
    comes back f32 (aux, when present, is passed through untouched). The
    identity policy returns ``spec`` itself."""

    # engine imports this module, so BilevelSpec must resolve lazily
    from repro.core.bilevel import BilevelSpec

    if policy.is_identity:
        return spec
    cdt = policy.compute_jnp

    def wrap(loss_fn):
        def wrapped(theta, lam, batch):
            out = loss_fn(cast_floats(theta, cdt), lam, cast_floats(batch, cdt))
            if spec.has_aux:
                return out[0].astype(jnp.float32), out[1]
            return out.astype(jnp.float32)

        return wrapped

    return BilevelSpec(base_loss=wrap(spec.base_loss),
                       meta_loss=wrap(spec.meta_loss),
                       has_aux=spec.has_aux)


# ---------------------------------------------------------------------------
# dynamic loss scaling
# ---------------------------------------------------------------------------


class LossScaleState(NamedTuple):
    """Carried in ``EngineState.scale`` when the policy scales losses."""

    scale: jnp.ndarray  # f32 scalar, the live multiplier
    good_steps: jnp.ndarray  # i32 scalar, consecutive finite base steps


def init_scale_state(policy: PrecisionPolicy) -> Optional[LossScaleState]:
    """The initial LossScaleState for a policy (None when the policy does
    not scale — the EngineState field then stays an empty subtree and old
    checkpoints keep restoring)."""

    if not policy.dynamic_scaling:
        return None
    return LossScaleState(scale=jnp.asarray(policy.loss_scale, jnp.float32),
                          good_steps=jnp.zeros([], jnp.int32))


def all_finite(tree: PyTree) -> jnp.ndarray:
    """Scalar bool: every float leaf of ``tree`` is finite."""

    leaves = [x for x in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.inexact)]
    if not leaves:
        return jnp.asarray(True)
    finite = [jnp.all(jnp.isfinite(x)) for x in leaves]
    out = finite[0]
    for f in finite[1:]:
        out = jnp.logical_and(out, f)
    return out


def update_scale(state: LossScaleState, finite: jnp.ndarray,
                 policy: PrecisionPolicy) -> LossScaleState:
    """The standard dynamic-loss-scale automaton: halve on a non-finite
    step (and reset the streak), double after ``growth_interval``
    consecutive finite steps, clamped to [min_loss_scale, max_loss_scale]."""

    good = jnp.where(finite, state.good_steps + 1, 0)
    grow = jnp.logical_and(finite, good >= policy.growth_interval)
    scale = jnp.where(
        finite,
        jnp.where(grow, state.scale * 2.0, state.scale),
        state.scale * 0.5,
    )
    scale = jnp.clip(scale, policy.min_loss_scale, policy.max_loss_scale)
    good = jnp.where(grow, 0, good)
    return LossScaleState(scale=scale.astype(jnp.float32),
                          good_steps=good.astype(jnp.int32))


def backoff_on(state: LossScaleState, finite: jnp.ndarray,
               policy: PrecisionPolicy) -> LossScaleState:
    """Backoff-only automaton step: halve the scale and reset the growth
    streak when ``finite`` is False, identity otherwise. Used for events
    that should never GROW the scale (the hypergradient path's per-meta-
    step finiteness — growth streaks are counted in base steps only, so a
    meta event must not double-count them)."""

    scale = jnp.where(finite, state.scale,
                      jnp.clip(state.scale * 0.5, policy.min_loss_scale,
                               policy.max_loss_scale))
    good = jnp.where(finite, state.good_steps, 0)
    return LossScaleState(scale=scale.astype(jnp.float32),
                          good_steps=good.astype(jnp.int32))


def select_tree(pred: jnp.ndarray, on_true: PyTree, on_false: PyTree) -> PyTree:
    """Elementwise tree select on a scalar predicate (the skip-on-nonfinite
    update gate: params/moments keep their old values on a skipped step)."""

    return jax.tree_util.tree_map(
        lambda t, f: jnp.where(pred, t, f), on_true, on_false
    )


# ---------------------------------------------------------------------------
# the user-facing config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScaleConfig:
    """The ``repro.scale`` knobs as they ride on ``EngineConfig`` (and so
    on ``MetaLearner`` / ``DataOptimizer`` scoring / ``launch.train``):

    ``policy``     — "f32" | "bf16" | "f16" or a PrecisionPolicy instance.
    ``microbatch`` — M: each base batch (and the meta/last batches the
      hypergradient stage consumes) is split into M microbatches that are
      accumulated shard-locally under ``lax.scan`` (repro.scale.accum), so
      activation memory is O(batch/M) while the distributed schedule still
      fires exactly ``unroll_steps + 1`` all-reduces. Batch leading dims
      must be divisible by M (``plan_microbatch`` only proposes divisors).
    """

    policy: Union[str, PrecisionPolicy] = "f32"
    microbatch: int = 1

    def __post_init__(self):
        resolve_policy(self.policy)  # fail at config time, not trace time
        if self.microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {self.microbatch}")

    def resolve(self) -> PrecisionPolicy:
        return resolve_policy(self.policy)

    @property
    def is_identity(self) -> bool:
        return self.microbatch == 1 and self.resolve().is_identity
