"""repro.scale — microbatch accumulation, mixed-precision policies, and
the HBM-budget memory planner for the SAMA hot path (DESIGN.md §11).

The paper's "2.0/3.8x decrease in memory consumption" claim rides on
first-order distributed-training machinery; this package is that
machinery for the bilevel step:

* ``policy``  — PrecisionPolicy (f32 master params / bf16 or loss-scaled
  f16 compute / f32 accumulation) + ScaleConfig, the knob that rides on
  ``EngineConfig`` and everything above it (MetaLearner, DataOptimizer
  scoring, launch.train).
* ``accum``   — collective-free microbatch accumulation for the base
  unroll and the hypergradient stage; SAMA's linear reduce contract is
  what lets it compose with the single-sync schedule at exactly
  ``unroll_steps + 1`` all-reduces for every M.
* ``plan``    — ``plan_microbatch``: binary-search the largest microbatch
  that fits an HBM budget, measured on the compiled step via
  ``repro.perf.memory`` (aval fallback where XLA gives no buffer
  assignment).

    from repro import scale
    cfg = EngineConfig(method="sama", unroll_steps=2,
                       scale=scale.ScaleConfig(policy="bf16", microbatch=4))
    plan = scale.plan_microbatch(spec, base_opt, meta_opt, cfg, state,
                                 bb, mb, hbm_budget=8 * 2**30)
"""

from repro.scale.accum import (
    accumulate_mean,
    microbatch_local_terms,
    microbatch_value_and_grad,
    split_batch,
)
from repro.scale.policy import (
    POLICIES,
    LossScaleState,
    PrecisionPolicy,
    ScaleConfig,
    all_finite,
    apply_to_spec,
    backoff_on,
    cast_floats,
    init_scale_state,
    resolve_policy,
    select_tree,
    update_scale,
)

#: planner symbols resolve lazily (PEP 562): policy+accum are CORE-level
#: primitives (core.engine imports this package), while plan.py consumes
#: repro.perf — eager import here would drag perf/roofline into every
#: core consumer's import path and tighten the core<->scale cycle.
_PLAN_EXPORTS = ("AVAL_ACTIVATION_MULTIPLIER", "ExecPlan",
                 "candidate_microbatches", "measure_peak", "plan_microbatch")


def __getattr__(name):
    if name in _PLAN_EXPORTS:
        from repro.scale import plan

        return getattr(plan, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "AVAL_ACTIVATION_MULTIPLIER", "ExecPlan", "LossScaleState", "POLICIES",
    "PrecisionPolicy", "ScaleConfig", "accumulate_mean", "all_finite",
    "apply_to_spec", "backoff_on", "candidate_microbatches", "cast_floats",
    "init_scale_state", "measure_peak", "microbatch_local_terms",
    "microbatch_value_and_grad", "plan_microbatch", "resolve_policy",
    "select_tree", "split_batch", "update_scale",
]
