"""The HBM-budget memory planner (DESIGN.md §11).

``plan_microbatch`` answers the deployment question the paper's memory
claims raise: *given this model, this mesh and this much HBM per device,
how little accumulation can I get away with?* It binary-searches the
candidate microbatch counts (divisors of the batch's leading dims) for the
SMALLEST M — i.e. the largest fitting microbatch — whose compiled step
fits the budget, measuring each candidate with ``repro.perf.memory``:

* primary source: ``compiled.memory_analysis()`` of the lowered+compiled
  step (argument + output + temp - alias per device, the same peak
  composition every BENCH_*.json reports). Compilation happens on
  ShapeDtypeStructs — no device allocation, so planning a 90B config on a
  laptop works exactly like the dry-run harness.
* fallback (backends with no buffer assignment): aval arithmetic —
  argument + output bytes exactly, plus a COARSE activation-slab estimate
  ``batch_bytes / M * activation_multiplier`` (ALL leaves, so int32 token
  batches still register — each token expands to activations). Its job is
  not accuracy, it is strict monotonicity in M so the binary search still
  converges; the returned ``ExecPlan.source`` says which path produced
  the numbers, and callers gating real deployments should insist on
  ``memory_analysis``.

The search assumes peak memory is non-increasing in M (more accumulation
never costs memory) — true by construction for the scan-accumulated step
and verified empirically by ``benchmarks/bench_scale.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax

from repro.perf import memory as perf_memory
from repro.scale.policy import ScaleConfig

PyTree = Any

#: coarse activations-per-batch-byte multiplier for the aval fallback —
#: transformer backward passes hold O(10) activation copies of the token
#: stream; only monotonicity in M matters for the search (see module doc).
AVAL_ACTIVATION_MULTIPLIER = 12.0


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """The planner's verdict: run with ``scale`` (= the input ScaleConfig
    with ``microbatch`` replaced by the chosen M)."""

    microbatch: int
    scale: ScaleConfig
    peak_bytes: Optional[int]  # measured peak of the CHOSEN M
    hbm_budget: int
    fits: bool  # False: even the largest candidate M busts the budget
    source: str  # perf.memory source tag of the measurements
    #: every (M, peak_bytes) the search actually compiled/estimated —
    #: the audit trail benchmarks and tests assert monotonicity on
    candidates: Tuple[Tuple[int, Optional[int]], ...] = ()


def _batch_dims(base_batches, meta_batch) -> Tuple[int, int]:
    base_leaves = jax.tree_util.tree_leaves(base_batches)
    meta_leaves = jax.tree_util.tree_leaves(meta_batch)
    if not base_leaves or not meta_leaves:
        raise ValueError("plan_microbatch needs non-empty base and meta batches")
    return base_leaves[0].shape[1], meta_leaves[0].shape[0]  # (K, B, ...) / (B, ...)


def candidate_microbatches(base_batches, meta_batch,
                           max_microbatch: Optional[int] = None,
                           *, shard_divisor: int = 1) -> Tuple[int, ...]:
    """Ascending Ms that divide BOTH the per-step base batch and the meta
    batch (``split_batch`` requires exact divisibility).

    ``shard_divisor``: the data-parallel extent when the step runs under
    the manual schedule — ``split_batch`` there executes on the PER-DEVICE
    shard inside shard_map, so candidates must divide the shard
    (global/dp), not the global batch. 1 for pjit/single-device."""

    base_b, meta_b = _batch_dims(base_batches, meta_batch)
    if shard_divisor < 1 or base_b % shard_divisor or meta_b % shard_divisor:
        raise ValueError(
            f"batches (base {base_b}, meta {meta_b}) do not shard evenly "
            f"over {shard_divisor} data-parallel devices"
        )
    base_b //= shard_divisor
    meta_b //= shard_divisor
    ms = [m for m in range(1, min(base_b, meta_b) + 1)
          if base_b % m == 0 and meta_b % m == 0
          and (max_microbatch is None or m <= max_microbatch)]
    if not ms:
        raise ValueError(
            f"no common microbatch divisor for per-shard base batch {base_b} / "
            f"meta batch {meta_b} under max_microbatch={max_microbatch}"
        )
    return tuple(ms)


# the activation estimate counts EVERY leaf (perf.memory.tree_bytes) —
# int32 token batches included: for the repo's LM/encoder models the
# activation slab scales with the token COUNT (each token expands to
# d_model floats downstream), so a floats-only sum would be 0 for a token
# batch and break the fallback's monotonicity-in-M job.
_batch_bytes = perf_memory.tree_bytes


def measure_peak(spec, base_opt, meta_opt, engine_cfg, state, base_batches,
                 meta_batch, *, mesh=None, schedule: str = "pjit",
                 _dryrun: bool = False):
    """Compile ONE candidate step on example avals and return
    ``(peak_bytes, source)``. ``state`` / batches may be concrete arrays
    or ShapeDtypeStructs — only shapes/dtypes are consumed."""

    from repro.core.engine import make_meta_step  # lazy: engine imports scale

    if schedule == "single_sync":
        from repro.launch.distributed import make_manual_step

        if mesh is None:
            raise ValueError("schedule='single_sync' needs a mesh")
        step = make_manual_step(spec, base_opt, meta_opt, engine_cfg, mesh)
    else:
        step = make_meta_step(spec, base_opt, meta_opt, engine_cfg)

    def lower():
        return jax.jit(step).lower(state, base_batches, meta_batch)

    if mesh is not None:
        with mesh:
            compiled = lower().compile()
    else:
        compiled = lower().compile()
    stats = perf_memory.compiled_memory(
        compiled, example_args=(state, base_batches, meta_batch))
    if stats.peak_bytes is not None:
        return int(stats.peak_bytes), stats.source
    # aval fallback: argument/output exact + monotone activation estimate
    m = engine_cfg.scale.microbatch
    act = int(_batch_bytes((base_batches, meta_batch))
              * AVAL_ACTIVATION_MULTIPLIER / max(m, 1))
    return stats.argument_bytes + stats.output_bytes + act, stats.source


def plan_microbatch(spec, base_opt, meta_opt, engine_cfg, state, base_batches,
                    meta_batch, *, hbm_budget: int, mesh=None,
                    schedule: str = "pjit",
                    max_microbatch: Optional[int] = None) -> ExecPlan:
    """Binary-search the smallest microbatch count M whose compiled step
    peak fits ``hbm_budget`` bytes per device. Returns an ``ExecPlan``
    whose ``scale`` is ``engine_cfg.scale`` with the chosen M — feed it
    back as ``dataclasses.replace(engine_cfg, scale=plan.scale)``.

    When even the LARGEST candidate M does not fit, ``fits=False`` and the
    plan carries that largest M (the least-bad configuration) — callers
    decide whether to run anyway or shrink the batch."""

    if hbm_budget <= 0:
        raise ValueError(f"hbm_budget must be > 0 bytes, got {hbm_budget}")
    # under the manual schedule split_batch runs on the PER-DEVICE shard
    # inside shard_map — candidates must divide the shard, not the global
    dp = 1
    if schedule == "single_sync" and mesh is not None:
        from repro.launch.mesh import data_axes  # lazy: launch sits above scale

        for axis in data_axes(mesh):
            dp *= mesh.shape[axis]
    cands = candidate_microbatches(base_batches, meta_batch, max_microbatch,
                                   shard_divisor=dp)
    tried = {}

    def peak_of(m: int):
        if m not in tried:
            cfg_m = dataclasses.replace(
                engine_cfg, scale=dataclasses.replace(engine_cfg.scale, microbatch=m))
            tried[m] = measure_peak(
                spec, base_opt, meta_opt, cfg_m, state, base_batches, meta_batch,
                mesh=mesh, schedule=schedule)
        return tried[m]

    # bisect the ascending candidate list: peak(M) is non-increasing, so
    # the fitting candidates form a suffix — find its first element.
    lo, hi = 0, len(cands) - 1
    best = None
    if peak_of(cands[hi])[0] <= hbm_budget:
        while lo < hi:
            mid = (lo + hi) // 2
            if peak_of(cands[mid])[0] <= hbm_budget:
                hi = mid
            else:
                lo = mid + 1
        best = cands[lo]

    chosen = best if best is not None else cands[-1]
    peak, source = tried[chosen]
    return ExecPlan(
        microbatch=chosen,
        scale=dataclasses.replace(engine_cfg.scale, microbatch=chosen),
        peak_bytes=peak,
        hbm_budget=int(hbm_budget),
        fits=best is not None,
        source=source,
        candidates=tuple((m, tried[m][0]) for m in sorted(tried)),
    )
