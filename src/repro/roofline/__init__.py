"""Roofline analysis from compiled dry-run artifacts (TPU v5e constants)."""

from repro.roofline.analysis import (
    HBM_BW,
    HBM_BYTES,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    analyze,
    forward_flops,
    param_counts,
    step_bytes,
    step_flops,
)
from repro.roofline.hlo_parse import collective_stats

__all__ = [
    "HBM_BW", "HBM_BYTES", "ICI_BW", "PEAK_FLOPS",
    "Roofline", "analyze", "collective_stats", "forward_flops",
    "param_counts", "step_bytes", "step_flops",
]
