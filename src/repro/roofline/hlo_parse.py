"""Trip-count-aware collective accounting from partitioned HLO text.

XLA's ``cost_analysis`` counts while-loop (lax.scan) bodies ONCE, not
multiplied by trip count — verified empirically (see EXPERIMENTS.md §Method).
Collectives inside scanned layer stacks would be undercounted by ~num_layers.
This parser:

  1. splits the module into named computations,
  2. reads every ``while`` op's ``body=%comp`` edge and its
     ``known_trip_count`` from backend_config,
  3. propagates multipliers ENTRY -> bodies (nested loops multiply),
  4. sums collective result bytes x multiplier.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^\s*(?:ENTRY\s+)?%([^\s(]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?body=%([^\s,]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
#: every way one computation invokes another in HLO text: loop body /
#: condition, fusion/call targets, reducer lambdas, conditional branches
_CALLEE_RE = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)"
    r"=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_COMP_REF_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    is_entry = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line)
        if m:
            cur = m.group(1)
            if line.lstrip().startswith("ENTRY"):
                is_entry = cur
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    if is_entry is not None:
        comps["__entry__"] = comps[is_entry]
    return comps


def computation_multipliers(comps: Dict[str, List[str]],
                            follow_calls: bool = False) -> Dict[str, float]:
    """Multiplier per computation = product of enclosing loop trip counts.

    By default only while ``body=`` edges are followed (what the
    collective census needs — collectives never hide inside fusions).
    ``follow_calls=True`` additionally walks ``calls=``/``to_apply=``/
    condition/branch edges at trip 1, so fused computations *inside* a
    scanned loop body inherit the body's trip multiplier — required for
    FLOP attribution (obs.profile), where most compute lives in fusions.
    """

    # edges: computation -> [(callee_body, trip)]
    edges: Dict[str, List[Tuple[str, int]]] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for line in lines:
            is_while = " while(" in line
            if is_while:
                mb = _WHILE_RE.search(line)
                if mb:
                    mt = _TRIP_RE.search(line)
                    trip = int(mt.group(1)) if mt else 1
                    edges.setdefault(name, []).append((mb.group(1), trip))
            if not follow_calls:
                continue
            body = _WHILE_RE.search(line).group(1) if is_while and _WHILE_RE.search(line) else None
            for callee in _CALLEE_RE.findall(line):
                if callee == body:
                    continue  # trip-scaled edge already added above
                edges.setdefault(name, []).append((callee, 1))
            mbr = _BRANCHES_RE.search(line)
            if mbr:
                for callee in _COMP_REF_RE.findall(mbr.group(1)):
                    edges.setdefault(name, []).append((callee, 1))

    entry = None
    for name, lines in comps.items():
        if name != "__entry__" and comps.get("__entry__") is lines:
            entry = name
            break

    mult: Dict[str, float] = {}
    if entry is None:
        return {name: 1.0 for name in comps}

    def visit(name: str, m: float):
        # a body may appear once; take max to be safe against re-visits
        if mult.get(name, 0.0) >= m:
            return
        mult[name] = m
        for body, trip in edges.get(name, []):
            visit(body, m * trip)

    visit(entry, 1.0)
    for name in comps:
        mult.setdefault(name, 1.0)
    return mult


def collective_stats(text: str) -> Dict[str, float]:
    """Per-type collective bytes/op counts, trip-count scaled."""

    comps = split_computations(text)
    mult = computation_multipliers(comps)

    out: Dict[str, float] = {f"{c}_bytes": 0.0 for c in COLLECTIVES}
    out.update({f"{c}_count": 0.0 for c in COLLECTIVES})
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0)
        for line in lines:
            for c in COLLECTIVES:
                mm = re.search(rf"=\s+(.*?)\s+{c}(?:-start)?\(", line)
                if mm and f"{c}-done" not in line:
                    out[f"{c}_bytes"] += shape_bytes(mm.group(1)) * m
                    out[f"{c}_count"] += m
                    break
    out["total_bytes"] = sum(out[f"{c}_bytes"] for c in COLLECTIVES)
    out["total_count"] = sum(out[f"{c}_count"] for c in COLLECTIVES)
    return out
