"""Three-term roofline analysis for dry-run jobs.

    compute_s    = FLOPs_global / (chips * peak_flops)
    memory_s     = HBM_bytes_global / (chips * hbm_bw)
    collective_s = collective_bytes_per_device / ici_bw

Measurement methodology (see EXPERIMENTS.md §Method):

* XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies
  ONCE — verified empirically — so raw HLO flops/bytes undercount scanned
  layer stacks by ~num_layers. We therefore use **analytic accounting**
  (exact matmul/attention/scan/moe-dispatch terms from the architecture
  config — the standard MFU methodology) for compute and memory, and keep
  the raw HLO numbers in the record labeled ``hlo_*_body_once``.
* Collective bytes come from the partitioned HLO with **trip-count
  correction** (roofline.hlo_parse): every collective inside a scan body is
  scaled by the loop's known_trip_count. cost_analysis cannot see these at
  all. Transfer model: result bytes / one ICI link — a stated lower bound.
* compute/memory terms assume ideal sharding (global / chips); the HLO is
  the structural witness that the program actually partitions.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 16 GiB HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax

from repro.roofline import hlo_parse

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes / s / chip
ICI_BW = 50e9  # bytes / s / link
HBM_BYTES = 16 * 2**30  # v5e HBM capacity

ACT_BYTES = 2  # bf16 activations
LOGIT_BYTES = 4  # f32 logits
META_FRACTION = 8  # meta batch = base batch / 8 in the SAMA train job


def param_counts(param_shapes) -> Dict[str, int]:
    total = experts = embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        key = jax.tree_util.keystr(path)
        if "experts" in key:
            experts += n
        if "embed" in key:  # embed + pos_embed: gathers, not matmuls
            embed += n
    return {"total": total, "experts": experts, "embed": embed}


# ---------------------------------------------------------------------------
# analytic FLOPs
# ---------------------------------------------------------------------------


def _attn_flops(cfg, batch, s_q, t_kv):
    """Self/cross attention score+AV flops for one forward pass, per layer."""
    if cfg.use_mla:
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        return 2 * batch * cfg.num_heads * s_q * t_kv * (dn + dr + dv)
    return 4 * batch * cfg.num_heads * s_q * t_kv * cfg.head_dim


def _moe_dispatch_flops(cfg, tokens):
    """GShard one-hot dispatch + combine einsums per MoE layer."""
    from repro.models.moe import MOE_GROUP

    g = min(MOE_GROUP, tokens)
    cap = max(int(cfg.capacity_factor * cfg.top_k * g / cfg.num_experts), 4)
    per_group = 2 * g * cfg.num_experts * cap * cfg.d_model * 2  # dispatch+combine
    return (tokens // g) * per_group


def _ssm_scan_flops(cfg, batch, seq):
    """Mamba2 SSD chunkwise flops per layer (intra matmuls + state updates)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    q = min(cfg.ssm_chunk, seq)
    intra = 2 * batch * seq * q * (n + d_inner)
    inter = 4 * batch * seq * d_inner * n
    return intra + inter


def _rwkv_scan_flops(cfg, batch, seq):
    d = cfg.d_model
    k = cfg.rwkv_head_dim
    q = min(cfg.ssm_chunk, seq)
    intra = 4 * batch * seq * q * d  # (i,j,channel) products
    inter = 4 * batch * seq * d * k
    return intra + inter


def forward_flops(cfg, counts, batch, s_q, t_kv=None) -> float:
    """One forward pass over (batch, s_q) query tokens (kv length t_kv)."""

    t_kv = t_kv if t_kv is not None else s_q
    tokens = batch * s_q

    n_matmul = counts["total"] - counts["embed"] - counts["experts"]
    n_matmul += cfg.vocab_size * cfg.d_model  # tied unembed
    if cfg.num_experts:
        n_matmul += counts["experts"] * cfg.top_k / cfg.num_experts
    total = 2.0 * tokens * n_matmul

    fam = cfg.family
    if fam in ("dense", "encoder", "moe"):
        n_attn_layers = cfg.num_layers
        kinds = cfg.layer_kinds
        for kind in kinds:
            t_eff = min(cfg.sliding_window, t_kv) if (kind == "local" and cfg.sliding_window) else t_kv
            total += _attn_flops(cfg, batch, s_q, t_eff)
        if fam == "moe":
            total += (cfg.num_layers - cfg.first_k_dense) * _moe_dispatch_flops(cfg, tokens)
    elif fam == "hybrid":
        n_groups = cfg.num_layers // cfg.hybrid_attn_every
        total += cfg.num_layers * _ssm_scan_flops(cfg, batch, s_q)
        total += n_groups * _attn_flops(cfg, batch, s_q, t_kv)
    elif fam == "ssm":
        total += cfg.num_layers * _rwkv_scan_flops(cfg, batch, s_q)
    elif fam == "vlm":
        n_groups = cfg.num_layers // cfg.cross_attn_every
        n_self = n_groups * (cfg.cross_attn_every - 1)
        total += n_self * _attn_flops(cfg, batch, s_q, t_kv)
        total += n_groups * _attn_flops(cfg, batch, s_q, cfg.vision_tokens)
    elif fam == "audio":
        f = cfg.encoder_seq
        total += cfg.encoder_layers * _attn_flops(cfg, batch, f, f)  # encoder (runs every fwd)
        total += cfg.num_layers * (_attn_flops(cfg, batch, s_q, t_kv) + _attn_flops(cfg, batch, s_q, f))
    return total


def step_flops(cfg, counts, shape, kind: str) -> float:
    """Whole-step analytic flops. Train = the SAMA bilevel step:
    base fwd+bwd (3x fwd) + meta pass (3x fwd, B/8) + 2 central-difference
    forwards (their lambda-backward is cut by the feature stop-gradient)."""

    b, s = shape.global_batch, shape.seq_len
    if kind == "train":
        f_base = forward_flops(cfg, counts, b, s)
        f_meta = forward_flops(cfg, counts, max(b // META_FRACTION, 1), s)
        return 3 * f_base + 3 * f_meta + 2 * f_base
    if kind == "prefill":
        return forward_flops(cfg, counts, b, s)
    # decode: one token against a cache of length seq_len
    if cfg.family == "audio":
        # decode does NOT rerun the encoder (cross-kv cached)
        f = forward_flops(cfg, counts, b, 1, t_kv=s)
        f -= cfg.encoder_layers * _attn_flops(cfg, b, cfg.encoder_seq, cfg.encoder_seq)
        return f
    return forward_flops(cfg, counts, b, 1, t_kv=s)


# ---------------------------------------------------------------------------
# analytic HBM traffic
# ---------------------------------------------------------------------------


def _activation_traffic(cfg, batch, s_q, t_kv) -> float:
    """Rough per-pass activation HBM traffic: ~8 read/writes of the residual
    stream per block plus attention score materialization (f32 read+write) —
    the latter is what flash/blockwise attention removes (see §Perf)."""

    tokens = batch * s_q
    blocks = cfg.num_layers + (cfg.encoder_layers if cfg.family == "audio" else 0)
    stream = 8.0 * tokens * cfg.d_model * ACT_BYTES * blocks
    scores = 0.0
    if cfg.family in ("dense", "encoder", "moe", "vlm", "audio"):
        for kind in cfg.layer_kinds:
            t_eff = min(cfg.sliding_window, t_kv) if (kind == "local" and cfg.sliding_window) else t_kv
            scores += 8.0 * batch * cfg.num_heads * s_q * t_eff  # f32 write+read
    logits = 0.0
    if cfg.family != "encoder":
        logits = tokens * cfg.vocab_size * LOGIT_BYTES
    return stream + scores + logits


def step_bytes(cfg, counts, shape, kind: str, cache_bytes: int = 0) -> float:
    b, s = shape.global_batch, shape.seq_len
    params_bytes = counts["total"] * ACT_BYTES  # bf16 params in the dry-run
    if kind == "train":
        # fwd reads W; bwd reads W + writes grad; x4 passes; optimizer reads/
        # writes f32-equiv moments (bf16 here) — ~8x params traffic total.
        t = 8.0 * params_bytes
        t += 3.0 * _activation_traffic(cfg, b, s, s)  # base fwd+bwd
        t += 3.0 * _activation_traffic(cfg, max(b // META_FRACTION, 1), s, s)
        t += 2.0 * _activation_traffic(cfg, b, s, s)  # central-difference fwds
        return t
    if kind == "prefill":
        return params_bytes + _activation_traffic(cfg, b, s, s)
    # decode: params once + cache read/write + small activations
    t = params_bytes + 2.0 * cache_bytes
    t += _activation_traffic(cfg, b, 1, s)
    return t


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    name: str
    flops_global: float
    bytes_global: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    useful_ratio: float  # model matmul flops / total analytic flops
    peak_memory_bytes: Optional[int]
    hlo_flops_body_once: float
    hlo_bytes_body_once: float
    collectives: Dict[str, Any]

    def as_dict(self):
        return dataclasses.asdict(self)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` returns a dict on modern jax but a
    per-partition list of dicts on 0.4.x — normalize to one dict."""

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def analyze(name: str, compiled, hlo_text: str, cfg, shape, kind: str,
            param_shapes, n_devices: int, cache_shapes=None) -> Roofline:
    counts = param_counts(param_shapes)
    cache_bytes = 0
    if cache_shapes is not None:
        for leaf in jax.tree_util.tree_leaves(cache_shapes):
            n = 1
            for d in leaf.shape:
                n *= d
            cache_bytes += n * leaf.dtype.itemsize

    flops = step_flops(cfg, counts, shape, kind)
    mem = step_bytes(cfg, counts, shape, kind, cache_bytes)
    coll = hlo_parse.collective_stats(hlo_text)

    compute_s = flops / (n_devices * PEAK_FLOPS)
    memory_s = mem / (n_devices * HBM_BW)
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    # "useful" = pure matmul-param flops (6ND-style) over everything the step does
    n_matmul = counts["total"] - counts["embed"] - counts["experts"] + cfg.vocab_size * cfg.d_model
    if cfg.num_experts:
        n_matmul += counts["experts"] * cfg.top_k / cfg.num_experts
    tokens = shape.global_batch * shape.seq_len
    if kind == "train":
        useful = (6 + 6 / META_FRACTION + 4) * n_matmul * tokens
    elif kind == "prefill":
        useful = 2 * n_matmul * tokens
    else:
        useful = 2 * n_matmul * shape.global_batch
    useful_ratio = useful / flops if flops else 0.0

    cost = cost_analysis_dict(compiled)
    peak_mem = None
    try:
        stats = compiled.memory_analysis()
        peak_mem = int(
            stats.argument_size_in_bytes + stats.output_size_in_bytes + stats.temp_size_in_bytes
        )
    except Exception:
        pass

    return Roofline(
        name=name,
        flops_global=flops,
        bytes_global=mem,
        collective_bytes_per_device=coll["total_bytes"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        useful_ratio=useful_ratio,
        peak_memory_bytes=peak_mem,
        hlo_flops_body_once=float(cost.get("flops", 0.0)),
        hlo_bytes_body_once=float(cost.get("bytes accessed", 0.0)),
        collectives=coll,
    )
