"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced jnp ops); on a TPU runtime set
``repro.kernels.ops.INTERPRET = False`` (or export REPRO_PALLAS_COMPILE=1) to
compile them for real. The jnp oracles in ``ref.py`` stay the numerical
ground truth either way.
"""

from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.kernels import adam_adapt as _adam
from repro.kernels import weighted_ce as _wce
from repro.kernels import ref

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Per-token CE for (..., V) logits and (...,) int targets, via the
    blockwise-vocab Pallas kernel (differentiable)."""

    shape = targets.shape
    r = math.prod(shape)  # static shapes never round-trip through a device array
    logits2 = logits.reshape(r, logits.shape[-1])
    targets1 = targets.reshape(r)
    ce = _wce.cross_entropy(logits2, targets1, INTERPRET)
    return ce.reshape(shape)


def adam_adapt_product(g, m, v, g_meta, *, t, b1=0.9, b2=0.999, eps=1e-8, lr=1.0):
    """Fused SAMA adaptation product over a flat array."""
    return _adam.adam_adapt_product(
        g, m, v, g_meta, t=t, b1=b1, b2=b2, eps=eps, lr=lr, interpret=INTERPRET
    )


__all__ = ["INTERPRET", "adam_adapt_product", "cross_entropy", "ref"]
