"""Jit'd public wrappers around the dispatched kernels.

These helpers add the shape plumbing (flattening token axes, reshaping
back) on top of ``kernels.dispatch``: which implementation actually runs —
compiled Pallas on TPU, the Pallas interpreter, or the pure-jnp ``ref``
fallback — is the registry's decision (platform default, overridable per
call via ``backend=`` or globally via ``REPRO_KERNEL_BACKEND``). The jnp
oracles in ``ref.py`` stay the numerical ground truth either way.

``INTERPRET`` / ``REPRO_PALLAS_COMPILE`` are the pre-dispatch interface,
kept for back-compat: they are superseded by ``REPRO_KERNEL_BACKEND``
(``pallas-tpu`` means compiled, everything else interprets or skips Pallas
entirely) and are no longer consulted here.
"""

from __future__ import annotations

import math
import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, ref

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"  # legacy knob
if not INTERPRET:  # pragma: no cover - legacy-env warning only
    warnings.warn(
        "REPRO_PALLAS_COMPILE is no longer consulted; use "
        "REPRO_KERNEL_BACKEND=pallas-tpu (see repro.kernels.dispatch)",
        DeprecationWarning, stacklevel=2,
    )


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, *, backend=None) -> jnp.ndarray:
    """Per-token CE for (..., V) logits and (...,) int targets, via the
    dispatched ``weighted_ce`` kernel (differentiable on every backend)."""

    shape = targets.shape
    r = math.prod(shape)  # static shapes never round-trip through a device array
    logits2 = logits.reshape(r, logits.shape[-1])
    targets1 = targets.reshape(r)
    ce = dispatch.get_kernel("weighted_ce", backend=backend)(logits2, targets1)
    return ce.reshape(shape)


def adam_adapt_product(g, m, v, g_meta, *, t, b1=0.9, b2=0.999, eps=1e-8, lr=1.0,
                       backend=None):
    """Fused SAMA adaptation product over a flat array."""
    return dispatch.get_kernel("adam_adapt", backend=backend)(
        g, m, v, g_meta, t=t, b1=b1, b2=b2, eps=eps, lr=lr
    )


def lion_adapt_product(g, m, g_meta, *, lr=1.0, b1=0.9, delta=1e-3, backend=None):
    """Fused SAMA Lion (surrogate-sign) adaptation product over a flat array."""
    return dispatch.get_kernel("lion_adapt", backend=backend)(
        g, m, g_meta, lr=lr, b1=b1, delta=delta
    )


def adafactor_adapt_product(vhat, g_meta, *, lr=1.0, eps=1e-8, backend=None):
    """Fused SAMA Adafactor (frozen-statistics) adaptation product over a
    flat array of bias-corrected second moments."""
    return dispatch.get_kernel("adafactor_adapt", backend=backend)(
        vhat, g_meta, lr=lr, eps=eps
    )


# NB: INTERPRET stays importable for back-compat but is deliberately NOT in
# __all__ — it is a dead knob superseded by REPRO_KERNEL_BACKEND.
__all__ = [
    "adafactor_adapt_product",
    "adam_adapt_product",
    "cross_entropy",
    "lion_adapt_product",
    "ref",
]
