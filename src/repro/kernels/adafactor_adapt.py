"""Pallas TPU kernel: fused SAMA Adafactor-adaptation product.

Adafactor's factored second moment couples every element of a row/column, so
its exact du/dg is not diagonal; the repo's Adafactor optimizer declares the
frozen-statistics diagonal ``lr / (sqrt(vhat) + eps)`` (see
``optim.adafactor``'s docstring — exact in the b2 -> 1 limit where the
factored statistics move slowly). The factored reconstruction
``vhat = rhat cx chat / mean(rhat)`` is a cheap rank-1 outer product computed
by the caller; this kernel fuses the remaining elementwise chain — rsqrt,
scale, product against ``g_meta``, and the per-tile partial sum of squares
for eps = alpha/||v|| — into one pass over (vhat, g_meta).

Same layout contract as ``adam_adapt``: 1-D grid over (BLK,)-tiles of the
flattened tensor, the traced lr rides a scalar input block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adafactor_kernel(sched_ref, vhat_ref, gm_ref, out_ref, ss_ref, *, eps):
    lr = sched_ref[0]
    vhat = vhat_ref[...].astype(jnp.float32)
    gm = gm_ref[...].astype(jnp.float32)

    diag = lr / (jnp.sqrt(vhat) + eps)
    out = diag * gm
    out_ref[...] = out
    ss_ref[0] = jnp.sum(out * out)


def adafactor_adapt_product(
    vhat: jnp.ndarray,
    g_meta: jnp.ndarray,
    *,
    lr=1.0,
    eps: float = 1e-8,
    block: int = 8 * 1024,
    interpret: bool = True,
):
    """Flat f32 arrays (N,). ``vhat`` must be the bias-corrected second
    moment (non-negative). Returns (v_out (N,) f32, sumsq scalar f32)."""

    (n,) = vhat.shape
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        # pad vhat with ones (not zeros): 1/(sqrt(0)+eps) would be huge and,
        # multiplied by the zero-padded g_meta, still contributes exact zeros
        # — but ones keep the intermediate finite for any eps.
        vhat = jnp.concatenate([vhat, jnp.ones((pad,), vhat.dtype)])
        g_meta = jnp.concatenate([g_meta, jnp.zeros((pad,), g_meta.dtype)])
    n_pad = n + pad
    grid = (n_pad // blk,)

    sched = jnp.asarray(lr, jnp.float32).reshape(1)
    kern = functools.partial(_adafactor_kernel, eps=float(eps))
    out, partial_ss = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))]
        + [pl.BlockSpec((blk,), lambda i: (i,))] * 2,
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        ],
        interpret=interpret,
    )(sched, vhat, g_meta)
    return out[:n], jnp.sum(partial_ss)
