"""Pallas TPU kernel: fused SAMA Adam-adaptation product.

SAMA's perturbation direction v = (du_adam/dg) .* g_meta (Eq. 4 + App. C)
touches four HBM-resident arrays (g, m, v, g_meta) and, written naively,
lowers to ~12 elementwise HLO ops with several HBM round-trips, plus a
separate reduction for eps = alpha/||v||_2. This kernel fuses the whole
chain into one pass: each (BLK,)-tile is read once, the adaptation diagonal
is computed in registers, and a per-tile partial sum of squares is emitted so
the norm needs no second pass over the data.

1-D grid over tiles of the flattened parameter tensor; BLK = 8 * 128 * k to
match f32 (sublane, lane) tiling.

The step index ``t`` and learning rate ``lr`` ride a (2,) scalar input
(every grid step maps to the same block) rather than being baked in as
static kernel params: in the hot path both are traced values
(``state.count`` under jit, scheduled lr), and a static bake would force a
retrace per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adapt_kernel(sched_ref, g_ref, m_ref, v_ref, gm_ref, out_ref, ss_ref, *, b1, b2, eps):
    t = sched_ref[0]
    lr = sched_ref[1]
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    gm = gm_ref[...].astype(jnp.float32)

    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    m1 = b1 * m + (1.0 - b1) * g
    v1 = b2 * v + (1.0 - b2) * g * g
    mhat = m1 / bc1
    vhat = v1 / bc2
    sq = jnp.sqrt(vhat)
    denom = sq + eps
    a = (1.0 - b1) / bc1
    b = (1.0 - b2) / bc2
    diag = lr * (a / denom - mhat * b * g / (jnp.maximum(sq, 1e-15) * denom * denom))
    out = diag * gm
    out_ref[...] = out
    ss_ref[0] = jnp.sum(out * out)


def adam_adapt_product(
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    g_meta: jnp.ndarray,
    *,
    t,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    lr=1.0,
    block: int = 8 * 1024,
    interpret: bool = True,
):
    """Flat f32 arrays (N,). Returns (v_out (N,) f32, sumsq scalar f32).

    ``t`` and ``lr`` may be python numbers or traced scalars (they are fed
    to the kernel as a (2,) input array, not static params)."""

    (n,) = g.shape
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        zeros = jnp.zeros((pad,), g.dtype)
        g, m, v, g_meta = (jnp.concatenate([x, zeros]) for x in (g, m, v, g_meta))
    n_pad = n + pad
    grid = (n_pad // blk,)

    sched = jnp.stack([jnp.asarray(t, jnp.float32), jnp.asarray(lr, jnp.float32)])
    kern = functools.partial(_adapt_kernel, b1=float(b1), b2=float(b2), eps=float(eps))
    out, partial_ss = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda i: (0,))]
        + [pl.BlockSpec((blk,), lambda i: (i,))] * 4,
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        ],
        interpret=interpret,
    )(sched, g, m, v, g_meta)
    return out[:n], jnp.sum(partial_ss)
