"""Blockwise flash attention + split-KV decode Pallas kernels (ISSUE 9).

Two kernels, both registered through :mod:`repro.kernels.dispatch`:

``flash_attention``
    Training forward/backward for GQA self-attention. The forward is the
    classic online-softmax blockwise scan over KV tiles (running max
    ``m``, running denominator ``l``, rescaled accumulator ``acc`` in
    VMEM scratch, finalized on the last KV block of each query tile).
    The backward is recompute-based: only ``(out, lse)`` are saved as
    residuals; score tiles are rebuilt from q/k in the dq and dk/dv
    kernels, so activation memory is O(B*S*H*Dh) instead of O(S*T).
    Supports causal masking, logit softcap (tanh), and sliding-window
    masking gated by a *traced* per-layer ``local_flag`` (the flag rides
    into the kernel as a tiny int32 input with a constant index map —
    the adam_adapt idiom — so heterogeneous local/global layers inside a
    ``lax.scan`` over layers work without retracing).

GQA without grid races: q is laid out as ``(B*KV, G, S, Dh)`` so each
grid cell owns one (batch, kv-head) pair and its whole query group. The
kernels flatten the ``(G, block_q)`` rows into a single ``(G*block_q,
Dh)`` matmul operand, which means dk/dv accumulate contributions from
every query head of the group *inside* one grid cell — no revisited
output blocks across a parallel axis.

``flash_decode``
    Split-KV decode for the one-token path: stage 1 launches a grid of
    ``(B*KV, n_splits)`` cells, each producing a *normalized* partial
    output plus its log-sum-exp over one contiguous KV span; stage 2
    (:func:`merge_partials`, plain jnp) combines them with the standard
    log-sum-exp merge ``m* = max lse_i; out = sum_i exp(lse_i - m*) *
    o_i / sum_i exp(lse_i - m*)``. The split count comes from
    :func:`pick_splits`, an occupancy heuristic (enough grid cells to
    fill the cores, each split long enough to amortize the HBM DMA).
    Decode is inference-only: no VJP is defined.

Both kernels carry a ``ref`` twin that reproduces the existing
``models/attention.py`` ops *literally* (including the chunk-gate
selection between ``_sdpa`` and ``_chunked_sdpa``), so the default CPU
dispatch is bitwise-identical to the pre-kernel code and every tier-1
pin (scan-prefill bitwise equality, attribution FLOP bands) holds.

Masking convention shared with the ref path: padded positions are
``-1`` sentinels, masked scores are set to the finite ``NEG = -1e30``
(never ``-inf`` — fully-masked rows then produce ``l == 0`` and are
normalized by ``max(l, 1e-30)`` to exact zeros instead of NaN).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
_TINY = 1e-30

__all__ = [
    "flash_attention",
    "flash_attention_ref",
    "flash_decode",
    "flash_decode_ref",
    "merge_partials",
    "pick_splits",
]


def _pow2ceil(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def _pick_blocks(s: int, t: int) -> tuple[int, int]:
    """Query/KV tile sizes: 128 lanes when the problem affords it,
    shrunk (but >= 8 sublanes) for small shapes so padding stays cheap."""
    bq = max(8, min(128, _pow2ceil(s)))
    bk = max(8, min(128, _pow2ceil(t)))
    return bq, bk


def _pad_to(x, axis, mult, value):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _flag_array(local_flag, window: int):
    """Traced window-gate scalar as a (1,)-int32 kernel input."""
    if window <= 0 or local_flag is None:
        return jnp.zeros((1,), jnp.int32)
    return jnp.asarray(local_flag, jnp.int32).reshape(1)


def _tile_mask(qp, kp, *, causal: bool, window: int, use_window: bool, lf):
    """(bq, bk) validity for one score tile. ``qp``/``kp`` are int32
    position rows; -1 marks padding. ``lf`` is the traced 0/1 gate."""
    valid = (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if use_window:
        local = (qp[:, None] - kp[None, :]) < window
        valid &= jnp.where(lf != 0, local, True)
    return valid


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------


def _fwd_kernel(lf_ref, qp_ref, kp_ref, q_ref, k_ref, v_ref,
                o_ref, lse_ref, m_ref, l_ref, acc_ref,
                *, softcap, window, causal, scale, g):
    j, nj = pl.program_id(2), pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bq = q_ref.shape[2]
    rows = g * bq
    q = q_ref[0].astype(jnp.float32).reshape(rows, q_ref.shape[3])
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = _tile_mask(qp_ref[0], kp_ref[0], causal=causal, window=window,
                       use_window=window > 0, lf=lf_ref[0])
    valid = jnp.broadcast_to(valid[None], (g, bq, k.shape[0])).reshape(
        rows, k.shape[0])
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[...].reshape(rows)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(valid, jnp.exp(s - m_cur[:, None]), 0.0)
    alpha = jnp.where(m_prev <= NEG, 0.0,
                      jnp.exp(jnp.minimum(m_prev - m_cur, 0.0)))
    l_ref[...] = (l_ref[...].reshape(rows) * alpha
                  + jnp.sum(p, axis=1)).reshape(g, bq)
    m_ref[...] = m_cur.reshape(g, bq)
    pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = (acc_ref[...].reshape(rows, -1) * alpha[:, None]
                    + pv).reshape(acc_ref.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[...].reshape(rows)
        m = m_ref[...].reshape(rows)
        out = acc_ref[...].reshape(rows, -1) / jnp.maximum(l, _TINY)[:, None]
        o_ref[0] = out.reshape(o_ref.shape[1:])
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, _TINY)), NEG)
        lse_ref[0] = lse.reshape(g, bq)


def _layouts(q, k, v, q_pos, kv_pos, bq, bk):
    """Fold GQA into per-(batch, kv-head) blocks and pad to tiles."""
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    q4 = q.reshape(b, s, kv, g, dh).transpose(0, 2, 3, 1, 4)
    q4 = _pad_to(q4.reshape(b * kv, g, s, dh), 2, bq, 0)
    k3 = _pad_to(k.transpose(0, 2, 1, 3).reshape(b * kv, t, dh), 1, bk, 0)
    v3 = _pad_to(v.transpose(0, 2, 1, 3).reshape(b * kv, t, dh), 1, bk, 0)
    qp = _pad_to(q_pos.astype(jnp.int32), 1, bq, -1)
    kp = _pad_to(kv_pos.astype(jnp.int32).reshape(1, t), 1, bk, -1)
    return q4, k3, v3, qp, kp, (b, s, h, dh, t, kv, g)


def _fwd_impl(q, k, v, q_pos, kv_pos, lf, softcap, window, causal,
              interpret, bq, bk):
    q4, k3, v3, qp, kp, (b, s, h, dh, t, kv, g) = _layouts(
        q, k, v, q_pos, kv_pos, bq, bk)
    bh, sp, tp = q4.shape[0], q4.shape[2], k3.shape[1]
    grid = (bh, sp // bq, tp // bk)
    kernel = functools.partial(
        _fwd_kernel, softcap=float(softcap), window=int(window),
        causal=bool(causal), scale=1.0 / math.sqrt(dh), g=g)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, i, j: (0,)),
            pl.BlockSpec((1, bq), lambda bb, i, j, kvh=kv: (bb // kvh, i)),
            pl.BlockSpec((1, bk), lambda bb, i, j: (0, j)),
            pl.BlockSpec((1, g, bq, dh), lambda bb, i, j: (bb, 0, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, bq, dh), lambda bb, i, j: (bb, 0, i, 0)),
            pl.BlockSpec((1, g, bq), lambda bb, i, j: (bb, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, g, sp, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, g, sp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq), jnp.float32),
            pltpu.VMEM((g, bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lf, qp, kp, q4, k3, v3)
    # (B*KV, G, Sp, Dh) -> (B, S, H, Dh)
    o = out[:, :, :s].reshape(b, kv, g, s, dh).transpose(0, 3, 1, 2, 4)
    return o.reshape(b, s, h, dh).astype(q.dtype), lse


# ---------------------------------------------------------------------------
# training backward (recompute)
# ---------------------------------------------------------------------------


def _bwd_tile(q, k, v, do, qp, kp, lf, lse, delta,
              *, softcap, window, causal, scale, g, bq):
    """Recompute p/ds for one tile. q/do are (g*bq, Dh) row blocks,
    k/v are (bk, Dh); lse/delta are (g*bq,) rows."""
    rows, bk = q.shape[0], k.shape[0]
    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    if softcap:
        tt = jnp.tanh(s_raw / softcap)
        s = softcap * tt
        dcap = 1.0 - tt * tt
    else:
        s = s_raw
        dcap = 1.0
    valid = _tile_mask(qp, kp, causal=causal, window=window,
                       use_window=window > 0, lf=lf)
    valid = jnp.broadcast_to(valid[None], (g, bq, bk)).reshape(rows, bk)
    # lse == NEG marks fully-masked/padded rows; exp would overflow to
    # +inf in the dead branch, so clamp the subtrahend first.
    lse_safe = jnp.where(lse <= NEG, 0.0, lse)
    p = jnp.where(valid, jnp.exp(s - lse_safe[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * dcap * scale
    return p, ds


def _dq_kernel(lf_ref, qp_ref, kp_ref, q_ref, k_ref, v_ref, do_ref,
               lse_ref, delta_ref, dq_ref, dq_acc,
               *, softcap, window, causal, scale, g):
    j, nj = pl.program_id(2), pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    bq, dh = q_ref.shape[2], q_ref.shape[3]
    rows = g * bq
    q = q_ref[0].astype(jnp.float32).reshape(rows, dh)
    do = do_ref[0].astype(jnp.float32).reshape(rows, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    _, ds = _bwd_tile(q, k, v, do, qp_ref[0], kp_ref[0], lf_ref[0],
                      lse_ref[0].reshape(rows), delta_ref[0].reshape(rows),
                      softcap=softcap, window=window, causal=causal,
                      scale=scale, g=g, bq=bq)
    dq_acc[...] = (dq_acc[...].reshape(rows, dh)
                   + jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
                   ).reshape(dq_acc.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...]


def _dkv_kernel(lf_ref, qp_ref, kp_ref, q_ref, k_ref, v_ref, do_ref,
                lse_ref, delta_ref, dk_ref, dv_ref, dk_acc, dv_acc,
                *, softcap, window, causal, scale, g):
    j, nj = pl.program_id(2), pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    bq, dh = q_ref.shape[2], q_ref.shape[3]
    rows = g * bq
    q = q_ref[0].astype(jnp.float32).reshape(rows, dh)
    do = do_ref[0].astype(jnp.float32).reshape(rows, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    p, ds = _bwd_tile(q, k, v, do, qp_ref[0], kp_ref[0], lf_ref[0],
                      lse_ref[0].reshape(rows), delta_ref[0].reshape(rows),
                      softcap=softcap, window=window, causal=causal,
                      scale=scale, g=g, bq=bq)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...]
        dv_ref[0] = dv_acc[...]


def _bwd_impl(q, k, v, q_pos, kv_pos, lf, out, lse, g_out,
              softcap, window, causal, interpret, bq, bk):
    q4, k3, v3, qp, kp, (b, s, h, dh, t, kv, g) = _layouts(
        q, k, v, q_pos, kv_pos, bq, bk)
    do4 = g_out.reshape(b, s, kv, g, dh).transpose(0, 2, 3, 1, 4)
    do4 = _pad_to(do4.reshape(b * kv, g, s, dh), 2, bq, 0)
    # delta = rowsum(dO * O), computed once in plain jnp (f32)
    delta = jnp.sum(g_out.astype(jnp.float32) * out.astype(jnp.float32), -1)
    delta = delta.reshape(b, s, kv, g).transpose(0, 2, 3, 1)
    delta = _pad_to(delta.reshape(b * kv, g, s), 2, bq, 0)
    # lse from the forward is already padded (B*KV, G, Sp)
    bh, sp, tp = q4.shape[0], q4.shape[2], k3.shape[1]
    nq, nk = sp // bq, tp // bk
    scale = 1.0 / math.sqrt(dh)
    common = dict(softcap=float(softcap), window=int(window),
                  causal=bool(causal), scale=scale, g=g)

    row_specs = [
        pl.BlockSpec((1,), lambda bb, i, j: (0,)),                       # lf
        pl.BlockSpec((1, bq), lambda bb, i, j, kvh=kv: (bb // kvh, i)),  # qp
        pl.BlockSpec((1, bk), lambda bb, i, j: (0, j)),                  # kp
        pl.BlockSpec((1, g, bq, dh), lambda bb, i, j: (bb, 0, i, 0)),    # q
        pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, j, 0)),          # k
        pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, j, 0)),          # v
        pl.BlockSpec((1, g, bq, dh), lambda bb, i, j: (bb, 0, i, 0)),    # do
        pl.BlockSpec((1, g, bq), lambda bb, i, j: (bb, 0, i)),           # lse
        pl.BlockSpec((1, g, bq), lambda bb, i, j: (bb, 0, i)),           # delta
    ]
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(bh, nq, nk),
        in_specs=row_specs,
        out_specs=[pl.BlockSpec((1, g, bq, dh),
                                lambda bb, i, j: (bb, 0, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, g, sp, dh), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((g, bq, dh), jnp.float32)],
        interpret=interpret,
    )(lf, qp, kp, q4, k3, v3, do4, lse, delta)[0]

    # dk/dv: grid iterates KV tiles on the middle axis, q tiles innermost,
    # so the (bk, Dh) scratch accumulates over every query block of one KV
    # tile before finalizing.
    col_specs = [
        pl.BlockSpec((1,), lambda bb, i, j: (0,)),
        pl.BlockSpec((1, bq), lambda bb, i, j, kvh=kv: (bb // kvh, j)),
        pl.BlockSpec((1, bk), lambda bb, i, j: (0, i)),
        pl.BlockSpec((1, g, bq, dh), lambda bb, i, j: (bb, 0, j, 0)),
        pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, i, 0)),
        pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, i, 0)),
        pl.BlockSpec((1, g, bq, dh), lambda bb, i, j: (bb, 0, j, 0)),
        pl.BlockSpec((1, g, bq), lambda bb, i, j: (bb, 0, j)),
        pl.BlockSpec((1, g, bq), lambda bb, i, j: (bb, 0, j)),
    ]
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(bh, nk, nq),
        in_specs=col_specs,
        out_specs=[pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, i, 0)),
                   pl.BlockSpec((1, bk, dh), lambda bb, i, j: (bb, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, tp, dh), jnp.float32),
                   jax.ShapeDtypeStruct((bh, tp, dh), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bk, dh), jnp.float32),
                        pltpu.VMEM((bk, dh), jnp.float32)],
        interpret=interpret,
    )(lf, qp, kp, q4, k3, v3, do4, lse, delta)

    dq = dq[:, :, :s].reshape(b, kv, g, s, dh).transpose(0, 3, 1, 2, 4)
    dq = dq.reshape(b, s, h, dh).astype(q.dtype)
    dk = dk[:, :t].reshape(b, kv, t, dh).transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv[:, :t].reshape(b, kv, t, dh).transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11))
def _flash(q, k, v, q_pos, kv_pos, lf, softcap, window, causal,
           interpret, bq, bk):
    out, _ = _fwd_impl(q, k, v, q_pos, kv_pos, lf, softcap, window, causal,
                       interpret, bq, bk)
    return out


def _flash_fwd(q, k, v, q_pos, kv_pos, lf, softcap, window, causal,
               interpret, bq, bk):
    out, lse = _fwd_impl(q, k, v, q_pos, kv_pos, lf, softcap, window, causal,
                         interpret, bq, bk)
    return out, (q, k, v, q_pos, kv_pos, lf, out, lse)


def _flash_bwd(softcap, window, causal, interpret, bq, bk, res, g_out):
    q, k, v, q_pos, kv_pos, lf, out, lse = res
    dq, dk, dv = _bwd_impl(q, k, v, q_pos, kv_pos, lf, out, lse, g_out,
                           softcap, window, causal, interpret, bq, bk)
    return dq, dk, dv, None, None, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, q_pos, kv_pos, local_flag=None, *,
                    softcap=0.0, window=0, causal=True, chunk=0,
                    interpret=False, block_q=None, block_k=None):
    """Pallas blockwise flash attention.

    q: (B, S, H, Dh); k/v: (B, T, KV, Dh) with H % KV == 0;
    q_pos: (B, S) int32; kv_pos: (T,) int32 (-1 = padding);
    local_flag: optional traced scalar bool gating the sliding window.
    ``chunk`` is accepted for call-convention parity with the ref
    backend and ignored — the kernel's own KV blocking subsumes it.
    Returns (B, S, H, Dh) in q.dtype.
    """
    del chunk
    b, s, h, dh = q.shape
    t = k.shape[1]
    bq, bk = _pick_blocks(s, t)
    if block_q:
        bq = block_q
    if block_k:
        bk = block_k
    use_window = window if (window and local_flag is not None) else 0
    lf = _flag_array(local_flag, use_window)
    return _flash(q, k, v, q_pos.astype(jnp.int32),
                  jnp.asarray(kv_pos, jnp.int32), lf,
                  float(softcap or 0.0), int(use_window), bool(causal),
                  bool(interpret), int(bq), int(bk))


def flash_attention_ref(q, k, v, q_pos, kv_pos, local_flag=None, *,
                        softcap=0.0, window=0, causal=True, chunk=0,
                        **_ignored):
    """Reference twin: literally the pre-kernel models/attention.py ops,
    including the chunk-gate selection — the default CPU path must stay
    bitwise-identical to the seed behavior."""
    from repro.models import attention as attn  # lazy: avoids import cycle

    b, s, h, dh = q.shape
    kv = k.shape[2]
    if chunk and s > chunk:
        return attn._chunked_sdpa(
            q.reshape(b, s, kv, h // kv, dh), k, v, q_pos, kv_pos,
            chunk=chunk, softcap=softcap, local_flag=local_flag,
            window=window, causal=causal)
    mask = (attn.make_mask(q_pos, kv_pos, causal=True,
                           local_flag=local_flag, window=window)
            if causal else None)
    return attn._sdpa(q, k, v, mask, softcap=softcap)


# ---------------------------------------------------------------------------
# split-KV decode
# ---------------------------------------------------------------------------


def pick_splits(t: int, bh: int, *, min_split: int = 128,
                target_cells: int = 64, max_splits: int = 16) -> int:
    """Occupancy heuristic for the decode KV split count.

    Enough ``(B*KV, n_splits)`` grid cells to occupy ``target_cells``
    cores, but never splits shorter than ``min_split`` tokens (the DMA
    would dominate) and never more than ``max_splits`` (stage-2 merge
    cost grows linearly).
    """
    by_len = max(1, math.ceil(t / min_split))
    want = max(1, math.ceil(target_cells / max(bh, 1)))
    return max(1, min(by_len, want, max_splits))


def merge_partials(o, lse):
    """Two-stage softmax combine: ``o`` is (..., n_splits, G, Dh) of
    *normalized* partial outputs, ``lse`` (..., n_splits, G) their
    log-sum-exps (NEG for empty splits). Returns (..., G, Dh)."""
    m = jnp.max(lse, axis=-2, keepdims=True)
    w = jnp.exp(lse - m)                       # empty splits: exp(NEG-m)->0
    denom = jnp.sum(w, axis=-2)
    out = jnp.sum(w[..., None] * o, axis=-3)
    return out / jnp.maximum(denom, _TINY)[..., None]


def _decode_kernel(pos_ref, lf_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                   *, softcap, window, scale, t, split):
    si = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)           # (G, Dh)
    k = k_ref[0].astype(jnp.float32)           # (split, Dh)
    v = v_ref[0].astype(jnp.float32)
    g = q.shape[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = pos_ref[0, 0]
    idx = si * split + jax.lax.broadcasted_iota(jnp.int32, (g, split), 1)
    valid = (idx <= pos) & (idx < t)
    if window > 0:
        local = (pos - idx) < window
        valid &= jnp.where(lf_ref[0] != 0, local, True)
    s = jnp.where(valid, s, NEG)
    m = jnp.max(s, axis=1)
    p = jnp.where(valid, jnp.exp(s - m[:, None]), 0.0)
    l = jnp.sum(p, axis=1)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0] = o / jnp.maximum(l, _TINY)[:, None]
    lse_ref[0, 0] = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, _TINY)), NEG)


def flash_decode(q, k, v, q_pos, local_flag=None, *, softcap=0.0, window=0,
                 interpret=False, n_splits=None):
    """Split-KV decode: q (B, 1, H, Dh), k/v (B, T, KV, Dh), q_pos (B, 1)
    per-lane positions. Inference-only (no VJP). Returns (B, 1, H, Dh)."""
    b, s, h, dh = q.shape
    assert s == 1, "flash_decode is the one-token path"
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    bh = b * kv
    if n_splits is None:
        n_splits = pick_splits(t, bh)
    split = math.ceil(t / n_splits)
    tp = split * n_splits
    q3 = q[:, 0].reshape(b, kv, g, dh).reshape(bh, g, dh)
    k3 = _pad_to(k.transpose(0, 2, 1, 3).reshape(bh, t, dh), 1, split, 0)
    v3 = _pad_to(v.transpose(0, 2, 1, 3).reshape(bh, t, dh), 1, split, 0)
    pos = q_pos.astype(jnp.int32).reshape(b, 1)
    use_window = window if (window and local_flag is not None) else 0
    lf = _flag_array(local_flag, use_window)
    kernel = functools.partial(
        _decode_kernel, softcap=float(softcap or 0.0), window=int(use_window),
        scale=1.0 / math.sqrt(dh), t=t, split=split)
    o_part, lse_part = pl.pallas_call(
        kernel,
        grid=(bh, n_splits),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, si, kvh=kv: (bb // kvh, 0)),
            pl.BlockSpec((1,), lambda bb, si: (0,)),
            pl.BlockSpec((1, g, dh), lambda bb, si: (bb, 0, 0)),
            pl.BlockSpec((1, split, dh), lambda bb, si: (bb, si, 0)),
            pl.BlockSpec((1, split, dh), lambda bb, si: (bb, si, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bb, si: (bb, si, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bb, si: (bb, si, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n_splits, g, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, n_splits, g), jnp.float32),
        ],
        interpret=interpret,
    )(pos, lf, q3, k3, v3)
    out = merge_partials(o_part, lse_part)     # (B*KV, G, Dh)
    out = out.reshape(b, kv, g, dh).reshape(b, 1, h, dh)
    return out.astype(q.dtype)


def flash_decode_ref(q, k, v, q_pos, local_flag=None, *, softcap=0.0,
                     window=0, **_ignored):
    """Reference twin: exactly the pre-kernel decode ops
    (make_mask over arange(T) + _sdpa)."""
    from repro.models import attention as attn  # lazy: avoids import cycle

    t = k.shape[1]
    mask = attn.make_mask(q_pos, jnp.arange(t), causal=True,
                          local_flag=local_flag, window=window)
    return attn._sdpa(q, k, v, mask, softcap=softcap)
