"""Pallas TPU kernel: fused SAMA Lion-adaptation product.

Lion's update direction is ``sign(c)`` with ``c = b1*m + (1-b1)*g``; the
exact derivative of ``sign`` is zero almost everywhere, which would make the
algorithmic-adaptation matrix vanish and reduce SAMA to SAMA-NA. Instead the
repo's Lion optimizer declares (see ``optim.lion``'s docstring) the smoothed
surrogate ``sign_d(c) = c / (|c| + delta)``, whose elementwise derivative

    du/dg = lr * (1-b1) * delta / (|c| + delta)^2

is the diagonal this kernel fuses against ``g_meta`` — one pass over
(g, m, g_meta) emitting the product tile plus a per-tile partial sum of
squares for the eps = alpha/||v|| step size (no second norm pass).

Same layout contract as ``adam_adapt``: 1-D grid over (BLK,)-tiles of the
flattened tensor, traced scalars (lr) ride a scalar input block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lion_kernel(sched_ref, g_ref, m_ref, gm_ref, out_ref, ss_ref, *, b1, delta):
    lr = sched_ref[0]
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    gm = gm_ref[...].astype(jnp.float32)

    c = b1 * m + (1.0 - b1) * g
    ad = jnp.abs(c) + delta
    diag = lr * (1.0 - b1) * delta / (ad * ad)
    out = diag * gm
    out_ref[...] = out
    ss_ref[0] = jnp.sum(out * out)


def lion_adapt_product(
    g: jnp.ndarray,
    m: jnp.ndarray,
    g_meta: jnp.ndarray,
    *,
    lr=1.0,
    b1: float = 0.9,
    delta: float = 1e-3,
    block: int = 8 * 1024,
    interpret: bool = True,
):
    """Flat f32 arrays (N,). Returns (v_out (N,) f32, sumsq scalar f32)."""

    (n,) = g.shape
    blk = min(block, n)
    pad = (-n) % blk
    if pad:
        zeros = jnp.zeros((pad,), g.dtype)
        g, m, g_meta = (jnp.concatenate([x, zeros]) for x in (g, m, g_meta))
    n_pad = n + pad
    grid = (n_pad // blk,)

    sched = jnp.asarray(lr, jnp.float32).reshape(1)
    kern = functools.partial(_lion_kernel, b1=float(b1), delta=float(delta))
    out, partial_ss = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))]
        + [pl.BlockSpec((blk,), lambda i: (i,))] * 3,
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        ],
        interpret=interpret,
    )(sched, g, m, g_meta)
    return out[:n], jnp.sum(partial_ss)
