"""Fused Pallas kernels for the SAMA hot path, behind a backend-dispatch
registry.

The paper's throughput/memory wins come from computing the adaptive-optimizer
adaptation product as cheap first-order elementwise work (Eq. 4 / App. C);
this package is where that work stops being a ~12-op jnp chain and becomes
one fused pass:

* ``adam_adapt`` / ``lion_adapt`` / ``adafactor_adapt`` — the fused
  adaptation-diagonal x meta-gradient product, emitting per-tile partial
  sums of squares so SAMA's ``eps = alpha/||v||`` needs no second pass;
* ``weighted_ce`` — blockwise (flash-style) cross-entropy over very large
  vocabularies, forward and backward, each logit read exactly once.

Every kernel name resolves through ``dispatch.get_kernel`` to one of three
registered implementations — ``pallas-tpu`` (compiled), ``pallas-interpret``
(the kernel body under the Pallas interpreter; any backend), or ``ref``
(pure jnp, always eligible) — selected per call from an explicit
``backend=`` argument, the ``REPRO_KERNEL_BACKEND`` environment variable,
or the platform default (TPU prefers compiled Pallas, CPU/GPU prefer
``ref``). Shapes a backend cannot tile fall back down that order; ragged
tails are padded inside the flat kernels. See docs/kernels.md for the
support matrix, tiling rules and how to add a kernel, and ``ref.py`` for
the jnp oracles every implementation is tested against
(tests/test_kernel_dispatch.py).

Consumers in the hot path: ``optim.adam/adamw/lion/adafactor`` route their
``adaptation`` / fused ``adapt_product`` through the registry, SAMA's
perturbation-direction build consumes the fused product + norm, and the CE
losses in ``core.problems`` / ``models.model`` route through
``weighted_ce`` at ``dispatch.CE_VOCAB_THRESHOLD`` and above.
"""

from repro.kernels import ops, ref
from repro.kernels.dispatch import (
    BACKENDS,
    CE_VOCAB_THRESHOLD,
    ENV_VAR,
    KernelImpl,
    available_kernels,
    backend_order,
    clear_dispatch_log,
    dispatch_log,
    get_kernel,
    kernel_backends,
    register_kernel,
    unregister_kernel,
)

__all__ = [
    "BACKENDS",
    "CE_VOCAB_THRESHOLD",
    "ENV_VAR",
    "KernelImpl",
    "available_kernels",
    "backend_order",
    "clear_dispatch_log",
    "dispatch_log",
    "get_kernel",
    "kernel_backends",
    "ops",
    "ref",
    "register_kernel",
    "unregister_kernel",
]
