"""Pallas TPU kernel: blockwise cross-entropy over very large vocabularies.

The base-level loss of every data-optimization experiment in the paper is a
(sample-weighted) cross-entropy; with vocabularies up to 262 144 the logits
row does not fit VMEM, and a naive logsumexp materializes several (R, V)
temporaries in HBM. This kernel streams the vocabulary in (BR, BV) VMEM
blocks with an online max/sum-exp accumulator (flash-style), so each logit is
read exactly once for the forward and once for the backward.

Grid: (rows/BR, V/BV) — TPU iterates the last axis fastest, so the scratch
accumulators (m, l, target-logit) persist across a row-block's vocab sweep
and are finalized on the last vocab step.

Layout decisions (TPU): BV is a multiple of 128 (lane width), BR a multiple
of 8 (f32 sublanes). Targets ride along as one int32 per row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ce_fwd_kernel(targets_ref, logits_ref, out_ce_ref, out_lse_ref, m_ref, l_ref, t_ref):
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    block = logits_ref[...].astype(jnp.float32)  # (BR, BV)
    bv = block.shape[1]
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(block, axis=1))
    scale = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * scale + jnp.sum(jnp.exp(block - m_cur[:, None]), axis=1)
    m_ref[...] = m_cur

    # pick out the target logit if it falls inside this vocab block
    tgt = targets_ref[...]  # (BR,) int32 absolute ids
    local = tgt - j * bv
    cols = jax.lax.broadcasted_iota(jnp.int32, block.shape, 1)
    hit = cols == local[:, None]
    t_ref[...] += jnp.sum(jnp.where(hit, block, 0.0), axis=1)

    @pl.when(j == nv - 1)
    def _fin():
        lse = jnp.log(l_ref[...]) + m_ref[...]
        out_lse_ref[...] = lse
        out_ce_ref[...] = lse - t_ref[...]


def _ce_bwd_kernel(targets_ref, lse_ref, g_ref, logits_ref, dlogits_ref):
    j = pl.program_id(1)
    block = logits_ref[...].astype(jnp.float32)
    bv = block.shape[1]
    p = jnp.exp(block - lse_ref[...][:, None])
    tgt = targets_ref[...]
    local = tgt - j * bv
    cols = jax.lax.broadcasted_iota(jnp.int32, block.shape, 1)
    onehot = (cols == local[:, None]).astype(jnp.float32)
    dlogits_ref[...] = ((p - onehot) * g_ref[...][:, None]).astype(dlogits_ref.dtype)


def _pick_blocks(rows, v):
    br = 8
    while rows % br and br > 1:
        br //= 2
    bv = 2048 if v % 2048 == 0 else (512 if v % 512 == 0 else (128 if v % 128 == 0 else v))
    return br, bv


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, interpret: bool = True):
    """logits: (R, V); targets: (R,) int32. Returns per-row CE (R,) f32."""
    ce, _ = _ce_fwd(logits, targets, interpret)
    return ce


def _ce_fwd(logits, targets, interpret):
    R, V = logits.shape
    BR, BV = _pick_blocks(R, V)
    grid = (R // BR, V // BV)
    ce, lse = pl.pallas_call(
        _ce_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BR,), lambda i, j: (i,)),
            pl.BlockSpec((BR, BV), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((BR,), lambda i, j: (i,)),
            pl.BlockSpec((BR,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.float32),
            jax.ShapeDtypeStruct((R,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((BR,), jnp.float32),
            pltpu.VMEM((BR,), jnp.float32),
            pltpu.VMEM((BR,), jnp.float32),
        ],
        interpret=interpret,
    )(targets.astype(jnp.int32), logits)
    return ce, lse


def _cross_entropy_fwd(logits, targets, interpret):
    ce, lse = _ce_fwd(logits, targets, interpret)
    return ce, (logits, targets, lse)


def _cross_entropy_bwd(interpret, res, g):
    logits, targets, lse = res
    R, V = logits.shape
    BR, BV = _pick_blocks(R, V)
    grid = (R // BR, V // BV)
    dlogits = pl.pallas_call(
        _ce_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BR,), lambda i, j: (i,)),
            pl.BlockSpec((BR,), lambda i, j: (i,)),
            pl.BlockSpec((BR,), lambda i, j: (i,)),
            pl.BlockSpec((BR, BV), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BR, BV), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, V), logits.dtype),
        interpret=interpret,
    )(targets.astype(jnp.int32), lse, g.astype(jnp.float32), logits)
    return dlogits, None


cross_entropy.defvjp(_cross_entropy_fwd, _cross_entropy_bwd)
