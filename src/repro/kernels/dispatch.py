"""The kernel backend-dispatch registry (DESIGN.md §10, docs/kernels.md).

Every fused kernel in this package has up to three interchangeable
implementations of one calling convention:

* ``"pallas-tpu"``      — the Pallas kernel compiled for real (TPU runtimes);
* ``"pallas-interpret"`` — the same kernel body run through the Pallas
  interpreter (works on any backend; the CPU CI's way of executing the
  actual kernel code);
* ``"ref"``             — a pure-jnp implementation in the inputs' native
  dtype (the fastest choice on CPU/GPU and the always-eligible fallback).

``register_kernel(name, backend, impl, eligible=...)`` installs one
implementation; ``get_kernel(name)`` returns a dispatching callable that
picks an implementation *per call*, in this precedence order:

1. an explicit ``backend=`` argument to ``get_kernel`` (tests, benchmarks);
2. the ``REPRO_KERNEL_BACKEND`` environment variable — consulted on every
   dispatch, which under jit means at TRACE time: set it before the first
   call for a given shape, because an already-cached executable will not
   re-dispatch;
3. the platform default: ``jax.default_backend() == "tpu"`` prefers
   ``pallas-tpu``, everything else prefers ``ref`` (the interpreter is a
   correctness tool, not a fast path).

Whatever picked the backend, a per-kernel ``eligible(*args, **kwargs)``
predicate is consulted on the concrete call (static shapes/dtypes only — it
runs at trace time). An ineligible or unregistered choice falls through to
the next entry in the order, ending at ``ref`` which must always be
registered and always eligible; the fallback is recorded, never an error.
Ragged/non-tile-aligned shapes are therefore safe on every backend: the
flat adaptation kernels pad internally (pad-or-fallback), and shapes the
blockwise-CE kernel cannot tile fall back to ``ref``.

Dispatch decisions are appended to a trace-time log (``dispatch_log()``) —
selection happens while JAX traces, so the log records which implementation
a jitted function lowered through (what the acceptance tests pin), not
per-call execution counts.
"""

from __future__ import annotations

import collections
import dataclasses
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

ENV_VAR = "REPRO_KERNEL_BACKEND"

#: recognised backends, in no particular order (precedence is computed
#: per-call by ``backend_order``).
BACKENDS = ("pallas-tpu", "pallas-interpret", "ref")

#: vocabulary size at or above which the CE loss paths route through the
#: dispatched ``weighted_ce`` kernel (below it, a plain fused-by-XLA
#: log_softmax is already optimal and the blockwise machinery buys nothing).
CE_VOCAB_THRESHOLD = 4096


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered implementation of a kernel."""

    name: str
    backend: str
    fn: Callable[..., Any]
    #: static-shape eligibility predicate; None = always eligible.
    eligible: Optional[Callable[..., bool]] = None

    def is_eligible(self, *args, **kwargs) -> bool:
        if self.eligible is None:
            return True
        return bool(self.eligible(*args, **kwargs))


_REGISTRY: Dict[str, Dict[str, KernelImpl]] = {}

#: trace-time dispatch decisions: (kernel, backend, reason) tuples. Bounded
#: so eager callers in long-running processes (scoring loops, serve) don't
#: leak — jitted hot paths only append on (re)trace anyway.
_DISPATCH_LOG: "collections.deque[Tuple[str, str, str]]" = collections.deque(maxlen=4096)


def register_kernel(
    name: str,
    backend: str,
    impl: Optional[Callable[..., Any]] = None,
    *,
    eligible: Optional[Callable[..., bool]] = None,
    overwrite: bool = False,
):
    """Register ``impl`` as the ``backend`` implementation of kernel
    ``name``. Usable directly or as a decorator::

        register_kernel("adam_adapt", "ref", _adam_ref)

        @register_kernel("mine", "pallas-interpret", eligible=_tiles_ok)
        def _mine(x): ...

    All implementations of one name must share a calling convention —
    callers never know which backend they got.
    """

    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")

    def _install(fn):
        per_kernel = _REGISTRY.setdefault(name, {})
        if backend in per_kernel and not overwrite:
            raise ValueError(
                f"kernel {name!r} already has a {backend!r} implementation "
                "(pass overwrite=True to replace)"
            )
        per_kernel[backend] = KernelImpl(name=name, backend=backend, fn=fn, eligible=eligible)
        return fn

    if impl is None:
        return _install
    return _install(impl)


def unregister_kernel(name: str, backend: Optional[str] = None):
    """Remove a kernel (or one backend of it) — test hygiene."""

    if backend is None:
        _REGISTRY.pop(name, None)
    elif name in _REGISTRY:
        _REGISTRY[name].pop(backend, None)


def available_kernels() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def kernel_backends(name: str) -> Tuple[str, ...]:
    """Backends registered for ``name`` (registry order-independent)."""

    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {available_kernels()}")
    return tuple(b for b in BACKENDS if b in _REGISTRY[name])


def backend_order(backend: Optional[str] = None) -> Tuple[str, ...]:
    """The per-call backend precedence list (most preferred first). ``ref``
    is always the terminal fallback."""

    forced = backend or os.environ.get(ENV_VAR)
    if forced:
        if forced not in BACKENDS:
            raise ValueError(f"{ENV_VAR}/backend= must be one of {BACKENDS}, got {forced!r}")
        return (forced, "ref") if forced != "ref" else ("ref",)
    if jax.default_backend() == "tpu":
        return ("pallas-tpu", "ref")
    return ("ref",)


def _observe_dispatch(name: str, cand: str, reason: str) -> None:
    """Mirror one dispatch decision into the process-global obs pipeline
    (counter keyed by kernel/backend/reason + a ``dispatch`` event).
    Dispatch happens at trace time, so per-decision cost is per-compile,
    not per-step; the NULL_OBS default makes this a two-attribute check."""

    from repro import obs as obs_mod

    obs = obs_mod.get_default()
    if not obs.enabled:
        return
    obs.counter("dispatch_total").inc(
        labels={"kernel": name, "backend": cand, "reason": reason})
    obs.emit("dispatch", name, data={"backend": cand, "reason": reason})


def dispatch_log() -> List[Tuple[str, str, str]]:
    """Trace-time decisions so far (most recent 4096): (kernel, backend,
    reason)."""

    return list(_DISPATCH_LOG)


def clear_dispatch_log() -> None:
    _DISPATCH_LOG.clear()


def get_kernel(name: str, *, backend: Optional[str] = None) -> Callable[..., Any]:
    """A callable dispatching ``name`` per the precedence rules above.

    The returned function resolves its implementation at every call (trace
    time under jit): explicit ``backend=`` beats ``$REPRO_KERNEL_BACKEND``
    beats the platform default, and an ineligible/unregistered choice falls
    through to ``ref``."""

    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {available_kernels()}")

    def dispatch(*args, **kwargs):
        per_kernel = _REGISTRY[name]
        order = backend_order(backend)
        tried = []
        for cand in order:
            if cand == "pallas-tpu" and jax.default_backend() != "tpu":
                # compiled Pallas only exists on a TPU runtime; even a forced
                # choice degrades safely rather than crashing in lowering
                tried.append(f"{cand}:unavailable")
                continue
            impl = per_kernel.get(cand)
            if impl is None:
                tried.append(f"{cand}:unregistered")
                continue
            if not impl.is_eligible(*args, **kwargs):
                tried.append(f"{cand}:ineligible")
                continue
            reason = "selected" if not tried else "fallback(" + ",".join(tried) + ")"
            _DISPATCH_LOG.append((name, cand, reason))
            _observe_dispatch(name, cand, reason)
            return impl.fn(*args, **kwargs)
        raise RuntimeError(  # unreachable while every kernel registers a ref impl
            f"no eligible implementation for kernel {name!r}: tried {tried}"
        )

    dispatch.__name__ = f"dispatch[{name}]"
    return dispatch


# ---------------------------------------------------------------------------
# built-in registrations: the package's support matrix (docs/kernels.md)
# ---------------------------------------------------------------------------


def _flat_inputs_ok(*arrays, **kwargs) -> bool:
    """The flat adaptation kernels pad ragged tails internally, so any
    non-empty 1-D input is tile-eligible."""

    return all(a.ndim == 1 for a in arrays) and arrays[0].size > 0


def _ce_tiles_ok(logits, targets, **kwargs) -> bool:
    """The compiled blockwise-CE kernel needs a lane-aligned vocabulary
    (V % 128) — `_pick_blocks` would otherwise fall back to BV=V, which
    defeats the VMEM streaming the kernel exists for. Interpret mode has
    no such constraint (any block shape interprets)."""

    return logits.ndim == 2 and logits.shape[-1] % 128 == 0


_ATTN_DTYPES = ("float32", "bfloat16", "float16")


def _attn_shapes_ok(q, k, v, *args, **kwargs) -> bool:
    """Flash attention handles any S/T (pad+mask internally); the gate is
    the calling convention itself: 4-D GQA layouts with H % KV == 0 and a
    dtype the f32-accumulating kernel supports."""

    return (
        q.ndim == 4 and k.ndim == 4 and v.shape == k.shape
        and q.shape[0] == k.shape[0] and q.shape[-1] == k.shape[-1]
        and k.shape[2] > 0 and q.shape[2] % k.shape[2] == 0
        and str(q.dtype) in _ATTN_DTYPES
    )


def _attn_tpu_ok(q, k, v, *args, **kwargs) -> bool:
    """Compiled TPU tiles additionally want a lane-aligned head dim and
    sequences long enough that 128-wide q/kv tiles are not all padding."""

    return (
        _attn_shapes_ok(q, k, v, *args, **kwargs)
        and q.shape[-1] % 128 == 0
        and q.shape[1] >= 128 and k.shape[1] >= 128
    )


def _decode_shapes_ok(q, k, v, *args, **kwargs) -> bool:
    return (
        q.ndim == 4 and q.shape[1] == 1 and k.ndim == 4 and v.shape == k.shape
        and q.shape[0] == k.shape[0] and q.shape[-1] == k.shape[-1]
        and k.shape[2] > 0 and q.shape[2] % k.shape[2] == 0
        and str(q.dtype) in _ATTN_DTYPES
    )


def _decode_tpu_ok(q, k, v, *args, **kwargs) -> bool:
    return (_decode_shapes_ok(q, k, v, *args, **kwargs)
            and q.shape[-1] % 128 == 0 and k.shape[1] >= 128)


def _register_builtins() -> None:
    from repro.kernels import (adafactor_adapt, adam_adapt, flash_attn,
                               lion_adapt, ref, weighted_ce)

    # -- adam_adapt: (g, m, v, g_meta, *, t, b1, b2, eps, lr) -> (out, sumsq)
    register_kernel(
        "adam_adapt", "pallas-tpu",
        lambda *a, **k: adam_adapt.adam_adapt_product(*a, interpret=False, **k),
        eligible=_flat_inputs_ok,
    )
    register_kernel(
        "adam_adapt", "pallas-interpret",
        lambda *a, **k: adam_adapt.adam_adapt_product(*a, interpret=True, **k),
        eligible=_flat_inputs_ok,
    )
    register_kernel("adam_adapt", "ref", ref.adam_adapt_math)

    # -- lion_adapt: (g, m, g_meta, *, lr, b1, delta) -> (out, sumsq)
    register_kernel(
        "lion_adapt", "pallas-tpu",
        lambda *a, **k: lion_adapt.lion_adapt_product(*a, interpret=False, **k),
        eligible=_flat_inputs_ok,
    )
    register_kernel(
        "lion_adapt", "pallas-interpret",
        lambda *a, **k: lion_adapt.lion_adapt_product(*a, interpret=True, **k),
        eligible=_flat_inputs_ok,
    )
    register_kernel("lion_adapt", "ref", ref.lion_adapt_math)

    # -- adafactor_adapt: (vhat, g_meta, *, lr, eps) -> (out, sumsq)
    register_kernel(
        "adafactor_adapt", "pallas-tpu",
        lambda *a, **k: adafactor_adapt.adafactor_adapt_product(*a, interpret=False, **k),
        eligible=_flat_inputs_ok,
    )
    register_kernel(
        "adafactor_adapt", "pallas-interpret",
        lambda *a, **k: adafactor_adapt.adafactor_adapt_product(*a, interpret=True, **k),
        eligible=_flat_inputs_ok,
    )
    register_kernel("adafactor_adapt", "ref", ref.adafactor_adapt_math)

    # -- weighted_ce: (logits (R, V), targets (R,)) -> per-row CE (R,),
    #    differentiable (the pallas paths carry the flash-style custom VJP).
    register_kernel(
        "weighted_ce", "pallas-tpu",
        lambda logits, targets: weighted_ce.cross_entropy(logits, targets, False),
        eligible=_ce_tiles_ok,
    )
    register_kernel(
        "weighted_ce", "pallas-interpret",
        lambda logits, targets: weighted_ce.cross_entropy(logits, targets, True),
        eligible=lambda logits, targets: logits.ndim == 2,
    )
    register_kernel("weighted_ce", "ref", ref.cross_entropy)

    # -- flash_attention: (q, k, v, q_pos, kv_pos, local_flag=None, *,
    #    softcap, window, causal, chunk) -> (B, S, H, Dh); differentiable
    #    (recompute-based custom VJP on the pallas paths).
    register_kernel(
        "flash_attention", "pallas-tpu",
        lambda *a, **k: flash_attn.flash_attention(*a, interpret=False, **k),
        eligible=_attn_tpu_ok,
    )
    register_kernel(
        "flash_attention", "pallas-interpret",
        lambda *a, **k: flash_attn.flash_attention(*a, interpret=True, **k),
        eligible=_attn_shapes_ok,
    )
    register_kernel("flash_attention", "ref", flash_attn.flash_attention_ref)

    # -- flash_decode: (q, k, v, q_pos, local_flag=None, *, softcap,
    #    window) -> (B, 1, H, Dh); split-KV two-stage merge, inference-only.
    register_kernel(
        "flash_decode", "pallas-tpu",
        lambda *a, **k: flash_attn.flash_decode(*a, interpret=False, **k),
        eligible=_decode_tpu_ok,
    )
    register_kernel(
        "flash_decode", "pallas-interpret",
        lambda *a, **k: flash_attn.flash_decode(*a, interpret=True, **k),
        eligible=_decode_shapes_ok,
    )
    register_kernel("flash_decode", "ref", flash_attn.flash_decode_ref)


_register_builtins()
