"""Pure-jnp oracles for every Pallas kernel. Tests assert_allclose the
kernels (interpret mode on CPU) against these across shape/dtype sweeps.

Two layers:

* ``*_math`` helpers compute in the inputs' native dtype (x64-safe). They
  are the single source of truth for the adaptation expressions — the
  ``ref`` backend registered in ``kernels.dispatch`` and the f32 oracles
  below both call them, so the dispatcher's pure-jnp fallback can never
  drift from the test oracle.
* the public oracles mirror the kernels' f32 compute (cast inputs to f32
  first), which is what the parity tests compare against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """logits: (R, V); targets: (R,) int. Returns per-row CE (R,) f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return lse - tgt


def cross_entropy_grad(logits, targets, g):
    """d(sum g_r * CE_r)/dlogits: (softmax - onehot) * g."""
    logits32 = logits.astype(jnp.float32)
    p = jax.nn.softmax(logits32, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    return ((p - onehot) * g[:, None]).astype(logits.dtype)


# ---------------------------------------------------------------------------
# native-dtype adaptation math (shared with kernels.dispatch's ref backend)
# ---------------------------------------------------------------------------


def _sumsq32(out):
    """Sum of squares accumulated in f32 — mirroring ``sama.global_norm``'s
    f32 upcast, so the fused eps = alpha/||v|| agrees with the unfused
    global-norm pass for low-precision trees too (the Pallas kernels
    already accumulate in f32)."""

    out32 = out.astype(jnp.float32)
    return jnp.sum(out32 * out32)


def adam_adapt_math(g, m, v, g_meta, *, t, b1, b2, eps, lr):
    """Native-dtype SAMA Adam perturbation direction (paper Appendix C,
    exact): out = (du_adam/dg)|_(g, m, v, t) * g_meta, elementwise, plus
    sum(out^2) for the eps = alpha/||v|| step size. This is the single
    source of truth for the Adam adaptation expression — ``optim.adam``'s
    ``adaptation``/``adapt_product`` reach it through the dispatch
    registry's ``ref`` backend."""

    # bias corrections in at-least-f32 (same fix as optim.adam.update):
    # 1 - 0.999^t rounds to 0.0 in bf16, poisoning vhat/b with inf on
    # sub-f32 trees; f32/f64 paths are bit-identical to computing in g.dtype
    t = jnp.asarray(t).astype(jnp.promote_types(g.dtype, jnp.float32))
    bc1 = (1.0 - b1**t).astype(g.dtype)
    bc2 = (1.0 - b2**t).astype(g.dtype)
    m1 = b1 * m + (1.0 - b1) * g
    v1 = b2 * v + (1.0 - b2) * g * g
    mhat = m1 / bc1
    vhat = v1 / bc2
    denom = jnp.sqrt(vhat) + eps
    a = (1.0 - b1) / bc1
    b = (1.0 - b2) / bc2
    safe_sqrt = jnp.maximum(jnp.sqrt(vhat), 1e-15)
    diag = lr * (a / denom - mhat * b * g / (safe_sqrt * denom * denom))
    out = diag * g_meta
    return out, _sumsq32(out)


def lion_adapt_math(g, m, g_meta, *, lr, b1, delta):
    """Native-dtype Lion surrogate adaptation product (see
    ``kernels.lion_adapt``): diag = lr*(1-b1)*delta/(|c|+delta)^2 with
    c = b1*m + (1-b1)*g."""

    c = b1 * m + (1.0 - b1) * g
    ad = jnp.abs(c) + delta
    diag = lr * (1.0 - b1) * delta / (ad * ad)
    out = diag * g_meta
    return out, _sumsq32(out)


def adafactor_adapt_math(vhat, g_meta, *, lr, eps):
    """Native-dtype Adafactor frozen-statistics adaptation product:
    diag = lr / (sqrt(vhat) + eps)."""

    out = (lr / (jnp.sqrt(vhat) + eps)) * g_meta
    return out, _sumsq32(out)


# ---------------------------------------------------------------------------
# f32 oracles (what the Pallas kernels are tested against)
# ---------------------------------------------------------------------------


def _f32(*xs):
    return tuple(x.astype(jnp.float32) for x in xs)


def adam_adapt_product(g, m, v, g_meta, *, t, b1, b2, eps, lr):
    """SAMA perturbation direction for Adam (paper Appendix C, exact):
    out = (du_adam/dg)|_(g, m, v, t) * g_meta, elementwise. All f32.
    Also returns sum(out^2) for the eps = alpha/||v|| step size."""

    g, m, v, g_meta = _f32(g, m, v, g_meta)
    return adam_adapt_math(g, m, v, g_meta, t=t, b1=b1, b2=b2, eps=eps, lr=lr)


def lion_adapt_product(g, m, g_meta, *, lr=1.0, b1=0.9, delta=1e-3):
    """Lion surrogate adaptation product. All f32."""

    g, m, g_meta = _f32(g, m, g_meta)
    return lion_adapt_math(g, m, g_meta, lr=lr, b1=b1, delta=delta)


def adafactor_adapt_product(vhat, g_meta, *, lr=1.0, eps=1e-8):
    """Adafactor frozen-statistics adaptation product. All f32."""

    vhat, g_meta = _f32(vhat, g_meta)
    return adafactor_adapt_math(vhat, g_meta, lr=lr, eps=eps)
