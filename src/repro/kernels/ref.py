"""Pure-jnp oracles for every Pallas kernel. Tests assert_allclose the
kernels (interpret mode on CPU) against these across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """logits: (R, V); targets: (R,) int. Returns per-row CE (R,) f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return lse - tgt


def cross_entropy_grad(logits, targets, g):
    """d(sum g_r * CE_r)/dlogits: (softmax - onehot) * g."""
    logits32 = logits.astype(jnp.float32)
    p = jax.nn.softmax(logits32, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    return ((p - onehot) * g[:, None]).astype(logits.dtype)


def adam_adapt_product(g, m, v, g_meta, *, t, b1, b2, eps, lr):
    """SAMA perturbation direction for Adam (paper Appendix C, exact):
    out = (du_adam/dg)|_(g, m, v, t) * g_meta, elementwise. All f32.
    Also returns sum(out^2) for the eps = alpha/||v|| step size."""

    g = g.astype(jnp.float32)
    m = m.astype(jnp.float32)
    v = v.astype(jnp.float32)
    g_meta = g_meta.astype(jnp.float32)

    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    m1 = b1 * m + (1.0 - b1) * g
    v1 = b2 * v + (1.0 - b2) * g * g
    mhat = m1 / bc1
    vhat = v1 / bc2
    denom = jnp.sqrt(vhat) + eps
    a = (1.0 - b1) / bc1
    b = (1.0 - b2) / bc2
    safe_sqrt = jnp.maximum(jnp.sqrt(vhat), 1e-15)
    diag = lr * (a / denom - mhat * b * g / (safe_sqrt * denom * denom))
    out = diag * g_meta
    return out, jnp.sum(out * out)
