"""Continuous batching over fixed decode lanes (docs/serve.md §3).

The decode batch is ``slots`` fixed lanes. A lane is bound to one
request from admission to retirement; finished lanes free immediately
and the next queued request prefills into the freed slot — *joining the
in-flight batch between steps without recompiling*, because the jitted
step's shapes depend only on ``(slots, bucket_len, pool_capacity)``,
never on which lanes are live.

One decode step is ONE jitted call fusing gather (paged pool -> dense
bucket view) -> ``decode_step`` -> scatter (one column per lane back to
its page), with the pool/state buffers donated so XLA can update pages
in place. The bucket view length is the smallest member of a
power-of-two page-multiple bucket set covering the longest live lane —
short traffic never pays long-context attention, and the bucket set is
capped by the same HBM-budget arithmetic ``scale/plan.py`` applies to
training microbatches (``hbm_budget_bytes``).

Per-lane positions are ragged (``pos[lane] = seq_len``): a lane
admitted at step 1000 decodes its position-7 token in the same call a
long lane decodes position 900. Inactive lanes run the step on trash
inputs (position 0, trash page) and their outputs are discarded.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm
from repro.obs import trace as obs_trace
from repro.serve import prefill as prefill_mod
from repro.serve.cache import CacheSpec, PagedCache, gather_dense, scatter_token
from repro.serve.queue import Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs for the serving stack. ``max_len`` bounds prompt + generated
    tokens per request and must be a multiple of ``page_size``;
    ``dtype=None`` serves in the model config's dtype
    (``models.common.dtype_of``)."""

    slots: int = 4
    page_size: int = 8
    max_len: int = 128
    max_new_tokens: int = 16
    queue_depth: int = 64
    default_timeout_s: Optional[float] = None
    prefill_mode: str = "auto"  # "auto" | "block" | "scan"
    hbm_budget_bytes: Optional[int] = None
    initial_pages: Optional[int] = None
    max_pages: Optional[int] = None
    dtype: Optional[str] = None
    # flight recorder / hang watchdog (docs/observability.md):
    # flight_capacity=0 disables the always-on postmortem ring;
    # hang_deadline_s=None disables the watchdog thread.
    flight_capacity: int = 2048
    flight_dir: Optional[str] = None
    flight_snapshot_every: int = 16   # ticks between flight metric snapshots
    hang_deadline_s: Optional[float] = None


def decode_buckets(spec: CacheSpec, cfg: ServeConfig) -> Tuple[int, ...]:
    """Power-of-two page-multiple view lengths up to ``max_len``, filtered
    by the gathered-view HBM cost (``slots x bucket x bytes/token`` — the
    transient the gather materializes on top of the pool). The ``max_len``
    bucket must survive the filter: a request the config admits must also
    be decodable."""

    buckets: List[int] = []
    b = cfg.page_size
    while b < cfg.max_len:
        buckets.append(b)
        b *= 2
    buckets.append(cfg.max_len)
    if cfg.hbm_budget_bytes is not None:
        per_token = spec.token_view_bytes() * cfg.slots
        kept = [b for b in buckets if b * per_token <= cfg.hbm_budget_bytes]
        if cfg.max_len not in kept:
            raise ValueError(
                f"hbm_budget_bytes={cfg.hbm_budget_bytes} cannot fit the "
                f"max_len={cfg.max_len} decode view "
                f"({cfg.max_len * per_token} bytes); lower max_len or slots")
        buckets = kept
    return tuple(buckets)


@functools.lru_cache(maxsize=None)  # (Model identity, frozen spec)-keyed:
def _fused_step(model, spec):       # batchers over the same model share
    """gather -> decode_step -> scatter as ONE jitted call, pool/state
    buffers donated so XLA updates pages in place."""

    def step(params, pools, states, table_view, pos, tokens, active):
        # phase() = metadata-only named_scope (identical HLO with obs on
        # or off) — it makes the fused step attributable as "serve_step"
        # by repro.obs.profile
        with obs_trace.phase("serve_step"):
            dense = gather_dense(spec, pools, states, table_view)
            logits, new_cache = model.decode_step(params, dense,
                                                  tokens[:, None], pos)
            pools, states = scatter_token(spec, pools, states, new_cache,
                                          table_view, pos, active)
            lg = logits[:, 0].astype(jnp.float32)
            next_tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            finite = jnp.all(jnp.isfinite(lg), axis=-1)
        return pools, states, next_tok, finite

    return jax.jit(step, donate_argnums=(1, 2))


@dataclasses.dataclass
class Lane:
    """One live request bound to a decode slot."""

    request: Request
    slot: int
    prompt_len: int
    target_new: int
    tokens: List[int]
    admitted_t: float
    # set by the executor once prefill has produced the first token —
    # the TTFT anchor (and the point TPOT measures from)
    first_token_t: Optional[float] = None


@dataclasses.dataclass
class PendingStep:
    """In-flight device step: arrays are uncommitted futures until
    ``harvest`` blocks on them."""

    next_tok: jnp.ndarray
    finite: jnp.ndarray
    lanes: List[Optional[Lane]]
    bucket: int


class ContinuousBatcher:
    """Admission + fused-step mechanics. The executor owns the loop,
    deadlines and terminal statuses; this class owns lanes, pages and
    the jitted step."""

    def __init__(self, model, params, cfg: ServeConfig):
        if model.cfg.family == "encoder":
            raise ValueError(
                f"{model.cfg.name!r} is encoder-only: no decode step to serve")
        if cfg.max_len % cfg.page_size != 0:
            raise ValueError("max_len must be a multiple of page_size")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.dtype = cm.dtype_of(cfg.dtype if cfg.dtype is not None
                                 else model.cfg.dtype)
        self.cache = PagedCache(
            model, slots=cfg.slots, page_size=cfg.page_size,
            max_len=cfg.max_len, dtype=self.dtype,
            initial_pages=cfg.initial_pages, max_pages=cfg.max_pages,
        )
        self.buckets = decode_buckets(self.cache.spec, cfg)
        self.lanes: List[Optional[Lane]] = [None] * cfg.slots
        self._step_fn = _fused_step(model, self.cache.spec)
        self.steps_dispatched = 0
        # per-slot goodput accounting: every dispatched step runs ALL
        # slots — an inactive slot burns the step on trash inputs, so
        # useful/(useful+trash) is each lane's useful-token fraction
        self.useful_ticks = [0] * cfg.slots
        self.trash_ticks = [0] * cfg.slots
        self.tokens_emitted = [0] * cfg.slots

    # -- admission -----------------------------------------------------------

    def can_admit(self) -> bool:
        return self.cache.free_slot_count() > 0

    def admit(self, request: Request, now: float) -> Lane:
        """Prefill the request's prompt into a free slot. The prompt is
        right-padded to a page multiple; one chunked-prefill call produces
        the first greedy token and the slot's pages/state."""

        prompt = np.asarray(request.payload["prompt"], np.int32).reshape(-1)
        target_new = int(request.payload.get("max_new_tokens",
                                             self.cfg.max_new_tokens))
        P = int(prompt.size)
        if P < 1:
            raise ValueError("empty prompt")
        if P + target_new > self.cfg.max_len:
            raise ValueError(
                f"prompt_len={P} + max_new_tokens={target_new} exceeds "
                f"max_len={self.cfg.max_len}")
        pg = self.cfg.page_size
        P_pad = pg * math.ceil(P / pg)
        slot = self.cache.alloc_slot()
        try:
            self.cache.reserve(slot, P)
            cache0 = self.model.init_cache(1, P_pad, dtype=self.dtype)
            padded = np.zeros((1, P_pad), np.int32)
            padded[0, :P] = prompt
            last, filled = prefill_mod.chunked_prefill(
                self.model, self.params, jnp.asarray(padded), cache0,
                lengths=jnp.asarray([P], jnp.int32),
                mode=self.cfg.prefill_mode,
            )
            self.cache.write_prefill(slot, filled, P)
        except Exception:
            self.cache.free(slot)
            raise
        tok0 = int(jnp.argmax(last[0], axis=-1))
        lane = Lane(request=request, slot=slot, prompt_len=P,
                    target_new=target_new, tokens=[tok0], admitted_t=now)
        self.lanes[slot] = lane
        return lane

    # -- decode --------------------------------------------------------------

    def live_lanes(self) -> List[Lane]:
        return [ln for ln in self.lanes if ln is not None]

    def lane_done(self, lane: Lane) -> bool:
        return len(lane.tokens) >= lane.target_new

    def bucket_for(self, need: int) -> int:
        for b in self.buckets:
            if b >= need:
                return b
        raise ValueError(f"no bucket covers length {need}")  # unreachable: max_len gates admission

    def dispatch(self) -> Optional[PendingStep]:
        """Launch one fused decode step for all live lanes (async — the
        returned arrays are futures). Returns None when no lane is live."""

        live = self.live_lanes()
        if not live:
            return None
        need = 0
        for ln in live:
            self.cache.reserve(ln.slot, int(self.cache.seq_lens[ln.slot]) + 1)
            need = max(need, int(self.cache.seq_lens[ln.slot]) + 1)
        bucket = self.bucket_for(need)

        S = self.cfg.slots
        # per-lane positions come from the cache's ragged qo_indptr layout
        # (ISSUE 9): consecutive row-pointer differences are each active
        # slot's live length — the same view the split-KV decode kernel
        # keys its per-lane masking on. Inactive lanes diff to 0.
        pos = np.diff(self.cache.qo_indptr()).astype(np.int32)
        toks = np.zeros((S,), np.int32)
        active = np.zeros((S,), bool)
        for ln in live:
            toks[ln.slot] = ln.tokens[-1]
            active[ln.slot] = True
        for s in range(S):
            if active[s]:
                self.useful_ticks[s] += 1
            else:
                self.trash_ticks[s] += 1

        pools, states, next_tok, finite = self._step_fn(
            self.params, self.cache.pools, self.cache.states,
            self.cache.table_view(bucket), jnp.asarray(pos),
            jnp.asarray(toks), jnp.asarray(active),
        )
        # the old pool buffers were donated — rebind before anything else
        # can touch them
        self.cache.pools = pools
        self.cache.states = states
        self.steps_dispatched += 1
        return PendingStep(next_tok=next_tok, finite=finite,
                           lanes=list(self.lanes), bucket=bucket)

    def harvest(self, pending: PendingStep) -> List[Tuple[Lane, int, bool]]:
        """Block on a dispatched step; append each live lane's token and
        advance its length. Returns ``(lane, token, finite)`` per lane —
        the executor decides retirement."""

        next_tok = np.asarray(pending.next_tok)
        finite = np.asarray(pending.finite)
        out: List[Tuple[Lane, int, bool]] = []
        for slot, lane in enumerate(pending.lanes):
            if lane is None or self.lanes[slot] is not lane:
                continue  # retired while in flight (executor shed it)
            tok = int(next_tok[slot])
            ok = bool(finite[slot])
            if ok:
                lane.tokens.append(tok)
                self.cache.set_len(slot, int(self.cache.seq_lens[slot]) + 1)
                self.tokens_emitted[slot] += 1
            out.append((lane, tok, ok))
        return out

    def retire(self, lane: Lane) -> None:
        self.cache.free(lane.slot)
        self.lanes[lane.slot] = None

    # -- telemetry -----------------------------------------------------------

    def lower_step(self, bucket: Optional[int] = None):
        """Lower (not run) the fused step at one bucket's shapes — the
        input of ``repro.obs.profile.attribute`` for serve-side cost
        attribution. Abstract avals only: nothing executes and the
        donated pool buffers are untouched. Defaults to the largest
        bucket (the worst-case decode view)."""

        bucket = self.buckets[-1] if bucket is None else bucket
        S = self.cfg.slots
        args = (self.params, self.cache.pools, self.cache.states,
                self.cache.table_view(bucket),
                jnp.zeros((S,), jnp.int32), jnp.zeros((S,), jnp.int32),
                jnp.zeros((S,), bool))
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
            args)
        return self._step_fn.lower(*abstract)

    def lane_stats(self) -> List[Dict[str, Any]]:
        """Per-slot occupancy/goodput over the run so far. ``goodput`` is
        the useful-token fraction of the steps the slot rode along in —
        the cost of fixed-lane batching made visible per lane."""

        out: List[Dict[str, Any]] = []
        for s in range(self.cfg.slots):
            useful = self.useful_ticks[s]
            trash = self.trash_ticks[s]
            total = useful + trash
            out.append({
                "slot": s, "useful_ticks": useful, "trash_ticks": trash,
                "tokens": self.tokens_emitted[s],
                "goodput": (useful / total) if total else None,
            })
        return out

    def memory_stats(self) -> Dict[str, Any]:
        return {
            "allocated_bytes": self.cache.allocated_bytes(),
            "peak_bytes": self.cache.peak_bytes,
            "live_tokens": self.cache.live_tokens(),
            "grow_events": self.cache.grow_events,
            "buckets": list(self.buckets),
        }
