"""Production score/decode serving (docs/serve.md, DESIGN.md §12).

The pipeline: ``queue`` (admission, deadlines, shed) -> ``batcher``
(continuous batching over fixed decode lanes, bucketed view lengths) ->
``cache`` (paged KV/recurrent-state pool, free-list allocator) ->
``executor`` (async dispatch, graceful degradation, p50/p99 telemetry).
``prefill`` holds the single-call chunked teacher-forced prefill shared
by the batched and serial paths, and ``score_api`` serves dataopt
per-example scores through the same queue machinery.

    from repro import serve

    ex = serve.ServeExecutor(model, params, serve.ServeConfig(slots=8))
    rid = ex.submit(prompt_ids, max_new_tokens=16)
    stats = ex.run()                      # ServeStats: qps, p50/p99, sheds
    ex.results[rid].tokens               # greedy tokens (== serial reference)
"""

from repro.serve.batcher import ContinuousBatcher, ServeConfig, decode_buckets
from repro.serve.cache import (
    CacheSpec,
    LeafSpec,
    PagedCache,
    PagedCacheError,
    build_spec,
    dense_cache_bytes,
    gather_dense,
    scatter_token,
)
from repro.serve.executor import (
    OK_STATUSES,
    STATUS_ERROR,
    STATUS_FALLBACK,
    STATUS_OK,
    STATUS_REJECTED,
    STATUS_SHED_DEADLINE,
    STATUS_SHED_OVERFLOW,
    RequestResult,
    ServeExecutor,
    ServeStats,
)
from repro.serve.prefill import chunked_prefill, greedy_generate
from repro.serve.queue import (
    QueueClosed,
    QueueFull,
    QueueStats,
    Request,
    RequestQueue,
    ShedEvent,
)
from repro.serve.score_api import ScoreAPI, ScoreAPIStats, ScoreStore

__all__ = [
    "CacheSpec", "ContinuousBatcher", "LeafSpec", "OK_STATUSES",
    "PagedCache", "PagedCacheError", "QueueClosed", "QueueFull", "QueueStats",
    "Request", "RequestQueue", "RequestResult", "STATUS_ERROR",
    "STATUS_FALLBACK", "STATUS_OK", "STATUS_REJECTED", "STATUS_SHED_DEADLINE",
    "STATUS_SHED_OVERFLOW", "ScoreAPI", "ScoreAPIStats", "ScoreStore",
    "ServeConfig", "ServeExecutor", "ServeStats", "ShedEvent",
    "build_spec", "chunked_prefill", "decode_buckets", "dense_cache_bytes",
    "gather_dense", "greedy_generate", "scatter_token",
]
