"""The serving loop: async dispatch, deadline enforcement, graceful
degradation, and per-request latency telemetry (docs/serve.md §5).

One tick: (1) resolve queue sheds and expired in-flight deadlines,
(2) admit queued requests into freed slots (prefill), (3) harvest the
*previous* decode step, (4) dispatch the next. Because ``dispatch`` is
async (JAX returns futures), all of the host-side work in (1)-(2) —
queue management, page allocation, prefill argument staging — overlaps
the device executing the in-flight step; the only blocking point is the
``harvest`` device->host read of the step's token ids.

Degradation is graceful by construction: queue overflow sheds at
admission (``shed_overflow``), deadline misses shed queued *or*
mid-generation requests with partial output (``shed_deadline``), and a
lane producing nonfinite logits is retired and replayed through the
serial dense-cache ``greedy_generate`` path (``ok_serial_fallback``)
rather than poisoning the batch or crashing the loop.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import flight as flight_mod
from repro.obs import trace as trace_mod
from repro.obs.events import make_event
from repro.perf.timers import LatencyStats
from repro.serve.batcher import ContinuousBatcher, Lane, ServeConfig
from repro.serve.cache import PagedCacheError
from repro.serve.prefill import greedy_generate
from repro.serve.queue import (
    SHED_DEADLINE,
    SHED_OVERFLOW,
    QueueFull,
    Request,
    RequestQueue,
)

STATUS_OK = "ok"
STATUS_FALLBACK = "ok_serial_fallback"
STATUS_SHED_OVERFLOW = SHED_OVERFLOW
STATUS_SHED_DEADLINE = SHED_DEADLINE
STATUS_REJECTED = "rejected"
STATUS_ERROR = "error"

#: statuses that produced a complete generation
OK_STATUSES = (STATUS_OK, STATUS_FALLBACK)


@dataclasses.dataclass
class RequestResult:
    """Terminal record for one submitted request. ``finish_t`` is set
    only for statuses that produced a complete (or errored-out)
    generation; ``resolved_t`` is set for EVERY terminal status — the
    moment the request left the system, whatever happened to it — so
    queue-resident time is measurable for sheds too."""

    id: int
    status: str
    tokens: List[int]
    submit_t: float
    admitted_t: Optional[float] = None
    finish_t: Optional[float] = None
    resolved_t: Optional[float] = None
    first_token_t: Optional[float] = None
    slot: Optional[int] = None
    trace_id: str = ""
    detail: str = ""

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    @property
    def queue_s(self) -> Optional[float]:
        if self.admitted_t is None:
            return None
        return self.admitted_t - self.submit_t

    @property
    def resident_s(self) -> Optional[float]:
        """submit -> terminal, regardless of outcome (the satellite fix:
        sheds used to drop out of the latency histogram entirely)."""

        if self.resolved_t is None:
            return None
        return self.resolved_t - self.submit_t

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token: submit -> first generated token."""

        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def tpot_s(self) -> Optional[float]:
        """Time per output token (inter-token latency): the decode-phase
        wall time amortized over tokens after the first."""

        if self.first_token_t is None or self.resolved_t is None \
                or len(self.tokens) < 2:
            return None
        return (self.resolved_t - self.first_token_t) / (len(self.tokens) - 1)


@dataclasses.dataclass
class ServeStats:
    completed: int
    fallbacks: int
    shed_overflow: int
    shed_deadline: int
    rejected: int
    errors: int
    steps: int
    qps: float
    latency: LatencyStats       # n == 0 when nothing completed
    queue_wait: LatencyStats
    ttft: LatencyStats          # time to first token (completed requests)
    tpot: LatencyStats          # per-output-token decode latency
    lanes: List[Dict[str, Any]]  # per-slot occupancy/goodput
    memory: Dict[str, Any]


class ServeExecutor:
    """Owns the queue, the batcher, and every request's terminal status."""

    #: terminal status -> serve-event name (the health monitors' SLO
    #: vocabulary: "done"/"deadline_miss"/"shed" count toward the miss
    #: rate; "rejected"/"error" are bugs or impossibilities, not load)
    TERMINAL_EVENT = {
        STATUS_OK: "done",
        STATUS_FALLBACK: "done",
        STATUS_SHED_DEADLINE: "deadline_miss",
        STATUS_SHED_OVERFLOW: "shed",
        STATUS_REJECTED: "rejected",
        STATUS_ERROR: "error",
    }

    def __init__(self, model, params, cfg: Optional[ServeConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic, obs=None):
        cfg = cfg or ServeConfig()
        self.cfg = cfg
        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self._obs = obs
        # always-on flight recorder (cfg.flight_capacity=0 opts out): the
        # ring keeps the recent event tail in memory even with no obs
        # pipeline, so a crash/hang postmortem never depends on the run
        # having been launched with --obs-log
        self.flight: Optional[flight_mod.FlightRecorder] = None
        if cfg.flight_capacity > 0:
            self.flight = flight_mod.FlightRecorder(
                cfg.flight_capacity, out_dir=cfg.flight_dir)
            self.flight.attach(obs)  # degraded health alert -> dump
            self.flight.add_state_provider("queue", self._queue_state)
            self.flight.add_state_provider("lanes", self._lane_state)
            self.flight.add_state_provider("memory",
                                           lambda: self.batcher.memory_stats())
        self._watchdog: Optional[flight_mod.HangWatchdog] = None
        if cfg.hang_deadline_s is not None:
            self._watchdog = flight_mod.HangWatchdog(
                cfg.hang_deadline_s, self._on_hang)
        self.batcher = ContinuousBatcher(model, params, cfg)  # rejects encoders
        self.queue = RequestQueue(cfg.queue_depth,
                                  default_timeout_s=cfg.default_timeout_s,
                                  clock=clock, obs=obs, flight=self.flight)
        self._clock = clock
        self.results: Dict[int, RequestResult] = {}
        self._stalled: Optional[Request] = None
        self._inject_hang: Optional[tuple] = None  # (at_step, seconds) debug hook
        # per-call instrument handles, hoisted out of the hot loop (each
        # registry access is a lock + dict lookup)
        if obs.enabled:
            self._hist_request = obs.histogram("serve_request_us")
            self._hist_tick = obs.histogram("serve_tick_us")
            self._ctr_requests = obs.counter("serve_requests")
            self._gauge_lanes = obs.gauge("serve_active_lanes")
            self._gauge_depth = obs.gauge("serve_queue_depth")

    # -- flight-recorder plumbing -------------------------------------------

    def _queue_state(self) -> Dict[str, Any]:
        return dataclasses.asdict(self.queue.stats())

    def _lane_state(self) -> List[Dict[str, Any]]:
        return [{"slot": ln.slot, "trace_id": ln.request.trace_id,
                 "request_id": ln.request.id, "prompt_len": ln.prompt_len,
                 "tokens": len(ln.tokens), "target_new": ln.target_new}
                for ln in self.batcher.live_lanes()]

    def _on_hang(self, stall_s: float) -> None:
        """Watchdog trigger — runs on the watchdog thread while the tick
        loop is stuck, so it must only read."""

        if self.flight is not None:
            self.flight.dump(
                flight_mod.REASON_HANG,
                detail=f"no tick progress for {stall_s:.2f}s "
                       f"(deadline {self.cfg.hang_deadline_s}s)")

    def _emit(self, name: str, data: Dict[str, Any],
              step: Optional[int] = None) -> None:
        """One serve-plane lifecycle event, teed into the obs pipeline
        (when enabled) and the flight ring (when present)."""

        ev = self._obs.emit("serve", name, data=data, step=step)
        if self.flight is not None:
            self.flight.write(ev if ev is not None else
                              make_event("serve", name, data=data, step=step))

    def _observe_terminal(self, result: RequestResult) -> None:
        if not self._obs.enabled and self.flight is None:
            return
        name = self.TERMINAL_EVENT.get(result.status, result.status)
        data: Dict[str, Any] = {"request_id": result.id,
                                "trace_id": result.trace_id,
                                "status": result.status,
                                "tokens": len(result.tokens)}
        if result.slot is not None:
            data["slot"] = result.slot
        if result.latency_s is not None:
            data["latency_us"] = result.latency_s * 1e6
        # queue-resident time exists for EVERY terminal status — sheds
        # included — so SLO percentiles see the worst outcomes too
        if result.resident_s is not None:
            data["resident_us"] = result.resident_s * 1e6
        if result.queue_s is not None:
            data["queue_wait_us"] = result.queue_s * 1e6
        if result.ttft_s is not None:
            data["ttft_us"] = result.ttft_s * 1e6
        if result.tpot_s is not None:
            data["tpot_us"] = result.tpot_s * 1e6
        if self._obs.enabled:
            if result.resident_s is not None:
                self._hist_request.observe(result.resident_s * 1e6)
            self._ctr_requests.inc(labels={"status": result.status})
        self._emit(name, data)

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: Optional[int] = None,
               timeout_s: Optional[float] = None) -> int:
        """Enqueue one decode request; returns its id. Malformed requests
        raise immediately (caller bug); overflow records a
        ``shed_overflow`` result instead of raising (load, not bug)."""

        prompt = np.asarray(prompt, np.int32).reshape(-1)
        target = int(self.cfg.max_new_tokens if max_new_tokens is None
                     else max_new_tokens)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if target < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + target > self.cfg.max_len:
            raise ValueError(
                f"prompt_len={prompt.size} + max_new_tokens={target} exceeds "
                f"max_len={self.cfg.max_len}")
        payload = {"prompt": prompt, "max_new_tokens": target}
        try:
            req = self.queue.submit(payload, timeout_s=timeout_s)
        except QueueFull as e:
            self._resolve_shed()
            return e.event.request.id
        return req.id

    def _record(self, req: Request, status: str, tokens: List[int],
                admitted_t: Optional[float], detail: str = "", *,
                slot: Optional[int] = None,
                first_token_t: Optional[float] = None) -> None:
        now = self._clock()
        self.results[req.id] = RequestResult(
            id=req.id, status=status, tokens=list(tokens),
            submit_t=req.submit_t, admitted_t=admitted_t,
            finish_t=now if status in OK_STATUSES + (STATUS_ERROR,) else None,
            resolved_t=now, first_token_t=first_token_t, slot=slot,
            trace_id=req.trace_id, detail=detail,
        )
        self._observe_terminal(self.results[req.id])

    def _resolve_shed(self) -> None:
        for ev in self.queue.drain_shed():
            self.results[ev.request.id] = RequestResult(
                id=ev.request.id, status=ev.reason, tokens=[],
                submit_t=ev.request.submit_t, resolved_t=ev.t,
                trace_id=ev.request.trace_id,
            )
            self._observe_terminal(self.results[ev.request.id])

    # -- the loop ------------------------------------------------------------

    def _finalize(self, lane: Lane, status: str, detail: str = "") -> None:
        self.batcher.retire(lane)
        self._record(lane.request, status, lane.tokens[: lane.target_new],
                     lane.admitted_t, detail, slot=lane.slot,
                     first_token_t=lane.first_token_t)

    def _shed_lane(self, lane: Lane) -> None:
        """Mid-generation deadline miss: keep the partial output but mark
        the request shed (no finish_t — it never met its SLO)."""

        self.batcher.retire(lane)
        self.results[lane.request.id] = RequestResult(
            id=lane.request.id, status=STATUS_SHED_DEADLINE,
            tokens=list(lane.tokens), submit_t=lane.request.submit_t,
            admitted_t=lane.admitted_t, resolved_t=self._clock(),
            first_token_t=lane.first_token_t, slot=lane.slot,
            trace_id=lane.request.trace_id,
        )
        self._observe_terminal(self.results[lane.request.id])

    def _fallback(self, lane: Lane) -> None:
        """Nonfinite logits in the batched path: retire the lane and replay
        the request through the serial dense-cache reference."""

        self.batcher.retire(lane)
        req = lane.request
        prompt = np.asarray(req.payload["prompt"], np.int32)
        pg = self.cfg.page_size
        cache_len = pg * math.ceil((prompt.size + lane.target_new) / pg)
        try:
            toks = greedy_generate(
                self.batcher.model, self.batcher.params,
                jnp.asarray(prompt[None]), lane.target_new, cache_len,
                dtype=self.batcher.dtype, prefill_mode=self.cfg.prefill_mode,
            )
            self._record(req, STATUS_FALLBACK, [int(t) for t in toks[0]],
                         lane.admitted_t, "nonfinite logits in batched path",
                         slot=lane.slot, first_token_t=lane.first_token_t)
        except Exception as e:  # degradation must not take the loop down
            self._record(req, STATUS_ERROR, lane.tokens, lane.admitted_t,
                         f"serial fallback failed: {e!r}",
                         slot=lane.slot, first_token_t=lane.first_token_t)

    def _admit_one(self, req: Request, now: float) -> None:
        trace = self._obs.enabled or self.flight is not None
        if trace:
            # "admitted" precedes batcher.admit (the slot is unknown until
            # prefill allocates one — it rides on first_token instead); a
            # stalled-then-retried admission repeats both stage events,
            # which timeline validation allows
            self._emit("admitted", {
                "trace_id": req.trace_id, "request_id": req.id,
                "queue_wait_us": (now - req.submit_t) * 1e6})
            self._emit("prefill_start", {
                "trace_id": req.trace_id, "request_id": req.id,
                "prompt_len": int(np.asarray(req.payload["prompt"]).size)})
        try:
            lane = self.batcher.admit(req, now)
        except PagedCacheError as e:
            if self.batcher.live_lanes():
                self._stalled = req  # retry once pages/slots free up
            else:
                self._record(req, STATUS_REJECTED, [], None, str(e))
            return
        except ValueError as e:
            self._record(req, STATUS_REJECTED, [], None, str(e))
            return
        lane.first_token_t = self._clock()  # prefill produced token 0
        if trace:
            self._emit("first_token", {
                "trace_id": req.trace_id, "request_id": req.id,
                "slot": lane.slot,
                "ttft_us": (lane.first_token_t - req.submit_t) * 1e6})
        if self.batcher.lane_done(lane):  # max_new_tokens == 1
            self._finalize(lane, STATUS_OK)

    def _admissions(self, now: float) -> None:
        if self._stalled is not None and self.batcher.can_admit():
            req, self._stalled = self._stalled, None
            self._admit_one(req, now)
        while self.batcher.can_admit() and self._stalled is None:
            got = self.queue.pop(1, now)
            if not got:
                break
            self._admit_one(got[0], now)

    def inject_hang(self, seconds: float, at_step: int = 1) -> None:
        """Debug/CI fault injection: stall the tick loop for ``seconds``
        just before harvesting decode step ``at_step`` — the watchdog must
        notice and dump a postmortem (the obs-smoke CI job asserts it)."""

        self._inject_hang = (at_step, seconds)

    def run(self) -> ServeStats:
        """Drive until the queue and all lanes drain. Deterministic: no
        threads (the optional hang watchdog only reads) — async overlap
        comes from JAX's dispatch model."""

        if self._watchdog is not None:
            self._watchdog.beat()
            self._watchdog.start()
        try:
            return self._run()
        except Exception as e:
            if self.flight is not None:  # unhandled loop failure -> postmortem
                self.flight.dump(flight_mod.REASON_EXCEPTION, detail=repr(e))
            raise
        finally:
            if self._watchdog is not None:
                self._watchdog.stop()

    def _run(self) -> ServeStats:
        pending = None
        observe = self._obs.enabled  # hoisted: zero per-tick work when off
        trace = observe or self.flight is not None
        tracer = trace_mod.active_tracer()  # hoisted: contextvar read once
        watchdog = self._watchdog
        tick_n = 0
        snapshot_every = max(1, self.cfg.flight_snapshot_every)
        while True:
            # --chrome-trace: each tick is one span on the Perfetto
            # timeline; nullcontext (no tracer) costs nothing per tick
            span = (tracer.span("serve_tick") if tracer is not None
                    else contextlib.nullcontext())
            with span:
                tick_t0 = time.perf_counter() if trace else 0.0
                now = self._clock()
                self._resolve_shed()
                for lane in self.batcher.live_lanes():
                    if lane.request.expired(now):
                        self._shed_lane(lane)
                self._admissions(now)  # host + prefill work overlapping `pending`
                if self._inject_hang is not None \
                        and self.batcher.steps_dispatched >= self._inject_hang[0]:
                    seconds, self._inject_hang = self._inject_hang[1], None
                    time.sleep(seconds)
                if pending is not None:
                    step_n = self.batcher.steps_dispatched
                    for lane, _tok, ok in self.batcher.harvest(pending):
                        if trace and ok:
                            self._emit("token", {
                                "trace_id": lane.request.trace_id,
                                "slot": lane.slot, "n": len(lane.tokens)},
                                step=step_n)
                        if not ok:
                            self._fallback(lane)
                        elif self.batcher.lane_done(lane):
                            self._finalize(lane, STATUS_OK)
                    pending = None
                live = self.batcher.live_lanes()
                if live:
                    pending = self.batcher.dispatch()
                if trace:
                    self._observe_tick(tick_t0, len(live))
                tick_n += 1
                if self.flight is not None and tick_n % snapshot_every == 0:
                    self.flight.record_snapshot({
                        "tick": tick_n, "queue_depth": len(self.queue),
                        "active_lanes": len(live),
                        "steps": self.batcher.steps_dispatched})
                if watchdog is not None:
                    watchdog.beat()  # tick completed = progress
            if not live and len(self.queue) == 0 and self._stalled is None:
                break
        self._resolve_shed()
        if trace:
            self._emit("lane_stats", {"lanes": self.batcher.lane_stats(),
                                      "steps": self.batcher.steps_dispatched})
        return self.stats()

    def _observe_tick(self, tick_t0: float, active_lanes: int) -> None:
        """Per-tick telemetry: tick latency histogram, lane-occupancy and
        queue-depth gauges, and the ``serve/tick`` event the queue-depth
        health monitor consumes. Called when obs is enabled OR a flight
        ring needs the tick context (metric instruments stay obs-only)."""

        dur_us = (time.perf_counter() - tick_t0) * 1e6
        depth = len(self.queue)
        lanes = self.cfg.slots
        if self._obs.enabled:
            self._hist_tick.observe(dur_us)
            self._gauge_lanes.set(active_lanes)
            self._gauge_depth.set(depth)
        self._emit("tick", data={
            "dur_us": dur_us, "active_lanes": active_lanes, "lanes": lanes,
            "queue_depth": depth, "capacity": self.queue.max_depth,
        })

    # -- telemetry -----------------------------------------------------------

    def stats(self) -> ServeStats:
        res = list(self.results.values())
        ok = [r for r in res if r.status in OK_STATUSES]
        lat = [r.latency_s for r in ok if r.latency_s is not None]
        qwait = [r.queue_s for r in ok if r.queue_s is not None]
        ttft = [r.ttft_s for r in ok if r.ttft_s is not None]
        tpot = [r.tpot_s for r in ok if r.tpot_s is not None]
        qps = 0.0
        if ok:
            span = max(r.finish_t for r in ok) - min(r.submit_t for r in ok)
            qps = len(ok) / span if span > 0 else float("inf")
        return ServeStats(
            completed=len(ok),
            fallbacks=sum(r.status == STATUS_FALLBACK for r in res),
            shed_overflow=sum(r.status == STATUS_SHED_OVERFLOW for r in res),
            shed_deadline=sum(r.status == STATUS_SHED_DEADLINE for r in res),
            rejected=sum(r.status == STATUS_REJECTED for r in res),
            errors=sum(r.status == STATUS_ERROR for r in res),
            steps=self.batcher.steps_dispatched,
            qps=qps,
            # always a LatencyStats: zero completed requests (everything
            # shed) reports LatencyStats.empty() (n=0) instead of crashing
            # or going None — consumers branch on `.n`
            latency=LatencyStats.from_samples(lat),
            queue_wait=LatencyStats.from_samples(qwait),
            ttft=LatencyStats.from_samples(ttft),
            tpot=LatencyStats.from_samples(tpot),
            lanes=self.batcher.lane_stats(),
            memory=self.batcher.memory_stats(),
        )
