"""The serving loop: async dispatch, deadline enforcement, graceful
degradation, and per-request latency telemetry (docs/serve.md §5).

One tick: (1) resolve queue sheds and expired in-flight deadlines,
(2) admit queued requests into freed slots (prefill), (3) harvest the
*previous* decode step, (4) dispatch the next. Because ``dispatch`` is
async (JAX returns futures), all of the host-side work in (1)-(2) —
queue management, page allocation, prefill argument staging — overlaps
the device executing the in-flight step; the only blocking point is the
``harvest`` device->host read of the step's token ids.

Degradation is graceful by construction: queue overflow sheds at
admission (``shed_overflow``), deadline misses shed queued *or*
mid-generation requests with partial output (``shed_deadline``), and a
lane producing nonfinite logits is retired and replayed through the
serial dense-cache ``greedy_generate`` path (``ok_serial_fallback``)
rather than poisoning the batch or crashing the loop.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import trace as trace_mod
from repro.perf.timers import LatencyStats
from repro.serve.batcher import ContinuousBatcher, Lane, ServeConfig
from repro.serve.cache import PagedCacheError
from repro.serve.prefill import greedy_generate
from repro.serve.queue import (
    SHED_DEADLINE,
    SHED_OVERFLOW,
    QueueFull,
    Request,
    RequestQueue,
)

STATUS_OK = "ok"
STATUS_FALLBACK = "ok_serial_fallback"
STATUS_SHED_OVERFLOW = SHED_OVERFLOW
STATUS_SHED_DEADLINE = SHED_DEADLINE
STATUS_REJECTED = "rejected"
STATUS_ERROR = "error"

#: statuses that produced a complete generation
OK_STATUSES = (STATUS_OK, STATUS_FALLBACK)


@dataclasses.dataclass
class RequestResult:
    """Terminal record for one submitted request."""

    id: int
    status: str
    tokens: List[int]
    submit_t: float
    admitted_t: Optional[float] = None
    finish_t: Optional[float] = None
    detail: str = ""

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    @property
    def queue_s(self) -> Optional[float]:
        if self.admitted_t is None:
            return None
        return self.admitted_t - self.submit_t


@dataclasses.dataclass
class ServeStats:
    completed: int
    fallbacks: int
    shed_overflow: int
    shed_deadline: int
    rejected: int
    errors: int
    steps: int
    qps: float
    latency: LatencyStats       # n == 0 when nothing completed
    queue_wait: LatencyStats
    memory: Dict[str, Any]


class ServeExecutor:
    """Owns the queue, the batcher, and every request's terminal status."""

    #: terminal status -> serve-event name (the health monitors' SLO
    #: vocabulary: "done"/"deadline_miss"/"shed" count toward the miss
    #: rate; "rejected"/"error" are bugs or impossibilities, not load)
    TERMINAL_EVENT = {
        STATUS_OK: "done",
        STATUS_FALLBACK: "done",
        STATUS_SHED_DEADLINE: "deadline_miss",
        STATUS_SHED_OVERFLOW: "shed",
        STATUS_REJECTED: "rejected",
        STATUS_ERROR: "error",
    }

    def __init__(self, model, params, cfg: Optional[ServeConfig] = None, *,
                 clock: Callable[[], float] = time.monotonic, obs=None):
        cfg = cfg or ServeConfig()
        self.cfg = cfg
        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self._obs = obs
        self.batcher = ContinuousBatcher(model, params, cfg)  # rejects encoders
        self.queue = RequestQueue(cfg.queue_depth,
                                  default_timeout_s=cfg.default_timeout_s,
                                  clock=clock, obs=obs)
        self._clock = clock
        self.results: Dict[int, RequestResult] = {}
        self._stalled: Optional[Request] = None

    def _observe_terminal(self, result: RequestResult) -> None:
        if not self._obs.enabled:
            return
        name = self.TERMINAL_EVENT.get(result.status, result.status)
        data: Dict[str, Any] = {"request_id": result.id,
                                "status": result.status}
        if result.latency_s is not None:
            data["latency_us"] = result.latency_s * 1e6
            self._obs.histogram("serve_request_us").observe(result.latency_s * 1e6)
        self._obs.counter("serve_requests").inc(labels={"status": result.status})
        self._obs.emit("serve", name, data=data)

    # -- submission ----------------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: Optional[int] = None,
               timeout_s: Optional[float] = None) -> int:
        """Enqueue one decode request; returns its id. Malformed requests
        raise immediately (caller bug); overflow records a
        ``shed_overflow`` result instead of raising (load, not bug)."""

        prompt = np.asarray(prompt, np.int32).reshape(-1)
        target = int(self.cfg.max_new_tokens if max_new_tokens is None
                     else max_new_tokens)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if target < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + target > self.cfg.max_len:
            raise ValueError(
                f"prompt_len={prompt.size} + max_new_tokens={target} exceeds "
                f"max_len={self.cfg.max_len}")
        payload = {"prompt": prompt, "max_new_tokens": target}
        try:
            req = self.queue.submit(payload, timeout_s=timeout_s)
        except QueueFull as e:
            self._resolve_shed()
            return e.event.request.id
        return req.id

    def _record(self, req: Request, status: str, tokens: List[int],
                admitted_t: Optional[float], detail: str = "") -> None:
        now = self._clock()
        self.results[req.id] = RequestResult(
            id=req.id, status=status, tokens=list(tokens),
            submit_t=req.submit_t, admitted_t=admitted_t,
            finish_t=now if status in OK_STATUSES + (STATUS_ERROR,) else None,
            detail=detail,
        )
        self._observe_terminal(self.results[req.id])

    def _resolve_shed(self) -> None:
        for ev in self.queue.drain_shed():
            self.results[ev.request.id] = RequestResult(
                id=ev.request.id, status=ev.reason, tokens=[],
                submit_t=ev.request.submit_t,
            )
            self._observe_terminal(self.results[ev.request.id])

    # -- the loop ------------------------------------------------------------

    def _finalize(self, lane: Lane, status: str, detail: str = "") -> None:
        self.batcher.retire(lane)
        self._record(lane.request, status, lane.tokens[: lane.target_new],
                     lane.admitted_t, detail)

    def _shed_lane(self, lane: Lane) -> None:
        """Mid-generation deadline miss: keep the partial output but mark
        the request shed (no finish_t — it never met its SLO)."""

        self.batcher.retire(lane)
        self.results[lane.request.id] = RequestResult(
            id=lane.request.id, status=STATUS_SHED_DEADLINE,
            tokens=list(lane.tokens), submit_t=lane.request.submit_t,
            admitted_t=lane.admitted_t,
        )
        self._observe_terminal(self.results[lane.request.id])

    def _fallback(self, lane: Lane) -> None:
        """Nonfinite logits in the batched path: retire the lane and replay
        the request through the serial dense-cache reference."""

        self.batcher.retire(lane)
        req = lane.request
        prompt = np.asarray(req.payload["prompt"], np.int32)
        pg = self.cfg.page_size
        cache_len = pg * math.ceil((prompt.size + lane.target_new) / pg)
        try:
            toks = greedy_generate(
                self.batcher.model, self.batcher.params,
                jnp.asarray(prompt[None]), lane.target_new, cache_len,
                dtype=self.batcher.dtype, prefill_mode=self.cfg.prefill_mode,
            )
            self._record(req, STATUS_FALLBACK, [int(t) for t in toks[0]],
                         lane.admitted_t, "nonfinite logits in batched path")
        except Exception as e:  # degradation must not take the loop down
            self._record(req, STATUS_ERROR, lane.tokens, lane.admitted_t,
                         f"serial fallback failed: {e!r}")

    def _admit_one(self, req: Request, now: float) -> None:
        try:
            lane = self.batcher.admit(req, now)
        except PagedCacheError as e:
            if self.batcher.live_lanes():
                self._stalled = req  # retry once pages/slots free up
            else:
                self._record(req, STATUS_REJECTED, [], None, str(e))
            return
        except ValueError as e:
            self._record(req, STATUS_REJECTED, [], None, str(e))
            return
        if self.batcher.lane_done(lane):  # max_new_tokens == 1
            self._finalize(lane, STATUS_OK)

    def _admissions(self, now: float) -> None:
        if self._stalled is not None and self.batcher.can_admit():
            req, self._stalled = self._stalled, None
            self._admit_one(req, now)
        while self.batcher.can_admit() and self._stalled is None:
            got = self.queue.pop(1, now)
            if not got:
                break
            self._admit_one(got[0], now)

    def run(self) -> ServeStats:
        """Drive until the queue and all lanes drain. Deterministic: no
        threads — async overlap comes from JAX's dispatch model."""

        pending = None
        observe = self._obs.enabled  # hoisted: zero per-tick work when off
        tracer = trace_mod.active_tracer()  # hoisted: contextvar read once
        while True:
            # --chrome-trace: each tick is one span on the Perfetto
            # timeline; nullcontext (no tracer) costs nothing per tick
            span = (tracer.span("serve_tick") if tracer is not None
                    else contextlib.nullcontext())
            with span:
                tick_t0 = time.perf_counter() if observe else 0.0
                now = self._clock()
                self._resolve_shed()
                for lane in self.batcher.live_lanes():
                    if lane.request.expired(now):
                        self._shed_lane(lane)
                self._admissions(now)  # host + prefill work overlapping `pending`
                if pending is not None:
                    for lane, _tok, ok in self.batcher.harvest(pending):
                        if not ok:
                            self._fallback(lane)
                        elif self.batcher.lane_done(lane):
                            self._finalize(lane, STATUS_OK)
                    pending = None
                live = self.batcher.live_lanes()
                if live:
                    pending = self.batcher.dispatch()
                if observe:
                    self._observe_tick(tick_t0, len(live))
            if not live and len(self.queue) == 0 and self._stalled is None:
                break
        self._resolve_shed()
        return self.stats()

    def _observe_tick(self, tick_t0: float, active_lanes: int) -> None:
        """Per-tick telemetry: tick latency histogram, lane-occupancy and
        queue-depth gauges, and the ``serve/tick`` event the queue-depth
        health monitor consumes. Called only when obs is enabled."""

        dur_us = (time.perf_counter() - tick_t0) * 1e6
        depth = len(self.queue)
        lanes = self.cfg.slots
        self._obs.histogram("serve_tick_us").observe(dur_us)
        self._obs.gauge("serve_active_lanes").set(active_lanes)
        self._obs.gauge("serve_queue_depth").set(depth)
        self._obs.emit("serve", "tick", data={
            "dur_us": dur_us, "active_lanes": active_lanes, "lanes": lanes,
            "queue_depth": depth, "capacity": self.queue.max_depth,
        })

    # -- telemetry -----------------------------------------------------------

    def stats(self) -> ServeStats:
        res = list(self.results.values())
        ok = [r for r in res if r.status in OK_STATUSES]
        lat = [r.latency_s for r in ok if r.latency_s is not None]
        qwait = [r.queue_s for r in ok if r.queue_s is not None]
        qps = 0.0
        if ok:
            span = max(r.finish_t for r in ok) - min(r.submit_t for r in ok)
            qps = len(ok) / span if span > 0 else float("inf")
        return ServeStats(
            completed=len(ok),
            fallbacks=sum(r.status == STATUS_FALLBACK for r in res),
            shed_overflow=sum(r.status == STATUS_SHED_OVERFLOW for r in res),
            shed_deadline=sum(r.status == STATUS_SHED_DEADLINE for r in res),
            rejected=sum(r.status == STATUS_REJECTED for r in res),
            errors=sum(r.status == STATUS_ERROR for r in res),
            steps=self.batcher.steps_dispatched,
            qps=qps,
            # always a LatencyStats: zero completed requests (everything
            # shed) reports LatencyStats.empty() (n=0) instead of crashing
            # or going None — consumers branch on `.n`
            latency=LatencyStats.from_samples(lat),
            queue_wait=LatencyStats.from_samples(qwait),
            memory=self.batcher.memory_stats(),
        )
