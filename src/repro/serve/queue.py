"""Request queue with admission control, per-request deadlines, and shed
accounting (docs/serve.md §2).

The queue is the only stateful boundary between callers and the serving
loop: ``submit`` either admits a request or sheds it *immediately*
(bounded depth — backpressure instead of unbounded growth), and ``pop``
drops requests whose deadline already passed before they reached a
decode slot (a request that cannot meet its SLO should not occupy one).
Both shed paths are recorded as :class:`ShedEvent` so the executor can
resolve the request with a terminal status rather than leaving the
caller hanging.

Time is injected (``clock=``) so deadline behavior is deterministic
under test — tests advance a fake clock instead of sleeping.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import uuid
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

SHED_OVERFLOW = "shed_overflow"
SHED_DEADLINE = "shed_deadline"


def mint_trace_id() -> str:
    """A fresh request trace id (16 hex chars — unique within any
    realistic request volume, short enough to read in a log line)."""

    return uuid.uuid4().hex[:16]


class QueueFull(RuntimeError):
    """Admission refused: the queue is at ``max_depth``. Carries the
    recorded overflow ``.event`` so the caller can resolve the request
    with a terminal status."""

    event: "ShedEvent"


class QueueClosed(RuntimeError):
    """Admission refused: the queue no longer accepts requests."""


@dataclasses.dataclass
class Request:
    """One admitted decode request. ``deadline`` is an absolute clock
    reading (``None`` = no SLO); ``payload`` is opaque to the queue.
    ``trace_id`` is minted at submit and rides on every lifecycle event
    the request produces downstream (enqueued → admitted → prefill →
    tokens → terminal), so any request's timeline reconstructs from the
    event stream alone."""

    id: int
    payload: Any
    submit_t: float
    deadline: Optional[float] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    trace_id: str = dataclasses.field(default_factory=mint_trace_id)

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


@dataclasses.dataclass(frozen=True)
class ShedEvent:
    request: Request
    reason: str  # SHED_OVERFLOW | SHED_DEADLINE
    t: float


@dataclasses.dataclass(frozen=True)
class QueueStats:
    submitted: int
    admitted: int
    shed_overflow: int
    shed_deadline: int
    depth: int


class RequestQueue:
    """Bounded FIFO with deadline shedding. Thread-safe: callers may
    ``submit`` from any thread while one serving loop ``pop``s."""

    def __init__(self, max_depth: int = 64, *,
                 default_timeout_s: Optional[float] = None,
                 validator: Optional[Callable[[Any], None]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 obs=None, flight=None):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = max_depth
        self.default_timeout_s = default_timeout_s
        self._validator = validator
        self._clock = clock
        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self._obs = obs
        # always-on postmortem ring (repro.obs.flight) — lifecycle events
        # land here even when no obs pipeline is enabled
        self._flight = flight
        self._ids = itertools.count()
        self._q: Deque[Request] = deque()
        self._shed: List[ShedEvent] = []
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._closed = False
        self._submitted = 0
        self._admitted = 0
        self._n_shed_overflow = 0
        self._n_shed_deadline = 0

    # -- admission -----------------------------------------------------------

    def submit(self, payload: Any, *, timeout_s: Optional[float] = None,
               meta: Optional[Dict[str, Any]] = None) -> Request:
        """Admit ``payload`` or raise. ``QueueFull`` counts as an overflow
        shed (the event carries the would-be request so the caller can
        resolve it); validation errors propagate uncounted — they are
        caller bugs, not load."""

        if self._validator is not None:
            self._validator(payload)
        now = self._clock()
        timeout_s = self.default_timeout_s if timeout_s is None else timeout_s
        with self._lock:
            self._submitted += 1
            req = Request(
                id=next(self._ids), payload=payload, submit_t=now,
                deadline=None if timeout_s is None else now + timeout_s,
                meta=dict(meta or {}),
            )
            if self._closed:
                raise QueueClosed("queue is closed")
            # lifecycle start: emitted before the overflow check so even an
            # overflow-shed request has an enqueued→shed timeline
            self._observe_enqueued(req)
            if len(self._q) >= self.max_depth:
                self._n_shed_overflow += 1
                ev = ShedEvent(req, SHED_OVERFLOW, now)
                self._shed.append(ev)
                self._observe_shed(ev)
                err = QueueFull(
                    f"queue depth {len(self._q)} at max_depth={self.max_depth}")
                err.event = ev
                raise err
            self._admitted += 1
            self._q.append(req)
            self._nonempty.notify()
            return req

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._nonempty.notify_all()

    # -- consumption ---------------------------------------------------------

    def pop(self, n: int = 1, now: Optional[float] = None) -> List[Request]:
        """Take up to ``n`` live requests in FIFO order, shedding any whose
        deadline passed while queued."""

        now = self._clock() if now is None else now
        out: List[Request] = []
        with self._lock:
            while self._q and len(out) < n:
                req = self._q.popleft()
                if req.expired(now):
                    self._n_shed_deadline += 1
                    ev = ShedEvent(req, SHED_DEADLINE, now)
                    self._shed.append(ev)
                    self._observe_shed(ev)
                    continue
                out.append(req)
        return out

    def wait(self, timeout_s: Optional[float] = None) -> bool:
        """Block until the queue is non-empty or closed. True iff a request
        may be available (used by the threaded executor to idle cheaply)."""

        with self._lock:
            if self._q or self._closed:
                return bool(self._q)
            self._nonempty.wait(timeout=timeout_s)
            return bool(self._q)

    def _observe_enqueued(self, req: Request) -> None:
        """First lifecycle event of every request's trace."""

        if not self._obs.enabled and self._flight is None:
            return
        from repro.obs.flight import emit_teed
        emit_teed(self._obs, self._flight, "serve", "enqueued", data={
            "trace_id": req.trace_id, "request_id": req.id,
            "deadline_s": None if req.deadline is None
            else req.deadline - req.submit_t,
        })

    def _observe_shed(self, ev: ShedEvent) -> None:
        """Mirror a shed into the obs pipeline: a counter keyed by reason
        plus the queue-level shed fact (the executor emits the request's
        TERMINAL serve event — this is the queue's own accounting)."""

        if not self._obs.enabled and self._flight is None:
            return
        if self._obs.enabled:
            self._obs.counter("queue_sheds").inc(labels={"reason": ev.reason})
        from repro.obs.flight import emit_teed
        emit_teed(self._obs, self._flight, "serve", "queue_shed",
                  data={"reason": ev.reason, "request_id": ev.request.id,
                        "trace_id": ev.request.trace_id})

    def drain_shed(self) -> List[ShedEvent]:
        """Return-and-clear shed events (the executor resolves each into a
        terminal request status)."""

        with self._lock:
            shed, self._shed = self._shed, []
            return shed

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def stats(self) -> QueueStats:
        with self._lock:
            return QueueStats(
                submitted=self._submitted,
                admitted=self._admitted,
                shed_overflow=self._n_shed_overflow,
                shed_deadline=self._n_shed_deadline,
                depth=len(self._q),
            )
