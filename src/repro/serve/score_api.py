"""Dataopt score API: serve per-example keep/weight scores from an
exported score store through the same queue/shed/latency machinery as
token serving (docs/serve.md §6).

The store is a ``dataopt/export.py`` artifact (npz + validated
manifest): per-example meta-learned scores, optionally a keep mask.
Requests are id-batches; the endpoint coalesces every queued batch into
ONE ragged lookup per drain (ids concatenated, split back by a
``qo_indptr`` row-pointer — the same ragged indexing the paged decode
path uses), so per-request overhead is amortized exactly like decode
lanes amortize ``decode_step``.

``weight`` answers are softmax weights over the FULL dataset's scores
at a requested temperature (the ``dataopt.reweight`` sampling
distribution), so callers can turn scores into sampling probabilities
without holding the store.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.dataopt import export as export_mod
from repro.perf.timers import LatencyStats
from repro.serve.queue import QueueFull, QueueStats, RequestQueue

KINDS = ("score", "keep", "weight")


class ScoreStore:
    """In-memory view over one exported score set."""

    def __init__(self, scores: np.ndarray, mask: Optional[np.ndarray] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.scores = np.asarray(scores, np.float32)
        if self.scores.ndim != 1:
            raise ValueError(f"scores must be 1-D, got shape {self.scores.shape}")
        self.mask = None if mask is None else np.asarray(mask, bool)
        if self.mask is not None and self.mask.shape != self.scores.shape:
            raise ValueError("mask/scores shape mismatch")
        self.meta = dict(meta or {})
        self._logz: Dict[float, float] = {}  # per-temperature log-normalizer

    @classmethod
    def load(cls, path: str, *, expect_n: Optional[int] = None,
             expect_scorer: Optional[str] = None) -> "ScoreStore":
        scores, mask, meta = export_mod.import_scores(
            path, expect_n=expect_n, expect_scorer=expect_scorer)
        return cls(scores, mask, meta)

    def __len__(self) -> int:
        return int(self.scores.size)

    def _check(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= len(self)):
            raise IndexError(
                f"example ids must be in [0, {len(self)}), got range "
                f"[{ids.min()}, {ids.max()}]")
        return ids

    def lookup(self, ids) -> np.ndarray:
        return self.scores[self._check(ids)]

    def keep(self, ids) -> np.ndarray:
        ids = self._check(ids)
        if self.mask is None:
            return np.ones(ids.shape, bool)
        return self.mask[ids]

    def weight(self, ids, temperature: float = 1.0) -> np.ndarray:
        """Softmax sampling weights over the full dataset at ``temperature``
        (the dataopt.reweight distribution), gathered at ``ids``."""

        if temperature <= 0:
            raise ValueError("temperature must be > 0")
        ids = self._check(ids)
        t = float(temperature)
        if t not in self._logz:
            s = self.scores.astype(np.float64) / t
            m = s.max()
            self._logz[t] = float(m + np.log(np.exp(s - m).sum()))
        return np.exp(self.scores[ids].astype(np.float64) / t
                      - self._logz[t]).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class ScoreAPIStats:
    answered: int
    batches: int
    latency: Optional[LatencyStats]
    queue: QueueStats


class ScoreAPI:
    """Queued, coalescing endpoint over a :class:`ScoreStore`. ``submit``
    returns a Future; ``run_pending`` drains the queue in ragged
    coalesced batches."""

    def __init__(self, store: ScoreStore, *, max_batch: int = 64,
                 queue_depth: int = 256,
                 default_timeout_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic, obs=None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.store = store
        self.max_batch = max_batch
        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self._obs = obs
        self.queue = RequestQueue(queue_depth,
                                  default_timeout_s=default_timeout_s,
                                  clock=clock, obs=obs)
        self._clock = clock
        self._latency_s: List[float] = []
        self.answered = 0
        self.batches = 0

    def submit(self, ids, *, kind: str = "score", temperature: float = 1.0,
               timeout_s: Optional[float] = None) -> "Future[np.ndarray]":
        """Enqueue an id-batch; the Future resolves on the next drain.
        Shed requests (overflow here, deadline at drain) resolve with the
        shed reason as the exception."""

        if kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        ids = self.store._check(ids)  # validate before queuing, not at drain
        fut: "Future[np.ndarray]" = Future()
        payload = {"ids": ids, "kind": kind, "temperature": temperature,
                   "future": fut}
        try:
            self.queue.submit(payload, timeout_s=timeout_s)
        except QueueFull as e:
            fut.set_exception(e)
        return fut

    def _answer(self, batch) -> None:
        """One coalesced lookup for every request in ``batch`` that shares
        a kind/temperature signature."""

        groups: Dict[Tuple[str, float], List[Any]] = {}
        for req in batch:
            key = (req.payload["kind"], float(req.payload["temperature"]))
            groups.setdefault(key, []).append(req)
        for (kind, temp), reqs in groups.items():
            indptr = np.cumsum([0] + [r.payload["ids"].size for r in reqs])
            flat = np.concatenate([r.payload["ids"] for r in reqs]) \
                if indptr[-1] else np.zeros((0,), np.int64)
            if kind == "score":
                vals = self.store.lookup(flat)
            elif kind == "keep":
                vals = self.store.keep(flat)
            else:
                vals = self.store.weight(flat, temperature=temp)
            now = self._clock()
            for k, req in enumerate(reqs):
                req.payload["future"].set_result(vals[indptr[k]:indptr[k + 1]])
                self._latency_s.append(now - req.submit_t)
                self.answered += 1
                # terminal lifecycle event: a score trace is
                # enqueued -> done (no decode stages), and timeline
                # validation accepts exactly that shape
                self._obs.emit("serve", "done", data={
                    "trace_id": req.trace_id, "request_id": req.id,
                    "status": "ok", "kind": kind,
                    "resident_us": (now - req.submit_t) * 1e6,
                    "latency_us": (now - req.submit_t) * 1e6})
        self.batches += 1

    def run_pending(self) -> int:
        """Drain the queue (coalesced ``max_batch`` at a time). Returns the
        number of requests answered; shed futures resolve exceptionally."""

        answered_before = self.answered
        while True:
            batch = self.queue.pop(self.max_batch)
            for ev in self.queue.drain_shed():
                fut = ev.request.payload["future"]
                if not fut.done():  # overflow futures resolved at submit
                    fut.set_exception(TimeoutError(f"request shed: {ev.reason}"))
                # same terminal vocabulary as the decode executor so the
                # SLO monitor and timeline validation treat both planes
                # uniformly
                self._obs.emit("serve", "deadline_miss"
                               if ev.reason == "shed_deadline" else "shed",
                               data={"trace_id": ev.request.trace_id,
                                     "request_id": ev.request.id,
                                     "status": ev.reason,
                                     "resident_us":
                                         (ev.t - ev.request.submit_t) * 1e6})
            if not batch:
                break
            self._answer(batch)
        return self.answered - answered_before

    def stats(self) -> ScoreAPIStats:
        return ScoreAPIStats(
            answered=self.answered,
            batches=self.batches,
            latency=LatencyStats.from_samples(self._latency_s),  # n=0 when none
            queue=self.queue.stats(),
        )
