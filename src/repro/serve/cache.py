"""Paged decode cache: fixed-size pages, a free-list allocator, and ragged
``qo_indptr`` accounting, generic over every decoder cache layout in
``models/`` (docs/serve.md §4).

Dense serving preallocates ``batch x max_len`` cache — almost all of it
dead for mixed-length traffic. Here the *time* axis of every cache leaf
is chopped into fixed-size pages living in one shared pool; a per-slot
page table maps logical token positions to physical pages, so allocated
bytes track live tokens (plus one partially-filled page per sequence)
and the pool grows by doubling only when the free list runs dry.

Which axis is "time"? Not hard-coded per family: the layouts differ
(dense KV ``(L,B,T,KV,Dh)``, hybrid ``(G,B,T,KV,Dh)`` attention plus
``(G,K,B,...)`` recurrent state, audio cross-KV with a *config-sized*
``enc_seq`` axis that must NOT be paged). ``build_spec`` probes
``init_cache`` under ``jax.eval_shape`` with two batch sizes and two
cache lengths: the axis that moves with ``cache_len`` is the time axis
(paged), leaves with no such axis are per-slot state (RWKV/Mamba
recurrent state, encoder cross-KV) stored dense at ``slots`` lanes.

Physical page 0 is reserved as a trash page: inactive lanes' page-table
rows are all-zero, so their decode writes land in the trash and their
gathers read finite garbage that the batcher discards — no masking
branches inside the jitted step. ``gather_dense`` / ``scatter_token``
are pure functions of (pools, states, table-view) so the batcher can
fuse gather -> decode_step -> scatter into ONE jitted call with the pool
buffers donated.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm

PyTree = Any


class PagedCacheError(RuntimeError):
    """Allocation failure: pool capacity exhausted at ``max_pages``."""


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Axis roles for one cache leaf. ``time_axis is None`` => state leaf."""

    batch_axis: int
    time_axis: Optional[int]
    rest_shape: Tuple[int, ...]  # non-batch non-time dims, original order
    dtype: Any

    @property
    def paged(self) -> bool:
        return self.time_axis is not None


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static layout of a model's decode cache under paging."""

    treedef: Any
    leaves: Tuple[LeafSpec, ...]
    paged_idx: Tuple[int, ...]  # leaf indices with a time axis
    state_idx: Tuple[int, ...]
    page_size: int

    def token_view_bytes(self) -> int:
        """Bytes per (lane, token) of a gathered dense view — the unit the
        bucket planner multiplies by ``slots x bucket_len``."""

        total = 0
        for i in self.paged_idx:
            ls = self.leaves[i]
            total += int(np.prod(ls.rest_shape, dtype=np.int64)) * jnp.dtype(ls.dtype).itemsize
        return total

    def state_bytes(self, slots: int) -> int:
        total = 0
        for i in self.state_idx:
            ls = self.leaves[i]
            total += slots * int(np.prod(ls.rest_shape, dtype=np.int64)) \
                * jnp.dtype(ls.dtype).itemsize
        return total


def _axis_diff(a: Sequence[int], b: Sequence[int]) -> List[int]:
    return [i for i, (x, y) in enumerate(zip(a, b)) if x != y]


def build_spec(model, *, page_size: int, dtype,
               allow_unpaged: bool = True) -> CacheSpec:
    """Probe ``model.init_cache`` under eval_shape to classify every leaf's
    axes. No device memory is touched.

    A pure-recurrent cache (RWKV/Mamba: every leaf constant-size state)
    has nothing to page — paging degenerates to the dense per-slot state
    store, which already scales with slots rather than length. Pass
    ``allow_unpaged=False`` to reject that instead."""

    if page_size < 1:
        raise ValueError("page_size must be >= 1")
    b1, b2, l1, l2 = 2, 3, 2 * page_size, 3 * page_size
    t_ref = jax.eval_shape(lambda: model.init_cache(b1, l1, dtype=dtype))
    t_b = jax.eval_shape(lambda: model.init_cache(b2, l1, dtype=dtype))
    t_l = jax.eval_shape(lambda: model.init_cache(b1, l2, dtype=dtype))

    ref_leaves, treedef = jax.tree_util.tree_flatten(t_ref)
    b_leaves = jax.tree_util.tree_leaves(t_b)
    l_leaves = jax.tree_util.tree_leaves(t_l)

    specs: List[LeafSpec] = []
    for ref, lb, ll in zip(ref_leaves, b_leaves, l_leaves):
        bdiff = _axis_diff(ref.shape, lb.shape)
        if len(bdiff) != 1:
            raise ValueError(
                f"cache leaf {ref.shape} has {len(bdiff)} batch-dependent axes; "
                "paged serving needs exactly one")
        tdiff = _axis_diff(ref.shape, ll.shape)
        if len(tdiff) > 1:
            raise ValueError(
                f"cache leaf {ref.shape} has {len(tdiff)} cache_len-dependent axes")
        b_ax = bdiff[0]
        t_ax = tdiff[0] if tdiff else None
        rest = tuple(d for i, d in enumerate(ref.shape) if i not in (b_ax, t_ax))
        specs.append(LeafSpec(b_ax, t_ax, rest, ref.dtype))

    paged = tuple(i for i, s in enumerate(specs) if s.paged)
    state = tuple(i for i, s in enumerate(specs) if not s.paged)
    if not paged and not allow_unpaged:
        raise ValueError("no cache leaf depends on cache_len — nothing to page")
    return CacheSpec(treedef, tuple(specs), paged, state, page_size)


def dense_cache_bytes(model, batch: int, cache_len: int, dtype) -> int:
    """Bytes a dense ``init_cache(batch, cache_len)`` would allocate
    (eval_shape — nothing is materialized). The bench's paged-vs-dense
    comparison point."""

    tree = jax.eval_shape(lambda: model.init_cache(batch, cache_len, dtype=dtype))
    return sum(int(np.prod(l.shape, dtype=np.int64)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# pure view/update functions (jit-safe; the batcher fuses them around
# decode_step with the pools donated)
# ---------------------------------------------------------------------------


def _dense_perm(ls: LeafSpec) -> Tuple[int, ...]:
    """transpose perm taking ``(B, T, *rest)`` to the leaf's native layout."""

    ndim = 2 + len(ls.rest_shape)
    others = [i for i in range(ndim) if i not in (ls.batch_axis, ls.time_axis)]
    perm = [0] * ndim
    perm[ls.batch_axis] = 0
    perm[ls.time_axis] = 1
    for k, i in enumerate(others):
        perm[i] = 2 + k
    return tuple(perm)


def _bt_first(leaf: jnp.ndarray, ls: LeafSpec) -> jnp.ndarray:
    """The leaf as ``(B, T, *rest)`` (inverse of ``_dense_perm``)."""

    return jnp.moveaxis(leaf, (ls.batch_axis, ls.time_axis), (0, 1))


def gather_dense(spec: CacheSpec, pools: List[jnp.ndarray],
                 states: List[jnp.ndarray], table_view: jnp.ndarray) -> PyTree:
    """Materialize a dense cache view of ``table_view.shape[1] * page_size``
    tokens per lane from the pools. Inactive lanes (all-zero table rows)
    read the trash page — finite garbage, discarded by the caller."""

    nv = table_view.shape[1]
    dense: List[Any] = [None] * len(spec.leaves)
    for j, i in enumerate(spec.paged_idx):
        ls = spec.leaves[i]
        v = pools[j][table_view]  # (slots, nv, page, *rest)
        v = v.reshape(v.shape[0], nv * spec.page_size, *v.shape[3:])
        dense[i] = jnp.transpose(v, _dense_perm(ls))
    for j, i in enumerate(spec.state_idx):
        dense[i] = states[j]
    return jax.tree_util.tree_unflatten(spec.treedef, dense)


def scatter_token(spec: CacheSpec, pools: List[jnp.ndarray],
                  states: List[jnp.ndarray], new_cache: PyTree,
                  table_view: jnp.ndarray, pos: jnp.ndarray,
                  active: jnp.ndarray) -> Tuple[List[jnp.ndarray], List[jnp.ndarray]]:
    """Write back one decoded token per lane: extract column ``pos[lane]``
    of every paged leaf of ``new_cache`` into physical page
    ``table[lane, pos // page]``; inactive lanes write the trash page.
    State leaves are committed only where ``active`` (a retired lane must
    not clobber a freed slot that may be re-allocated the same step)."""

    new_leaves = jax.tree_util.tree_leaves(new_cache)
    B = table_view.shape[0]
    pg = spec.page_size
    lanes = jnp.arange(B)
    page_col = jnp.take_along_axis(table_view, (pos // pg)[:, None], axis=1)[:, 0]
    page_col = jnp.where(active, page_col, 0)
    off = pos % pg

    new_pools: List[jnp.ndarray] = []
    for j, i in enumerate(spec.paged_idx):
        ls = spec.leaves[i]
        col = _bt_first(new_leaves[i], ls)[lanes, pos]  # (B, *rest)
        new_pools.append(pools[j].at[page_col, off].set(col.astype(pools[j].dtype)))

    new_states: List[jnp.ndarray] = []
    for j, i in enumerate(spec.state_idx):
        ls = spec.leaves[i]
        shape = [1] * (1 + len(ls.rest_shape))
        shape[ls.batch_axis] = B
        keep = active.reshape(shape)
        new_states.append(jnp.where(keep, new_leaves[i].astype(states[j].dtype),
                                    states[j]))
    return new_pools, new_states


# ---------------------------------------------------------------------------
# the host-side allocator
# ---------------------------------------------------------------------------


class PagedCache:
    """Free-list page allocator + per-slot bookkeeping over device pools.

    ``slots`` is the fixed lane count of the continuous batch (shapes the
    jitted step compiles for); ``max_len`` caps any single sequence
    (prompt + generated) and sizes the page table width. The pool starts
    at ``initial_pages`` physical pages (plus the trash page) and doubles
    on demand up to ``max_pages``.
    """

    def __init__(self, model, *, slots: int, page_size: int, max_len: int,
                 dtype=None, initial_pages: Optional[int] = None,
                 max_pages: Optional[int] = None):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if max_len < 1 or max_len % page_size != 0:
            raise ValueError("max_len must be a positive multiple of page_size")
        self.model = model
        self.dtype = cm.dtype_of(model.cfg.dtype) if dtype is None else dtype
        self.spec = build_spec(model, page_size=page_size, dtype=self.dtype)
        self.slots = slots
        self.page_size = page_size
        self.max_len = max_len
        self.pages_per_seq = max_len // page_size
        # +1 everywhere: physical page 0 is the trash page, never allocated
        self.max_pages = (1 + slots * self.pages_per_seq if max_pages is None
                          else max_pages)
        cap = min(self.max_pages, 1 + (initial_pages if initial_pages is not None
                                       else slots))
        self.pools: List[jnp.ndarray] = [
            jnp.zeros((cap, page_size, *self.spec.leaves[i].rest_shape),
                      self.spec.leaves[i].dtype)
            for i in self.spec.paged_idx
        ]
        self.states: List[jnp.ndarray] = []
        for i in self.spec.state_idx:
            ls = self.spec.leaves[i]
            shape = list(ls.rest_shape)
            shape.insert(ls.batch_axis, slots)
            self.states.append(jnp.zeros(tuple(shape), ls.dtype))
        self._capacity = cap
        self._free_pages: List[int] = list(range(cap - 1, 0, -1))  # pop() -> low ids first
        self._free_slots: List[int] = list(range(slots - 1, -1, -1))
        self.table = np.zeros((slots, self.pages_per_seq), np.int32)
        self.seq_lens = np.zeros((slots,), np.int64)
        self.active = np.zeros((slots,), bool)
        self._pages_held = np.zeros((slots,), np.int64)
        self.grow_events = 0
        self.peak_bytes = self.allocated_bytes()

    # -- accounting ----------------------------------------------------------

    def allocated_bytes(self) -> int:
        """Live allocation: pools at current capacity + state store + table."""

        total = sum(x.size * x.dtype.itemsize for x in self.pools)
        total += sum(x.size * x.dtype.itemsize for x in self.states)
        total += self.table.size * self.table.itemsize
        return int(total)

    def live_tokens(self) -> int:
        return int(self.seq_lens[self.active].sum())

    def qo_indptr(self) -> np.ndarray:
        """Ragged row-pointer over active slots' lengths (the aiter-style
        ``qo_indptr`` a split-KV decode kernel consumes): ``indptr[k+1] -
        indptr[k]`` is slot k's live length (0 for inactive lanes)."""

        lens = np.where(self.active, self.seq_lens, 0)
        return np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)

    def free_slot_count(self) -> int:
        return len(self._free_slots)

    # -- allocation ----------------------------------------------------------

    def _grow(self, min_extra: int) -> None:
        new_cap = min(self.max_pages, max(2 * self._capacity,
                                          self._capacity + min_extra))
        if new_cap <= self._capacity:
            raise PagedCacheError(
                f"page pool exhausted: capacity {self._capacity} at "
                f"max_pages={self.max_pages}")
        extra = new_cap - self._capacity
        self.pools = [
            jnp.concatenate([p, jnp.zeros((extra, *p.shape[1:]), p.dtype)], axis=0)
            for p in self.pools
        ]
        self._free_pages = list(range(new_cap - 1, self._capacity - 1, -1)) \
            + self._free_pages
        self._capacity = new_cap
        self.grow_events += 1
        self.peak_bytes = max(self.peak_bytes, self.allocated_bytes())

    def alloc_slot(self) -> int:
        if not self._free_slots:
            raise PagedCacheError("no free decode slot")
        slot = self._free_slots.pop()
        self.table[slot] = 0
        self.seq_lens[slot] = 0
        self._pages_held[slot] = 0
        self.active[slot] = True
        return slot

    def reserve(self, slot: int, length: int) -> None:
        """Ensure slot owns pages covering ``length`` tokens."""

        if length > self.max_len:
            raise PagedCacheError(f"sequence length {length} > max_len={self.max_len}")
        need = math.ceil(length / self.page_size)
        held = int(self._pages_held[slot])
        if need <= held:
            return
        if need - held > len(self._free_pages):
            self._grow(need - held - len(self._free_pages))
        for k in range(held, need):
            self.table[slot, k] = self._free_pages.pop()
        self._pages_held[slot] = need

    def set_len(self, slot: int, length: int) -> None:
        self.reserve(slot, length)
        self.seq_lens[slot] = length

    def free(self, slot: int) -> None:
        held = int(self._pages_held[slot])
        self._free_pages.extend(int(p) for p in self.table[slot, :held])
        self.table[slot] = 0
        self.seq_lens[slot] = 0
        self._pages_held[slot] = 0
        self.active[slot] = False
        self._free_slots.append(slot)

    # -- views / writes ------------------------------------------------------

    def table_view(self, view_len: int) -> jnp.ndarray:
        """Page-table slice covering ``view_len`` tokens (a bucket length)."""

        if view_len % self.page_size != 0:
            raise ValueError(f"view_len {view_len} not a multiple of page_size")
        nv = view_len // self.page_size
        if nv > self.pages_per_seq:
            raise ValueError(f"view_len {view_len} > max_len={self.max_len}")
        return jnp.asarray(self.table[:, :nv])

    def write_prefill(self, slot: int, dense_cache: PyTree, n_tokens: int) -> None:
        """Commit a B=1 prefill cache (``n_tokens`` valid, padded to a page
        multiple) into slot's pages + state row, and set its length."""

        n_pages = math.ceil(n_tokens / self.page_size)
        self.reserve(slot, n_tokens)
        leaves = jax.tree_util.tree_leaves(dense_cache)
        pages = jnp.asarray(self.table[slot, :n_pages])
        for j, i in enumerate(self.spec.paged_idx):
            ls = self.spec.leaves[i]
            v = _bt_first(leaves[i], ls)[0, : n_pages * self.page_size]
            v = v.reshape(n_pages, self.page_size, *v.shape[1:])
            self.pools[j] = self.pools[j].at[pages].set(v.astype(self.pools[j].dtype))
        for j, i in enumerate(self.spec.state_idx):
            ls = self.spec.leaves[i]
            row = jnp.moveaxis(leaves[i], ls.batch_axis, 0)[0]
            s = jnp.moveaxis(self.states[j], ls.batch_axis, 0)
            s = s.at[slot].set(row.astype(s.dtype))
            self.states[j] = jnp.moveaxis(s, 0, ls.batch_axis)
        self.seq_lens[slot] = n_tokens
