"""Teacher-forced prefill in ONE jitted call, and the serial greedy
reference loop (docs/serve.md §3).

The seed's ``greedy_generate`` prefilled with P separate jitted
``decode_step`` calls — P dispatches, P cache round-trips. Here prefill
is a single call in one of two modes:

* ``block`` — the whole (right-padded) prompt as one multi-token
  ``decode_step``. Valid for attention-family caches: padded positions
  write garbage K/V *beyond* every valid query position, causal masking
  never attends it, and continuous decode overwrites position ``len``
  onward token by token before it ever enters a mask. Recurrent
  families cannot use this (state updates are order-dependent and
  unmaskable after the fact).
* ``scan`` — a ``lax.scan`` over single-token steps with per-lane
  validity gating: ``jnp.where(t < length)`` on every cache leaf (axis-
  aware via :func:`repro.serve.cache.build_spec`) so a padded lane's
  recurrent state and cache stop evolving exactly at its length. This
  is the per-step op sequence of the serial loop, so it is the
  bitwise-conservative path, and the only correct one for ssm/hybrid.

``mode="auto"`` picks block for attention families and scan for
recurrent ones. Greedy *token* output is identical to the seed loop in
both modes (pinned in tests/test_serve.py); block-mode logits are
additionally bitwise for GQA-style attention, and within float ulps for
MLA/MoE/cross-attention (different contraction order at S>1).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.obs import trace as obs_trace
from repro.serve import cache as cache_mod

RECURRENT_FAMILIES = ("ssm", "hybrid")


def default_mode(cfg) -> str:
    return "scan" if cfg.family in RECURRENT_FAMILIES else "block"


@functools.lru_cache(maxsize=None)  # Model is eq=False: identity-keyed
def _block_fn(model):
    def fn(params, cache, prompt, lengths):
        # phase() = metadata-only named_scope: prefill cost shows up as
        # "serve_prefill" in repro.obs.profile attribution, HLO unchanged
        with obs_trace.phase("serve_prefill"):
            logits, cache = model.decode_step(params, cache, prompt,
                                              jnp.asarray(0, jnp.int32))
            last = logits[jnp.arange(prompt.shape[0]), lengths - 1]
        return last, cache
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _scan_fn(model):
    # batch axis per cache leaf, for validity gating (shape probe only;
    # axes are dtype-independent)
    spec = cache_mod.build_spec(model, page_size=1, dtype=jnp.float32)
    baxes = [ls.batch_axis for ls in spec.leaves]
    treedef = spec.treedef

    def gate(cache_new, cache_old, valid):
        old = jax.tree_util.tree_leaves(cache_old)
        new = jax.tree_util.tree_leaves(cache_new)
        gated = []
        for ax, o, n in zip(baxes, old, new):
            shape = [1] * o.ndim
            shape[ax] = o.shape[ax]
            gated.append(jnp.where(valid.reshape(shape), n, o))
        return jax.tree_util.tree_unflatten(treedef, gated)

    def fn(params, cache, prompt, lengths):
        B, P = prompt.shape
        # phase() = metadata-only named_scope: the whole scan prefill is
        # attributable as "serve_prefill", HLO unchanged
        with obs_trace.phase("serve_prefill"):
            # step 0 outside the scan: it fixes the carry dtypes (logits
            # dtype is family-dependent) and P >= 1 always holds
            logits, new_cache = model.decode_step(params, cache, prompt[:, :1],
                                                  jnp.asarray(0, jnp.int32))
            cache = gate(new_cache, cache, 0 < lengths)
            last = logits[:, 0]
            if P == 1:
                return last, cache

            def body(carry, xs):
                c, lg = carry
                tok, t = xs
                step_logits, c_new = model.decode_step(params, c,
                                                       tok[:, None], t)
                valid = t < lengths
                c = gate(c_new, c, valid)
                lg = jnp.where(valid[:, None], step_logits[:, 0], lg)
                return (c, lg), None

            ts = jnp.arange(1, P, dtype=jnp.int32)
            (cache, last), _ = jax.lax.scan(body, (cache, last),
                                            (prompt[:, 1:].T, ts))
        return last, cache
    return jax.jit(fn)


def chunked_prefill(model, params, prompt: jnp.ndarray, cache,
                    *, lengths: Optional[jnp.ndarray] = None,
                    mode: str = "auto"):
    """Prefill ``prompt`` (B, P) into ``cache`` with one jitted call.

    ``lengths`` (B,) marks each lane's valid prompt length (``None`` =
    all P — the uniform serial case). Returns ``(last_logits, cache)``
    where ``last_logits[b]`` is the logits after lane b's token
    ``lengths[b] - 1`` — the distribution the first generated token is
    sampled from.
    """

    B, P = prompt.shape
    if mode == "auto":
        mode = default_mode(model.cfg)
    if mode == "block" and model.cfg.family in RECURRENT_FAMILIES:
        raise ValueError(
            f"block prefill is order-unsafe for family={model.cfg.family!r}; "
            "use mode='scan'")
    if mode not in ("block", "scan"):
        raise ValueError(f"unknown prefill mode {mode!r}")
    lengths = (jnp.full((B,), P, jnp.int32) if lengths is None
               else jnp.asarray(lengths, jnp.int32))
    fn = _block_fn(model) if mode == "block" else _scan_fn(model)
    return fn(params, cache, prompt, lengths)


def greedy_generate(model, params, prompt: jnp.ndarray, gen: int,
                    cache_len: int, *, step=None, dtype=None,
                    prefill_mode: str = "auto") -> jnp.ndarray:
    """Serial dense-cache greedy decode: the correctness reference every
    served output is pinned against. prompt: (B, P) int32; returns (B, gen)
    greedy tokens. The cache dtype follows the model config
    (``models.common.dtype_of``) unless overridden — the seed hard-coded
    f32, silently doubling serve memory for bf16 configs."""

    B, P = prompt.shape
    dtype = cm.dtype_of(model.cfg.dtype) if dtype is None else dtype
    cache = model.init_cache(B, cache_len, dtype=dtype)
    last, cache = chunked_prefill(model, params, prompt, cache,
                                  mode=prefill_mode)
    step = step if step is not None else jax.jit(model.decode_step)
    toks = [jnp.argmax(last, axis=-1).astype(jnp.int32)]
    for t in range(P, P + gen - 1):
        logits, cache = step(params, cache, toks[-1][:, None],
                             jnp.asarray(t, jnp.int32))
        toks.append(jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32))
    return jnp.stack(toks, axis=1)
