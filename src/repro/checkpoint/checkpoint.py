"""Pytree checkpointing: npz blobs + a JSON manifest (treedef + shapes +
dtypes + user metadata), no external deps. Handles the full EngineState
(both levels' params + optimizer states + step) for resume.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

MANIFEST = "manifest.json"
ARRAYS = "arrays.npz"


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(np.asarray(leaf))
    return names, leaves, treedef


def save(path: str, tree: PyTree, *, step: Optional[int] = None, meta: Optional[Dict] = None):
    os.makedirs(path, exist_ok=True)
    names, leaves, _ = _flatten_with_paths(tree)
    np.savez(os.path.join(path, ARRAYS), **{f"a{i}": leaf for i, leaf in enumerate(leaves)})
    manifest = {
        "names": names,
        "shapes": [list(l.shape) for l in leaves],
        "dtypes": [str(l.dtype) for l in leaves],
        "step": step,
        "meta": meta or {},
    }
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: PyTree) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""

    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    blobs = np.load(os.path.join(path, ARRAYS))
    names, leaves_like, treedef = _flatten_with_paths(like)
    if names != manifest["names"]:
        raise ValueError(
            f"checkpoint structure mismatch: {set(names) ^ set(manifest['names'])}"
        )
    restored = []
    for i, (name, ref) in enumerate(zip(names, leaves_like)):
        arr = blobs[f"a{i}"]
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"{name}: shape {arr.shape} != expected {ref.shape}")
        restored.append(jnp.asarray(arr, dtype=ref.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    return tree, manifest


def latest_step(root: str) -> Optional[str]:
    """Given root/step_000123 layout, return the newest checkpoint dir."""

    if not os.path.isdir(root):
        return None
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_"))
    return os.path.join(root, steps[-1]) if steps else None
