"""Thresholded health monitors over the event stream.

Each monitor watches one failure mode the paper (or PRs 1-6) made
load-bearing, consumes events incrementally, and produces:

* :class:`Alert`\\ s as thresholds trip (fired through callbacks and —
  when wired into an :class:`~repro.obs.Obs` — re-emitted as ``alert``
  events so they land in the JSONL too), and
* a **verdict** (``ok`` / ``warn`` / ``degraded``) summarizing the run.

Monitors are pure functions of the event stream, so the same classes
run live (callbacks during training/serving) and offline
(:func:`replay` over a JSONL for ``repro.obs.report``'s health table).

The built-in set and their default thresholds:

=====================  ======================================================
NonfiniteMonitor       nonfinite hypergradients / gated meta updates.
                       warn on any skip; degraded on >= 3 consecutive or
                       >25% of recent steps (window 100). A skipped step is
                       recovery by design (scale backoff re-arms it) — a
                       *run* of skips means the automaton is not recovering.
LossScaleThrashMonitor loss-scale backoffs from ``scale.policy``. warn on
                       >= 3 backoffs inside a 200-step window, degraded on
                       >= 6: growth→overflow→backoff cycling wastes the
                       steps the paper's throughput claim counts.
CensusMonitor          collective census vs the pinned ``unroll+1``.
                       Any mismatch is degraded immediately — a new
                       all-reduce is a structural regression of the
                       single-sync schedule, never noise (DESIGN.md §9).
ServeSLOMonitor        deadline-miss + shed rate over the last 100
                       terminal request events. warn > 10%, degraded > 30%.
QueueDepthMonitor      queue occupancy from serve tick events. warn when
                       depth/capacity >= 0.8 for 5 consecutive ticks,
                       degraded at >= 0.95 (shedding is imminent —
                       overflow shed triggers at capacity).
=====================  ======================================================
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional

from .events import Event

SEVERITIES = ("ok", "warn", "degraded")


def worst(a: str, b: str) -> str:
    return a if SEVERITIES.index(a) >= SEVERITIES.index(b) else b


@dataclasses.dataclass(frozen=True)
class Alert:
    monitor: str
    severity: str       # "warn" | "degraded"
    message: str
    t: float
    step: Optional[int] = None
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class Monitor:
    """One failure mode. Subclasses implement ``observe`` returning any
    alerts this event tripped, and keep enough state for ``verdict``."""

    name = "monitor"

    def observe(self, event: Event) -> List[Alert]:
        raise NotImplementedError

    def verdict(self) -> Dict[str, Any]:
        raise NotImplementedError

    def _alert(self, severity: str, message: str, event: Event,
               **data: Any) -> Alert:
        return Alert(monitor=self.name, severity=severity, message=message,
                     t=event.t, step=event.step, data=data)


def _is_nonfinite(v: Any) -> bool:
    return isinstance(v, (int, float)) and not math.isfinite(v)


class NonfiniteMonitor(Monitor):
    """Nonfinite hypergradients and gated (skipped) meta updates."""

    name = "nonfinite"

    def __init__(self, consecutive_limit: int = 3, window: int = 100,
                 rate_limit: float = 0.25):
        self.consecutive_limit = consecutive_limit
        self.rate_limit = rate_limit
        self._recent: "deque[bool]" = deque(maxlen=window)  # True = bad step
        self._consecutive = 0
        self.total_bad = 0
        self.total_steps = 0
        self._severity = "ok"
        self._saw_metrics = False

    def _bad_step(self, event: Event) -> bool:
        if event.kind == "gate":
            return not event.data.get("finite", True)
        if event.kind == "metrics":
            if event.data.get("meta_skipped", 0):
                return True
            return _is_nonfinite(event.data.get("hypergrad_norm")) or \
                _is_nonfinite(event.data.get("meta_loss"))
        return False

    def observe(self, event: Event) -> List[Alert]:
        if event.kind not in ("gate", "metrics"):
            return []
        if event.kind == "metrics" and event.name != "step":
            return []  # registry snapshots etc. are not steps
        if event.kind == "metrics":
            self._saw_metrics = True
        elif self._saw_metrics:
            # live streams emit a metrics/step AND a gate event for the
            # same skipped step — the step event (meta_skipped) already
            # counted it; gate events only define the timeline on
            # gate-only (synthetic/test) streams
            return []
        bad = self._bad_step(event)
        self._recent.append(bad)
        self.total_steps += 1
        alerts: List[Alert] = []
        if bad:
            self.total_bad += 1
            self._consecutive += 1
            if self._consecutive == 1:
                self._severity = worst(self._severity, "warn")
                alerts.append(self._alert(
                    "warn", "nonfinite hypergradient / meta update skipped",
                    event, consecutive=self._consecutive))
            if self._consecutive == self.consecutive_limit:
                self._severity = "degraded"
                alerts.append(self._alert(
                    "degraded",
                    f"{self._consecutive} consecutive skipped meta updates "
                    "— loss-scale automaton is not recovering",
                    event, consecutive=self._consecutive))
        else:
            self._consecutive = 0
        if len(self._recent) == self._recent.maxlen:
            rate = sum(self._recent) / len(self._recent)
            if rate > self.rate_limit and self._severity != "degraded":
                self._severity = "degraded"
                alerts.append(self._alert(
                    "degraded",
                    f"{rate:.0%} of the last {len(self._recent)} steps were "
                    "nonfinite/skipped", event, rate=rate))
        return alerts

    def verdict(self) -> Dict[str, Any]:
        return {"status": self._severity, "bad_steps": self.total_bad,
                "steps": self.total_steps,
                "detail": f"{self.total_bad}/{self.total_steps} steps "
                          "nonfinite or skipped"}


class LossScaleThrashMonitor(Monitor):
    """Backoff frequency from the f16 loss-scale automaton."""

    name = "loss_scale"

    def __init__(self, window_steps: int = 200, warn_backoffs: int = 3,
                 degraded_backoffs: int = 6):
        self.window_steps = window_steps
        self.warn_backoffs = warn_backoffs
        self.degraded_backoffs = degraded_backoffs
        self._backoff_steps: "deque[int]" = deque()
        self._seq = 0  # fallback clock when events carry no step
        self.total_backoffs = 0
        self.total_growths = 0
        self.last_scale: Optional[float] = None
        self._severity = "ok"

    def observe(self, event: Event) -> List[Alert]:
        if event.kind != "scale":
            return []
        self._seq += 1
        step = event.step if event.step is not None else self._seq
        self.last_scale = event.data.get("scale", self.last_scale)
        if event.name == "growth":
            self.total_growths += 1
            return []
        if event.name != "backoff":
            return []
        self.total_backoffs += 1
        self._backoff_steps.append(step)
        while self._backoff_steps and step - self._backoff_steps[0] > self.window_steps:
            self._backoff_steps.popleft()
        n = len(self._backoff_steps)
        alerts: List[Alert] = []
        if n >= self.degraded_backoffs and self._severity != "degraded":
            self._severity = "degraded"
            alerts.append(self._alert(
                "degraded",
                f"loss scale thrashing: {n} backoffs within "
                f"{self.window_steps} steps", event, backoffs_in_window=n,
                scale=self.last_scale))
        elif n >= self.warn_backoffs and self._severity == "ok":
            self._severity = "warn"
            alerts.append(self._alert(
                "warn",
                f"{n} loss-scale backoffs within {self.window_steps} steps",
                event, backoffs_in_window=n, scale=self.last_scale))
        return alerts

    def verdict(self) -> Dict[str, Any]:
        return {"status": self._severity, "backoffs": self.total_backoffs,
                "growths": self.total_growths, "last_scale": self.last_scale,
                "detail": f"{self.total_backoffs} backoffs / "
                          f"{self.total_growths} growths"}


class CensusMonitor(Monitor):
    """Collective census vs the schedule's pinned expectation."""

    name = "census"

    def __init__(self):
        self.observed: Optional[int] = None
        self.expected: Optional[int] = None
        self._severity = "ok"
        self._checked = 0

    def observe(self, event: Event) -> List[Alert]:
        if event.kind != "census":
            return []
        self._checked += 1
        self.observed = event.data.get("observed")
        self.expected = event.data.get("expected")
        ok = event.data.get("ok")
        if ok is None:
            ok = (self.observed == self.expected)
        if not ok:
            self._severity = "degraded"
            return [self._alert(
                "degraded",
                f"collective census mismatch: {self.observed} all-reduces, "
                f"expected {self.expected} (unroll+1)", event,
                observed=self.observed, expected=self.expected)]
        return []

    def verdict(self) -> Dict[str, Any]:
        if self._checked == 0:
            detail = "no census observed"
        else:
            detail = f"{self.observed} all-reduces (expected {self.expected})"
        return {"status": self._severity, "observed": self.observed,
                "expected": self.expected, "detail": detail}


class ServeSLOMonitor(Monitor):
    """Deadline-miss + shed rate over recent terminal request events.

    Terminal events: ``serve/done`` (completed in deadline),
    ``serve/deadline_miss``, ``serve/shed``.

    **Burn-rate mode** (``budget`` set): alongside the plain rate
    thresholds, the monitor tracks the miss rate over a *fast* window
    (catches spikes quickly) and the main *slow* window (confirms they
    are sustained, not one unlucky batch). When BOTH windows burn the
    deadline-miss budget faster than ``burn_threshold``× the allowed
    rate, it fires a ``degraded`` alert immediately — the multi-window
    multi-burn-rate SLO alerting shape, and the signal the flight
    recorder's alert-escalation trigger dumps a postmortem on.
    """

    name = "serve_slo"

    TERMINAL = ("done", "deadline_miss", "shed")

    def __init__(self, window: int = 100, warn_rate: float = 0.10,
                 degraded_rate: float = 0.30, min_events: int = 10,
                 budget: Optional[float] = None, fast_window: int = 20,
                 burn_threshold: float = 4.0):
        self.warn_rate = warn_rate
        self.degraded_rate = degraded_rate
        self.min_events = min_events
        self.budget = budget
        self.burn_threshold = burn_threshold
        self._recent: "deque[bool]" = deque(maxlen=window)  # True = miss/shed
        self._fast: "deque[bool]" = deque(maxlen=fast_window)
        self._burning = False
        self.burn_alerts = 0
        self.totals = {k: 0 for k in self.TERMINAL}
        self._severity = "ok"

    def observe(self, event: Event) -> List[Alert]:
        if event.kind != "serve" or event.name not in self.TERMINAL:
            return []
        bad = event.name != "done"
        self.totals[event.name] += 1
        self._recent.append(bad)
        self._fast.append(bad)
        alerts: List[Alert] = []
        alerts.extend(self._observe_burn(event))
        if len(self._recent) < self.min_events:
            return alerts
        rate = sum(self._recent) / len(self._recent)
        if rate > self.degraded_rate and self._severity != "degraded":
            self._severity = "degraded"
            alerts.append(self._alert(
                "degraded", f"{rate:.0%} of recent requests missed deadline "
                "or were shed", event, rate=rate))
        elif rate > self.warn_rate and self._severity == "ok":
            self._severity = "warn"
            alerts.append(self._alert(
                "warn", f"{rate:.0%} of recent requests missed deadline or "
                "were shed", event, rate=rate))
        return alerts

    def _observe_burn(self, event: Event) -> List[Alert]:
        if self.budget is None or len(self._fast) < self._fast.maxlen \
                or len(self._recent) < self.min_events:
            return []
        fast_rate = sum(self._fast) / len(self._fast)
        slow_rate = sum(self._recent) / len(self._recent)
        burn = self.burn_threshold * self.budget
        if fast_rate >= burn and slow_rate >= burn:
            if self._burning:
                return []  # one alert per sustained burn episode
            self._burning = True
            self.burn_alerts += 1
            self._severity = "degraded"
            return [self._alert(
                "degraded",
                f"SLO burn: miss rate {fast_rate:.0%} (fast) / "
                f"{slow_rate:.0%} (slow) >= {self.burn_threshold:g}x the "
                f"{self.budget:.0%} budget", event,
                fast_rate=fast_rate, slow_rate=slow_rate,
                budget=self.budget, burn_threshold=self.burn_threshold)]
        if fast_rate < burn:
            self._burning = False  # episode over; re-arm
        return []

    def verdict(self) -> Dict[str, Any]:
        n = sum(self.totals.values())
        bad = self.totals["deadline_miss"] + self.totals["shed"]
        out = {"status": self._severity, "requests": n, **self.totals,
               "detail": f"{bad}/{n} requests missed deadline or shed"}
        if self.budget is not None:
            out["budget"] = self.budget
            out["burn_alerts"] = self.burn_alerts
        return out


class QueueDepthMonitor(Monitor):
    """Sustained queue saturation from ``serve/tick`` events carrying
    ``queue_depth`` and ``capacity``."""

    name = "queue_depth"

    def __init__(self, warn_frac: float = 0.80, degraded_frac: float = 0.95,
                 sustain: int = 5):
        self.warn_frac = warn_frac
        self.degraded_frac = degraded_frac
        self.sustain = sustain
        self._warn_run = 0
        self._degraded_run = 0
        self.max_frac = 0.0
        self._severity = "ok"

    def observe(self, event: Event) -> List[Alert]:
        if event.kind != "serve" or event.name != "tick":
            return []
        depth = event.data.get("queue_depth")
        cap = event.data.get("capacity")
        if depth is None or not cap:
            return []
        frac = depth / cap
        self.max_frac = max(self.max_frac, frac)
        self._warn_run = self._warn_run + 1 if frac >= self.warn_frac else 0
        self._degraded_run = self._degraded_run + 1 if frac >= self.degraded_frac else 0
        alerts: List[Alert] = []
        if self._degraded_run == self.sustain:
            self._severity = "degraded"
            alerts.append(self._alert(
                "degraded", f"queue at {frac:.0%} capacity for "
                f"{self.sustain} consecutive ticks — overflow shedding "
                "imminent", event, frac=frac, depth=depth, capacity=cap))
        elif self._warn_run == self.sustain and self._severity == "ok":
            self._severity = "warn"
            alerts.append(self._alert(
                "warn", f"queue at {frac:.0%} capacity for "
                f"{self.sustain} consecutive ticks", event,
                frac=frac, depth=depth, capacity=cap))
        return alerts

    def verdict(self) -> Dict[str, Any]:
        return {"status": self._severity, "max_frac": self.max_frac,
                "detail": f"peak queue occupancy {self.max_frac:.0%}"}


def default_monitors(slo_budget: Optional[float] = None) -> List[Monitor]:
    """The built-in monitor set. ``slo_budget`` (an allowed deadline-miss
    fraction, e.g. 0.05) arms ServeSLOMonitor's burn-rate mode."""

    return [NonfiniteMonitor(), LossScaleThrashMonitor(), CensusMonitor(),
            ServeSLOMonitor(budget=slo_budget), QueueDepthMonitor()]


class HealthMonitor:
    """Fans events out to a set of monitors; collects alerts and the
    aggregate status. ``on_alert`` callbacks run synchronously for each
    fired alert (keep them cheap — they run inside the observed loop)."""

    def __init__(self, monitors: Optional[List[Monitor]] = None,
                 on_alert: Optional[Callable[[Alert], None]] = None):
        self.monitors = monitors if monitors is not None else default_monitors()
        self._callbacks: List[Callable[[Alert], None]] = []
        if on_alert is not None:
            self._callbacks.append(on_alert)
        self.alerts: List[Alert] = []

    def add_callback(self, fn: Callable[[Alert], None]) -> None:
        self._callbacks.append(fn)

    def observe(self, event: Event) -> List[Alert]:
        fired: List[Alert] = []
        for m in self.monitors:
            fired.extend(m.observe(event))
        for a in fired:
            self.alerts.append(a)
            for fn in self._callbacks:
                fn(a)
        return fired

    @property
    def status(self) -> str:
        s = "ok"
        for m in self.monitors:
            s = worst(s, m.verdict()["status"])
        return s

    def summary(self) -> Dict[str, Any]:
        """The degraded-status summary: aggregate status + per-monitor
        verdicts + the alert log."""

        return {
            "status": self.status,
            "t": time.time(),
            "monitors": {m.name: m.verdict() for m in self.monitors},
            "alerts": [a.as_dict() for a in self.alerts],
        }


def replay(events: Iterable[Event],
           monitors: Optional[List[Monitor]] = None) -> HealthMonitor:
    """Run monitors over a recorded stream (the offline path used by
    ``repro.obs.report`` to print health verdicts from a JSONL)."""

    hm = HealthMonitor(monitors=monitors)
    for e in events:
        hm.observe(e)
    return hm
