"""repro.obs — runtime tracing, metrics, and health telemetry.

The facade is :class:`Obs`: one object bundling an event sink
(`events.py`), a metric registry (`metrics.py`), a span tracer
(`trace.py`), and health monitors (`health.py`). Subsystems take an
``obs=`` knob; when it is omitted they fall back to :data:`NULL_OBS`,
whose ``enabled`` flag is False — every instrumentation site guards on
that flag first, so a run without observability does zero per-event
work and (because in-graph annotations are unconditional metadata-only
``jax.named_scope``) compiles to byte-identical HLO. Both guarantees
are pinned in ``tests/test_obs.py``.

Typical wiring (what ``launch/train.py --obs-log run.jsonl`` does)::

    from repro import obs as obs_mod
    obs = obs_mod.make_obs(log_path="run.jsonl", console=True)
    obs_mod.set_default(obs)          # deep call sites (kernel dispatch)
    ...
    learner.fit(batches, steps=200, obs=obs)
    obs.emit("run", "run_end", data=obs.health.summary())
    obs.close()

Then offline::

    python -m repro.obs.report run.jsonl

There is also a process-global default (:func:`set_default` /
:func:`get_default`) for call sites too deep to thread a knob through —
kernel dispatch decisions, launch-script logging. It starts as
:data:`NULL_OBS`; nothing is observed unless a CLI or a user opts in.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

from . import events as events_mod
from . import flight as flight_mod
from . import health as health_mod
from . import metrics as metrics_mod
from . import trace as trace_mod
from .events import (ConsoleSink, Event, JsonlSink, NullSink, RingSink, Sink,
                     TeeSink, make_event, read_jsonl, read_jsonl_stats,
                     validate_event, validate_jsonl)
from .flight import FlightRecorder, HangWatchdog, load_bundle, validate_bundle
from .health import Alert, HealthMonitor, default_monitors
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, packed_read
from .trace import PHASES, Span, Tracer, activate, active_tracer, \
    chrome_trace, lane_chrome_events, phase, span_tree_summary, \
    write_chrome_trace

__all__ = [
    "Obs", "NULL_OBS", "make_obs", "set_default", "get_default",
    "Event", "Sink", "NullSink", "JsonlSink", "RingSink", "ConsoleSink",
    "TeeSink", "make_event", "read_jsonl", "read_jsonl_stats",
    "validate_event", "validate_jsonl",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "packed_read",
    "Tracer", "Span", "phase", "activate", "active_tracer", "chrome_trace",
    "write_chrome_trace", "span_tree_summary", "PHASES", "lane_chrome_events",
    "HealthMonitor", "Alert", "default_monitors",
    "FlightRecorder", "HangWatchdog", "validate_bundle", "load_bundle",
]


class Obs:
    """One observability pipeline: events → [health] → sink, plus a
    metric registry and a span tracer sharing the same sink.

    ``enabled=False`` (or the :data:`NULL_OBS` singleton) is the
    contract for "off": ``emit`` returns before constructing anything,
    and instrumented code guards loops/dict-building on ``obs.enabled``.
    """

    def __init__(self, sink: Optional[Sink] = None, *, enabled: bool = True,
                 run_id: Optional[str] = None,
                 health: Optional[HealthMonitor] = None,
                 monitor: bool = True):
        self.sink: Sink = sink if sink is not None else RingSink()
        self.enabled = enabled
        self.run_id = run_id
        self.metrics = MetricsRegistry()
        self.health: Optional[HealthMonitor] = (
            health if health is not None
            else (HealthMonitor() if monitor else None))
        self.tracer = Tracer(obs=self)
        self._last_loss_scale: Optional[float] = None

    # -- event pipeline ----------------------------------------------------

    def emit(self, kind: str, name: str, *, data: Optional[Dict[str, Any]] = None,
             step: Optional[int] = None) -> Optional[Event]:
        """Build, monitor, and sink one event. No-op when disabled."""

        if not self.enabled:
            return None
        event = make_event(kind, name, data=data, step=step)
        self.sink.write(event)
        if self.health is not None:
            for alert in self.health.observe(event):
                # alerts are themselves events, but bypass health to keep
                # the pipeline loop-free
                self.sink.write(make_event(
                    "alert", alert.monitor, step=alert.step,
                    data={"severity": alert.severity, "message": alert.message,
                          **alert.data}))
        return event

    def log(self, name: str, text: Optional[str] = None,
            step: Optional[int] = None, **data: Any) -> None:
        """Structured replacement for ``print()``: a ``log`` event whose
        console rendering is the original line."""

        if not self.enabled:
            return
        if text is not None:
            data = {"text": text, **data}
        self.emit("log", name, data=data, step=step)

    # -- metrics convenience ----------------------------------------------

    def counter(self, name: str, help: str = "") -> Counter:
        return self.metrics.counter(name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self.metrics.gauge(name, help)

    def histogram(self, name: str, help: str = "", bounds=None) -> Histogram:
        return self.metrics.histogram(name, help, bounds=bounds)

    # -- step / domain observation helpers --------------------------------

    def observe_step(self, step: int, metrics: Dict[str, float]) -> None:
        """Ingest one training step's metric dict (already host floats —
        see :func:`repro.obs.metrics.packed_read`).

        Emits the ``metrics`` event and derives the loss-scale/gate
        events host-side from the ``loss_scale`` / ``meta_skipped``
        scalars the engine exposes under dynamic scaling, so the traced
        step function needs no obs-conditional code at all.
        """

        if not self.enabled:
            return
        self.emit("metrics", "step", data=dict(metrics), step=step)
        scale = metrics.get("loss_scale")
        if scale is not None:
            prev = self._last_loss_scale
            if prev is not None and scale != prev:
                name = "backoff" if scale < prev else "growth"
                self.emit("scale", name, data={"scale": scale, "prev": prev},
                          step=step)
                self.counter("loss_scale_transitions").inc(labels={"kind": name})
            self._last_loss_scale = scale
        skipped = metrics.get("meta_skipped")
        if skipped is not None and skipped:
            self.emit("gate", "meta_update",
                      data={"finite": False, "reason": "nonfinite_hypergrad"},
                      step=step)
            self.counter("meta_updates_skipped").inc()
        hg = metrics.get("hypergrad_norm")
        if isinstance(hg, float) and not math.isfinite(hg):
            self.emit("gate", "meta_update",
                      data={"finite": False, "reason": "nonfinite_hypergrad_norm"},
                      step=step)

    def observe_census(self, observed: int, expected: int,
                       detail: Optional[Dict[str, Any]] = None) -> None:
        """Record a collective-census check against the pinned
        ``unroll+1`` expectation; mismatch trips CensusMonitor."""

        if not self.enabled:
            return
        data = {"observed": int(observed), "expected": int(expected),
                "ok": int(observed) == int(expected)}
        if detail:
            data.update(detail)
        self.emit("census", "all_reduce", data=data)

    def sink_dropped(self) -> int:
        """Events evicted by any RingSink in this pipeline (recursing
        through TeeSink fan-outs). The CLIs report this at run_end so a
        too-small ring shows up as a number, not silently missing data."""

        def count(sink: Sink) -> int:
            if isinstance(sink, RingSink):
                return sink.dropped
            if isinstance(sink, TeeSink):
                return sum(count(s) for s in sink.sinks)
            return 0

        return count(self.sink)

    # -- lifecycle ---------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"run_id": self.run_id,
                               "metrics": self.metrics.snapshot()}
        if self.health is not None:
            out["health"] = self.health.summary()
        return out

    def flush(self) -> None:
        if self.enabled:
            self.sink.flush()

    def close(self) -> None:
        if self.enabled:
            self.sink.close()


#: The disabled pipeline every ``obs=``-knob defaults to. Shared and
#: stateless-by-construction: emit() returns immediately, so nothing is
#: ever written to its NullSink.
NULL_OBS = Obs(sink=NullSink(), enabled=False, monitor=False)


def make_obs(log_path: Optional[str] = None, *, console: bool = False,
             ring: int = 0, run_id: Optional[str] = None,
             monitor: bool = True, slo_budget: Optional[float] = None) -> Obs:
    """Build an enabled Obs from CLI-ish knobs: JSONL file sink
    (``log_path``), legacy-stdout console sink, and/or a ring buffer.
    With no sinks requested you get a 1024-event ring (events are kept,
    nothing is printed or written). ``slo_budget`` (allowed deadline-miss
    fraction) arms ServeSLOMonitor's burn-rate mode."""

    sinks: List[Sink] = []
    if log_path:
        sinks.append(JsonlSink(log_path))
    if console:
        sinks.append(ConsoleSink())
    if ring:
        sinks.append(RingSink(ring))
    if not sinks:
        sinks.append(RingSink())
    sink: Sink = sinks[0] if len(sinks) == 1 else TeeSink(sinks)
    health = None
    if monitor and slo_budget is not None:
        health = HealthMonitor(monitors=default_monitors(slo_budget))
    return Obs(sink=sink, run_id=run_id, monitor=monitor, health=health)


_default_obs: Obs = NULL_OBS


def set_default(obs: Optional[Obs]) -> None:
    """Install the process-global default pipeline (None resets to
    :data:`NULL_OBS`). Used by call sites too deep for an ``obs=`` knob
    — e.g. kernel dispatch decisions."""

    global _default_obs
    _default_obs = obs if obs is not None else NULL_OBS


def get_default() -> Obs:
    return _default_obs
