"""Nested span timing over the engine's phases, with Chrome-trace export.

Two layers cooperate here, and keeping them straight is what makes the
"byte-identical HLO when disabled" guarantee hold (tests/test_obs.py):

1. **In-graph phase names** — :func:`phase` wraps each engine phase in
   ``jax.named_scope`` *unconditionally*. named_scope only attaches
   name metadata to the ops traced under it; it is applied whether or
   not observability is on, so the lowered HLO text is identical either
   way (and the `unroll+1` collective census is untouched).
2. **Host span capture** — when a :class:`Tracer` is activated (a
   contextvar, see :func:`activate`), :func:`phase` ALSO records a host
   wall-time span and enters ``jax.profiler.TraceAnnotation`` so native
   JAX profiles carry the same labels. With no tracer active the extra
   cost is one contextvar read at Python execution time — which for
   jitted code means once per compilation, not per step.

What a span's duration *means* depends on where Python ran:

* under ``jax.jit`` tracing, the phase body executes once at trace time
  — the span measures tracing cost and is tagged ``traced=True``;
* eagerly (``MetaLearner.phase_profile()`` runs one step un-jitted),
  the span measures real dispatch+compute wall time per phase — these
  are the per-phase numbers ``repro.obs.report`` prints.

Spans nest: ``depth`` and ``parent`` reconstruct the tree, and
:func:`chrome_trace` emits ``traceEvents`` (``ph="X"``, µs timestamps)
loadable in chrome://tracing or Perfetto.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import json
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence

#: Engine phase names, in execution order. Used by report.py to order
#: the span table; emitting other names is fine.
PHASES = (
    "base_unroll",      # K-step inner unroll (core/engine._unroll_base)
    "local_terms",      # per-method local hypergrad terms (any method);
                        # SAMA's meta_pass/cd_passes nest inside it
    "meta_pass",        # SAMA perturbation direction (core/sama.py)
    "cd_passes",        # central-difference hypergradient passes
    "finalize",         # method.finalize / hypergrad assembly
    "meta_update",      # guarded_meta_update (gate + optimizer apply)
    "allreduce_flat",   # flat-bucket all-reduce (launch/distributed.py)
)


@dataclasses.dataclass
class Span:
    name: str
    start_s: float          # perf_counter seconds (monotonic, not unix)
    dur_s: float
    depth: int
    parent: Optional[str]
    traced: bool            # True if recorded while jax was tracing (compile-time span)
    step: Optional[int] = None

    @property
    def dur_us(self) -> float:
        return self.dur_s * 1e6

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "start_s": self.start_s, "dur_s": self.dur_s,
                "dur_us": self.dur_us, "depth": self.depth, "parent": self.parent,
                "traced": self.traced, "step": self.step}


def _in_jax_trace() -> bool:
    try:
        import jax
        return not jax.core.trace_state_clean()
    except Exception:  # pragma: no cover - jax absent or API moved
        return False


class Tracer:
    """Collects nested spans; optionally mirrors each completed span as
    a ``span`` event into an obs pipeline."""

    def __init__(self, obs=None, use_profiler: bool = True):
        self.spans: List[Span] = []
        self._stack: List[str] = []
        self._obs = obs
        self._use_profiler = use_profiler
        self.step: Optional[int] = None  # callers set this per step for labeling

    @contextlib.contextmanager
    def span(self, name: str) -> Iterator[None]:
        annotation = None
        if self._use_profiler:
            try:
                import jax
                annotation = jax.profiler.TraceAnnotation(name)
            except Exception:  # pragma: no cover - profiler unavailable
                annotation = None
        parent = self._stack[-1] if self._stack else None
        depth = len(self._stack)
        self._stack.append(name)
        traced = _in_jax_trace()
        t0 = time.perf_counter()
        try:
            if annotation is not None:
                with annotation:
                    yield
            else:
                yield
        finally:
            dur = time.perf_counter() - t0
            self._stack.pop()
            sp = Span(name=name, start_s=t0, dur_s=dur, depth=depth,
                      parent=parent, traced=traced, step=self.step)
            self.spans.append(sp)
            if self._obs is not None and self._obs.enabled:
                self._obs.emit("span", name, data={
                    "dur_us": sp.dur_us, "depth": depth, "parent": parent,
                    "traced": traced}, step=self.step)

    def runtime_spans(self) -> List[Span]:
        """Spans measured during real execution (not jit tracing)."""

        return [s for s in self.spans if not s.traced]

    def clear(self) -> None:
        self.spans.clear()


_ACTIVE: "contextvars.ContextVar[Optional[Tracer]]" = contextvars.ContextVar(
    "repro_obs_tracer", default=None)


def active_tracer() -> Optional[Tracer]:
    return _ACTIVE.get()


@contextlib.contextmanager
def activate(tracer: Tracer) -> Iterator[Tracer]:
    """Make ``tracer`` the target of :func:`phase` spans in this context."""

    token = _ACTIVE.set(tracer)
    try:
        yield tracer
    finally:
        _ACTIVE.reset(token)


@contextlib.contextmanager
def phase(name: str) -> Iterator[None]:
    """Annotate an engine phase.

    Always applies ``jax.named_scope(name)`` (metadata-only, identical
    HLO with obs on or off). Additionally records a host span iff a
    Tracer is activated in the current context.
    """

    try:
        import jax
        scope = jax.named_scope(name)
    except Exception:  # pragma: no cover - jax absent
        scope = contextlib.nullcontext()
    tracer = _ACTIVE.get()
    with scope:
        if tracer is None:
            yield
        else:
            with tracer.span(name):
                yield


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------


def chrome_trace(spans: Sequence[Span]) -> Dict[str, Any]:
    """Render spans as a Chrome-trace/Perfetto ``traceEvents`` document.

    Complete events (``ph="X"``) with µs timestamps relative to the
    earliest span; trace-time spans land on a separate "tid" row so
    compile-time work is visually distinct from runtime phases.
    """

    events: List[Dict[str, Any]] = []
    t0 = min((s.start_s for s in spans), default=0.0)
    for s in spans:
        events.append({
            "name": s.name,
            "ph": "X",
            "ts": (s.start_s - t0) * 1e6,
            "dur": s.dur_us,
            "pid": 0,
            "tid": 1 if s.traced else 0,
            "args": {k: v for k, v in (("step", s.step), ("parent", s.parent),
                                       ("traced", s.traced)) if v is not None},
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {"producer": "repro.obs.trace", "schema": 1},
    }


def lane_chrome_events(events: Sequence[Any]) -> List[Dict[str, Any]]:
    """Per-decode-lane request tracks from a serve event stream.

    Consumes the lifecycle events the serving executor emits (admitted /
    first_token / terminal, each carrying a ``trace_id``) and renders one
    Chrome-trace row per decode lane (``pid=1``, ``tid=slot``) with a
    request span from admission to its terminal event. Load next to the
    tick spans in Perfetto and the lane occupancy/goodput picture is the
    timeline itself: gaps are trash-page ticks.
    """

    TERMINALS = ("done", "deadline_miss", "shed", "rejected", "error")
    # trace_id -> {start, end, slot, status, tokens, request_id}
    reqs: Dict[str, Dict[str, Any]] = {}
    for e in events:
        if e.kind != "serve":
            continue
        tid = e.data.get("trace_id")
        if tid is None:
            continue
        r = reqs.setdefault(tid, {"start": None, "end": None, "slot": None,
                                  "status": None, "tokens": None,
                                  "request_id": e.data.get("request_id")})
        if e.name == "admitted" and r["start"] is None:
            r["start"] = e.t
        elif e.name == "first_token":
            r["slot"] = e.data.get("slot", r["slot"])
            if r["start"] is None:
                r["start"] = e.t
        elif e.name in TERMINALS:
            r["end"] = e.t
            r["status"] = e.data.get("status", e.name)
            r["tokens"] = e.data.get("tokens")
            if r["slot"] is None:
                r["slot"] = e.data.get("slot")

    spans = [(tid, r) for tid, r in reqs.items()
             if r["slot"] is not None and r["start"] is not None
             and r["end"] is not None]
    if not spans:
        return []
    t0 = min(r["start"] for _, r in spans)
    out: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "serve lanes"}},
    ]
    for slot in sorted({r["slot"] for _, r in spans}):
        out.append({"name": "thread_name", "ph": "M", "pid": 1, "tid": slot,
                    "args": {"name": f"lane {slot}"}})
    for tid, r in sorted(spans, key=lambda kv: kv[1]["start"]):
        out.append({
            "name": f"req {r['request_id']}" if r["request_id"] is not None
            else f"req {tid[:8]}",
            "ph": "X",
            "ts": (r["start"] - t0) * 1e6,
            "dur": max(0.0, (r["end"] - r["start"]) * 1e6),
            "pid": 1,
            "tid": r["slot"],
            "args": {k: v for k, v in (("trace_id", tid),
                                       ("status", r["status"]),
                                       ("tokens", r["tokens"]))
                     if v is not None},
        })
    return out


def write_chrome_trace(path: str, spans: Sequence[Span],
                       extra_events: Optional[Sequence[Dict[str, Any]]] = None
                       ) -> str:
    """Write a Chrome-trace document for ``spans``; ``extra_events`` are
    appended to ``traceEvents`` verbatim (e.g. :func:`lane_chrome_events`
    request tracks)."""

    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    doc = chrome_trace(spans)
    if extra_events:
        doc["traceEvents"].extend(extra_events)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    return path


def span_tree_summary(spans: Sequence[Span]) -> List[Dict[str, Any]]:
    """Aggregate spans by name → {name, n, total_us, mean_us, max_us,
    depth, parent}, ordered by PHASES then first appearance. Used by the
    report CLI's per-phase table."""

    order: List[str] = []
    agg: Dict[str, Dict[str, Any]] = {}
    for s in spans:
        if s.name not in agg:
            order.append(s.name)
            agg[s.name] = {"name": s.name, "n": 0, "total_us": 0.0,
                           "max_us": 0.0, "depth": s.depth, "parent": s.parent}
        a = agg[s.name]
        a["n"] += 1
        a["total_us"] += s.dur_us
        a["max_us"] = max(a["max_us"], s.dur_us)
    for a in agg.values():
        a["mean_us"] = a["total_us"] / a["n"]

    def _rank(name: str) -> tuple:
        try:
            return (0, PHASES.index(name))
        except ValueError:
            return (1, order.index(name))

    return [agg[name] for name in sorted(agg, key=_rank)]
