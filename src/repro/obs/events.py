"""The versioned structured-event schema and its sinks (docs/observability.md).

One :class:`Event` is one timestamped fact about a running system — a
metric snapshot, a completed span, a loss-scale backoff, a shed request,
a health alert. Every event the repo emits flows through a sink:

* :class:`NullSink`      — the disabled path. Writing is a constant-time
  no-op; the hot loops additionally guard on ``obs.enabled`` so a
  disabled run performs ZERO per-event work (and, because all engine
  instrumentation is host-side or metadata-only, lowers to byte-identical
  HLO — pinned in tests/test_obs.py).
* :class:`JsonlSink`     — append-only JSON Lines file, one event per
  line, flushed per write so a killed run keeps everything it logged.
  This is the durable format ``repro.obs.report`` consumes.
* :class:`RingSink`      — fixed-capacity in-memory ring buffer (oldest
  evicted first); the cheap always-on option for post-hoc inspection
  and tests.
* :class:`ConsoleSink`   — renders selected event kinds back into the
  greppable stdout lines the launch CLIs printed before observability
  existed (``log`` events print their text, ``metrics`` events print the
  same JSON dict ``launch/train.py`` always printed).
* :class:`TeeSink`       — fan-out to several sinks.

Schema v1 (validated by :func:`validate_event`; the CI obs-smoke job
runs every logged event through it)::

    {"v": 1, "t": <unix seconds>, "kind": <KINDS>, "name": str,
     "step": int | null, "data": {...}}

``kind`` is the coarse router (what machinery produced it), ``name`` the
fine label, ``data`` the payload. Unknown *names* are fine — monitors
and the report CLI key on (kind, name) pairs they know and ignore the
rest — but unknown *kinds* are schema errors: every emitter in-repo
picks from :data:`KINDS`, so a novel kind means a corrupted log or a
version skew worth failing loudly on.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

SCHEMA_VERSION = 1

#: The closed set of event kinds (coarse categories; ``name`` is open).
KINDS = (
    "run",        # run_start / run_end lifecycle markers
    "log",        # structured replacement for ad-hoc print() reporting
    "metrics",    # a step's metric scalars (or a registry snapshot)
    "span",       # a completed trace.Span (host wall time)
    "scale",      # loss-scale automaton transitions (backoff / growth)
    "gate",       # skip-on-nonfinite gates (guarded_meta_update etc.)
    "census",     # collective-census observation (all-reduce counts)
    "serve",      # serving-plane events (sheds, ticks, queue depth)
    "dispatch",   # kernel backend-dispatch decisions
    "checkpoint", # save / restore
    "alert",      # health-monitor firings
)


@dataclasses.dataclass(frozen=True)
class Event:
    """One structured observation. Immutable; ``data`` values must be
    JSON-serializable (the JsonlSink enforces this at write time by
    stringifying anything ``json`` refuses)."""

    kind: str
    name: str
    t: float
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)
    step: Optional[int] = None
    v: int = SCHEMA_VERSION

    def as_dict(self) -> Dict[str, Any]:
        return {"v": self.v, "t": self.t, "kind": self.kind, "name": self.name,
                "step": self.step, "data": self.data}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Event":
        return Event(kind=d["kind"], name=d["name"], t=d["t"],
                     data=dict(d.get("data") or {}), step=d.get("step"),
                     v=d.get("v", SCHEMA_VERSION))


def make_event(kind: str, name: str, *, data: Optional[Dict[str, Any]] = None,
               step: Optional[int] = None, t: Optional[float] = None) -> Event:
    if kind not in KINDS:
        raise ValueError(f"unknown event kind {kind!r}; have {KINDS}")
    return Event(kind=kind, name=name, t=time.time() if t is None else t,
                 data=dict(data or {}), step=step)


def validate_event(d: Any) -> List[str]:
    """Schema errors for one event dict ([] = valid)."""

    if not isinstance(d, dict):
        return [f"event must be a dict, got {type(d).__name__}"]
    errors: List[str] = []
    if d.get("v") != SCHEMA_VERSION:
        errors.append(f"event.v must be {SCHEMA_VERSION}, got {d.get('v')!r}")
    if d.get("kind") not in KINDS:
        errors.append(f"event.kind {d.get('kind')!r} not in {KINDS}")
    if not isinstance(d.get("name"), str) or not d.get("name"):
        errors.append("event.name must be a non-empty string")
    if not isinstance(d.get("t"), (int, float)):
        errors.append("event.t must be a number (unix seconds)")
    step = d.get("step")
    if step is not None and not isinstance(step, int):
        errors.append("event.step must be an int or null")
    if not isinstance(d.get("data"), dict):
        errors.append("event.data must be a dict")
    return errors


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------


class Sink:
    """Protocol anchor: ``write(event)``, ``flush()``, ``close()``."""

    def write(self, event: Event) -> None:
        raise NotImplementedError

    def flush(self) -> None:  # pragma: no cover - default no-op
        pass

    def close(self) -> None:  # pragma: no cover - default no-op
        pass


class NullSink(Sink):
    """Discards everything. ``Obs`` short-circuits before even building
    Event objects when disabled, so this sink exists for API symmetry
    (and as the terminal guarantee that a disabled pipeline stays
    zero-overhead if something writes anyway)."""

    def write(self, event: Event) -> None:
        pass


class RingSink(Sink):
    """Keep the most recent ``capacity`` events in memory (FIFO eviction,
    pinned in tests). ``events()`` returns oldest-first."""

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._ring: "deque[Event]" = deque(maxlen=capacity)
        self.dropped = 0  # count of evicted events (observability of the ring itself)

    def write(self, event: Event) -> None:
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        self._ring.append(event)

    def events(self) -> List[Event]:
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()


class JsonlSink(Sink):
    """Append-only JSON Lines file. One ``json.dumps`` per event, flushed
    immediately — the event rate is bounded by the host-side cadence
    (log_every for training, per-request for serving), so durability wins
    over batching. Non-JSON-serializable data values are stringified
    rather than crashing the run being observed."""

    def __init__(self, path: str):
        self.path = path
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")

    def write(self, event: Event) -> None:
        self._f.write(json.dumps(event.as_dict(), default=str) + "\n")
        self._f.flush()

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


#: ConsoleSink's default renderers; kind -> fn(event) -> printed line.
def _render_log(e: Event) -> str:
    text = e.data.get("text")
    if text is not None:
        return str(text)
    return f"{e.name}: " + json.dumps(
        {k: v for k, v in e.data.items() if k != "text"}, default=str)


def _render_metrics(e: Event) -> str:
    # the exact greppable shape launch/train.py always printed
    d = dict(e.data)
    if e.step is not None:
        d.setdefault("step", e.step)
    return json.dumps(d, default=str)


def _render_alert(e: Event) -> str:
    return (f"[obs:{e.data.get('severity', 'warn')}] {e.name}: "
            f"{e.data.get('message', '')}")


class ConsoleSink(Sink):
    """Renders selected kinds back into the legacy stdout lines so CLI
    output stays greppable when reporting is routed through events.
    Span/serve/dispatch chatter is NOT printed by default — the console
    shows what the pre-obs CLIs showed, the JSONL keeps everything."""

    RENDERERS: Dict[str, Callable[[Event], str]] = {
        "log": _render_log,
        "metrics": _render_metrics,
        "alert": _render_alert,
    }

    #: metrics-kind names worth a console line; registry snapshots and
    #: other bulk dumps stay JSONL-only
    METRIC_NAMES = ("step",)

    def __init__(self, stream=None, kinds: Optional[Tuple[str, ...]] = None):
        self.stream = stream if stream is not None else sys.stdout
        self.kinds = tuple(kinds) if kinds is not None else tuple(self.RENDERERS)

    def write(self, event: Event) -> None:
        if event.kind not in self.kinds:
            return
        if event.kind == "metrics" and event.name not in self.METRIC_NAMES:
            return
        render = self.RENDERERS.get(event.kind, _render_log)
        print(render(event), file=self.stream)

    def flush(self) -> None:
        self.stream.flush()


class TeeSink(Sink):
    def __init__(self, sinks: List[Sink]):
        self.sinks = list(sinks)

    def write(self, event: Event) -> None:
        for s in self.sinks:
            s.write(event)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()


# ---------------------------------------------------------------------------
# reading logs back
# ---------------------------------------------------------------------------


def read_jsonl(path: str, *, strict: bool = False) -> Iterator[Event]:
    """Iterate the events of a JSONL log. ``strict`` raises on the first
    malformed line / schema violation; otherwise bad lines are skipped
    (a crashed writer can leave a torn final line)."""

    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                if strict:
                    raise ValueError(f"{path}:{lineno}: not JSON: {e}") from e
                continue
            errors = validate_event(d)
            if errors:
                if strict:
                    raise ValueError(f"{path}:{lineno}: " + "; ".join(errors))
                continue
            yield Event.from_dict(d)


def read_jsonl_stats(path: str) -> Tuple[List[Event], Dict[str, int]]:
    """Like :func:`read_jsonl` (lenient mode), but also count what was
    skipped: ``torn_lines`` (not JSON — a crashed writer's torn tail) and
    ``invalid_lines`` (JSON but schema-invalid). The report CLI surfaces
    these so silent log loss is visible instead of silently absorbed."""

    events: List[Event] = []
    stats = {"torn_lines": 0, "invalid_lines": 0}
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError:
                stats["torn_lines"] += 1
                continue
            if validate_event(d):
                stats["invalid_lines"] += 1
                continue
            events.append(Event.from_dict(d))
    return events, stats


def validate_jsonl(path: str) -> List[str]:
    """Schema errors across a whole log file ([] = every line valid)."""

    errors: List[str] = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {lineno}: not JSON: {e}")
                continue
            errors.extend(f"line {lineno}: {e}" for e in validate_event(d))
    return errors
