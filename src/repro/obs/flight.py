"""Flight recorder: an always-on bounded ring for serving postmortems
(docs/observability.md §"Request tracing & flight recorder").

A crashed or hung serving process is exactly the run whose JSONL stream
is most likely to be missing (never configured, torn, or stalled before
the interesting part). The :class:`FlightRecorder` therefore keeps the
*recent past* in memory regardless of whether an obs pipeline is
enabled:

* a bounded ring of the most recent events (oldest evicted, evictions
  counted),
* a small ring of periodic metrics/queue snapshots, and
* a set of live **state providers** — callables the owner registers
  (the executor registers one reporting queue depth, live lanes with
  their ``trace_id``\\ s, and paged-cache stats) that are invoked at
  dump time so the bundle shows *what the system was doing*, not just
  what it said.

``dump(reason)`` freezes all of it into one ordered postmortem bundle
(a single JSON document, atomically written when an ``out_dir`` is
configured), readable offline via
``python -m repro.obs.report --postmortem bundle.json``. Three triggers
produce dumps in the serving stack:

1. **alert escalation** — :meth:`attach` registers an alert callback on
   an Obs pipeline's health monitor; any ``degraded`` alert dumps,
2. **unhandled executor exception** — ``ServeExecutor.run`` dumps
   before re-raising, and
3. **hang** — :class:`HangWatchdog` (its own daemon thread, because a
   hung tick loop by definition runs no Python) dumps when no tick
   progress was beaten within ``deadline_s``.

Dumps are throttled per reason (``min_interval_s``) so an alert storm
produces one bundle, not hundreds. All public methods are thread-safe:
the watchdog thread dumps while the tick thread appends.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

from .events import Event, make_event, validate_event

BUNDLE_VERSION = 1
BUNDLE_KIND = "postmortem"

#: dump trigger reasons used by the serving stack (open set; these are
#: the three the executor wires up)
REASON_ALERT = "alert"
REASON_EXCEPTION = "exception"
REASON_HANG = "hang"


class FlightRecorder:
    """Bounded in-memory ring of events + snapshots + state providers,
    dumpable as an ordered postmortem bundle. Usable as an event Sink
    (``write``/``flush``/``close``) so it can be teed into an Obs
    pipeline, and writable directly by instrumented code when no
    pipeline is enabled (the always-on path)."""

    def __init__(self, capacity: int = 4096, *, snapshot_capacity: int = 32,
                 out_dir: Optional[str] = None, min_interval_s: float = 5.0,
                 clock: Callable[[], float] = time.time):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.out_dir = out_dir
        self.min_interval_s = min_interval_s
        self._clock = clock
        self._ring: "deque[Event]" = deque(maxlen=capacity)
        self._snaps: "deque[Dict[str, Any]]" = deque(maxlen=snapshot_capacity)
        self._providers: Dict[str, Callable[[], Any]] = {}
        self._lock = threading.Lock()
        self._last_dump_t: Dict[str, float] = {}  # reason -> clock reading
        self._seq = 0
        self.dropped = 0
        self.dumps: List[str] = []          # paths written (out_dir set)
        self.last_bundle: Optional[Dict[str, Any]] = None

    # -- sink protocol -------------------------------------------------------

    def write(self, event: Event) -> None:
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(event)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    # -- recording -----------------------------------------------------------

    def record(self, kind: str, name: str, *, data: Optional[Dict[str, Any]] = None,
               step: Optional[int] = None) -> None:
        """Build-and-write convenience for the always-on path (no Obs
        pipeline enabled — nothing else constructs the Event)."""

        self.write(make_event(kind, name, data=data, step=step))

    def record_snapshot(self, snapshot: Dict[str, Any]) -> None:
        """Append one metrics/queue snapshot (timestamped here)."""

        with self._lock:
            self._snaps.append({"t": self._clock(), **snapshot})

    def add_state_provider(self, name: str, fn: Callable[[], Any]) -> None:
        """Register a zero-arg callable whose JSON-serializable return
        value is captured at dump time under ``state[name]``. A provider
        that raises contributes an error string instead of killing the
        dump (the dump path must never fail because the system being
        postmortemed is broken)."""

        self._providers[name] = fn

    def events(self) -> List[Event]:
        """Ring contents, oldest first."""

        with self._lock:
            return list(self._ring)

    def attach(self, obs) -> None:
        """Wire the alert-escalation trigger: any ``degraded`` alert from
        ``obs.health`` dumps a bundle."""

        if obs is None or obs.health is None:
            return

        def on_alert(alert) -> None:
            if alert.severity == "degraded":
                self.dump(REASON_ALERT,
                          detail=f"{alert.monitor}: {alert.message}")

        obs.health.add_callback(on_alert)

    # -- dumping -------------------------------------------------------------

    def dump(self, reason: str, detail: str = "",
             force: bool = False) -> Optional[Dict[str, Any]]:
        """Freeze the ring into a postmortem bundle. Returns the bundle
        dict (also kept as ``last_bundle``), or None when throttled
        (same ``reason`` within ``min_interval_s``, unless ``force``).
        When ``out_dir`` is set the bundle is also written atomically as
        ``postmortem-<reason>-<seq>.json``."""

        now = self._clock()
        with self._lock:
            last = self._last_dump_t.get(reason)
            if not force and last is not None and now - last < self.min_interval_s:
                return None
            self._last_dump_t[reason] = now
            events = list(self._ring)
            snaps = list(self._snaps)
            dropped = self.dropped
            self._seq += 1
            seq = self._seq

        state: Dict[str, Any] = {}
        for name, fn in self._providers.items():
            try:
                state[name] = fn()
            except Exception as e:  # dump must survive a broken system
                state[name] = f"<state provider failed: {e!r}>"

        bundle: Dict[str, Any] = {
            "v": BUNDLE_VERSION,
            "kind": BUNDLE_KIND,
            "trigger": {"reason": reason, "detail": detail, "t": now,
                        "seq": seq},
            "events": [e.as_dict() for e in events],
            "dropped": dropped,
            "metrics_snapshots": snaps,
            "state": state,
            "env": {"pid": os.getpid(), "unix_time": time.time()},
        }
        self.last_bundle = bundle
        if self.out_dir is not None:
            path = os.path.join(self.out_dir,
                                f"postmortem-{reason}-{seq:03d}.json")
            os.makedirs(self.out_dir, exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(bundle, f, default=str)
            os.replace(tmp, path)  # atomic: never a torn bundle
            self.dumps.append(path)
        return bundle


def validate_bundle(d: Any) -> List[str]:
    """Schema errors for one postmortem bundle ([] = valid). The CI
    obs-smoke job runs the hang-injected bundle through this via
    ``report --postmortem --validate``."""

    if not isinstance(d, dict):
        return [f"bundle must be a dict, got {type(d).__name__}"]
    errors: List[str] = []
    if d.get("v") != BUNDLE_VERSION:
        errors.append(f"bundle.v must be {BUNDLE_VERSION}, got {d.get('v')!r}")
    if d.get("kind") != BUNDLE_KIND:
        errors.append(f"bundle.kind must be {BUNDLE_KIND!r}, got {d.get('kind')!r}")
    trig = d.get("trigger")
    if not isinstance(trig, dict) or not trig.get("reason") \
            or not isinstance(trig.get("t"), (int, float)):
        errors.append("bundle.trigger must carry reason and numeric t")
    events = d.get("events")
    if not isinstance(events, list):
        errors.append("bundle.events must be a list")
    else:
        for i, ev in enumerate(events):
            for e in validate_event(ev):
                errors.append(f"events[{i}]: {e}")
    if not isinstance(d.get("metrics_snapshots"), list):
        errors.append("bundle.metrics_snapshots must be a list")
    if not isinstance(d.get("state"), dict):
        errors.append("bundle.state must be a dict")
    if not isinstance(d.get("dropped"), int):
        errors.append("bundle.dropped must be an int")
    return errors


def load_bundle(path: str) -> Dict[str, Any]:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


class HangWatchdog:
    """No-tick-progress watchdog. The owner calls :meth:`beat` whenever
    the loop makes progress; :meth:`check` fires ``on_hang(stall_s)``
    when the last beat is older than ``deadline_s``. Fires at most once
    per stall — a new beat re-arms it.

    :meth:`start` runs ``check`` on a daemon thread every ``poll_s``
    (default ``deadline_s / 4``): a loop blocked inside a device read
    runs no Python of its own, so the dump has to come from elsewhere.
    Tests drive :meth:`check` directly with an injected clock instead.
    """

    def __init__(self, deadline_s: float, on_hang: Callable[[float], None], *,
                 clock: Callable[[], float] = time.monotonic,
                 poll_s: Optional[float] = None):
        if deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        self.deadline_s = deadline_s
        self.poll_s = poll_s if poll_s is not None else deadline_s / 4.0
        self._on_hang = on_hang
        self._clock = clock
        self._last_beat = clock()
        self._fired = False
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.beats = 0
        self.fires = 0

    def beat(self) -> None:
        with self._lock:
            self._last_beat = self._clock()
            self._fired = False
            self.beats += 1

    def check(self, now: Optional[float] = None) -> bool:
        """True iff this call fired ``on_hang``."""

        now = self._clock() if now is None else now
        with self._lock:
            stall = now - self._last_beat
            if self._fired or stall <= self.deadline_s:
                return False
            self._fired = True
            self.fires += 1
        self._on_hang(stall)
        return True

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.poll_s):
                try:
                    self.check()
                except Exception:  # the watchdog must outlive a bad dump
                    pass

        self._thread = threading.Thread(target=loop, name="repro-hang-watchdog",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None


def emit_teed(obs, flight: Optional[FlightRecorder], kind: str, name: str, *,
              data: Optional[Dict[str, Any]] = None,
              step: Optional[int] = None) -> None:
    """Emit one event into an Obs pipeline AND a flight ring.

    The single shared emission helper for the serve plane: when obs is
    enabled the event it built is reused for the ring (one construction,
    two destinations); when obs is disabled but a recorder is present —
    the always-on postmortem path — the event is built only for the
    ring. With neither, nothing is constructed.
    """

    ev = obs.emit(kind, name, data=data, step=step)
    if flight is not None:
        flight.write(ev if ev is not None
                     else make_event(kind, name, data=data, step=step))
