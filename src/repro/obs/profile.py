"""Per-phase cost attribution of a compiled step (``repro.obs.profile``).

PR 7 wrapped every engine phase in an unconditional ``jax.named_scope``
(`trace.PHASES`), and those scope names survive lowering into each HLO
instruction's ``metadata={op_name="jit(step)/.../base_unroll/..."}``.
This module walks the compiled module text and charges every
instruction's cost to the *innermost* phase on its op_name path:

* **FLOPs** — ``dot`` = 2 x out-elements x contracted sizes (read off
  ``lhs_contracting_dims``), ``convolution`` = 2 x out x kernel/out-ch,
  reduce = input elements, elementwise/transcendental = output elements,
  pure data movement = 0. Instructions inside a scanned loop body are
  scaled by the loop's ``known_trip_count`` — including ops hidden in
  fusion computations called *from* the body
  (``hlo_parse.computation_multipliers(follow_calls=True)``).
* **Bytes moved** — operand + result bytes per instruction, counted at
  fusion boundaries only (traffic inside a fused computation stays
  on-chip and is not charged).
* **Collectives** — per-phase bytes/count, trip-scaled, same opcode set
  as ``hlo_parse.collective_stats``.
* **Live-buffer watermark** — a liveness walk over the scheduled entry
  computation (alloc at def, free after last use) yields each phase's
  peak live bytes. Buffer sizes are aval arithmetic over the printed
  shapes — the CPU-safe fallback of ``perf.memory``; loop internals are
  charged as their carried state.

Joining with measured per-phase wall time (``Tracer.runtime_spans()``
from ``MetaLearner.phase_profile()``) turns the static counts into
achieved FLOP/s and utilization against the roofline peak
(``roofline.analysis.PEAK_FLOPS`` by default).

The result dict is the optional ``attribution`` section of a
``PerfRecord`` (schema v1, additive — ``perf.record.validate_attribution``)
and the input of ``python -m repro.obs.diff``. CLI::

    PYTHONPATH=src python -m repro.obs.profile --smoke-arch gemma3-1b \
        --out attr.json        # attribute one smoke train step
    PYTHONPATH=src python -m repro.obs.profile --validate attr.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import re
import sys
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.roofline import hlo_parse
from repro.obs.trace import PHASES

#: phase bucket for instructions carrying no recognized phase annotation
OTHER = "other"

#: default phase vocabulary: the engine phases plus serve's fused step
DEFAULT_PHASES: Tuple[str, ...] = PHASES + ("serve_step",)

_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-zA-Z][\w\-]*)\(")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_SRC_RE = re.compile(r'source_file="([^"]*)"')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")
_DIM_LABELS_RE = re.compile(r"dim_labels=\w+_(\w+)->")
_OPERAND_REF_RE = re.compile(r"%([\w.\-]+)")

#: opcodes costing ~1 FLOP per output element (elementwise arithmetic,
#: comparisons, transcendentals — close enough for attribution)
ELEMENTWISE = frozenset({
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "sign", "compare", "select", "clamp", "exponential", "log",
    "tanh", "sqrt", "rsqrt", "power", "cosine", "sine", "logistic", "atan2",
    "remainder", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "exponential-minus-one",
    "log-plus-one", "is-finite", "cbrt", "tan", "erf",
})

#: opcodes whose result aliases existing buffers — no fresh allocation
#: in the watermark walk
NO_ALLOC = frozenset({"get-tuple-element", "tuple", "bitcast", "parameter"})


@dataclasses.dataclass
class Instr:
    """One parsed HLO instruction."""

    name: str               # result variable (no leading %)
    opcode: str
    type_text: str          # result type segment, layouts included
    operand_text: str       # inside the opcode's parens
    attr_text: str          # everything after the operand parens
    is_root: bool

    @property
    def out_bytes(self) -> int:
        return hlo_parse.shape_bytes(self.type_text)

    @property
    def operand_bytes(self) -> int:
        return hlo_parse.shape_bytes(self.operand_text)

    @property
    def op_name(self) -> str:
        m = _OPNAME_RE.search(self.attr_text)
        return m.group(1) if m else ""

    @property
    def source_file(self) -> str:
        m = _SRC_RE.search(self.attr_text)
        return m.group(1) if m else ""


def _split_type(rest: str) -> Tuple[str, str]:
    """Split ``f32[8,4]{1,0} add(...)`` (or a tuple type) into
    (type segment, remainder)."""

    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:].lstrip()
    i = rest.find(" ")
    if i < 0:
        return rest, ""
    return rest[:i], rest[i + 1:].lstrip()


def parse_instructions(lines: Iterable[str]) -> List[Instr]:
    """Parse the instructions of one computation's body lines."""

    out: List[Instr] = []
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rest = m.group(3)
        type_text, rem = _split_type(rest)
        mo = _OPCODE_RE.match(rem)
        if not mo:
            continue
        # operand segment: up to the paren matching the opcode's open
        depth, end = 0, len(rem)
        for i in range(mo.end() - 1, len(rem)):
            if rem[i] == "(":
                depth += 1
            elif rem[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        out.append(Instr(
            name=m.group(2), opcode=mo.group(1), type_text=type_text,
            operand_text=rem[mo.end():end], attr_text=rem[end:],
            is_root=bool(m.group(1)),
        ))
    return out


def _first_shape_dims(segment: str, index: int = 0) -> List[int]:
    got = hlo_parse._SHAPE_RE.findall(segment)
    dims = []
    for k, (dtype, d) in enumerate(got):
        if dtype not in hlo_parse._DTYPE_BYTES:
            continue
        dims.append([int(x) for x in d.split(",")] if d else [])
    return dims[index] if index < len(dims) else []


def _nelems(dims: Sequence[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def instr_flops(ins: Instr) -> float:
    """FLOP estimate for one instruction (see module docstring)."""

    op = ins.opcode
    if op == "dot":
        mc = _LHS_CONTRACT_RE.search(ins.attr_text)
        cdims = ([int(x) for x in mc.group(1).split(",")]
                 if mc and mc.group(1) else [])
        lhs = _first_shape_dims(ins.operand_text, 0)
        contracted = 1
        for d in cdims:
            contracted *= lhs[d] if d < len(lhs) else 1
        return 2.0 * _nelems(_first_shape_dims(ins.type_text)) * contracted
    if op == "convolution":
        out_elems = _nelems(_first_shape_dims(ins.type_text))
        rhs = _first_shape_dims(ins.operand_text, 1)
        kernel = _nelems(rhs)
        ml = _DIM_LABELS_RE.search(ins.attr_text)
        out_ch = 1
        if ml and rhs:
            o = ml.group(1).find("o")
            if 0 <= o < len(rhs):
                out_ch = max(1, rhs[o])
        return 2.0 * out_elems * kernel / out_ch
    if op in ("reduce", "reduce-window"):
        return float(_nelems(_first_shape_dims(ins.operand_text)))
    if op in ELEMENTWISE:
        return float(_nelems(_first_shape_dims(ins.type_text)))
    return 0.0


def phase_of(op_name: str, phases: Sequence[str]) -> str:
    """Innermost phase-name segment on an op_name scope path, so an op
    under ``.../local_terms/meta_pass/...`` charges to ``meta_pass``."""

    found = OTHER
    for seg in op_name.split("/"):
        if seg in phases:
            found = seg
    return found


def _module_of(source_file: str) -> Optional[str]:
    return source_file.rsplit("/", 1)[-1] if source_file else None


def _collective_opcode(op: str) -> Optional[str]:
    if op.endswith("-start"):
        op = op[: -len("-start")]
    return op if op in hlo_parse.COLLECTIVES else None


def _entry_watermark(instrs: List[Instr],
                     phases: Sequence[str]) -> Dict[str, float]:
    """Per-phase peak live bytes over the scheduled entry computation:
    alloc at def, free past the last use. Aval arithmetic on printed
    shapes; aliasing opcodes (gte/tuple/bitcast) allocate nothing."""

    size: Dict[str, int] = {}
    last_use: Dict[str, int] = {}
    for idx, ins in enumerate(instrs):
        size[ins.name] = 0 if ins.opcode in NO_ALLOC else ins.out_bytes
        for ref in _OPERAND_REF_RE.findall(ins.operand_text):
            last_use[ref] = idx
    frees: Dict[int, List[str]] = defaultdict(list)
    for ref, idx in last_use.items():
        frees[idx].append(ref)
    live = 0.0
    peaks: Dict[str, float] = {}
    for idx, ins in enumerate(instrs):
        live += size[ins.name]
        ph = phase_of(ins.op_name, phases)
        peaks[ph] = max(peaks.get(ph, 0.0), live)
        for ref in frees[idx]:
            live -= size.get(ref, 0)
        if ins.name not in last_use and not ins.is_root:
            live -= size[ins.name]  # dead result: freed immediately
    return peaks


def _wall_by_phase(spans) -> Dict[str, float]:
    """Total measured wall µs per span name (runtime spans only)."""

    out: Dict[str, float] = defaultdict(float)
    for s in spans:
        if getattr(s, "traced", False):
            continue
        dur = s.dur_us if hasattr(s, "dur_us") else float(s.get("dur_us", 0.0))
        name = s.name if hasattr(s, "name") else s.get("name")
        out[name] += dur
    return dict(out)


def attribute(compiled_or_text: Any, *, phases: Optional[Sequence[str]] = None,
              spans: Optional[Sequence[Any]] = None,
              peak_flops: Optional[float] = None,
              n_devices: int = 1) -> Dict[str, Any]:
    """Partition one compiled program's cost by engine phase.

    ``compiled_or_text`` is a ``jax.stages.Compiled`` (or anything with
    ``as_text()``) or the HLO module text itself. ``spans`` (optional)
    are measured ``Tracer`` spans — when given, each phase also carries
    ``wall_us``/``achieved_flops_per_s``/``utilization`` against
    ``peak_flops`` x ``n_devices`` (default: the roofline model's
    per-chip bf16 peak). Returns the ``attribution`` PerfRecord section.
    """

    text = (compiled_or_text if isinstance(compiled_or_text, str)
            else compiled_or_text.as_text())
    phases = tuple(phases) if phases is not None else DEFAULT_PHASES
    if peak_flops is None:
        from repro.roofline.analysis import PEAK_FLOPS
        peak_flops = PEAK_FLOPS

    comps = hlo_parse.split_computations(text)
    mult = hlo_parse.computation_multipliers(comps, follow_calls=True)

    entry_name = None
    for name, lines in comps.items():
        if name != "__entry__" and comps.get("__entry__") is lines:
            entry_name = name
            break

    zero = lambda: {"flops": 0.0, "bytes": 0.0,
                    "collective_bytes": 0.0, "collective_count": 0.0}
    per_phase: Dict[str, Dict[str, float]] = defaultdict(zero)
    per_module: Dict[str, float] = defaultdict(float)

    for cname, lines in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 1.0)
        if m == 0.0:
            continue  # unreachable computation
        in_fusion = cname.startswith("fused_computation")
        for ins in parse_instructions(lines):
            ph = phase_of(ins.op_name, phases)
            bucket = per_phase[ph]
            flops = instr_flops(ins) * m
            bucket["flops"] += flops
            if not in_fusion:
                bucket["bytes"] += (ins.out_bytes + ins.operand_bytes) * m
            coll = _collective_opcode(ins.opcode)
            if coll is not None and not ins.opcode.endswith("-done"):
                bucket["collective_bytes"] += ins.out_bytes * m
                bucket["collective_count"] += m
            if flops:
                mod = _module_of(ins.source_file)
                if mod:
                    per_module[mod] += flops

    total = {k: sum(b[k] for b in per_phase.values())
             for k in ("flops", "bytes", "collective_bytes", "collective_count")}
    total_flops = total["flops"]
    for b in per_phase.values():
        b["flop_frac"] = b["flops"] / total_flops if total_flops else 0.0
    coverage = (1.0 - per_phase[OTHER]["flops"] / total_flops
                if total_flops and OTHER in per_phase else
                (1.0 if total_flops else 0.0))

    if entry_name is not None:
        peaks = _entry_watermark(parse_instructions(comps[entry_name]), phases)
        for ph, peak in peaks.items():
            per_phase[ph]["peak_live_bytes"] = peak

    wall_source = None
    if spans is not None:
        wall = _wall_by_phase(spans)
        wall_source = "tracer_runtime_spans"
        device_peak = peak_flops * max(1, n_devices)
        for ph, b in per_phase.items():
            us = wall.get(ph)
            if us is None or us <= 0:
                continue
            b["wall_us"] = us
            b["achieved_flops_per_s"] = b["flops"] / (us * 1e-6)
            b["utilization"] = b["achieved_flops_per_s"] / device_peak

    modules = {}
    for mod, fl in sorted(per_module.items(), key=lambda kv: -kv[1]):
        modules[mod] = {"flops": fl,
                        "flop_frac": fl / total_flops if total_flops else 0.0}
    top_module = next(iter(modules), None)

    return {
        "phases": {ph: dict(b) for ph, b in sorted(
            per_phase.items(), key=lambda kv: -kv[1]["flops"])},
        "total": total,
        "coverage": coverage,
        "modules": modules,
        "top_module": top_module,
        "wall_source": wall_source,
        "memory_source": "hlo_entry_walk",
        "peak_flops": peak_flops,
        "n_devices": int(n_devices),
    }


def render(attr: Dict[str, Any]) -> str:
    """Human-readable attribution table."""

    lines: List[str] = []
    add = lines.append
    add("== cost attribution ==")
    add(f"coverage: {attr['coverage']:.1%} of "
        f"{attr['total']['flops']:.3e} FLOPs attributed to a phase")
    add(f"{'phase':<16} {'flops':>12} {'frac':>7} {'bytes':>12} "
        f"{'coll':>5} {'peak_live':>12} {'wall':>10} {'util':>8}")
    for ph, b in attr["phases"].items():
        wall = f"{b['wall_us'] / 1e3:.1f}ms" if b.get("wall_us") else "-"
        util = f"{b['utilization']:.2e}" if b.get("utilization") else "-"
        peak = (f"{b['peak_live_bytes'] / 2**20:.1f}MB"
                if b.get("peak_live_bytes") else "-")
        add(f"{ph:<16} {b['flops']:>12.3e} {b['flop_frac']:>7.3f} "
            f"{b['bytes']:>12.3e} {b['collective_count']:>5.0f} "
            f"{peak:>12} {wall:>10} {util:>8}")
    if attr.get("modules"):
        add("")
        add(f"{'module':<28} {'flops':>12} {'frac':>7}")
        for mod, b in list(attr["modules"].items())[:10]:
            add(f"{mod:<28} {b['flops']:>12.3e} {b['flop_frac']:>7.3f}")
        add(f"top FLOP sink: {attr['top_module']}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI: smoke-probe one arch / validate attribution sections in a JSON
# ---------------------------------------------------------------------------


def _smoke_attribution(arch: str, *, unroll: int = 2, batch: int = 4,
                       seq: int = 32) -> Dict[str, Any]:
    """Compile one smoke-config SAMA step for ``arch`` and attribute it.
    Pure compile — nothing executes, so even the MoE configs stay fast."""

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs, data, optim
    from repro.core import EngineConfig, init_state, make_meta_step, problems
    from repro.models import Model

    cfg = configs.get_smoke_config(arch)
    model = Model(cfg)
    spec = problems.make_data_optimization_spec(
        model.classifier_per_example if cfg.family == "encoder"
        else model.per_example, reweight=True)
    theta = model.init(jax.random.PRNGKey(0))
    lam = problems.init_data_optimization_lam(jax.random.PRNGKey(1),
                                              reweight=True)
    base_opt, meta_opt = optim.adam(1e-3), optim.adam(1e-3)
    ecfg = EngineConfig(method="sama", unroll_steps=unroll)
    state = init_state(theta, lam, base_opt, meta_opt, scale=ecfg.scale)
    step = make_meta_step(spec, base_opt, meta_opt, ecfg)

    lm = data.LMStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq)
    rng = np.random.default_rng(0)

    def batch_of(b, k=None):
        raw = data.lm_batch(lm, rng, b * (k or 1))
        toks = raw["tokens"].reshape((k, b, seq) if k else (b, seq))
        out = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            shp = ((k, b) if k else (b,)) + (cfg.vision_tokens, cfg.vision_dim)
            out["patches"] = jnp.zeros(shp, jnp.float32)
        if cfg.family == "audio":
            shp = ((k, b) if k else (b,)) + (cfg.encoder_seq, cfg.d_model)
            out["frames"] = jnp.zeros(shp, jnp.float32)
        if cfg.family == "encoder":
            yshape = (k, b) if k else (b,)
            out["y"] = jnp.asarray(rng.integers(0, cfg.num_labels, size=yshape),
                                   jnp.int32)
        return out

    compiled = jax.jit(step).lower(state, batch_of(batch, unroll),
                                   batch_of(max(batch // 2, 1))).compile()
    attr = attribute(compiled)
    attr_extra = {"arch": cfg.name, "unroll": unroll, "batch": batch, "seq": seq}
    return {"attribution": attr, "extra": attr_extra}


def _validate_file(path: str) -> List[str]:
    """Validate every attribution section found in ``path`` (a BENCH
    payload, a PerfRecord dict, or a bare attribution dict)."""

    from repro.perf.record import validate_attribution

    with open(path) as f:
        payload = json.load(f)
    found = []
    if "records" in payload:  # BENCH file
        found = [(r.get("name", "?"), r["attribution"])
                 for r in payload["records"] if r.get("attribution")]
    elif "attribution" in payload:
        found = [(payload.get("name", "record"), payload["attribution"])]
    elif "phases" in payload:
        found = [("attribution", payload)]
    if not found:
        return [f"{path}: no attribution section found"]
    errors: List[str] = []
    for name, attr in found:
        errors.extend(f"{path}:{name}: {e}" for e in validate_attribution(attr))
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.profile",
        description="Attribute a compiled step's cost to engine phases.")
    ap.add_argument("--smoke-arch", default=None, metavar="ARCH",
                    help="compile one smoke SAMA step for ARCH and print "
                         "its attribution table")
    ap.add_argument("--unroll", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write the attribution (with a validated "
                         "'attribution' key) as JSON")
    ap.add_argument("--validate", default=None, metavar="PATH",
                    help="validate attribution sections in a record/BENCH "
                         "JSON and exit")
    args = ap.parse_args(argv)

    if args.validate:
        errors = _validate_file(args.validate)
        for e in errors:
            print(e, file=sys.stderr)
        print(f"{args.validate}: attribution "
              + ("INVALID" if errors else "valid"))
        return 1 if errors else 0

    if not args.smoke_arch:
        ap.error("one of --smoke-arch or --validate is required")
    probe = _smoke_attribution(args.smoke_arch, unroll=args.unroll,
                               batch=args.batch)
    print(render(probe["attribution"]))
    if args.out:
        from repro.perf.record import validate_attribution
        errors = validate_attribution(probe["attribution"])
        if errors:
            for e in errors:
                print(e, file=sys.stderr)
            return 1
        with open(args.out, "w") as f:
            json.dump(probe, f, indent=1)
        print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
