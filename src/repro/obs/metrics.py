"""Host-side metric registry: counters, gauges, histograms.

Design constraint (ISSUE 7 / docs/observability.md): the hot training
loop must not gain host↔device synchronization points. Metrics here are
therefore **host values**. Device scalars enter the registry only at
boundaries where the loop already blocks — `run_loop`'s ``log_every``
cadence, `ServeExecutor`'s per-tick harvest — and they arrive through
:func:`packed_read`, which pulls an arbitrary pytree of device scalars
in ONE `jax.device_get` transfer instead of one sync per key (the
`float(v)` per-key pattern this replaces issued a blocking D2H copy per
metric).

Instrument types:

* :class:`Counter`   — monotone ``inc(n)``; totals per label.
* :class:`Gauge`     — ``set(v)`` last-write-wins; also tracks min/max.
* :class:`Histogram` — fixed log-spaced or explicit bucket boundaries,
  O(1) memory, ``observe(v)``; percentile estimates from bucket CDF
  (exact for the common serve-latency use because boundaries are dense
  where the SLO lives).

All instruments accept a ``labels`` tuple so one name can fan out —
``dispatch_total{kernel=adam_adapt,backend=pallas-tpu,reason=selected}``.
Label values are stringified; the registry is a plain dict guarded by a
lock (serving harvests from an executor thread while train code reads).
"""

from __future__ import annotations

import math
import threading
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

LabelPairs = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Mapping[str, Any]]) -> LabelPairs:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter with optional labels."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[LabelPairs, float] = {}
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0, labels: Optional[Mapping[str, Any]] = None) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + n

    def value(self, labels: Optional[Mapping[str, Any]] = None) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def total(self) -> float:
        return sum(self._values.values())

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name,
                "values": [{"labels": dict(k), "value": v}
                           for k, v in sorted(self._values.items())]}


class Gauge:
    """Last-write-wins scalar; remembers the min/max ever set so a
    snapshot shows excursions the final value hides (queue depth spikes)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[LabelPairs, Tuple[float, float, float]] = {}  # (last, min, max)
        self._lock = threading.Lock()

    def set(self, v: float, labels: Optional[Mapping[str, Any]] = None) -> None:
        v = float(v)
        key = _label_key(labels)
        with self._lock:
            prev = self._values.get(key)
            if prev is None:
                self._values[key] = (v, v, v)
            else:
                self._values[key] = (v, min(prev[1], v), max(prev[2], v))

    def value(self, labels: Optional[Mapping[str, Any]] = None) -> Optional[float]:
        got = self._values.get(_label_key(labels))
        return None if got is None else got[0]

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name,
                "values": [{"labels": dict(k), "value": v, "min": lo, "max": hi}
                           for k, (v, lo, hi) in sorted(self._values.items())]}


def log_buckets(lo: float, hi: float, per_decade: int = 4) -> Tuple[float, ...]:
    """Log-spaced bucket upper bounds covering [lo, hi]."""

    if not (lo > 0 and hi > lo):
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    n = max(2, int(math.ceil(per_decade * math.log10(hi / lo))) + 1)
    ratio = (hi / lo) ** (1.0 / (n - 1))
    return tuple(lo * ratio ** i for i in range(n))


class Histogram:
    """Fixed-bucket histogram (upper-bound boundaries + overflow).

    Default boundaries are log-spaced 100µs..30s — right for the latency
    distributions the serve plane feeds it. ``quantile`` interpolates
    within the containing bucket, which is the usual Prometheus-style
    estimate: exact bucket membership, linear within.
    """

    kind = "histogram"

    DEFAULT_BOUNDS = log_buckets(100.0, 30_000_000.0, per_decade=4)  # µs

    def __init__(self, name: str, help: str = "",
                 bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help
        bounds = tuple(bounds) if bounds is not None else self.DEFAULT_BOUNDS
        if list(bounds) != sorted(bounds) or len(bounds) < 1:
            raise ValueError(f"histogram bounds must be sorted & non-empty: {bounds}")
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # +1 overflow
        self._sum = 0.0
        self._n = 0
        self._max = float("-inf")
        self._min = float("inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        # binary search for first bound >= v
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            self._counts[lo] += 1
            self._sum += v
            self._n += 1
            self._max = max(self._max, v)
            self._min = min(self._min, v)

    @property
    def n(self) -> int:
        return self._n

    def mean(self) -> float:
        return self._sum / self._n if self._n else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-CDF quantile estimate; 0.0 when empty."""

        if not (0.0 <= q <= 1.0):
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self._n == 0:
            return 0.0
        rank = q * self._n
        seen = 0
        for i, c in enumerate(self._counts):
            if seen + c >= rank and c > 0:
                if i >= len(self.bounds):           # overflow bucket
                    return self._max
                lower = self.bounds[i - 1] if i > 0 else 0.0
                upper = self.bounds[i]
                frac = (rank - seen) / c
                # clamp into the observed range: a single sample (or a
                # value landing exactly on a bucket bound) must not
                # report a quantile below the smallest / above the
                # largest value actually seen
                return min(max(lower + frac * (upper - lower), self._min),
                           self._max)
            seen += c
        return self._max if self._max != float("-inf") else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name, "n": self._n,
                "sum": self._sum, "mean": self.mean(),
                "min": self._min if self._n else 0.0,
                "max": self._max if self._n else 0.0,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99),
                "bounds": list(self.bounds), "counts": list(self._counts)}


class MetricsRegistry:
    """Name → instrument. ``counter/gauge/histogram`` are get-or-create
    (idempotent across re-wiring), so subsystems can grab the same
    instrument without coordinating construction order."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help: str, **kwargs):
        with self._lock:
            got = self._metrics.get(name)
            if got is None:
                got = cls(name, help, **kwargs)
                self._metrics[name] = got
            elif not isinstance(got, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {got.kind}, "
                    f"requested {cls.kind}")
            return got

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        if bounds is not None:
            return self._get_or_create(Histogram, name, help, bounds=bounds)
        return self._get_or_create(Histogram, name, help)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready dump of every instrument (the ``metrics``-kind
        ``registry_snapshot`` event at run end)."""

        return {name: m.snapshot() for name, m in sorted(self._metrics.items())}


def packed_read(tree: Any) -> Any:
    """Fetch a pytree of device scalars in one host transfer.

    `jax.device_get` walks the whole tree and issues a single batched
    D2H copy, so reading N step metrics costs one sync — the loop
    already blocked on this step's results at the log boundary, so the
    marginal cost is the copy of a handful of scalars. Returns plain
    Python floats/ints (0-d arrays unwrapped via ``.item()``).
    """

    import jax

    fetched = jax.device_get(tree)

    def _scalar(x):
        try:
            return x.item()
        except (AttributeError, ValueError):
            return x

    return jax.tree_util.tree_map(_scalar, fetched)
