"""``python -m repro.obs.report run.jsonl`` — render a run summary.

Reads an event log written by a JsonlSink (``--obs-log`` on the launch
CLIs), replays the health monitors over it, and prints:

* the run header (who/when/how many events of each kind),
* per-phase span times (runtime spans preferred; trace-time spans —
  phases captured while jit was tracing — reported separately),
* the metric trajectory (first/last step scalars),
* loss-scale and skip history (every backoff/growth + gated update),
* serving SLO numbers when serve events are present,
* kernel dispatch decisions keyed by (kernel, backend, reason),
* health verdicts per monitor plus the fired alerts.

``--json`` emits the same summary machine-readable; ``--validate``
exits non-zero if any line fails schema validation (the CI obs-smoke
job runs this over its artifact).
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import Counter as TallyCounter
from typing import Any, Dict, List, Optional

from .events import Event, read_jsonl_stats, validate_jsonl
from .health import replay
from .trace import PHASES

#: serve-plane terminal event names (the executor's TERMINAL_EVENT
#: values) — every trace ends in exactly one of these
TERMINAL_NAMES = ("done", "deadline_miss", "shed", "rejected", "error")

#: non-terminal lifecycle stages in canonical order
STAGE_NAMES = ("enqueued", "admitted", "prefill_start", "first_token", "token")


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.0f}us"


def _pct_dict(samples: List[float]) -> Optional[Dict[str, float]]:
    """{p50,p90,p99,n} over raw samples (sorted-index percentiles, same
    convention as the tick percentiles below); None when empty."""

    if not samples:
        return None
    s = sorted(samples)

    def at(q: float) -> float:
        return s[min(len(s) - 1, int(q * len(s)))]

    return {"p50": at(0.50), "p90": at(0.90), "p99": at(0.99),
            "n": len(s)}


# ---------------------------------------------------------------------------
# request timeline reconstruction (the tentpole's offline consumer)
# ---------------------------------------------------------------------------


def serve_timelines(events: List[Event]) -> Dict[str, List[Event]]:
    """Group the serve-plane lifecycle events by ``trace_id``, preserving
    stream order — one entry per request that ever touched the queue."""

    out: Dict[str, List[Event]] = {}
    for e in events:
        if e.kind != "serve":
            continue
        tid = e.data.get("trace_id")
        if tid is None:
            continue
        out.setdefault(tid, []).append(e)
    return out


def validate_timelines(events: List[Event]) -> List[str]:
    """End-to-end timeline checks over a serve event stream ([] = every
    request's lifecycle reconstructs). Per trace_id:

    * the first event is ``enqueued`` (minted at queue.submit);
    * timestamps are monotone non-decreasing;
    * exactly ONE terminal event, and it is the last event;
    * the stages present appear in canonical order (first occurrences;
      repeats are allowed — a stalled admission retries its
      admitted/prefill_start pair).

    A score-API trace (enqueued → done, no decode stages) passes: order
    is only enforced over the stages that are present.
    """

    errors: List[str] = []
    for tid, evs in serve_timelines(events).items():
        names = [e.name for e in evs]
        short = tid[:8]
        if names[0] != "enqueued":
            errors.append(f"{short}: first event is {names[0]!r}, "
                          "expected 'enqueued'")
        ts = [e.t for e in evs]
        if any(b < a for a, b in zip(ts, ts[1:])):
            errors.append(f"{short}: timestamps not monotone")
        terminals = [n for n in names if n in TERMINAL_NAMES]
        if len(terminals) != 1:
            errors.append(f"{short}: {len(terminals)} terminal events "
                          f"({terminals}), expected exactly 1")
        elif names[-1] not in TERMINAL_NAMES:
            errors.append(f"{short}: last event is {names[-1]!r}, "
                          "expected the terminal")
        firsts = {n: names.index(n) for n in STAGE_NAMES if n in names}
        order = [firsts[n] for n in STAGE_NAMES if n in firsts]
        if order != sorted(order):
            errors.append(f"{short}: stages out of order: "
                          + " -> ".join(n for n in names))
    return errors


def _span_table(events: List[Event], traced: bool) -> List[Dict[str, Any]]:
    agg: Dict[str, Dict[str, Any]] = {}
    order: List[str] = []
    for e in events:
        if e.kind != "span" or bool(e.data.get("traced")) != traced:
            continue
        name = e.name
        if name not in agg:
            order.append(name)
            agg[name] = {"name": name, "n": 0, "total_us": 0.0, "max_us": 0.0}
        a = agg[name]
        dur = float(e.data.get("dur_us", 0.0))
        a["n"] += 1
        a["total_us"] += dur
        a["max_us"] = max(a["max_us"], dur)
    for a in agg.values():
        a["mean_us"] = a["total_us"] / a["n"]

    def _rank(name: str):
        try:
            return (0, PHASES.index(name))
        except ValueError:
            return (1, order.index(name))

    return [agg[n] for n in sorted(agg, key=_rank)]


def summarize(events: List[Event],
              io: Optional[Dict[str, int]] = None) -> Dict[str, Any]:
    """Machine-readable run summary (the ``--json`` payload). ``io``
    (the stats of ``read_jsonl_stats``) surfaces skipped log lines."""

    kinds = TallyCounter(e.kind for e in events)
    t = [e.t for e in events]
    summary: Dict[str, Any] = {
        "events": len(events),
        "kinds": dict(sorted(kinds.items())),
        "t_start": min(t) if t else None,
        "t_end": max(t) if t else None,
    }

    run_meta = [e for e in events if e.kind == "run"]
    if run_meta:
        summary["run"] = {e.name: e.data for e in run_meta}

    # log-integrity accounting: torn/invalid lines skipped at read time
    # plus ring-buffer evictions the producer reported at run_end
    run_end = next((e for e in reversed(events)
                    if e.kind == "run" and e.name == "run_end"), None)
    summary["io"] = {
        "torn_lines": int((io or {}).get("torn_lines", 0)),
        "invalid_lines": int((io or {}).get("invalid_lines", 0)),
        "ring_dropped": int(run_end.data.get("ring_dropped", 0))
        if run_end is not None else 0,
    }

    summary["phases"] = _span_table(events, traced=False)
    summary["phases_trace_time"] = _span_table(events, traced=True)

    steps = [e for e in events if e.kind == "metrics" and e.name == "step"]
    if steps:
        summary["steps"] = {
            "n": len(steps),
            "first": {"step": steps[0].step, **steps[0].data},
            "last": {"step": steps[-1].step, **steps[-1].data},
        }

    scale_events = [e for e in events if e.kind == "scale"]
    gate_events = [e for e in events
                   if e.kind == "gate" and not e.data.get("finite", True)]
    summary["scale_history"] = [
        {"step": e.step, "event": e.name, "scale": e.data.get("scale"),
         "prev": e.data.get("prev")} for e in scale_events]
    summary["skip_history"] = [
        {"step": e.step, "gate": e.name, "reason": e.data.get("reason")}
        for e in gate_events]

    serve_events = [e for e in events if e.kind == "serve"]
    serve_term = TallyCounter(
        e.name for e in serve_events
        if e.name in ("done", "deadline_miss", "shed"))
    ticks = [e for e in serve_events if e.name == "tick"]
    if serve_term or ticks:
        tick_us = sorted(float(e.data["dur_us"]) for e in ticks
                         if "dur_us" in e.data)

        def _pct(q: float) -> Optional[float]:
            if not tick_us:
                return None
            i = min(len(tick_us) - 1, int(q * len(tick_us)))
            return tick_us[i]

        terminals = [e for e in serve_events if e.name in TERMINAL_NAMES]
        summary["serve"] = {
            "terminal": dict(sorted(serve_term.items())),
            "ticks": len(ticks),
            "tick_p50_us": _pct(0.50),
            "tick_p99_us": _pct(0.99),
            "max_queue_depth": max(
                (e.data.get("queue_depth", 0) for e in ticks), default=0),
            # request-latency splits derived from the terminal events'
            # embedded metrics (no cross-event joins needed offline)
            "ttft_us": _pct_dict([float(e.data["ttft_us"])
                                  for e in terminals if "ttft_us" in e.data]),
            "tpot_us": _pct_dict([float(e.data["tpot_us"])
                                  for e in terminals if "tpot_us" in e.data]),
            "queue_wait_us": _pct_dict(
                [float(e.data["queue_wait_us"])
                 for e in terminals if "queue_wait_us" in e.data]),
            "resident_us": _pct_dict(
                [float(e.data["resident_us"])
                 for e in terminals if "resident_us" in e.data]),
        }
        lane_ev = next((e for e in reversed(serve_events)
                        if e.name == "lane_stats"), None)
        if lane_ev is not None:
            summary["serve"]["lanes"] = lane_ev.data.get("lanes")
        timelines = serve_timelines(events)
        if timelines:
            summary["serve"]["traces"] = len(timelines)
            summary["serve"]["trace_errors"] = validate_timelines(events)

    dispatch = TallyCounter(
        (e.data.get("kernel", e.name), e.data.get("backend", "?"),
         e.data.get("reason", "?"))
        for e in events if e.kind == "dispatch")
    if dispatch:
        summary["dispatch"] = [
            {"kernel": k, "backend": b, "reason": r, "n": n}
            for (k, b, r), n in sorted(dispatch.items())]

    census = [e for e in events if e.kind == "census"]
    if census:
        last = census[-1]
        summary["census"] = {"observed": last.data.get("observed"),
                             "expected": last.data.get("expected"),
                             "ok": last.data.get("ok")}

    health = replay(events)
    summary["health"] = health.summary()
    # replaying re-derives alerts; drop the duplicate alert events' echo
    summary["health"]["alerts"] = [a.as_dict() for a in health.alerts]
    return summary


def render(summary: Dict[str, Any]) -> str:
    """Human-readable rendering of :func:`summarize`'s output."""

    lines: List[str] = []
    add = lines.append

    add("== repro.obs run report ==")
    dur = None
    if summary.get("t_start") is not None and summary.get("t_end") is not None:
        dur = summary["t_end"] - summary["t_start"]
    add(f"events: {summary['events']}"
        + (f"  wall: {dur:.1f}s" if dur is not None else ""))
    add("kinds:  " + ", ".join(f"{k}={n}" for k, n in summary["kinds"].items()))
    io = summary.get("io") or {}
    if any(io.values()):
        add("io:     " + ", ".join(f"{k}={v}" for k, v in io.items())
            + "  (log loss — lines skipped or ring-evicted)")

    for key, title in (("phases", "phase spans (runtime)"),
                       ("phases_trace_time", "phase spans (jit trace time)")):
        rows = summary.get(key) or []
        if not rows:
            continue
        add("")
        add(f"-- {title} --")
        add(f"{'phase':<18} {'n':>5} {'mean':>10} {'max':>10} {'total':>10}")
        for r in rows:
            add(f"{r['name']:<18} {r['n']:>5} {_fmt_us(r['mean_us']):>10} "
                f"{_fmt_us(r['max_us']):>10} {_fmt_us(r['total_us']):>10}")

    steps = summary.get("steps")
    if steps:
        add("")
        add(f"-- metrics ({steps['n']} logged steps) --")
        for label in ("first", "last"):
            row = steps[label]
            scalars = ", ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in row.items() if k != "step")
            add(f"{label:<6} step {row.get('step')}: {scalars}")

    scale_hist = summary.get("scale_history") or []
    skip_hist = summary.get("skip_history") or []
    if scale_hist or skip_hist:
        add("")
        add("-- loss-scale / skip history --")
        for r in scale_hist:
            add(f"step {r['step']}: loss scale {r['event']} "
                f"{r['prev']} -> {r['scale']}")
        for r in skip_hist:
            add(f"step {r['step']}: {r['gate']} skipped ({r['reason']})")
        if not scale_hist:
            add("(no loss-scale transitions)")
    elif any(k in summary["kinds"] for k in ("metrics",)):
        add("")
        add("-- loss-scale / skip history --")
        add("(no transitions, no skips)")

    serve = summary.get("serve")
    if serve:
        add("")
        add("-- serve --")
        term = ", ".join(f"{k}={n}" for k, n in serve["terminal"].items()) or "none"
        add(f"requests: {term}")
        if serve["ticks"]:
            p50 = serve.get("tick_p50_us")
            p99 = serve.get("tick_p99_us")
            add(f"ticks: {serve['ticks']}  tick p50 "
                f"{_fmt_us(p50) if p50 is not None else '-'}  p99 "
                f"{_fmt_us(p99) if p99 is not None else '-'}  "
                f"max queue depth {serve['max_queue_depth']}")
        lat_rows = [(label, serve.get(key))
                    for label, key in (("ttft", "ttft_us"),
                                       ("tpot", "tpot_us"),
                                       ("queue wait", "queue_wait_us"),
                                       ("resident", "resident_us"))
                    if serve.get(key)]
        if lat_rows:
            add(f"{'latency':<12} {'n':>5} {'p50':>10} {'p90':>10} {'p99':>10}")
            for label, d in lat_rows:
                add(f"{label:<12} {d['n']:>5} {_fmt_us(d['p50']):>10} "
                    f"{_fmt_us(d['p90']):>10} {_fmt_us(d['p99']):>10}")
        lanes = serve.get("lanes")
        if lanes:
            add(f"{'lane':<6} {'useful':>8} {'trash':>8} {'tokens':>8} "
                f"{'goodput':>9}")
            for r in lanes:
                gp = f"{r['goodput']:.0%}" if r.get("goodput") is not None else "-"
                add(f"{r['slot']:<6} {r['useful_ticks']:>8} "
                    f"{r['trash_ticks']:>8} {r['tokens']:>8} {gp:>9}")
        if serve.get("traces"):
            errs = serve.get("trace_errors") or []
            mark = "OK" if not errs else f"{len(errs)} BROKEN"
            add(f"traces: {serve['traces']} request timelines ({mark})")
            for msg in errs[:10]:
                add(f"  broken timeline: {msg}")

    dispatch = summary.get("dispatch")
    if dispatch:
        add("")
        add("-- kernel dispatch --")
        for r in dispatch:
            add(f"{r['kernel']:<24} {r['backend']:<18} {r['reason']:<24} "
                f"x{r['n']}")

    census = summary.get("census")
    if census:
        add("")
        add("-- collective census --")
        mark = "OK" if census.get("ok") else "MISMATCH"
        add(f"all-reduces: {census.get('observed')} "
            f"(expected {census.get('expected')}) {mark}")

    health = summary["health"]
    add("")
    add(f"-- health: {health['status'].upper()} --")
    for name, v in health["monitors"].items():
        add(f"{name:<12} {v['status']:<9} {v.get('detail', '')}")
    alerts = health.get("alerts") or []
    if alerts:
        add("")
        add(f"alerts ({len(alerts)}):")
        for a in alerts:
            step = f" step {a['step']}" if a.get("step") is not None else ""
            add(f"  [{a['severity']}] {a['monitor']}{step}: {a['message']}")
    return "\n".join(lines)


def render_postmortem(bundle: Dict[str, Any], tail: int = 25) -> str:
    """Human-readable rendering of a flight-recorder postmortem bundle
    (``repro.obs.flight.FlightRecorder.dump``)."""

    lines: List[str] = []
    add = lines.append
    trig = bundle.get("trigger") or {}
    add("== repro.obs postmortem ==")
    add(f"trigger: {trig.get('reason', '?')}  {trig.get('detail', '')}".rstrip())
    events = [Event.from_dict(d) for d in bundle.get("events", [])]
    add(f"events: {len(events)} in ring"
        + (f"  (+{bundle.get('dropped', 0)} evicted)"
           if bundle.get("dropped") else ""))

    if events:
        t_end = events[-1].t
        add("")
        add(f"-- last {min(tail, len(events))} events (t relative to "
            "trigger) --")
        for e in events[-tail:]:
            tid = e.data.get("trace_id")
            label = f"  trace={tid[:8]}" if isinstance(tid, str) else ""
            add(f"{e.t - t_end:+9.3f}s  {e.kind}/{e.name}{label}")
        errs = validate_timelines(events)
        open_traces = sum(
            1 for evs in serve_timelines(events).values()
            if not any(ev.name in TERMINAL_NAMES for ev in evs))
        add("")
        add(f"traces in ring: {len(serve_timelines(events))} "
            f"({open_traces} still open — the likely hang suspects)")
        # a ring is a window: truncated head timelines are expected, so
        # timeline errors here are context, not verdicts
        for msg in errs[:5]:
            add(f"  note: {msg}")

    snaps = bundle.get("metrics_snapshots") or []
    if snaps:
        add("")
        add(f"-- metric snapshots ({len(snaps)}) --")
        for s in snaps[-5:]:
            kv = ", ".join(f"{k}={v}" for k, v in s.items() if k != "t")
            add(f"t={s.get('t', 0):.3f}: {kv}")

    state = bundle.get("state") or {}
    if state:
        add("")
        add("-- live state at dump --")
        for name, v in state.items():
            add(f"{name}: {json.dumps(v, default=str)}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs JSONL event log.")
    parser.add_argument("log", help="path to the JSONL event log (or a "
                                    "postmortem bundle with --postmortem)")
    parser.add_argument("--json", action="store_true",
                        help="emit the machine-readable summary instead")
    parser.add_argument("--validate", action="store_true",
                        help="fail (exit 1) if any line violates the schema")
    parser.add_argument("--diff", default=None, metavar="BASELINE",
                        help="also print a per-phase cost diff against a "
                             "baseline log/record (repro.obs.diff)")
    parser.add_argument("--postmortem", action="store_true",
                        help="treat LOG as a flight-recorder postmortem "
                             "bundle (repro.obs.flight) instead of a JSONL "
                             "stream")
    args = parser.parse_args(argv)

    if args.postmortem:
        from . import flight as flight_mod
        try:
            bundle = flight_mod.load_bundle(args.log)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{args.log}: cannot read bundle: {e}", file=sys.stderr)
            return 1
        if args.validate:
            errors = flight_mod.validate_bundle(bundle)
            if errors:
                for e in errors:
                    print(f"{args.log}: {e}", file=sys.stderr)
                return 1
        if args.json:
            print(json.dumps(bundle, indent=2, default=str))
        else:
            print(render_postmortem(bundle))
        return 0

    if args.validate:
        errors = validate_jsonl(args.log)
        if errors:
            for e in errors:
                print(f"{args.log}: {e}", file=sys.stderr)
            return 1

    events, io = read_jsonl_stats(args.log)
    if not events:
        print(f"{args.log}: no valid events", file=sys.stderr)
        return 1
    summary = summarize(events, io=io)
    if args.diff:
        from . import diff as diff_mod
        rows, unit = diff_mod.diff_paths(args.diff, args.log)
        summary["diff"] = {"baseline": args.diff, "unit": unit,
                           "phases": [r.as_dict() for r in rows]}
    if args.json:
        print(json.dumps(summary, indent=2, default=str))
    else:
        print(render(summary))
        if args.diff:
            print()
            print(diff_mod.render_diff(rows, summary["diff"]["unit"]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
