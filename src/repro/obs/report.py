"""``python -m repro.obs.report run.jsonl`` — render a run summary.

Reads an event log written by a JsonlSink (``--obs-log`` on the launch
CLIs), replays the health monitors over it, and prints:

* the run header (who/when/how many events of each kind),
* per-phase span times (runtime spans preferred; trace-time spans —
  phases captured while jit was tracing — reported separately),
* the metric trajectory (first/last step scalars),
* loss-scale and skip history (every backoff/growth + gated update),
* serving SLO numbers when serve events are present,
* kernel dispatch decisions keyed by (kernel, backend, reason),
* health verdicts per monitor plus the fired alerts.

``--json`` emits the same summary machine-readable; ``--validate``
exits non-zero if any line fails schema validation (the CI obs-smoke
job runs this over its artifact).
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import Counter as TallyCounter
from typing import Any, Dict, List, Optional

from .events import Event, read_jsonl_stats, validate_jsonl
from .health import replay
from .trace import PHASES


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.0f}us"


def _span_table(events: List[Event], traced: bool) -> List[Dict[str, Any]]:
    agg: Dict[str, Dict[str, Any]] = {}
    order: List[str] = []
    for e in events:
        if e.kind != "span" or bool(e.data.get("traced")) != traced:
            continue
        name = e.name
        if name not in agg:
            order.append(name)
            agg[name] = {"name": name, "n": 0, "total_us": 0.0, "max_us": 0.0}
        a = agg[name]
        dur = float(e.data.get("dur_us", 0.0))
        a["n"] += 1
        a["total_us"] += dur
        a["max_us"] = max(a["max_us"], dur)
    for a in agg.values():
        a["mean_us"] = a["total_us"] / a["n"]

    def _rank(name: str):
        try:
            return (0, PHASES.index(name))
        except ValueError:
            return (1, order.index(name))

    return [agg[n] for n in sorted(agg, key=_rank)]


def summarize(events: List[Event],
              io: Optional[Dict[str, int]] = None) -> Dict[str, Any]:
    """Machine-readable run summary (the ``--json`` payload). ``io``
    (the stats of ``read_jsonl_stats``) surfaces skipped log lines."""

    kinds = TallyCounter(e.kind for e in events)
    t = [e.t for e in events]
    summary: Dict[str, Any] = {
        "events": len(events),
        "kinds": dict(sorted(kinds.items())),
        "t_start": min(t) if t else None,
        "t_end": max(t) if t else None,
    }

    run_meta = [e for e in events if e.kind == "run"]
    if run_meta:
        summary["run"] = {e.name: e.data for e in run_meta}

    # log-integrity accounting: torn/invalid lines skipped at read time
    # plus ring-buffer evictions the producer reported at run_end
    run_end = next((e for e in reversed(events)
                    if e.kind == "run" and e.name == "run_end"), None)
    summary["io"] = {
        "torn_lines": int((io or {}).get("torn_lines", 0)),
        "invalid_lines": int((io or {}).get("invalid_lines", 0)),
        "ring_dropped": int(run_end.data.get("ring_dropped", 0))
        if run_end is not None else 0,
    }

    summary["phases"] = _span_table(events, traced=False)
    summary["phases_trace_time"] = _span_table(events, traced=True)

    steps = [e for e in events if e.kind == "metrics" and e.name == "step"]
    if steps:
        summary["steps"] = {
            "n": len(steps),
            "first": {"step": steps[0].step, **steps[0].data},
            "last": {"step": steps[-1].step, **steps[-1].data},
        }

    scale_events = [e for e in events if e.kind == "scale"]
    gate_events = [e for e in events
                   if e.kind == "gate" and not e.data.get("finite", True)]
    summary["scale_history"] = [
        {"step": e.step, "event": e.name, "scale": e.data.get("scale"),
         "prev": e.data.get("prev")} for e in scale_events]
    summary["skip_history"] = [
        {"step": e.step, "gate": e.name, "reason": e.data.get("reason")}
        for e in gate_events]

    serve_term = TallyCounter(
        e.name for e in events
        if e.kind == "serve" and e.name in ("done", "deadline_miss", "shed"))
    ticks = [e for e in events if e.kind == "serve" and e.name == "tick"]
    if serve_term or ticks:
        tick_us = sorted(float(e.data["dur_us"]) for e in ticks
                         if "dur_us" in e.data)

        def _pct(q: float) -> Optional[float]:
            if not tick_us:
                return None
            i = min(len(tick_us) - 1, int(q * len(tick_us)))
            return tick_us[i]

        summary["serve"] = {
            "terminal": dict(sorted(serve_term.items())),
            "ticks": len(ticks),
            "tick_p50_us": _pct(0.50),
            "tick_p99_us": _pct(0.99),
            "max_queue_depth": max(
                (e.data.get("queue_depth", 0) for e in ticks), default=0),
        }

    dispatch = TallyCounter(
        (e.data.get("kernel", e.name), e.data.get("backend", "?"),
         e.data.get("reason", "?"))
        for e in events if e.kind == "dispatch")
    if dispatch:
        summary["dispatch"] = [
            {"kernel": k, "backend": b, "reason": r, "n": n}
            for (k, b, r), n in sorted(dispatch.items())]

    census = [e for e in events if e.kind == "census"]
    if census:
        last = census[-1]
        summary["census"] = {"observed": last.data.get("observed"),
                             "expected": last.data.get("expected"),
                             "ok": last.data.get("ok")}

    health = replay(events)
    summary["health"] = health.summary()
    # replaying re-derives alerts; drop the duplicate alert events' echo
    summary["health"]["alerts"] = [a.as_dict() for a in health.alerts]
    return summary


def render(summary: Dict[str, Any]) -> str:
    """Human-readable rendering of :func:`summarize`'s output."""

    lines: List[str] = []
    add = lines.append

    add("== repro.obs run report ==")
    dur = None
    if summary.get("t_start") is not None and summary.get("t_end") is not None:
        dur = summary["t_end"] - summary["t_start"]
    add(f"events: {summary['events']}"
        + (f"  wall: {dur:.1f}s" if dur is not None else ""))
    add("kinds:  " + ", ".join(f"{k}={n}" for k, n in summary["kinds"].items()))
    io = summary.get("io") or {}
    if any(io.values()):
        add("io:     " + ", ".join(f"{k}={v}" for k, v in io.items())
            + "  (log loss — lines skipped or ring-evicted)")

    for key, title in (("phases", "phase spans (runtime)"),
                       ("phases_trace_time", "phase spans (jit trace time)")):
        rows = summary.get(key) or []
        if not rows:
            continue
        add("")
        add(f"-- {title} --")
        add(f"{'phase':<18} {'n':>5} {'mean':>10} {'max':>10} {'total':>10}")
        for r in rows:
            add(f"{r['name']:<18} {r['n']:>5} {_fmt_us(r['mean_us']):>10} "
                f"{_fmt_us(r['max_us']):>10} {_fmt_us(r['total_us']):>10}")

    steps = summary.get("steps")
    if steps:
        add("")
        add(f"-- metrics ({steps['n']} logged steps) --")
        for label in ("first", "last"):
            row = steps[label]
            scalars = ", ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in row.items() if k != "step")
            add(f"{label:<6} step {row.get('step')}: {scalars}")

    scale_hist = summary.get("scale_history") or []
    skip_hist = summary.get("skip_history") or []
    if scale_hist or skip_hist:
        add("")
        add("-- loss-scale / skip history --")
        for r in scale_hist:
            add(f"step {r['step']}: loss scale {r['event']} "
                f"{r['prev']} -> {r['scale']}")
        for r in skip_hist:
            add(f"step {r['step']}: {r['gate']} skipped ({r['reason']})")
        if not scale_hist:
            add("(no loss-scale transitions)")
    elif any(k in summary["kinds"] for k in ("metrics",)):
        add("")
        add("-- loss-scale / skip history --")
        add("(no transitions, no skips)")

    serve = summary.get("serve")
    if serve:
        add("")
        add("-- serve --")
        term = ", ".join(f"{k}={n}" for k, n in serve["terminal"].items()) or "none"
        add(f"requests: {term}")
        if serve["ticks"]:
            p50 = serve.get("tick_p50_us")
            p99 = serve.get("tick_p99_us")
            add(f"ticks: {serve['ticks']}  tick p50 "
                f"{_fmt_us(p50) if p50 is not None else '-'}  p99 "
                f"{_fmt_us(p99) if p99 is not None else '-'}  "
                f"max queue depth {serve['max_queue_depth']}")

    dispatch = summary.get("dispatch")
    if dispatch:
        add("")
        add("-- kernel dispatch --")
        for r in dispatch:
            add(f"{r['kernel']:<24} {r['backend']:<18} {r['reason']:<24} "
                f"x{r['n']}")

    census = summary.get("census")
    if census:
        add("")
        add("-- collective census --")
        mark = "OK" if census.get("ok") else "MISMATCH"
        add(f"all-reduces: {census.get('observed')} "
            f"(expected {census.get('expected')}) {mark}")

    health = summary["health"]
    add("")
    add(f"-- health: {health['status'].upper()} --")
    for name, v in health["monitors"].items():
        add(f"{name:<12} {v['status']:<9} {v.get('detail', '')}")
    alerts = health.get("alerts") or []
    if alerts:
        add("")
        add(f"alerts ({len(alerts)}):")
        for a in alerts:
            step = f" step {a['step']}" if a.get("step") is not None else ""
            add(f"  [{a['severity']}] {a['monitor']}{step}: {a['message']}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs JSONL event log.")
    parser.add_argument("log", help="path to the JSONL event log")
    parser.add_argument("--json", action="store_true",
                        help="emit the machine-readable summary instead")
    parser.add_argument("--validate", action="store_true",
                        help="fail (exit 1) if any line violates the schema")
    parser.add_argument("--diff", default=None, metavar="BASELINE",
                        help="also print a per-phase cost diff against a "
                             "baseline log/record (repro.obs.diff)")
    args = parser.parse_args(argv)

    if args.validate:
        errors = validate_jsonl(args.log)
        if errors:
            for e in errors:
                print(f"{args.log}: {e}", file=sys.stderr)
            return 1

    events, io = read_jsonl_stats(args.log)
    if not events:
        print(f"{args.log}: no valid events", file=sys.stderr)
        return 1
    summary = summarize(events, io=io)
    if args.diff:
        from . import diff as diff_mod
        rows, unit = diff_mod.diff_paths(args.diff, args.log)
        summary["diff"] = {"baseline": args.diff, "unit": unit,
                           "phases": [r.as_dict() for r in rows]}
    if args.json:
        print(json.dumps(summary, indent=2, default=str))
    else:
        print(render(summary))
        if args.diff:
            print()
            print(diff_mod.render_diff(rows, summary["diff"]["unit"]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
