"""Phase-level regression diff between two runs (``repro.obs.diff``).

    PYTHONPATH=src python -m repro.obs.diff BASELINE CURRENT \
        [--fail-over PCT] [--json]

Each side is either a JSONL event stream (``--obs-log`` output: per-phase
cost = mean runtime-span µs) or a BENCH/PerfRecord JSON carrying
``attribution`` sections (per-phase cost = measured ``wall_us`` when the
records have it, attributed FLOPs otherwise). The two sides must be the
same kind of file — µs vs FLOPs is not a comparison.

Output: a ranked table of per-phase deltas (worst absolute regression
first) and a one-line verdict naming the top regressor. ``--fail-over
PCT`` exits non-zero when the top regressor grew by more than PCT% — the
CI hook. Also callable from the report CLI:
``python -m repro.obs.report run.jsonl --diff other.jsonl``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

from .events import read_jsonl


@dataclasses.dataclass
class PhaseDelta:
    phase: str
    baseline: Optional[float]
    current: Optional[float]
    delta: float          # current - baseline (0-filled for one-sided phases)
    ratio: Optional[float]  # current / baseline; None when baseline is 0/absent

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


#: serve terminal-event metric -> pseudo-phase name; the serve latency
#: splits diff exactly like engine phases (same µs unit, so the
#: unit-mismatch refusal semantics are untouched)
SERVE_METRIC_PHASES = (
    ("ttft_us", "serve:ttft"),
    ("tpot_us", "serve:tpot"),
    ("queue_wait_us", "serve:queue_wait"),
    ("resident_us", "serve:resident"),
)

_SERVE_TERMINALS = ("done", "deadline_miss", "shed", "rejected", "error")


def phase_costs_from_events(events) -> Dict[str, float]:
    """Mean runtime-span µs per phase name (mean, not total, so streams
    of different lengths compare). Serve streams additionally contribute
    ``serve:*`` pseudo-phases — the mean TTFT/TPOT/queue-wait/resident µs
    over terminal request events — so two serving runs diff on the
    request-latency splits, not just tick spans."""

    total: Dict[str, float] = {}
    n: Dict[str, int] = {}
    for e in events:
        if e.kind == "serve" and e.name in _SERVE_TERMINALS:
            for key, phase in SERVE_METRIC_PHASES:
                v = e.data.get(key)
                if v is not None:
                    total[phase] = total.get(phase, 0.0) + float(v)
                    n[phase] = n.get(phase, 0) + 1
            continue
        if e.kind != "span" or e.data.get("traced"):
            continue
        total[e.name] = total.get(e.name, 0.0) + float(e.data.get("dur_us", 0.0))
        n[e.name] = n.get(e.name, 0) + 1
    return {name: total[name] / n[name] for name in total}


def phase_costs_from_bench(payload: Dict[str, Any]) -> Tuple[Dict[str, float], str]:
    """Per-phase cost summed over a BENCH payload's (or single record's)
    ``attribution`` sections. Prefers measured ``wall_us``; falls back to
    attributed FLOPs when no record carries wall times. Returns
    (costs, unit)."""

    records = payload.get("records", [payload])
    attrs = [r["attribution"] for r in records if r.get("attribution")]
    if not attrs and payload.get("phases"):
        attrs = [payload]  # a bare attribution dict
    walls: Dict[str, float] = {}
    flops: Dict[str, float] = {}
    for attr in attrs:
        for ph, b in (attr.get("phases") or {}).items():
            if b.get("wall_us") is not None:
                walls[ph] = walls.get(ph, 0.0) + float(b["wall_us"])
            flops[ph] = flops.get(ph, 0.0) + float(b.get("flops", 0.0))
    if walls:
        return walls, "us"
    return flops, "flops"


def load_phase_costs(path: str) -> Tuple[Dict[str, float], str]:
    """Sniff ``path`` (JSONL event stream vs JSON record/bench) and
    return (per-phase costs, unit)."""

    with open(path, encoding="utf-8") as f:
        head = f.read(1).strip()
    if path.endswith(".jsonl"):
        return phase_costs_from_events(read_jsonl(path)), "us"
    if head in ("{", "["):
        try:
            with open(path, encoding="utf-8") as f:
                payload = json.load(f)
        except json.JSONDecodeError:
            # multiple JSON lines -> treat as an event stream
            return phase_costs_from_events(read_jsonl(path)), "us"
        if isinstance(payload, dict):
            return phase_costs_from_bench(payload)
    return phase_costs_from_events(read_jsonl(path)), "us"


def diff_costs(baseline: Dict[str, float],
               current: Dict[str, float]) -> List[PhaseDelta]:
    """Ranked per-phase deltas, worst absolute regression first."""

    rows: List[PhaseDelta] = []
    for ph in sorted(set(baseline) | set(current)):
        b = baseline.get(ph)
        c = current.get(ph)
        delta = (c or 0.0) - (b or 0.0)
        ratio = (c / b) if (b and c is not None) else None
        rows.append(PhaseDelta(phase=ph, baseline=b, current=c,
                               delta=delta, ratio=ratio))
    rows.sort(key=lambda r: -r.delta)
    return rows


def top_regressor(rows: List[PhaseDelta]) -> Optional[PhaseDelta]:
    worst = next(iter(rows), None)
    return worst if worst is not None and worst.delta > 0 else None


def _fmt(v: Optional[float], unit: str) -> str:
    if v is None:
        return "-"
    if unit == "us":
        if v >= 1e6:
            return f"{v / 1e6:.2f}s"
        if v >= 1e3:
            return f"{v / 1e3:.1f}ms"
        return f"{v:.0f}us"
    return f"{v:.3e}"


def render_diff(rows: List[PhaseDelta], unit: str) -> str:
    lines: List[str] = []
    add = lines.append
    add("== phase diff (baseline -> current) ==")
    add(f"{'phase':<18} {'baseline':>12} {'current':>12} {'delta':>12} {'ratio':>8}")
    for r in rows:
        ratio = f"{r.ratio:.2f}x" if r.ratio is not None else "-"
        sign = "+" if r.delta > 0 else ("-" if r.delta < 0 else "")
        add(f"{r.phase:<18} {_fmt(r.baseline, unit):>12} "
            f"{_fmt(r.current, unit):>12} {sign + _fmt(abs(r.delta), unit):>12} "
            f"{ratio:>8}")
    worst = top_regressor(rows)
    add("")
    if worst is None:
        add("verdict: no phase regressed")
    else:
        pct = (f" (+{(worst.ratio - 1) * 100:.0f}%)"
               if worst.ratio is not None else " (new phase)")
        add(f"verdict: top regressor is {worst.phase}{pct}, "
            f"+{_fmt(worst.delta, unit)}")
    return "\n".join(lines)


def diff_paths(baseline_path: str, current_path: str
               ) -> Tuple[List[PhaseDelta], str]:
    base, base_unit = load_phase_costs(baseline_path)
    cur, cur_unit = load_phase_costs(current_path)
    if base_unit != cur_unit:
        raise ValueError(
            f"cannot diff {base_unit} ({baseline_path}) against "
            f"{cur_unit} ({current_path}) — one side has measured wall "
            "times, the other only FLOPs")
    if not base and not cur:
        raise ValueError("no per-phase costs found on either side "
                         "(no spans / no attribution sections)")
    return diff_costs(base, cur), base_unit


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.diff",
        description="Rank phase-level deltas between two runs.")
    ap.add_argument("baseline", help="JSONL event stream or BENCH/record JSON")
    ap.add_argument("current", help="same kind of file as baseline")
    ap.add_argument("--fail-over", type=float, default=None, metavar="PCT",
                    help="exit 1 when the top regressor grew more than PCT%%")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable rows instead of the table")
    args = ap.parse_args(argv)

    try:
        rows, unit = diff_paths(args.baseline, args.current)
    except (ValueError, FileNotFoundError) as e:
        print(f"obs.diff: ERROR {e}")
        return 2
    if args.json:
        worst = top_regressor(rows)
        print(json.dumps({
            "unit": unit,
            "phases": [r.as_dict() for r in rows],
            "top_regressor": worst.as_dict() if worst else None,
        }, indent=2))
    else:
        print(render_diff(rows, unit))
    if args.fail_over is not None:
        worst = top_regressor(rows)
        if worst is not None and worst.ratio is not None \
                and (worst.ratio - 1) * 100 > args.fail_over:
            print(f"obs.diff: FAIL {worst.phase} regressed "
                  f"{(worst.ratio - 1) * 100:.1f}% > {args.fail_over}%")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
