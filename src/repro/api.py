"""The three-level public API (DESIGN.md §5), learn2learn-style.

Level 1 — ``repro.api.MetaLearner``: one object that owns the bilevel
  program end-to-end. Pick optimizers by name, a hypergradient method by
  registry name (or hand in a ``HypergradMethod`` instance), optionally a
  mesh + schedule, and you get ``init / step / fit / save / load`` with
  checkpointing wired in. Users never hand-assemble
  spec -> opt -> engine -> mesh again.

Level 2 — ``repro.core.Engine`` / ``make_meta_step`` and
  ``repro.launch.distributed.make_manual_step``: pure step-function
  builders over the ``HypergradMethod`` protocol, for people composing
  their own training loops or launchers.

Level 3 — ``repro.core.methods`` / ``repro.core.sama`` /
  ``repro.core.baselines``: the raw estimator math and the protocol
  itself, for people writing new estimators (``register_method``) or
  studying the algorithms.

Typical use::

    from repro import api, optim, scale
    from repro.core import problems

    learner = api.MetaLearner(
        spec,
        base_opt="adam", base_lr=1e-2,
        meta_opt="adam", meta_lr=1e-2,
        method="sama", unroll_steps=2,
        scale=scale.ScaleConfig(policy="bf16", microbatch=4),  # repro.scale
        checkpoint_dir="out/ck",
    )
    learner.init(theta0, lam0)
    history = learner.fit(batch_iter, steps=200, log_every=50)
    learner.save()
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Iterator, List, Optional, Tuple, Union

import jax

from repro import checkpoint, optim
from repro.core.bilevel import BilevelSpec
from repro.core.engine import EngineConfig, EngineState, init_state, make_meta_step, run_loop
from repro.core.methods import HypergradMethod

PyTree = Any

#: schedule choices: "auto" = single_sync when a mesh is given, else jit;
#: "pjit" = naive-DDP Engine step (XLA places the collectives);
#: "single_sync" = the paper's one-bucket shard_map schedule.
SCHEDULES = ("auto", "pjit", "single_sync")

_ENGINE_FIELDS = {f.name for f in dataclasses.fields(EngineConfig)}


class MetaLearner:
    """High-level facade over the bilevel Engine and the distributed
    schedules. Holds the (pure) step function plus the current EngineState;
    all mutation is confined to ``self.state``."""

    def __init__(
        self,
        spec: BilevelSpec,
        *,
        base_opt: Union[str, optim.Optimizer] = "adam",
        base_lr: float = 1e-3,
        meta_opt: Union[str, optim.Optimizer] = "adam",
        meta_lr: float = 1e-3,
        method: Union[str, HypergradMethod] = "sama",
        unroll_steps: int = 1,
        engine_config: Optional[EngineConfig] = None,
        mesh=None,
        schedule: str = "auto",
        allow_nonlinear: bool = False,
        jit: bool = True,
        checkpoint_dir: Optional[str] = None,
        obs=None,
        **method_knobs,
    ):
        if schedule not in SCHEDULES:
            raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")
        unknown = set(method_knobs) - _ENGINE_FIELDS
        if unknown:
            raise TypeError(f"unknown method knobs {sorted(unknown)}; "
                            f"EngineConfig accepts {sorted(_ENGINE_FIELDS)}")

        self.spec = spec
        self.base_opt = optim.get_optimizer(base_opt, base_lr) if isinstance(base_opt, str) else base_opt
        self.meta_opt = optim.get_optimizer(meta_opt, meta_lr) if isinstance(meta_opt, str) else meta_opt
        if engine_config is not None:
            if method != "sama" or unroll_steps != 1 or method_knobs:
                raise ValueError(
                    "pass either engine_config or method/unroll_steps/method knobs, "
                    "not both — the explicit knobs would be silently ignored"
                )
            self.cfg = engine_config
        else:
            self.cfg = EngineConfig(method=method, unroll_steps=unroll_steps, **method_knobs)
        self.method = self.cfg.resolve()
        self.mesh = mesh
        self.checkpoint_dir = checkpoint_dir
        self.state: Optional[EngineState] = None
        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self.obs = obs

        if schedule == "auto":
            schedule = "single_sync" if mesh is not None else "pjit"
        if schedule == "single_sync":
            if mesh is None:
                raise ValueError("schedule='single_sync' needs a mesh")
            from repro.launch.distributed import make_manual_step

            step = make_manual_step(
                self.spec, self.base_opt, self.meta_opt, self.cfg, mesh,
                allow_nonlinear=allow_nonlinear,
            )
        else:
            step = make_meta_step(self.spec, self.base_opt, self.meta_opt, self.cfg)
        self.schedule = schedule
        self._raw_step = step  # un-jitted: phase_profile runs it eagerly
        self.step_fn = jax.jit(step) if jit else step

    # -- lifecycle ---------------------------------------------------------

    def init(self, theta: PyTree, lam: PyTree) -> EngineState:
        """Build the EngineState (both levels' params + optimizer moments;
        a loss-scaling precision policy additionally seeds its
        LossScaleState from ``cfg.scale``)."""
        self.state = init_state(theta, lam, self.base_opt, self.meta_opt,
                                scale=self.cfg.scale)
        return self.state

    def step(self, base_batches, meta_batch) -> Dict[str, Any]:
        """One meta step: K base updates + one meta update. Advances
        ``self.state`` and returns the metric dict (jax scalars)."""

        if self.state is None:
            raise RuntimeError("call init(theta, lam) or load(...) before step()")
        if self.mesh is not None:
            with self.mesh:
                self.state, metrics = self.step_fn(self.state, base_batches, meta_batch)
        else:
            self.state, metrics = self.step_fn(self.state, base_batches, meta_batch)
        return metrics

    def fit(
        self,
        batch_iter: Iterator[Tuple[Any, Any]],
        steps: int,
        *,
        log_every: int = 0,
        save_every: int = 0,
        obs=None,
    ) -> List[Dict[str, float]]:
        """Run ``steps`` meta steps from an iterator of
        (base_batches[K], meta_batch). Checkpoints every ``save_every``
        steps when a checkpoint_dir is configured. ``obs`` (defaulting to
        the learner's own) receives metric/scale/gate events at the
        ``log_every`` boundary — observability shares the loop's existing
        sync points (see ``run_loop``)."""

        if save_every and self.checkpoint_dir is None:
            raise ValueError("fit(save_every=...) needs a checkpoint_dir")
        if self.state is None:
            raise RuntimeError("call init(theta, lam) or load(...) before fit()")

        def step_adapter(state, base_batches, meta_batch):
            assert state is self.state
            metrics = self.step(base_batches, meta_batch)  # advances self.state
            return self.state, metrics

        def on_step(i, state):
            if save_every and (i + 1) % save_every == 0:
                self.save()

        _, history = run_loop(step_adapter, self.state, batch_iter, steps,
                              log_every, on_step=on_step,
                              obs=obs if obs is not None else self.obs)
        return history

    # -- telemetry ---------------------------------------------------------

    def profile(self, base_batches, meta_batch, *, warmup: int = 2,
                repeats: int = 5, name: Optional[str] = None,
                attribution: bool = False, attribution_spans=None):
        """Measure this learner's step on example batches through
        ``repro.perf``: warmup/repeat/block run timing with the compile
        split, per-device memory breakdown, and the trip-scaled collective
        census of the compiled step. Returns a ``perf.PerfRecord``.

        ``attribution=True`` additionally partitions the compiled step's
        FLOPs/bytes/collectives by engine phase (``repro.obs.profile``)
        into the record's ``attribution`` section; pass the spans from
        ``phase_profile`` as ``attribution_spans`` to join measured wall
        time and roofline utilization per phase.

        Always profiles the JIT-COMPILED step (memory/collective accounting
        needs the compiled executable) — for a ``jit=False`` learner these
        are the numbers ``fit`` would see after ``jax.jit``, not its eager
        per-call overhead. State advances are discarded: the probe operates
        on a snapshot of ``self.state``."""

        from repro import perf

        if self.state is None:
            raise RuntimeError("call init(theta, lam) or load(...) before profile()")
        fn = self.step_fn if hasattr(self.step_fn, "lower") else jax.jit(self.step_fn)
        args = (self.state, base_batches, meta_batch)
        rec_name = name or f"{self.method.name}_{self.schedule}"
        extra = {"method": self.method.name, "schedule": self.schedule,
                 "unroll_steps": self.cfg.unroll_steps,
                 "microbatch": self.cfg.scale.microbatch,
                 "policy": self.cfg.scale.resolve().name}
        if self.mesh is not None:
            with self.mesh:
                return perf.profile_step(rec_name, fn, *args, warmup=warmup,
                                         repeats=repeats, extra=extra,
                                         attribution=attribution,
                                         attribution_spans=attribution_spans)
        return perf.profile_step(rec_name, fn, *args, warmup=warmup,
                                 repeats=repeats, extra=extra,
                                 attribution=attribution,
                                 attribution_spans=attribution_spans)

    def phase_profile(self, base_batches, meta_batch):
        """Per-phase host wall times: run ONE step eagerly (un-jitted)
        under an activated span tracer, so the engine's phase annotations
        (base unroll, meta pass, CD passes, finalize, meta update, and the
        flat-bucket all-reduce on the manual schedule) record real
        execution spans instead of jit trace-time. Returns the list of
        ``repro.obs.Span``; when the learner carries an enabled obs, each
        span is also emitted as a ``span`` event.

        The state is NOT advanced and the jitted step's cache is
        untouched. Eager per-op dispatch overhead inflates absolute
        numbers — read the result as the *relative* cost of the phases
        (``repro.perf`` owns absolute step timing)."""

        from repro import obs as obs_mod

        if self.state is None:
            raise RuntimeError(
                "call init(theta, lam) or load(...) before phase_profile()")
        tracer = obs_mod.Tracer(obs=self.obs if self.obs.enabled else None)
        with obs_mod.activate(tracer):
            if self.mesh is not None:
                with self.mesh:
                    out = self._raw_step(self.state, base_batches, meta_batch)
            else:
                out = self._raw_step(self.state, base_batches, meta_batch)
            jax.block_until_ready(out)
        return tracer.runtime_spans()

    def verify_census(self, base_batches, meta_batch):
        """Compile the step on these example shapes and check the
        collective census against the pinned ``unroll+1`` all-reduces
        (``perf.verify_single_sync``). Returns the census dict; when the
        learner carries an enabled obs the verdict is emitted as a
        ``census`` event (a mismatch trips the census health monitor).

        Meaningful on the manual single-sync schedule — the pjit path
        lets XLA place collectives, so nothing is pinned there. Shares
        the jit cache with training when the shapes match."""

        from repro import perf

        if self.state is None:
            raise RuntimeError(
                "call init(theta, lam) or load(...) before verify_census()")
        fn = self.step_fn if hasattr(self.step_fn, "lower") else jax.jit(self.step_fn)
        args = (self.state, base_batches, meta_batch)
        if self.mesh is not None:
            with self.mesh:
                compiled = fn.lower(*args).compile()
        else:
            compiled = fn.lower(*args).compile()
        stats = perf.verify_single_sync(compiled, self.cfg.unroll_steps)
        if self.obs.enabled:
            self.obs.observe_census(stats.get("all-reduce_count", 0),
                                    stats["expected_all_reduces"],
                                    detail={"schedule": self.schedule})
        return stats

    # -- checkpointing -----------------------------------------------------

    def save(self, path: Optional[str] = None, *, meta: Optional[Dict[str, Any]] = None) -> str:
        """Checkpoint the full EngineState. Default path:
        ``{checkpoint_dir}/step_{NNNNNN}``. ``meta`` entries are merged into
        the manifest alongside the learner's own (method/unroll/schedule)."""

        if self.state is None:
            raise RuntimeError("nothing to save: no state")
        step = int(self.state.step)
        if path is None:
            if self.checkpoint_dir is None:
                raise ValueError("no path given and no checkpoint_dir configured")
            path = os.path.join(self.checkpoint_dir, f"step_{step:06d}")
        manifest_meta = {"method": self.method.name,
                         "unroll_steps": self.cfg.unroll_steps,
                         "schedule": self.schedule}
        if meta:
            manifest_meta.update(meta)
        checkpoint.save(path, self.state, step=step, meta=manifest_meta)
        if self.obs.enabled:
            self.obs.emit("checkpoint", "save", step=step,
                          data={"path": path})
        return path

    def load(self, path: Optional[str] = None) -> EngineState:
        """Restore the EngineState saved by ``save``. With no ``path``, the
        newest ``step_*`` under ``checkpoint_dir``. Needs a template state
        (from ``init``) to validate structure against."""

        if self.state is None:
            raise RuntimeError("call init(theta, lam) first: restore validates "
                               "against the live state structure")
        if path is None:
            if self.checkpoint_dir is None:
                raise ValueError("no path given and no checkpoint_dir configured")
            path = checkpoint.latest_step(self.checkpoint_dir)
            if path is None:
                raise FileNotFoundError(f"no step_* checkpoints under {self.checkpoint_dir}")
        state, manifest = checkpoint.restore(path, self.state)
        # the EngineState structure is method-independent, so a structural
        # match alone would silently resume a different estimator's
        # trajectory — cross-check the manifest save() wrote.
        meta = manifest.get("meta", {})
        for key, mine in (("method", self.method.name),
                          ("unroll_steps", self.cfg.unroll_steps)):
            if key in meta and meta[key] != mine:
                raise ValueError(
                    f"checkpoint {path} was saved with {key}={meta[key]!r} but this "
                    f"learner uses {mine!r}; construct a matching MetaLearner "
                    "(or restore via repro.checkpoint directly to override)"
                )
        self.state = state
        if self.obs.enabled:
            self.obs.emit("checkpoint", "restore", step=int(state.step),
                          data={"path": path})
        return self.state
