"""Data substrate: deterministic synthetic pipelines + weak supervision."""

from repro.data.pipeline import (
    BatchIterator,
    ClassificationConfig,
    LMStreamConfig,
    lm_batch,
    make_classification_dataset,
    weak_labels,
)

__all__ = [
    "BatchIterator",
    "ClassificationConfig",
    "LMStreamConfig",
    "lm_batch",
    "make_classification_dataset",
    "weak_labels",
]
