"""Deterministic synthetic data pipelines.

Everything is generated from seeds so runs are reproducible and no external
corpora are needed:

* ``lm_stream`` — Zipfian token sequences with short-range Markov structure
  (so models can actually reduce loss).
* ``classification`` — Gaussian-mixture features rendered as token sequences
  (for the WRENCH-analog benchmarks) with controllable label noise.
* ``BatchIterator`` — global-batch iterator that yields the (base_batches[K],
  meta_batch) pairs the Engine consumes and can shard the global batch over a
  mesh data axis (``jax.device_put`` with NamedSharding) for the launcher.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# synthetic LM stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LMStreamConfig:
    vocab_size: int
    seq_len: int
    zipf_a: float = 1.2
    markov_strength: float = 0.7  # prob. of following the deterministic chain
    seed: int = 0


def lm_batch(cfg: LMStreamConfig, rng: np.random.Generator, batch: int) -> Dict[str, np.ndarray]:
    """Markov-perturbed Zipf stream: next ~ (cur * 31 + 7) % V with prob p,
    else Zipf sample. Learnable structure, heavy-tailed unigrams."""

    V = cfg.vocab_size
    zipf = rng.zipf(cfg.zipf_a, size=(batch, cfg.seq_len)).astype(np.int64)
    zipf = np.minimum(zipf - 1, V - 1)
    toks = np.empty((batch, cfg.seq_len), np.int32)
    toks[:, 0] = zipf[:, 0]
    follow = rng.random((batch, cfg.seq_len)) < cfg.markov_strength
    for t in range(1, cfg.seq_len):
        chain = (toks[:, t - 1].astype(np.int64) * 31 + 7) % V
        toks[:, t] = np.where(follow[:, t], chain, zipf[:, t])
    return {"tokens": toks}


# ---------------------------------------------------------------------------
# synthetic classification ("WRENCH-analog")
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClassificationConfig:
    num_classes: int = 4
    vocab_size: int = 512
    seq_len: int = 32
    class_token_bias: float = 3.0  # how strongly class-indicative tokens dominate
    seed: int = 0


def make_classification_dataset(
    cfg: ClassificationConfig, n: int, *, noise: float = 0.0, seed: Optional[int] = None
) -> Dict[str, np.ndarray]:
    """Each class c over-samples a disjoint token band; labels optionally
    corrupted uniformly with prob ``noise``. Returns tokens, y (observed),
    y_true, corrupted (bool mask)."""

    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    C, V, S = cfg.num_classes, cfg.vocab_size, cfg.seq_len
    y_true = rng.integers(0, C, size=n)
    band = V // C
    logits = np.full((n, V), 1.0)
    for c in range(C):
        rows = y_true == c
        logits[rows, c * band : (c + 1) * band] += cfg.class_token_bias
    probs = logits / logits.sum(-1, keepdims=True)
    toks = np.stack([rng.choice(V, size=S, p=probs[i]) for i in range(n)]).astype(np.int32)

    corrupted = rng.random(n) < noise
    y_obs = np.where(corrupted, rng.integers(0, C, size=n), y_true).astype(np.int32)
    return {
        "tokens": toks,
        "y": y_obs,
        "y_true": y_true.astype(np.int32),
        "corrupted": corrupted,
    }


def weak_labels(y_true: np.ndarray, num_classes: int, *, num_lfs: int = 5,
                lf_accuracy: float = 0.7, seed: int = 0) -> np.ndarray:
    """Weak supervision via majority vote of ``num_lfs`` noisy labeling
    functions (the paper's WRENCH setup uses majority voting, App. B.1)."""

    rng = np.random.default_rng(seed)
    n = len(y_true)
    votes = np.where(
        rng.random((num_lfs, n)) < lf_accuracy,
        y_true[None, :],
        rng.integers(0, num_classes, size=(num_lfs, n)),
    )
    maj = np.empty(n, np.int32)
    for i in range(n):
        maj[i] = np.bincount(votes[:, i], minlength=num_classes).argmax()
    return maj


# ---------------------------------------------------------------------------
# batch iterators
# ---------------------------------------------------------------------------


class BatchIterator:
    """Yields (base_batches[K], meta_batch) pairs for the Engine.

    ``shard`` (optional NamedSharding for the batch axis of the META batch)
    device_puts the global batch so pjit consumes pre-sharded arrays — the
    data-parallel axes of the production mesh. Base batches carry a leading
    unroll axis (K, B, ...), so their sharding shifts one dim right
    (P(None, *spec)); subclasses override ``_base_idx`` to change the base
    sampling distribution (see ``repro.dataopt.ReweightedIterator``)."""

    def __init__(
        self,
        base_data: Dict[str, np.ndarray],
        meta_data: Dict[str, np.ndarray],
        *,
        batch_size: int,
        meta_batch_size: int,
        unroll: int,
        seed: int = 0,
        fields: Tuple[str, ...] = ("tokens", "y"),
        shard=None,
    ):
        self.base = {k: v for k, v in base_data.items() if k in fields}
        self.meta = {k: v for k, v in meta_data.items() if k in fields}
        self.bs, self.mbs, self.k = batch_size, meta_batch_size, unroll
        self.rng = np.random.default_rng(seed)
        self.n = len(next(iter(self.base.values())))
        self.nm = len(next(iter(self.meta.values())))
        self.shard = shard
        if shard is not None and hasattr(shard, "spec"):
            from jax.sharding import NamedSharding, PartitionSpec

            self.base_shard = NamedSharding(shard.mesh, PartitionSpec(None, *shard.spec))
        else:
            self.base_shard = shard

    def _base_idx(self) -> np.ndarray:
        """(K, B) base example indices; the uniform default."""
        return self.rng.integers(0, self.n, size=(self.k, self.bs))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        idx = self._base_idx()
        midx = self.rng.integers(0, self.nm, size=self.mbs)
        base = {k: v[idx] for k, v in self.base.items()}
        meta = {k: v[midx] for k, v in self.meta.items()}
        if self.shard is not None:
            base = jax.tree_util.tree_map(lambda x: jax.device_put(x, self.base_shard), base)
            meta = jax.tree_util.tree_map(lambda x: jax.device_put(x, self.shard), meta)
        return base, meta
