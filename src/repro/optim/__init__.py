"""Optimizer substrate: pytree optimizers + analytic SAMA adaptation matrices."""

from repro.optim.optimizers import (
    OptState,
    Optimizer,
    adafactor,
    adam,
    adamw,
    apply_updates,
    get_optimizer,
    lion,
    momentum,
    rmsprop,
    sgd,
)
from repro.optim import schedules

__all__ = [
    "OptState",
    "Optimizer",
    "adafactor",
    "adam",
    "adamw",
    "apply_updates",
    "get_optimizer",
    "lion",
    "momentum",
    "rmsprop",
    "sgd",
    "schedules",
]
