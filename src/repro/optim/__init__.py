"""Optimizer substrate: pytree optimizers + analytic SAMA adaptation matrices."""

from repro.optim.optimizers import (
    OptState,
    Optimizer,
    adam,
    adamw,
    apply_updates,
    get_optimizer,
    momentum,
    rmsprop,
    sgd,
)
from repro.optim import schedules

__all__ = [
    "OptState",
    "Optimizer",
    "adam",
    "adamw",
    "apply_updates",
    "get_optimizer",
    "momentum",
    "rmsprop",
    "sgd",
    "schedules",
]
