"""Learning-rate schedules as pure functions of the step count.

Every schedule is a ``Callable[[step], jnp.ndarray]`` so it can live inside
jitted update rules. ``resolve(lr)`` lets optimizer factories accept either a
float or a schedule.
"""

from __future__ import annotations

from typing import Callable, Union

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


def constant(value: float) -> Schedule:
    def sched(step):
        del step
        return jnp.asarray(value, dtype=jnp.float32)

    return sched


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0) -> Schedule:
    def sched(step):
        frac = jnp.clip(step / max(decay_steps, 1), 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return init_value * ((1.0 - alpha) * cosine + alpha)

    return sched


def linear_warmup_cosine(
    init_value: float, warmup_steps: int, decay_steps: int, end_value: float = 0.0
) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, dtype=jnp.float32)
        warm = init_value * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(decay_steps - warmup_steps, 1), 0.0, 1.0)
        cos = end_value + (init_value - end_value) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def linear_decay_with_warmup(
    init_value: float, total_steps: int, warmup_proportion: float = 0.1
) -> Schedule:
    """The BERT-style schedule used in the paper's continued-pretraining runs."""

    warmup_steps = max(int(total_steps * warmup_proportion), 1)

    def sched(step):
        step = jnp.asarray(step, dtype=jnp.float32)
        warm = init_value * step / warmup_steps
        decay = init_value * jnp.clip(
            (total_steps - step) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        return jnp.where(step < warmup_steps, warm, decay)

    return sched


def resolve(lr: ScalarOrSchedule) -> Schedule:
    if callable(lr):
        return lr
    return constant(float(lr))
