"""From-scratch pytree optimizers with analytic adaptation matrices.

The update convention follows the paper (Sec. 2, Eq. 2):

    theta_t = theta_{t-1} - u(g_t; state)

``Optimizer.update`` returns the *step* ``u`` (to be subtracted) plus new
state. ``Optimizer.adaptation`` returns the diagonal of ``du/dg`` evaluated at
the same (g, state) point — the "algorithmic adaptation" matrix of SAMA
(paper Sec. 3.2 / Appendix C). Because every supported optimizer is
elementwise, the adaptation matrix is diagonal and costs O(n) (a pytree of
the same structure as the params).

Correctness of each ``adaptation`` is pinned by tests that compare against
``jax.jacfwd`` of the scalarized update rule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import schedules

PyTree = Any


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


class OptState(NamedTuple):
    count: jnp.ndarray  # scalar int32 step counter (post-increment convention)
    mu: Optional[PyTree] = None  # first moment / momentum
    nu: Optional[PyTree] = None  # second moment


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """A base-level iterative solver ``u`` with its analytic ``du/dg``."""

    name: str
    init: Callable[[PyTree], OptState]
    # (grads, state, params) -> (step_u, new_state)
    update: Callable[[PyTree, OptState, PyTree], Tuple[PyTree, OptState]]
    # (grads, state, params) -> diagonal of du/dg, same structure as params
    adaptation: Callable[[PyTree, OptState, PyTree], PyTree]


def apply_updates(params: PyTree, step: PyTree) -> PyTree:
    """theta' = theta - u."""
    return _tmap(lambda p, s: (p - s).astype(p.dtype), params, step)


def _zeros_like(params):
    return _tmap(jnp.zeros_like, params)


# ---------------------------------------------------------------------------
# SGD family
# ---------------------------------------------------------------------------


def sgd(lr: schedules.ScalarOrSchedule, weight_decay: float = 0.0) -> Optimizer:
    """u = lr * (g + wd * theta).  du/dg = lr * I."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        del params
        return OptState(count=jnp.zeros([], jnp.int32))

    def update(grads, state, params):
        step_lr = lr_fn(state.count)
        if weight_decay:
            step = _tmap(lambda g, p: step_lr * (g + weight_decay * p), grads, params)
        else:
            step = _tmap(lambda g: step_lr * g, grads)
        return step, OptState(count=state.count + 1)

    def adaptation(grads, state, params):
        step_lr = lr_fn(state.count)
        return _tmap(lambda g: jnp.full_like(g, step_lr), grads)

    return Optimizer("sgd", init, update, adaptation)


def momentum(
    lr: schedules.ScalarOrSchedule, beta: float = 0.9, weight_decay: float = 0.0
) -> Optimizer:
    """Heavy-ball: m' = beta*m + g_eff; u = lr*m'.  du/dg = lr * I."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        return OptState(count=jnp.zeros([], jnp.int32), mu=_zeros_like(params))

    def _geff(grads, params):
        if weight_decay:
            return _tmap(lambda g, p: g + weight_decay * p, grads, params)
        return grads

    def update(grads, state, params):
        geff = _geff(grads, params)
        mu = _tmap(lambda m, g: beta * m + g, state.mu, geff)
        step_lr = lr_fn(state.count)
        step = _tmap(lambda m: step_lr * m, mu)
        return step, OptState(count=state.count + 1, mu=mu)

    def adaptation(grads, state, params):
        step_lr = lr_fn(state.count)
        return _tmap(lambda g: jnp.full_like(g, step_lr), grads)

    return Optimizer("momentum", init, update, adaptation)


# ---------------------------------------------------------------------------
# Adam family (paper Appendix C)
# ---------------------------------------------------------------------------


def _adam_math(g, m, v, count, b1, b2, eps, step_lr, wd, p):
    """Shared Adam step + exact diagonal du/dg (Appendix C, without the
    eps<<1 approximation — we keep the exact expression)."""

    t = count + 1  # bias-correction uses the post-increment step index
    m1 = b1 * m + (1.0 - b1) * g
    v1 = b2 * v + (1.0 - b2) * g * g
    bc1 = 1.0 - jnp.power(b1, t.astype(g.dtype))
    bc2 = 1.0 - jnp.power(b2, t.astype(g.dtype))
    mhat = m1 / bc1
    vhat = v1 / bc2
    denom = jnp.sqrt(vhat) + eps
    step = step_lr * mhat / denom
    if wd:
        step = step + step_lr * wd * p

    # d mhat / dg = (1-b1)/bc1 ; d vhat / dg = 2 (1-b2) g / bc2
    a = (1.0 - b1) / bc1
    b = (1.0 - b2) / bc2
    sqrt_vhat = jnp.sqrt(vhat)
    safe_sqrt = jnp.maximum(sqrt_vhat, 1e-15)
    dstep = step_lr * (a / denom - mhat * b * g / (safe_sqrt * denom * denom))
    return step, m1, v1, dstep


def adam(
    lr: schedules.ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam [32]; ``weight_decay`` here is *decoupled* (AdamW-style) so the
    adaptation matrix is unaffected by it (the wd term has no g dependence)."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        return OptState(
            count=jnp.zeros([], jnp.int32),
            mu=_zeros_like(params),
            nu=_zeros_like(params),
        )

    def update(grads, state, params):
        step_lr = lr_fn(state.count)
        mu = _tmap(lambda m, g: b1 * m + (1.0 - b1) * g, state.mu, grads)
        nu = _tmap(lambda v, g: b2 * v + (1.0 - b2) * g * g, state.nu, grads)

        def one(m1, v1, g, p):
            t = (state.count + 1).astype(g.dtype)
            mhat = m1 / (1.0 - jnp.power(b1, t))
            vhat = v1 / (1.0 - jnp.power(b2, t))
            step = step_lr * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + step_lr * weight_decay * p
            return step

        step = _tmap(one, mu, nu, grads, params)
        return step, OptState(count=state.count + 1, mu=mu, nu=nu)

    def adaptation(grads, state, params):
        step_lr = lr_fn(state.count)

        def one(g, m, v, p):
            _, _, _, dstep = _adam_math(
                g, m, v, state.count, b1, b2, eps, step_lr, weight_decay, p
            )
            return dstep

        return _tmap(one, grads, state.mu, state.nu, params)

    return Optimizer("adam", init, update, adaptation)


def adamw(
    lr: schedules.ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    opt = adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    return dataclasses.replace(opt, name="adamw")


def rmsprop(
    lr: schedules.ScalarOrSchedule,
    rho: float = 0.99,
    eps: float = 1e-8,
) -> Optimizer:
    """v' = rho*v + (1-rho) g^2 ; u = lr * g / (sqrt(v') + eps)."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        return OptState(count=jnp.zeros([], jnp.int32), nu=_zeros_like(params))

    def update(grads, state, params):
        del params
        step_lr = lr_fn(state.count)
        nu = _tmap(lambda v, g: rho * v + (1.0 - rho) * g * g, state.nu, grads)
        step = _tmap(lambda g, v: step_lr * g / (jnp.sqrt(v) + eps), grads, nu)
        return step, OptState(count=state.count + 1, nu=nu)

    def adaptation(grads, state, params):
        del params
        step_lr = lr_fn(state.count)

        def one(g, v):
            v1 = rho * v + (1.0 - rho) * g * g
            sq = jnp.sqrt(v1)
            denom = sq + eps
            safe_sq = jnp.maximum(sq, 1e-15)
            return step_lr * (1.0 / denom - g * (1.0 - rho) * g / (safe_sq * denom * denom))

        return _tmap(one, grads, state.nu)

    return Optimizer("rmsprop", init, update, adaptation)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_FACTORIES = {
    "sgd": sgd,
    "momentum": momentum,
    "adam": adam,
    "adamw": adamw,
    "rmsprop": rmsprop,
}


def get_optimizer(name: str, lr: schedules.ScalarOrSchedule, **kwargs) -> Optimizer:
    if name not in _FACTORIES:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(_FACTORIES)}")
    return _FACTORIES[name](lr, **kwargs)
