"""From-scratch pytree optimizers with analytic adaptation matrices.

The update convention follows the paper (Sec. 2, Eq. 2):

    theta_t = theta_{t-1} - u(g_t; state)

``Optimizer.update`` returns the *step* ``u`` (to be subtracted) plus new
state. ``Optimizer.adaptation`` returns the diagonal of ``du/dg`` evaluated
at the same (g, state) point — the "algorithmic adaptation" matrix of SAMA
(paper Sec. 3.2 / Appendix C). For the elementwise optimizers (sgd,
momentum, adam, adamw, rmsprop) that diagonal is jacfwd-exact and pinned by
tests; lion and adafactor document principled surrogates in their
docstrings (sign smoothing, frozen factored statistics) because their exact
derivatives are degenerate or non-diagonal.

``Optimizer.adapt_product`` is the fused fast path SAMA's hot loop consumes
(docs/kernels.md): ``(grads, state, params, g_meta) -> (v, sum(v^2))`` with
``v = diag(du/dg) .* g_meta`` computed per leaf through the kernel dispatch
registry (``repro.kernels.get_kernel``) — compiled Pallas on TPU, pure-jnp
``ref`` elsewhere — emitting the sum of squares alongside so the
``eps = alpha/||v||`` step size needs no second pass over the data.
Optimizers without a fused kernel leave it ``None`` and SAMA falls back to
``adaptation`` + elementwise product + a separate norm pass.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch
from repro.optim import schedules

PyTree = Any

#: type of the fused adaptation-product hook:
#: (grads, state, params, g_meta) -> (v pytree, sum(v^2) scalar)
AdaptProduct = Callable[[PyTree, "OptState", PyTree, PyTree], Tuple[PyTree, jnp.ndarray]]


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


class OptState(NamedTuple):
    count: jnp.ndarray  # scalar int32 step counter (post-increment convention)
    mu: Optional[PyTree] = None  # first moment / momentum
    nu: Optional[PyTree] = None  # second moment (adafactor: factored dicts)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """A base-level iterative solver ``u`` with its analytic ``du/dg``.

    ``adaptation`` returns the du/dg diagonal as a pytree shaped like the
    params; ``adapt_product`` (optional) is the fused kernel-dispatched
    ``diag .* g_meta`` + sum-of-squares — see the module docstring and
    docs/kernels.md for the contract each built-in declares."""

    name: str
    init: Callable[[PyTree], OptState]
    # (grads, state, params) -> (step_u, new_state)
    update: Callable[[PyTree, OptState, PyTree], Tuple[PyTree, OptState]]
    # (grads, state, params) -> diagonal of du/dg, same structure as params
    adaptation: Callable[[PyTree, OptState, PyTree], PyTree]
    # optional fused (diag .* g_meta, sumsq) fast path (kernel-dispatched)
    adapt_product: Optional[AdaptProduct] = None


def apply_updates(params: PyTree, step: PyTree) -> PyTree:
    """theta' = theta - u."""
    return _tmap(lambda p, s: (p - s).astype(p.dtype), params, step)


def _zeros_like(params):
    return _tmap(jnp.zeros_like, params)


def _fused_product(kernel_call, grads, *stat_trees):
    """Run a flat fused-product kernel leaf by leaf, accumulating the
    per-leaf sums of squares into one scalar. ``kernel_call(g_flat,
    *stats_flat) -> (out_flat, sumsq)``; returns (tree like grads, total)."""

    sumsqs = []

    def one(g, *stats):
        out, ss = kernel_call(g.reshape(-1), *(s.reshape(-1) for s in stats))
        sumsqs.append(ss)
        return out.reshape(g.shape)

    tree = _tmap(one, grads, *stat_trees)
    total = sumsqs[0]
    for ss in sumsqs[1:]:
        total = total + ss
    return tree, total


# ---------------------------------------------------------------------------
# SGD family
# ---------------------------------------------------------------------------


def sgd(lr: schedules.ScalarOrSchedule, weight_decay: float = 0.0) -> Optimizer:
    """u = lr * (g + wd * theta).

    Adaptation contract: du/dg = lr * I exactly (the wd term has no g
    dependence), for any state — sgd is stateless beyond the step count.
    No fused kernel: a constant diagonal gains nothing from fusion
    (docs/kernels.md)."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        del params
        return OptState(count=jnp.zeros([], jnp.int32))

    def update(grads, state, params):
        step_lr = lr_fn(state.count)
        if weight_decay:
            step = _tmap(lambda g, p: step_lr * (g + weight_decay * p), grads, params)
        else:
            step = _tmap(lambda g: step_lr * g, grads)
        return step, OptState(count=state.count + 1)

    def adaptation(grads, state, params):
        step_lr = lr_fn(state.count)
        return _tmap(lambda g: jnp.full_like(g, step_lr), grads)

    return Optimizer("sgd", init, update, adaptation)


def momentum(
    lr: schedules.ScalarOrSchedule, beta: float = 0.9, weight_decay: float = 0.0
) -> Optimizer:
    """Heavy-ball: m' = beta*m + g_eff; u = lr*m'.

    Adaptation contract: du/dg = lr * I exactly — the incoming gradient
    enters m' with unit coefficient, so the diagonal is lr at every state.
    No fused kernel (constant diagonal, docs/kernels.md)."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        return OptState(count=jnp.zeros([], jnp.int32), mu=_zeros_like(params))

    def _geff(grads, params):
        if weight_decay:
            return _tmap(lambda g, p: g + weight_decay * p, grads, params)
        return grads

    def update(grads, state, params):
        geff = _geff(grads, params)
        mu = _tmap(lambda m, g: beta * m + g, state.mu, geff)
        step_lr = lr_fn(state.count)
        step = _tmap(lambda m: step_lr * m, mu)
        return step, OptState(count=state.count + 1, mu=mu)

    def adaptation(grads, state, params):
        step_lr = lr_fn(state.count)
        return _tmap(lambda g: jnp.full_like(g, step_lr), grads)

    return Optimizer("momentum", init, update, adaptation)


# ---------------------------------------------------------------------------
# Adam family (paper Appendix C)
# ---------------------------------------------------------------------------


# The exact Adam du/dg diagonal (Appendix C, no eps<<1 approximation) lives
# in kernels/ref.py::adam_adapt_math — the dispatch registry's ref backend —
# so the update rule below and the adaptation expression have exactly one
# home each.


def adam(
    lr: schedules.ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam [32]; ``weight_decay`` here is *decoupled* (AdamW-style) so the
    adaptation matrix is unaffected by it (the wd term has no g dependence).

    Adaptation contract: the EXACT elementwise diagonal of du/dg at
    (g, mu, nu, count) — the state at which the last base gradient was
    computed — per paper Appendix C without the eps<<1 approximation:

        du/dg = lr * [ a/denom - mhat * b * g / (sqrt(vhat) * denom^2) ],
        a = (1-b1)/bc1,  b = (1-b2)/bc2,  denom = sqrt(vhat) + eps.

    Both ``adaptation`` and the fused ``adapt_product`` route through the
    ``adam_adapt`` kernel in the dispatch registry (docs/kernels.md):
    compiled Pallas on TPU, dtype-preserving jnp ``ref`` elsewhere — the
    jacfwd pin in tests/test_optim.py holds on the ref path."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        return OptState(
            count=jnp.zeros([], jnp.int32),
            mu=_zeros_like(params),
            nu=_zeros_like(params),
        )

    def update(grads, state, params):
        step_lr = lr_fn(state.count)
        mu = _tmap(lambda m, g: b1 * m + (1.0 - b1) * g, state.mu, grads)
        nu = _tmap(lambda v, g: b2 * v + (1.0 - b2) * g * g, state.nu, grads)

        def one(m1, v1, g, p):
            # bias corrections in at-least-f32: in bf16, 1 - 0.999^t rounds
            # to 0.0 (8 mantissa bits), making vhat 0/0=NaN on zero-gradient
            # coordinates and silently zeroing early updates otherwise
            # (f32/f64 paths are bit-identical to computing in g.dtype)
            t = (state.count + 1).astype(jnp.promote_types(g.dtype, jnp.float32))
            bc1 = (1.0 - jnp.power(b1, t)).astype(g.dtype)
            bc2 = (1.0 - jnp.power(b2, t)).astype(g.dtype)
            mhat = m1 / bc1
            vhat = v1 / bc2
            step = step_lr * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + step_lr * weight_decay * p
            return step

        step = _tmap(one, mu, nu, grads, params)
        return step, OptState(count=state.count + 1, mu=mu, nu=nu)

    def _kernel_call(state):
        kern = kdispatch.get_kernel("adam_adapt")
        step_lr = lr_fn(state.count)
        t = state.count + 1

        def call(g, m, v, gm):
            return kern(g, m, v, gm, t=t, b1=b1, b2=b2, eps=eps, lr=step_lr)

        return call

    def adaptation(grads, state, params):
        del params  # decoupled wd: no g dependence
        call = _kernel_call(state)

        def one(g, m, v):
            out, _ = call(g.reshape(-1), m.reshape(-1), v.reshape(-1),
                          jnp.ones_like(g.reshape(-1)))
            return out.reshape(g.shape)

        return _tmap(one, grads, state.mu, state.nu)

    def adapt_product(grads, state, params, g_meta):
        del params
        return _fused_product(_kernel_call(state), grads, state.mu, state.nu, g_meta)

    return Optimizer("adam", init, update, adaptation, adapt_product)


def adamw(
    lr: schedules.ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    """AdamW = Adam with decoupled weight decay on by default. Identical
    adaptation contract (and fused ``adam_adapt`` kernel route) to ``adam``:
    the decay term has no gradient dependence, so du/dg is untouched."""

    opt = adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    return dataclasses.replace(opt, name="adamw")


def rmsprop(
    lr: schedules.ScalarOrSchedule,
    rho: float = 0.99,
    eps: float = 1e-8,
) -> Optimizer:
    """v' = rho*v + (1-rho) g^2 ; u = lr * g / (sqrt(v') + eps).

    Adaptation contract: the EXACT elementwise diagonal at (g, nu):

        du/dg = lr * [ 1/denom - g^2 (1-rho) / (sqrt(v') * denom^2) ],
        denom = sqrt(v') + eps.

    No fused kernel registered yet — the pure-jnp expression below is the
    reference; add one via ``register_kernel`` per docs/kernels.md if
    rmsprop ever lands in a hot path."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        return OptState(count=jnp.zeros([], jnp.int32), nu=_zeros_like(params))

    def update(grads, state, params):
        del params
        step_lr = lr_fn(state.count)
        nu = _tmap(lambda v, g: rho * v + (1.0 - rho) * g * g, state.nu, grads)
        step = _tmap(lambda g, v: step_lr * g / (jnp.sqrt(v) + eps), grads, nu)
        return step, OptState(count=state.count + 1, nu=nu)

    def adaptation(grads, state, params):
        del params
        step_lr = lr_fn(state.count)

        def one(g, v):
            v1 = rho * v + (1.0 - rho) * g * g
            sq = jnp.sqrt(v1)
            denom = sq + eps
            safe_sq = jnp.maximum(sq, 1e-15)
            return step_lr * (1.0 / denom - g * (1.0 - rho) * g / (safe_sq * denom * denom))

        return _tmap(one, grads, state.nu)

    return Optimizer("rmsprop", init, update, adaptation)


# ---------------------------------------------------------------------------
# Lion (sign-momentum) — surrogate adaptation
# ---------------------------------------------------------------------------


def lion(
    lr: schedules.ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.99,
    weight_decay: float = 0.0,
    adapt_delta: float = 1e-3,
) -> Optimizer:
    """Lion (Chen et al., Symbolic Discovery of Optimization Algorithms):

        c  = b1*m + (1-b1)*g          (update interpolation)
        u  = lr * (sign(c) + wd*p)    (decoupled decay)
        m' = b2*m + (1-b2)*g

    Adaptation contract: the exact derivative of ``sign`` is zero almost
    everywhere, which would silently turn SAMA into its no-adaptation
    ablation (SAMA-NA). ``adaptation`` therefore declares the smoothed
    surrogate ``sign_d(c) = c/(|c|+delta)`` and returns ITS elementwise
    diagonal,

        du/dg = lr * (1-b1) * delta / (|c| + delta)^2,

    evaluated at (g, mu) with ``delta = adapt_delta`` (sharp sign as
    delta -> 0; mass concentrates on coordinates where the momentum vote is
    contested, |c| ~ 0, which is exactly where a gradient nudge can flip the
    sign). It is NOT the a.e.-zero jacfwd diagonal of the hard-sign update
    — tests pin it against the surrogate's jacfwd instead. Both
    ``adaptation`` and the fused ``adapt_product`` route through the
    ``lion_adapt`` kernel in the dispatch registry (docs/kernels.md)."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        return OptState(count=jnp.zeros([], jnp.int32), mu=_zeros_like(params))

    def update(grads, state, params):
        step_lr = lr_fn(state.count)

        def one(m, g, p):
            c = b1 * m + (1.0 - b1) * g
            step = step_lr * jnp.sign(c)
            if weight_decay:
                step = step + step_lr * weight_decay * p
            return step

        step = _tmap(one, state.mu, grads, params)
        mu = _tmap(lambda m, g: b2 * m + (1.0 - b2) * g, state.mu, grads)
        return step, OptState(count=state.count + 1, mu=mu)

    def _kernel_call(state):
        kern = kdispatch.get_kernel("lion_adapt")
        step_lr = lr_fn(state.count)

        def call(g, m, gm):
            return kern(g, m, gm, lr=step_lr, b1=b1, delta=adapt_delta)

        return call

    def adaptation(grads, state, params):
        del params
        call = _kernel_call(state)

        def one(g, m):
            out, _ = call(g.reshape(-1), m.reshape(-1), jnp.ones_like(g.reshape(-1)))
            return out.reshape(g.shape)

        return _tmap(one, grads, state.mu)

    def adapt_product(grads, state, params, g_meta):
        del params
        return _fused_product(_kernel_call(state), grads, state.mu, g_meta)

    return Optimizer("lion", init, update, adaptation, adapt_product)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment) — frozen-statistics adaptation
# ---------------------------------------------------------------------------


def _adafactor_stats(g, nu_leaf, t, b2, eps1):
    """Advance one leaf's (factored) second-moment statistics and return
    (new_nu_leaf, bias-corrected vhat). 2-D leaves factor into row/col
    means (O(r+c) state); everything else keeps the full moment."""

    g2 = g * g + eps1
    # at-least-f32 bias correction (see adam): 1 - b2^t rounds to 0 in bf16
    bc2 = (1.0 - jnp.power(
        b2, t.astype(jnp.promote_types(g.dtype, jnp.float32)))).astype(g.dtype)
    if "r" in nu_leaf:
        r1 = b2 * nu_leaf["r"] + (1.0 - b2) * jnp.mean(g2, axis=1)
        c1 = b2 * nu_leaf["c"] + (1.0 - b2) * jnp.mean(g2, axis=0)
        rhat = r1 / bc2
        chat = c1 / bc2
        vhat = rhat[:, None] * chat[None, :] / jnp.mean(rhat)
        return {"r": r1, "c": c1}, vhat
    v1 = b2 * nu_leaf["v"] + (1.0 - b2) * g2
    return {"v": v1}, v1 / bc2


def _adafactor_map(fn, grads, nu):
    """tree_map over (grads, nu) where nu's leaves are the per-param stat
    dicts (one level deeper than the grads tree)."""

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    nu_leaves = treedef.flatten_up_to(nu)
    out = [fn(g, n) for g, n in zip(leaves, nu_leaves)]
    return treedef, out


def adafactor(
    lr: schedules.ScalarOrSchedule,
    b2: float = 0.999,
    eps: float = 1e-8,
    eps1: float = 1e-30,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adafactor (Shazeer & Stern), simplified to its memory-factored core:
    2-D parameters keep row/col mean second-moment statistics (O(r+c)
    state instead of O(rc)), reconstructed as the rank-1
    ``vhat = rhat (x) chat / mean(rhat)``; other shapes keep the full
    moment. This variant uses Adam-style bias correction and a fixed
    ``b2`` in place of the original's relative step sizes and update
    clipping, so it composes with the repo's schedule/adaptation machinery.

        u = lr * g / (sqrt(vhat) + eps)   (+ lr*wd*p, decoupled)

    Adaptation contract: the factored statistics couple every element of a
    row/column, so the exact du/dg is NOT diagonal. ``adaptation`` declares
    the frozen-statistics diagonal

        du/dg = lr / (sqrt(vhat) + eps)

    — the derivative holding vhat fixed at its post-update value, exact in
    the b2 -> 1 limit where the statistics move slowly (and the analogue of
    the paper's Appendix C treatment of AdaGrad-family denominators). Both
    ``adaptation`` and the fused ``adapt_product`` route the elementwise
    tail through the ``adafactor_adapt`` kernel in the dispatch registry
    after the cheap rank-1 vhat reconstruction (docs/kernels.md)."""

    lr_fn = schedules.resolve(lr)

    def init(params):
        def one(p):
            if p.ndim == 2:
                return {"r": jnp.zeros((p.shape[0],), p.dtype),
                        "c": jnp.zeros((p.shape[1],), p.dtype)}
            return {"v": jnp.zeros_like(p)}

        return OptState(count=jnp.zeros([], jnp.int32), nu=_tmap(one, params))

    def update(grads, state, params):
        step_lr = lr_fn(state.count)
        p_leaves = jax.tree_util.tree_leaves(params)

        def one(g, nu_leaf):
            t = (state.count + 1).astype(g.dtype)
            return _adafactor_stats(g, nu_leaf, t, b2, eps1)

        treedef, pairs = _adafactor_map(one, grads, state.nu)
        nu = jax.tree_util.tree_unflatten(treedef, [n for n, _ in pairs])
        steps = []
        for (_, vhat), g, p in zip(pairs, jax.tree_util.tree_leaves(grads), p_leaves):
            step = step_lr * g / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + step_lr * weight_decay * p
            steps.append(step)
        step_tree = jax.tree_util.tree_unflatten(treedef, steps)
        return step_tree, OptState(count=state.count + 1, nu=nu)

    def _vhat_leaves(grads, state):
        def one(g, nu_leaf):
            t = (state.count + 1).astype(g.dtype)
            _, vhat = _adafactor_stats(g, nu_leaf, t, b2, eps1)
            return vhat

        return _adafactor_map(one, grads, state.nu)

    def adaptation(grads, state, params):
        del params
        kern = kdispatch.get_kernel("adafactor_adapt")
        step_lr = lr_fn(state.count)
        treedef, vhats = _vhat_leaves(grads, state)
        outs = []
        for vhat in vhats:
            out, _ = kern(vhat.reshape(-1), jnp.ones_like(vhat.reshape(-1)),
                          lr=step_lr, eps=eps)
            outs.append(out.reshape(vhat.shape))
        return jax.tree_util.tree_unflatten(treedef, outs)

    def adapt_product(grads, state, params, g_meta):
        del params
        kern = kdispatch.get_kernel("adafactor_adapt")
        step_lr = lr_fn(state.count)
        treedef, vhats = _vhat_leaves(grads, state)
        outs, total = [], None
        for vhat, gm in zip(vhats, jax.tree_util.tree_leaves(g_meta)):
            out, ss = kern(vhat.reshape(-1), gm.reshape(-1), lr=step_lr, eps=eps)
            outs.append(out.reshape(vhat.shape))
            total = ss if total is None else total + ss
        return jax.tree_util.tree_unflatten(treedef, outs), total

    return Optimizer("adafactor", init, update, adaptation, adapt_product)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_FACTORIES = {
    "sgd": sgd,
    "momentum": momentum,
    "adam": adam,
    "adamw": adamw,
    "rmsprop": rmsprop,
    "lion": lion,
    "adafactor": adafactor,
}


def get_optimizer(name: str, lr: schedules.ScalarOrSchedule, **kwargs) -> Optimizer:
    if name not in _FACTORIES:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(_FACTORIES)}")
    return _FACTORIES[name](lr, **kwargs)
