"""Measured wall-time protocol: warmup / repeat / block, compile-vs-run split.

Every timing number this repo reports flows through ``measure`` (or the
lighter ``time_callable``): warmup calls absorb tracing + first-touch
effects, every timed call ends in ``jax.block_until_ready`` so async
dispatch cannot hide work, and the reported statistic is the median with
an IQR spread — the robust pair for noisy shared machines (CI runners,
CPU containers). The compile phase is timed separately via
``lower().compile()`` so "it got slower" can always be attributed to
compile vs run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class TimingStats:
    """Robust run-phase statistics over ``repeats`` blocked calls (us)."""

    median_us: float
    iqr_us: float
    min_us: float
    max_us: float
    mean_us: float
    repeats: int
    warmup: int

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_samples(samples_s: Sequence[float], warmup: int) -> "TimingStats":
        us = np.asarray(samples_s, dtype=np.float64) * 1e6
        q1, q3 = np.percentile(us, [25, 75])
        return TimingStats(
            median_us=float(np.median(us)),
            iqr_us=float(q3 - q1),
            min_us=float(us.min()),
            max_us=float(us.max()),
            mean_us=float(us.mean()),
            repeats=int(us.size),
            warmup=int(warmup),
        )


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Tail-latency percentiles over per-request wall times (us).

    TimingStats measures one callable repeated under identical
    conditions — median + IQR is the right summary. Serving latency is
    the opposite regime: heterogeneous requests contending for batch
    slots, where the *tail* is the SLO. Hence explicit p50/p90/p99."""

    p50_us: float
    p90_us: float
    p99_us: float
    mean_us: float
    max_us: float
    n: int

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def empty() -> "LatencyStats":
        """The zero-request value (n=0, all percentiles 0.0).

        A serve run where every request is shed before decode has no
        latency samples but still needs a final report; callers check
        ``n == 0`` before treating the percentiles as measurements (and
        must NOT embed an empty section into a PerfRecord —
        ``validate_record`` requires positive percentiles there)."""

        return LatencyStats(p50_us=0.0, p90_us=0.0, p99_us=0.0,
                            mean_us=0.0, max_us=0.0, n=0)

    @staticmethod
    def from_samples(samples_s: Sequence[float]) -> "LatencyStats":
        if len(samples_s) == 0:
            return LatencyStats.empty()
        us = np.asarray(samples_s, dtype=np.float64) * 1e6
        p50, p90, p99 = np.percentile(us, [50, 90, 99])
        return LatencyStats(
            p50_us=float(p50), p90_us=float(p90), p99_us=float(p99),
            mean_us=float(us.mean()), max_us=float(us.max()), n=int(us.size),
        )


@dataclasses.dataclass(frozen=True)
class StepMeasurement:
    """One measured step function: run stats + the compile split + the
    compiled executable (reusable for memory / collective accounting)."""

    timing: TimingStats
    lower_s: Optional[float]
    compile_s: Optional[float]
    compiled: Optional[Any]  # jax.stages.Compiled when the split ran

    @property
    def us_per_step(self) -> float:
        return self.timing.median_us

    def samples_per_s(self, samples_per_step: float) -> float:
        return samples_per_step / (self.timing.median_us / 1e6)


def time_callable(fn: Callable, *args, warmup: int = 1, repeats: int = 5,
                  **kwargs) -> TimingStats:
    """Time ``fn(*args, **kwargs)`` with the warmup/repeat/block protocol.

    Works for any callable whose outputs are jax arrays (or pytrees of
    them) — no lowering required, so loops and host-side drivers can be
    timed with the same protocol as single jitted steps.
    """

    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        samples.append(time.perf_counter() - t0)
    return TimingStats.from_samples(samples, warmup)


def compile_split(fn: Callable, *args, **kwargs):
    """Lower + compile ``fn`` on example args, timing each phase.

    Returns ``(lower_s, compile_s, compiled)``. ``fn`` may be already
    jitted (jax.jit caches are shared, so a later ``fn(*args)`` call
    reuses this executable) or a plain traceable callable.
    """

    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    t0 = time.perf_counter()
    lowered = jitted.lower(*args, **kwargs)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return t1 - t0, t2 - t1, compiled


def measure(fn: Callable, *args, warmup: int = 2, repeats: int = 5,
            split_compile: bool = True, **kwargs) -> StepMeasurement:
    """The full protocol: (optionally) timed lower/compile, then
    warmup/repeat/block run timing. A plain traceable callable is timed
    through the jit wrapper whose compile was measured — never op-by-op
    eager; non-loweable callables (host loops, python drivers) still get
    run-phase stats, compile attribution is simply unavailable."""

    timed_fn = fn
    lower_s = comp_s = compiled = None
    if split_compile:
        jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
        try:
            lower_s, comp_s, compiled = compile_split(jitted, *args, **kwargs)
            timed_fn = jitted
        except Exception:
            lower_s = comp_s = compiled = None
    timing = time_callable(timed_fn, *args, warmup=warmup, repeats=repeats, **kwargs)
    return StepMeasurement(timing=timing, lower_s=lower_s, compile_s=comp_s,
                           compiled=compiled)
