"""The versioned ``PerfRecord`` schema and the ``BENCH_<name>.json`` files.

One ``PerfRecord`` = one measured probe (a step function, a decode loop,
a whole bench arm): robust run timing (timers.TimingStats), the compile
split, throughput, per-device memory breakdown (memory.memory_report)
and the trip-scaled collective census (collectives.census). A bench file
bundles the bench's CSV-equivalent ``rows`` with its ``records`` plus
environment provenance — the unit the regression gate (gate.py) compares
against committed baselines.

Writes are atomic (tmp file + ``os.replace``) so a killed bench run can
never leave a half-written JSON where the trajectory tracker reads.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Optional

import jax

from repro.perf.timers import StepMeasurement, TimingStats

SCHEMA_VERSION = 1

_TIMING_KEYS = {"median_us", "iqr_us", "min_us", "max_us", "mean_us", "repeats", "warmup"}
_LATENCY_KEYS = {"p50_us", "p90_us", "p99_us", "mean_us", "max_us", "n"}


@dataclasses.dataclass
class PerfRecord:
    """One measured performance probe. Sections are optional — a memory
    sweep has no timing, a census probe has neither — but a record with
    no section at all is invalid."""

    name: str
    us_per_step: Optional[Dict[str, Any]] = None  # TimingStats.as_dict()
    samples_per_s: Optional[float] = None
    compile_s: Optional[float] = None
    lower_s: Optional[float] = None
    memory: Optional[Dict[str, Any]] = None  # memory.memory_report()
    collectives: Optional[Dict[str, Any]] = None  # collectives.census()
    latency: Optional[Dict[str, Any]] = None  # timers.LatencyStats.as_dict()
    attribution: Optional[Dict[str, Any]] = None  # obs.profile.attribute()
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    @staticmethod
    def from_measurement(name: str, m: StepMeasurement, *,
                         samples_per_step: Optional[float] = None,
                         memory: Optional[Dict[str, Any]] = None,
                         collectives: Optional[Dict[str, Any]] = None,
                         extra: Optional[Dict[str, Any]] = None) -> "PerfRecord":
        return PerfRecord(
            name=name,
            us_per_step=m.timing.as_dict(),
            samples_per_s=(m.samples_per_s(samples_per_step)
                           if samples_per_step is not None else None),
            compile_s=m.compile_s,
            lower_s=m.lower_s,
            memory=memory,
            collectives=collectives,
            extra=dict(extra or {}),
        )

    @property
    def timing(self) -> Optional[TimingStats]:
        if self.us_per_step is None:
            return None
        return TimingStats(**{k: self.us_per_step[k] for k in _TIMING_KEYS})


def validate_attribution(d: Dict[str, Any]) -> List[str]:
    """Schema errors for one ``attribution`` section ([] = valid).

    The section is additive to schema v1 (like ``latency``): an optional
    dict produced by ``repro.obs.profile.attribute`` — per-phase FLOP /
    bytes / collective partition of one compiled step, with optional
    measured ``wall_us`` / ``utilization`` per phase. Lives here (not in
    obs) so the schema home stays one module."""

    errors: List[str] = []
    if not isinstance(d, dict):
        return [f"attribution must be a dict, got {type(d).__name__}"]
    phases = d.get("phases")
    if not isinstance(phases, dict) or not phases:
        return ["attribution.phases must be a non-empty dict"]
    frac_sum = 0.0
    for name, b in phases.items():
        if not isinstance(b, dict):
            errors.append(f"attribution.phases[{name!r}] must be a dict")
            continue
        for key in ("flops", "flop_frac"):
            v = b.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"attribution.phases[{name!r}].{key} must be "
                              "a non-negative number")
        frac_sum += float(b.get("flop_frac") or 0.0)
        wall = b.get("wall_us")
        if wall is not None and (not isinstance(wall, (int, float)) or wall <= 0):
            errors.append(f"attribution.phases[{name!r}].wall_us must be > 0")
    total = d.get("total")
    if not isinstance(total, dict) or "flops" not in total:
        errors.append("attribution.total must carry at least flops")
    cov = d.get("coverage")
    if not isinstance(cov, (int, float)) or not (0.0 <= cov <= 1.0 + 1e-9):
        errors.append("attribution.coverage must be a number in [0, 1]")
    total_flops = (total or {}).get("flops") or 0.0
    if total_flops > 0 and abs(frac_sum - 1.0) > 1e-3:
        errors.append(f"attribution phase flop_fracs sum to {frac_sum:.6f}, "
                      "expected ~1")
    return errors


def validate_record(d: Dict[str, Any]) -> List[str]:
    """Schema errors for one record dict ([] = valid)."""

    errors: List[str] = []
    if not isinstance(d, dict):
        return [f"record must be a dict, got {type(d).__name__}"]
    if not isinstance(d.get("name"), str) or not d.get("name"):
        errors.append("record.name must be a non-empty string")
    if d.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"record.schema_version must be {SCHEMA_VERSION}, "
                      f"got {d.get('schema_version')!r}")
    timing = d.get("us_per_step")
    if timing is not None:
        if not isinstance(timing, dict) or not _TIMING_KEYS <= set(timing):
            errors.append(f"record.us_per_step must carry {sorted(_TIMING_KEYS)}")
        elif timing["median_us"] <= 0:
            errors.append("record.us_per_step.median_us must be > 0")
    for scalar in ("samples_per_s", "compile_s", "lower_s"):
        v = d.get(scalar)
        if v is not None and (not isinstance(v, (int, float)) or v < 0):
            errors.append(f"record.{scalar} must be a non-negative number")
    mem = d.get("memory")
    if mem is not None:
        per_dev = mem.get("per_device") if isinstance(mem, dict) else None
        if not isinstance(per_dev, dict) or "argument_bytes" not in per_dev \
                or "source" not in per_dev:
            errors.append("record.memory.per_device must carry at least "
                          "argument_bytes and source")
    coll = d.get("collectives")
    if coll is not None:
        if not isinstance(coll, dict) or "total_count" not in coll \
                or "all-reduce_count" not in coll:
            errors.append("record.collectives must carry per-type and total counts")
    lat = d.get("latency")
    if lat is not None:
        if not isinstance(lat, dict) or not _LATENCY_KEYS <= set(lat):
            errors.append(f"record.latency must carry {sorted(_LATENCY_KEYS)}")
        elif lat["p50_us"] <= 0 or lat["p99_us"] < lat["p50_us"]:
            errors.append("record.latency needs p50_us > 0 and p99_us >= p50_us")
    attr = d.get("attribution")
    if attr is not None:
        errors.extend(f"record {d.get('name')!r}: {e}"
                      for e in validate_attribution(attr))
    if d.get("us_per_step") is None and mem is None and coll is None \
            and lat is None and attr is None:
        errors.append(f"record {d.get('name')!r} carries no measured section "
                      "(us_per_step / memory / collectives / latency / "
                      "attribution)")
    return errors


# ---------------------------------------------------------------------------
# bench files
# ---------------------------------------------------------------------------


def env_info() -> Dict[str, Any]:
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def bench_payload(bench: str, *, fast: bool, elapsed_s: float,
                  rows: List[Dict[str, Any]],
                  records: List[PerfRecord]) -> Dict[str, Any]:
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "fast": fast,
        "elapsed_s": round(elapsed_s, 1),
        "env": env_info(),
        "rows": list(rows),
        "records": [r.as_dict() if isinstance(r, PerfRecord) else r for r in records],
    }


def validate_bench(payload: Dict[str, Any]) -> List[str]:
    errors: List[str] = []
    if payload.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"bench.schema_version must be {SCHEMA_VERSION}")
    if not isinstance(payload.get("bench"), str) or not payload.get("bench"):
        errors.append("bench.bench must be a non-empty string")
    if not isinstance(payload.get("rows"), list):
        errors.append("bench.rows must be a list")
    records = payload.get("records")
    if not isinstance(records, list):
        errors.append("bench.records must be a list")
    else:
        for rec in records:
            errors.extend(validate_record(rec))
    return errors


def write_json_atomic(path: str, payload: Dict[str, Any]) -> None:
    """Write JSON via tmp file + rename — readers never see a torn file."""

    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".tmp_",
                               suffix=os.path.basename(path))
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=False)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def write_bench(path: str, payload: Dict[str, Any]) -> None:
    """Validate + atomically write one BENCH_<name>.json."""

    errors = validate_bench(payload)
    if errors:
        raise ValueError(f"invalid bench payload for {path}: " + "; ".join(errors))
    write_json_atomic(path, payload)


def load_bench(path: str) -> Dict[str, Any]:
    with open(path) as f:
        payload = json.load(f)
    errors = validate_bench(payload)
    if errors:
        raise ValueError(f"invalid bench file {path}: " + "; ".join(errors))
    return payload
