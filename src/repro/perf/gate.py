"""Regression gate: measured BENCH_*.json vs committed baselines.

    PYTHONPATH=src python -m repro.perf.gate \
        --records bench_out --baselines benchmarks/baselines [--strict-missing]

Records are matched bench-file by bench-file, then record by ``name``.
Per-metric tolerance bands (regressions only — getting faster/smaller
never fails):

* ``us_per_step.median``  — ratio band, default 2.5x (CI wall time on
  shared CPU runners is noisy; the band catches order-of-magnitude
  regressions, the trajectory catches drift)
* ``samples_per_s``       — inverse ratio band (same default)
* ``latency.p50_us/p99_us`` — ratio band (same default as time: served
  tail latency on shared runners inherits the same noise floor)
* ``memory.peak_bytes``   — ratio band, default 1.15x (buffer assignment
  is deterministic; 15% absorbs compiler-version churn)
* ``collectives.*_count`` — EXACT. A new all-reduce is a structural
  regression of the single-sync schedule, never noise.
* ``collectives.total_bytes`` — ratio band, default 1.10x

A record with no committed baseline is reported as NEW (pass); a
baseline whose record is missing from the run is MISSING — a pass by
default so subset CI jobs can gate what they ran, an error under
``--strict-missing`` (lost coverage should not slip through full runs).
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import os
import sys
from typing import Any, Dict, List, Optional

from repro.perf import record as record_mod


@dataclasses.dataclass(frozen=True)
class Tolerance:
    time_ratio: float = 2.5
    throughput_ratio: float = 2.5
    memory_ratio: float = 1.15
    collective_bytes_ratio: float = 1.10
    #: per-phase attributed FLOPs are deterministic given the jax pin
    #: (perf-gate CI pins it); 10% absorbs compiler-churn refusion only
    attribution_flops_ratio: float = 1.10


@dataclasses.dataclass(frozen=True)
class Violation:
    bench: str
    record: str
    metric: str
    baseline: float
    current: float
    limit: float

    def __str__(self) -> str:
        return (f"REGRESSION {self.bench}/{self.record}: {self.metric} "
                f"{self.current:.6g} vs baseline {self.baseline:.6g} "
                f"(limit {self.limit:.6g})")


def _peak_bytes(rec: Dict[str, Any]) -> Optional[float]:
    per_dev = (rec.get("memory") or {}).get("per_device") or {}
    peak = per_dev.get("peak_bytes")
    return float(peak) if peak is not None else None


def compare_record(bench: str, current: Dict[str, Any], baseline: Dict[str, Any],
                   tol: Tolerance) -> List[Violation]:
    """Band-compare one measured record against its committed baseline.
    Only metrics present in BOTH records participate."""

    name = current["name"]
    out: List[Violation] = []

    cur_t, base_t = current.get("us_per_step"), baseline.get("us_per_step")
    if cur_t and base_t:
        limit = base_t["median_us"] * tol.time_ratio
        if cur_t["median_us"] > limit:
            out.append(Violation(bench, name, "us_per_step.median_us",
                                 base_t["median_us"], cur_t["median_us"], limit))

    cur_s, base_s = current.get("samples_per_s"), baseline.get("samples_per_s")
    if cur_s is not None and base_s is not None and base_s > 0:
        limit = base_s / tol.throughput_ratio
        if cur_s < limit:
            out.append(Violation(bench, name, "samples_per_s", base_s, cur_s, limit))

    cur_l, base_l = current.get("latency"), baseline.get("latency")
    if cur_l and base_l:
        for key in ("p50_us", "p99_us"):  # the served-SLO pair (timers.LatencyStats)
            if key in cur_l and key in base_l:
                limit = base_l[key] * tol.time_ratio
                if cur_l[key] > limit:
                    out.append(Violation(bench, name, f"latency.{key}",
                                         base_l[key], cur_l[key], limit))

    cur_m, base_m = _peak_bytes(current), _peak_bytes(baseline)
    if cur_m is not None and base_m is not None and base_m > 0:
        limit = base_m * tol.memory_ratio
        if cur_m > limit:
            out.append(Violation(bench, name, "memory.peak_bytes", base_m, cur_m, limit))

    cur_c, base_c = current.get("collectives"), baseline.get("collectives")
    if cur_c and base_c:
        for key, base_val in base_c.items():
            if key.endswith("_count") and key in cur_c:
                if float(cur_c[key]) != float(base_val):
                    out.append(Violation(bench, name, f"collectives.{key}",
                                         float(base_val), float(cur_c[key]),
                                         float(base_val)))
        if "total_bytes" in cur_c and "total_bytes" in base_c and base_c["total_bytes"] > 0:
            limit = base_c["total_bytes"] * tol.collective_bytes_ratio
            if cur_c["total_bytes"] > limit:
                out.append(Violation(bench, name, "collectives.total_bytes",
                                     base_c["total_bytes"], cur_c["total_bytes"], limit))

    # per-phase attribution bands: FLOPs (tight — deterministic counts)
    # and measured wall time (the noisy time band). A CI failure here
    # names the phase, not just the record.
    cur_a = (current.get("attribution") or {}).get("phases") or {}
    base_a = (baseline.get("attribution") or {}).get("phases") or {}
    for ph in sorted(set(cur_a) & set(base_a)):
        cb, bb = cur_a[ph], base_a[ph]
        base_fl = float(bb.get("flops") or 0.0)
        if base_fl > 0 and cb.get("flops") is not None:
            limit = base_fl * tol.attribution_flops_ratio
            if float(cb["flops"]) > limit:
                out.append(Violation(bench, name, f"attribution.{ph}.flops",
                                     base_fl, float(cb["flops"]), limit))
        base_w = bb.get("wall_us")
        if base_w and cb.get("wall_us") is not None:
            limit = float(base_w) * tol.time_ratio
            if float(cb["wall_us"]) > limit:
                out.append(Violation(bench, name, f"attribution.{ph}.wall_us",
                                     float(base_w), float(cb["wall_us"]), limit))
    return out


@dataclasses.dataclass
class GateReport:
    violations: List[Violation]
    compared: int
    new_records: List[str]
    #: baselined records absent from a bench that WAS re-run — lost coverage
    missing_records: List[str]
    #: baselined benches not re-run at all — expected for subset CI jobs
    missing_benches: List[str]
    #: "bench: current_jax vs baseline_jax" where env.jax_version differs —
    #: the memory/collective hard bands are XLA-version-dependent
    env_mismatches: List[str] = dataclasses.field(default_factory=list)

    def ok(self, *, strict_missing: bool = False,
           strict_missing_records: bool = False) -> bool:
        """``strict_missing`` fails on ANY baselined-but-absent coverage
        (full-run mode); ``strict_missing_records`` fails only on records
        missing from benches that were re-run — the right strictness for
        subset CI jobs, where whole non-run benches are expected but a
        re-run bench silently dropping a gated record is not."""

        if self.violations:
            return False
        if strict_missing and (self.missing_records or self.missing_benches):
            return False
        if strict_missing_records and self.missing_records:
            return False
        return True


def compare_bench(current: Dict[str, Any], baseline: Dict[str, Any],
                  tol: Tolerance) -> GateReport:
    bench = current["bench"]
    cur = {r["name"]: r for r in current["records"]}
    base = {r["name"]: r for r in baseline["records"]}
    violations: List[Violation] = []
    compared = 0
    for name in sorted(set(cur) & set(base)):
        compared += 1
        violations.extend(compare_record(bench, cur[name], base[name], tol))
    cur_jax = (current.get("env") or {}).get("jax_version")
    base_jax = (baseline.get("env") or {}).get("jax_version")
    return GateReport(
        violations=violations,
        compared=compared,
        new_records=[f"{bench}/{n}" for n in sorted(set(cur) - set(base))],
        missing_records=[f"{bench}/{n}" for n in sorted(set(base) - set(cur))],
        missing_benches=[],
        env_mismatches=([f"{bench}: jax {cur_jax} vs baseline {base_jax}"]
                        if cur_jax != base_jax else []),
    )


def compare_dirs(records_dir: str, baselines_dir: str,
                 tol: Optional[Tolerance] = None) -> GateReport:
    """Gate every BENCH_*.json under ``records_dir`` against its namesake
    under ``baselines_dir``. Baselines with no run file count as missing
    benches (see --strict-missing); run files with no baseline are NEW."""

    tol = tol or Tolerance()
    total = GateReport([], 0, [], [], [])
    cur_files = {os.path.basename(p): p
                 for p in glob.glob(os.path.join(records_dir, "BENCH_*.json"))}
    base_files = {os.path.basename(p): p
                  for p in glob.glob(os.path.join(baselines_dir, "BENCH_*.json"))}
    if not cur_files:
        raise FileNotFoundError(f"no BENCH_*.json under {records_dir}")
    for fname, path in sorted(cur_files.items()):
        current = record_mod.load_bench(path)
        if fname not in base_files:
            total.new_records.append(f"{current['bench']} (whole bench)")
            continue
        report = compare_bench(current, record_mod.load_bench(base_files[fname]),
                               tol)
        total.violations.extend(report.violations)
        total.compared += report.compared
        total.new_records.extend(report.new_records)
        total.missing_records.extend(report.missing_records)
        total.env_mismatches.extend(report.env_mismatches)
    total.missing_benches = [f[len("BENCH_"):-len(".json")]
                             for f in sorted(set(base_files) - set(cur_files))]
    return total


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--records", required=True, help="dir with the run's BENCH_*.json")
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="dir with committed baseline BENCH_*.json")
    ap.add_argument("--tol-time", type=float, default=Tolerance.time_ratio)
    ap.add_argument("--tol-throughput", type=float, default=Tolerance.throughput_ratio)
    ap.add_argument("--tol-memory", type=float, default=Tolerance.memory_ratio)
    ap.add_argument("--tol-collective-bytes", type=float,
                    default=Tolerance.collective_bytes_ratio)
    ap.add_argument("--tol-attr-flops", type=float,
                    default=Tolerance.attribution_flops_ratio,
                    help="ratio band on per-phase attributed FLOPs")
    ap.add_argument("--strict-missing", action="store_true",
                    help="fail when ANY baselined bench/record was not re-measured "
                         "(full-run mode)")
    ap.add_argument("--strict-missing-records", action="store_true",
                    help="fail when a RE-RUN bench silently dropped a baselined "
                         "record (subset-CI mode: whole non-run benches still pass)")
    args = ap.parse_args(argv)

    tol = Tolerance(time_ratio=args.tol_time, throughput_ratio=args.tol_throughput,
                    memory_ratio=args.tol_memory,
                    collective_bytes_ratio=args.tol_collective_bytes,
                    attribution_flops_ratio=args.tol_attr_flops)
    try:
        report = compare_dirs(args.records, args.baselines, tol)
    except (FileNotFoundError, ValueError) as e:
        print(f"perf-gate: ERROR {e}")
        return 2

    for v in report.violations:
        print(str(v))
    for name in report.new_records:
        print(f"NEW {name} (no baseline — commit one to start gating it)")
    for name in report.missing_records:
        print(f"MISSING record {name} (baselined but not in this run)")
    for name in report.missing_benches:
        print(f"MISSING bench {name} (baselined but not in this run)")
    for msg in report.env_mismatches:
        print(f"WARNING env mismatch {msg} — the memory/collective hard bands "
              "are XLA-version-dependent; re-baseline on the new version if "
              "they trip")
    ok = report.ok(strict_missing=args.strict_missing,
                   strict_missing_records=args.strict_missing_records)
    print(f"perf-gate: {report.compared} records compared, "
          f"{len(report.violations)} regressions -> {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
