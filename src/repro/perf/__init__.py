"""Measured-performance telemetry: timers, memory accounting, collective
census, the versioned PerfRecord schema, and the baseline regression gate.

The paper's headline claims are systems numbers (throughput, memory,
collective count). ``repro.roofline`` predicts them analytically; this
package MEASURES them — every benchmark, example and the MetaLearner
facade reports through it, and CI gates the results against committed
baselines (gate.py). See DESIGN.md §9.

    from repro import perf

    m = perf.measure(jitted_step, state, bb, mb)          # warmup/repeat/block
    rec = perf.profile_step("sama", jitted_step, state, bb, mb,
                            samples_per_step=batch * unroll)
    rec.as_dict()  # -> PerfRecord JSON (timing + memory + collectives)
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from repro.perf.collectives import census, census_of, verify_single_sync
from repro.perf.gate import GateReport, Tolerance, compare_dirs, compare_record
from repro.perf.memory import (
    MemoryStats,
    compiled_memory,
    device_memory,
    memory_report,
    tree_bytes,
)
from repro.perf.record import (
    SCHEMA_VERSION,
    PerfRecord,
    bench_payload,
    env_info,
    load_bench,
    validate_attribution,
    validate_bench,
    validate_record,
    write_bench,
    write_json_atomic,
)
from repro.perf.timers import (
    LatencyStats,
    StepMeasurement,
    TimingStats,
    compile_split,
    measure,
    time_callable,
)


def profile_step(name: str, fn, *args, samples_per_step: Optional[float] = None,
                 warmup: int = 2, repeats: int = 5,
                 extra: Optional[Dict[str, Any]] = None,
                 attribution: bool = False,
                 attribution_spans=None) -> PerfRecord:
    """The full protocol on one step function: compile split + run timing
    + per-device memory + trip-scaled collective census, as a PerfRecord.
    Call under the owning mesh context when the step is sharded.

    ``attribution=True`` additionally partitions the compiled HLO's
    FLOPs/bytes/collectives by engine phase (``repro.obs.profile``) into
    the record's optional ``attribution`` section;
    ``attribution_spans`` (measured ``Tracer`` spans, e.g. from
    ``MetaLearner.phase_profile``) joins per-phase wall time and
    roofline utilization into it."""

    m = measure(fn, *args, warmup=warmup, repeats=repeats)
    mem = coll = None
    if m.compiled is not None:
        mem = memory_report(m.compiled, example_args=args)
        coll = census(m.compiled)
    rec = PerfRecord.from_measurement(
        name, m, samples_per_step=samples_per_step, memory=mem,
        collectives=coll, extra=extra,
    )
    if attribution and m.compiled is not None:
        from repro.obs import profile as profile_mod  # lazy: obs imports perf

        rec.attribution = profile_mod.attribute(m.compiled,
                                                spans=attribution_spans)
    return rec


__all__ = [
    "GateReport", "LatencyStats", "MemoryStats", "PerfRecord", "SCHEMA_VERSION",
    "StepMeasurement", "TimingStats", "Tolerance",
    "bench_payload", "census", "census_of", "compare_dirs", "compare_record",
    "compile_split", "compiled_memory", "device_memory", "env_info",
    "load_bench", "measure", "memory_report", "profile_step", "time_callable",
    "tree_bytes", "validate_attribution", "validate_bench", "validate_record",
    "verify_single_sync", "write_bench", "write_json_atomic",
]
