"""Measured collective census from the *compiled* program.

The single-sync schedule's claim — exactly ``unroll_steps`` base
all-reduces plus ONE meta bucket — is structural, so it must be audited
on what actually runs: the partitioned HLO of the compiled executable,
not the hand-written schedule. This module is that audit, built on
``roofline.hlo_parse``'s trip-count correction (collectives inside scan
bodies are scaled by the loop's ``known_trip_count`` — XLA's own
cost_analysis counts loop bodies once and would undercount them).
"""

from __future__ import annotations

from typing import Any, Dict

from repro.roofline import hlo_parse

COLLECTIVES = hlo_parse.COLLECTIVES


def _hlo_text(compiled_or_text) -> str:
    if isinstance(compiled_or_text, str):
        return compiled_or_text
    return compiled_or_text.as_text()


def census(compiled_or_text) -> Dict[str, Any]:
    """Trip-count-scaled per-type collective counts/bytes of a compiled
    executable (or raw HLO text). Counts come back as ints — a fractional
    collective count would mean the trip-count propagation broke."""

    stats = hlo_parse.collective_stats(_hlo_text(compiled_or_text))
    out: Dict[str, Any] = {}
    for key, val in stats.items():
        if key.endswith("_count"):
            as_int = int(round(val))
            out[key] = as_int if abs(val - as_int) < 1e-9 else val
        else:
            out[key] = val
    return out


def census_of(fn, *args, **kwargs) -> Dict[str, Any]:
    """Convenience: lower + compile ``fn`` on example args and census the
    result. ``fn`` may be jitted already; mesh context is the caller's."""

    import jax

    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    return census(jitted.lower(*args, **kwargs).compile())


def verify_single_sync(compiled_or_text, unroll_steps: int) -> Dict[str, Any]:
    """Check the paper's single-sync invariant on a compiled manual step:
    trip-scaled all-reduce count == unroll_steps (per-step base DDP syncs)
    + 1 (the one meta bucket). Returns the census dict augmented with
    ``single_sync_ok`` / ``expected_all_reduces`` so callers can record
    the verdict; raises nothing — gates decide what failure means."""

    stats = census(compiled_or_text)
    expected = unroll_steps + 1
    stats["expected_all_reduces"] = expected
    stats["single_sync_ok"] = stats["all-reduce_count"] == expected
    return stats
