"""Per-device memory accounting for compiled step functions.

Primary source: ``compiled.memory_analysis()`` — XLA's per-partition
buffer assignment, split into argument / output / temp / generated-code
bytes. Peak here is the standard upper-bound composition
``argument + output + temp`` (aliased buffers subtracted), the same
number the repo's Fig. 1 memory claims are stated in.

Runtime source: ``device.memory_stats()`` (live/peak allocator bytes).
Real accelerators report it; the CPU container returns ``None`` — so
every consumer must tolerate the fallback chain:

    memory_analysis  ->  aval arithmetic (argument/output only, temp unknown)

``source`` on the returned stats says which path produced the numbers.
Consumers that make DECISIONS on these numbers (the repro.scale
``plan_microbatch`` HBM-budget search) key off ``source`` — planning is
trustworthy under ``memory_analysis``, best-effort under the fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax

#: how the numbers were obtained, strongest first
SOURCE_COMPILED = "memory_analysis"
SOURCE_AVAL = "aval_fallback"
SOURCE_DEVICE = "device_memory_stats"


@dataclasses.dataclass(frozen=True)
class MemoryStats:
    """Per-device compiled-step memory breakdown (bytes)."""

    argument_bytes: int
    output_bytes: int
    temp_bytes: Optional[int]
    generated_code_bytes: Optional[int]
    alias_bytes: Optional[int]
    peak_bytes: Optional[int]
    source: str

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def tree_bytes(tree) -> int:
    """Total bytes of every leaf (shape x itemsize; shape/dtype-only
    leaves like ShapeDtypeStructs count too). Used for the aval fallback
    here and by the repro.scale planner's activation estimate."""

    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            continue
        n = 1
        for d in shape:
            n *= int(d)
        total += n * dtype.itemsize
    return total


def compiled_memory(compiled, *, example_args=None, example_out=None) -> MemoryStats:
    """Memory breakdown of a ``jax.stages.Compiled`` step.

    When ``memory_analysis()`` is unavailable (some backends return None
    or raise), falls back to aval arithmetic over ``example_args`` /
    ``example_out`` pytrees: argument/output bytes are exact, temp bytes
    are unknowable without the buffer assignment and reported as None.
    """

    ma = None
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if isinstance(ma, (list, tuple)):  # per-partition list on some versions
        ma = ma[0] if ma else None
    if ma is not None:
        arg = int(ma.argument_size_in_bytes)
        out = int(ma.output_size_in_bytes)
        temp = int(ma.temp_size_in_bytes)
        alias = int(getattr(ma, "alias_size_in_bytes", 0))
        code = int(getattr(ma, "generated_code_size_in_bytes", 0))
        return MemoryStats(
            argument_bytes=arg, output_bytes=out, temp_bytes=temp,
            generated_code_bytes=code, alias_bytes=alias,
            peak_bytes=arg + out + temp - alias, source=SOURCE_COMPILED,
        )
    arg = tree_bytes(example_args) if example_args is not None else 0
    out = tree_bytes(example_out) if example_out is not None else 0
    return MemoryStats(
        argument_bytes=arg, output_bytes=out, temp_bytes=None,
        generated_code_bytes=None, alias_bytes=None, peak_bytes=None,
        source=SOURCE_AVAL,
    )


def device_memory() -> Optional[List[Dict[str, Any]]]:
    """Live/peak allocator bytes per local device, or ``None`` where the
    backend has no allocator stats (CPU)."""

    rows = []
    for dev in jax.local_devices():
        stats = dev.memory_stats() if hasattr(dev, "memory_stats") else None
        if stats is None:
            return None
        rows.append({
            "device": str(dev),
            "live_bytes": int(stats.get("bytes_in_use", 0)),
            "peak_bytes": int(stats.get("peak_bytes_in_use", 0)),
            "source": SOURCE_DEVICE,
        })
    return rows


def memory_report(compiled, *, example_args=None, example_out=None) -> Dict[str, Any]:
    """The JSON-able memory section of a PerfRecord: per-device compiled
    breakdown plus runtime allocator stats when the backend exposes them."""

    per_device = compiled_memory(compiled, example_args=example_args,
                                 example_out=example_out)
    report: Dict[str, Any] = {
        "per_device": per_device.as_dict(),
        "n_devices": jax.device_count(),
    }
    live = device_memory()
    if live is not None:
        report["device_stats"] = live
    return report
