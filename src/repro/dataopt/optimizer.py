"""The DataOptimizer facade (DESIGN.md §8) — data optimization as one object.

    from repro.dataopt import DataOptimizer

    opt = DataOptimizer(model, train, meta=dev, scorer="meta", steps=80)
    scores = opt.fit_scores()                      # any registered scorer
    pruned, mask = opt.prune(ratio=0.3)            # or class_balanced=True
    theta = opt.retrain(steps=150)                 # fresh model on the keep set
    it = opt.reweighted_iterator(batch_size=32, meta_batch_size=32, unroll=2)
    opt.export("out/scores")                       # manifest-validated

Swapping ``scorer="meta"`` for ``"el2n"`` / ``"random"`` (or any
``register_scorer`` name) is the ONE argument that changes — everything
downstream (prune, retrain, reweight, export) consumes the uniform score
array. A ``mesh`` makes every full-dataset pass shard over its data axes.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.dataopt import export as export_mod
from repro.dataopt import prune as prune_mod
from repro.dataopt.reweight import ReweightedIterator
from repro.dataopt.scores import ScoreContext, resolve_scorer

PyTree = Any


class DataOptimizer:
    """Owns one dataset + one scorer; every product (masks, subsets,
    iterators, retrained params, exports) is derived from ``self.scores``.

    ``model`` is anything with ``init(key)`` and a per-example adapter
    (``classifier_per_example`` by default); pass ``per_example_fn`` /
    ``init_fn`` explicitly for bare function models (tests use tiny MLPs
    through ``problems.softmax_per_example``)."""

    def __init__(
        self,
        model=None,
        train: Dict[str, np.ndarray] = None,
        *,
        meta: Optional[Dict[str, np.ndarray]] = None,
        scorer: Any = "meta",
        per_example_fn=None,
        init_fn=None,
        num_classes: Optional[int] = None,
        fields: Tuple[str, ...] = ("tokens", "y"),
        mesh=None,
        batch_size: int = 128,
        seed: int = 0,
        theta: Optional[PyTree] = None,
        obs=None,
        **scorer_knobs,
    ):
        if train is None:
            raise TypeError("DataOptimizer needs the train dataset")
        if per_example_fn is None:
            if model is None:
                raise TypeError("pass a model or an explicit per_example_fn")
            per_example_fn = model.classifier_per_example
        if init_fn is None:
            if model is None:
                raise TypeError("pass a model or an explicit init_fn")
            init_fn = model.init
        if num_classes is None and model is not None:
            num_classes = getattr(model.cfg, "num_labels", None)

        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self.obs = obs
        self.model = model
        self.ctx = ScoreContext(
            per_example_fn=per_example_fn, init_fn=init_fn, train=train,
            meta=meta, fields=fields, mesh=mesh, batch_size=batch_size,
            seed=seed, theta=theta, num_classes=num_classes,
            obs=obs if obs.enabled else None,
        )
        self.scorer_name = scorer if isinstance(scorer, str) else getattr(scorer, "name", "custom")
        self.scorer = resolve_scorer(scorer, **scorer_knobs)
        self.scores: Optional[np.ndarray] = None

    # -- scoring -----------------------------------------------------------

    def fit_scores(self) -> np.ndarray:
        """Run the scorer over the full train set (sharded under a mesh).
        Caches and returns the (N,) keep-priority array."""

        import time

        t0 = time.perf_counter()
        scores = np.asarray(self.scorer(self.ctx), np.float32)
        if scores.shape != (self.ctx.n,):
            raise ValueError(
                f"scorer {self.scorer_name!r} returned shape {scores.shape}, "
                f"expected ({self.ctx.n},)"
            )
        self.scores = scores
        if self.obs.enabled:
            self.obs.histogram("dataopt_fit_scores_us").observe(
                (time.perf_counter() - t0) * 1e6)
            self.obs.counter("dataopt_scores_fitted").inc(
                labels={"scorer": self.scorer_name})
            self.obs.emit("log", "dataopt_scores", data={
                "scorer": self.scorer_name, "n": int(scores.size),
                "mean": float(scores.mean()) if scores.size else 0.0,
                "finite": bool(np.isfinite(scores).all())})
        return scores

    def _require_scores(self) -> np.ndarray:
        if self.scores is None:
            return self.fit_scores()
        return self.scores

    # -- pruning -----------------------------------------------------------

    def prune(
        self,
        ratio: float,
        *,
        class_balanced: bool = False,
        label_key: str = "y",
        rounds: int = 1,
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Keep the top (1 - ratio) fraction by score. ``rounds > 1`` prunes
        iteratively — each round re-scores the survivors and removes an equal
        slice of the ORIGINAL dataset, composing the round masks. Returns
        ``(pruned_dataset, keep_mask)`` over the original index space."""

        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        train = self.ctx.train
        n = self.ctx.n
        mask = np.ones(n, dtype=bool)
        per_round = ratio / rounds

        for r in range(rounds):
            if r == 0:
                scores = self._require_scores()
            else:  # re-score the survivors only (iterative re-score schedule)
                sub_opt = DataOptimizer(
                    self.model, prune_mod.apply_mask(train, mask),
                    meta=self.ctx.meta, scorer=self.scorer,
                    per_example_fn=self.ctx.per_example_fn, init_fn=self.ctx.init_fn,
                    num_classes=self.ctx.num_classes, fields=self.ctx.fields,
                    mesh=self.ctx.mesh, batch_size=self.ctx.batch_size,
                    seed=self.ctx.seed + r, theta=self.ctx.theta,
                    obs=self.obs,
                )
                scores = sub_opt.fit_scores()
            # the fraction of CURRENT survivors to drop so the kept count
            # tracks (1 - (r+1) * per_round) * n of the original dataset
            target_keep = prune_mod.keep_count(n, per_round * (r + 1))
            alive = int(mask.sum())
            round_ratio = 1.0 - target_keep / alive
            if round_ratio <= 0.0:
                continue
            if class_balanced:
                sub_mask = prune_mod.class_balanced_mask(
                    scores, train[label_key][mask], round_ratio)
            else:
                sub_mask = prune_mod.keep_mask(scores, round_ratio)
            next_mask = np.zeros(n, dtype=bool)
            next_mask[np.flatnonzero(mask)[sub_mask]] = True
            mask = next_mask
            if self.obs.enabled:
                self.obs.emit("log", "dataopt_prune_round", data={
                    "round": r + 1, "rounds": rounds,
                    "kept": int(mask.sum()), "n": n,
                    "class_balanced": class_balanced})
        if self.obs.enabled:
            self.obs.counter("dataopt_pruned_examples").inc(
                int(n - mask.sum()))
        return prune_mod.apply_mask(train, mask), mask

    # -- retraining / evaluation ------------------------------------------

    def retrain(self, *, steps: int, mask: Optional[np.ndarray] = None,
                seed: int = 0, batch: int = 32, lr: float = 1e-3) -> PyTree:
        """Fresh-init training on the kept subset (``mask=None`` = full data
        baseline)."""

        theta = prune_mod.retrain(
            self.ctx.per_example_fn, self.ctx.init_fn, self.ctx.train,
            mask=mask, steps=steps, seed=seed, batch=batch, lr=lr,
            fields=self.ctx.fields,
        )
        if self.obs.enabled:
            kept = self.ctx.n if mask is None else int(np.asarray(mask).sum())
            self.obs.emit("log", "dataopt_retrain", data={
                "steps": steps, "kept": kept, "n": self.ctx.n})
        return theta

    def evaluate(self, theta: PyTree, test: Dict[str, np.ndarray], *,
                 label_key: str = "y_true") -> float:
        """Test accuracy of ``theta`` (needs a Model-backed optimizer, or
        use ``prune.accuracy`` with an explicit forward)."""

        if self.model is None:
            raise RuntimeError("evaluate() needs a Model; use prune.accuracy "
                               "with an explicit forward_fn instead")
        return prune_mod.model_accuracy(self.model, theta, test,
                                        label_key=label_key,
                                        batch_size=self.ctx.batch_size,
                                        mesh=self.ctx.mesh)

    # -- online reweighting ------------------------------------------------

    def reweighted_iterator(
        self,
        *,
        batch_size: int,
        meta_batch_size: int,
        unroll: int,
        temperature=1.0,
        seed: Optional[int] = None,
        mesh=None,
    ) -> ReweightedIterator:
        """Score-proportional (base level) batch stream over the train set;
        shards batches over the optimizer's mesh unless overridden."""

        return ReweightedIterator(
            self.ctx.train, self.ctx.meta_data, self._require_scores(),
            batch_size=batch_size, meta_batch_size=meta_batch_size,
            unroll=unroll, seed=self.ctx.seed if seed is None else seed,
            fields=self.ctx.fields, temperature=temperature,
            mesh=self.ctx.mesh if mesh is None else mesh,
        )

    # -- persistence -------------------------------------------------------

    def export(self, path: str, *, mask: Optional[np.ndarray] = None,
               meta: Optional[Dict[str, Any]] = None) -> str:
        """Persist the fitted scores (+ optional keep mask) with a validated
        manifest (``dataopt.export``)."""

        return export_mod.export_scores(
            path, self._require_scores(), scorer=self.scorer_name,
            mask=mask, meta=meta,
        )

    def load(self, path: str, *, expect_scorer: Optional[str] = None) -> np.ndarray:
        """Adopt previously exported scores for THIS dataset (length
        validated against the live train set)."""

        scores, _, _ = export_mod.import_scores(
            path, expect_n=self.ctx.n, expect_scorer=expect_scorer,
        )
        self.scores = scores
        return scores
