"""Prune schedules + the retrain harness (DESIGN.md §8).

Masks, not index lists, are the interchange format: a boolean ``keep`` mask
of shape (N,) aligned with the scored dataset. Masks compose with the
export manifest (``dataopt.export``) and make the class-balance invariant
checkable (tests pin that the keep-ratio is honored per class).

Schedules:
* one-shot   — score once, keep the top (1 - ratio) fraction;
* class-balanced — the same ratio applied WITHIN each label class, so
  pruning cannot silently collapse a class (Sec. 4.3's failure mode for
  loss-based heuristics on imbalanced noise);
* iterative  — alternate re-scoring and pruning over several rounds
  (driven by ``DataOptimizer.prune(rounds=...)``; each round scores only
  the survivors, the composition of round masks is returned).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dataopt.distributed import map_batches
from repro.dataopt.scores import fit_plain

PyTree = Any

# Stable per-forward_fn / per-model prediction functions, so repeated
# evaluations of one model hit map_batches' jit cache instead of
# recompiling the forward per accuracy() call. Bounded LRU (the returned
# lambda closes over its key, so a weak map would never collect).


@functools.lru_cache(maxsize=64)
def _argmax_pred(forward_fn):
    return lambda p, b: jnp.argmax(forward_fn(p, b), axis=-1)


@functools.lru_cache(maxsize=64)
def _model_forward(model):
    return lambda p, b: model.forward(p, b)[0]


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def keep_count(n: int, ratio: float) -> int:
    """How many examples survive pruning ``ratio`` of ``n`` (at least 1)."""

    if not 0.0 <= ratio < 1.0:
        raise ValueError(f"prune ratio must be in [0, 1), got {ratio}")
    return max(int(round(n * (1.0 - ratio))), 1)


def keep_mask(scores: np.ndarray, ratio: float) -> np.ndarray:
    """Boolean mask keeping the top (1 - ratio) fraction by score (higher =
    keep; deterministic tie-break by index)."""

    scores = np.asarray(scores)
    k = keep_count(len(scores), ratio)
    order = np.argsort(-scores, kind="stable")
    mask = np.zeros(len(scores), dtype=bool)
    mask[order[:k]] = True
    return mask


def class_balanced_mask(scores: np.ndarray, labels: np.ndarray, ratio: float) -> np.ndarray:
    """Apply ``keep_mask`` independently within each label class, so every
    class keeps its own top (1 - ratio) fraction."""

    scores = np.asarray(scores)
    labels = np.asarray(labels)
    if len(scores) != len(labels):
        raise ValueError(f"scores ({len(scores)}) and labels ({len(labels)}) disagree")
    mask = np.zeros(len(scores), dtype=bool)
    for c in np.unique(labels):
        rows = np.flatnonzero(labels == c)
        mask[rows] = keep_mask(scores[rows], ratio)
    return mask


def apply_mask(dataset: Dict[str, np.ndarray], mask: np.ndarray) -> Dict[str, np.ndarray]:
    """Subset every aligned field of the dataset by a boolean keep mask."""

    mask = np.asarray(mask, dtype=bool)
    n = len(next(iter(dataset.values())))
    if mask.shape != (n,):
        raise ValueError(f"mask shape {mask.shape} != dataset length ({n},)")
    return {k: v[mask] for k, v in dataset.items()}


# ---------------------------------------------------------------------------
# retrain harness + evaluation
# ---------------------------------------------------------------------------


def retrain(
    per_example_fn,
    init_fn,
    dataset: Dict[str, np.ndarray],
    *,
    mask: Optional[np.ndarray] = None,
    steps: int,
    seed: int = 0,
    batch: int = 32,
    lr: float = 1e-3,
    fields: Tuple[str, ...] = ("tokens", "y"),
) -> PyTree:
    """Train a FRESH model (new init) on the kept subset — the paper's
    prune-then-retrain protocol. ``mask=None`` retrains on everything (the
    full-data baseline arm)."""

    sub = dataset if mask is None else apply_mask(dataset, mask)
    theta0 = init_fn(jax.random.PRNGKey(seed))
    return fit_plain(per_example_fn, theta0, sub, steps=steps, seed=seed,
                     batch=batch, lr=lr, fields=fields)


def train_plain(model, train: Dict[str, np.ndarray], *, steps: int, seed: int = 0,
                batch: int = 32, lr: float = 1e-3) -> PyTree:
    """Model-object convenience over ``scores.fit_plain`` (the examples' and
    benchmarks' no-meta finetuning baseline)."""

    return fit_plain(model.classifier_per_example, model.init(jax.random.PRNGKey(seed)),
                     train, steps=steps, seed=seed, batch=batch, lr=lr)


def accuracy(
    forward_fn: Callable[[PyTree, Dict[str, jnp.ndarray]], jnp.ndarray],
    theta: PyTree,
    dataset: Dict[str, np.ndarray],
    *,
    label_key: str = "y_true",
    fields: Tuple[str, ...] = ("tokens",),
    batch_size: int = 128,
    mesh=None,
) -> float:
    """Top-1 accuracy of ``argmax forward_fn(theta, batch)`` against
    ``dataset[label_key]`` — batched (and mesh-sharded) like scoring.
    ``fields`` selects the batch keys the forward consumes (bare-function
    models use e.g. ``("x",)``). The prediction function is cached per
    ``forward_fn``, so repeated evaluations of one model compile once."""

    preds = map_batches(_argmax_pred(forward_fn), dataset, args=(theta,),
                        fields=fields, batch_size=batch_size, mesh=mesh)
    return float(np.mean(preds == dataset[label_key]))


def model_accuracy(model, theta, dataset, *, label_key: str = "y_true",
                   batch_size: int = 128, mesh=None) -> float:
    """``accuracy`` for a ``repro.models.Model`` (its forward returns
    (logits, aux)); one compile per model across calls."""

    return accuracy(_model_forward(model), theta, dataset, label_key=label_key,
                    batch_size=batch_size, mesh=mesh)
