"""Manifest-validated score/mask export + import (DESIGN.md §8).

Scores are expensive (a meta-training run) and reusable (prune ratios,
reweighting temperatures and retrains are all derived views), so they
persist through the same npz+manifest substrate as model checkpoints
(``repro.checkpoint``) with a dataopt-specific manifest envelope:

    meta.kind    = "dataopt.scores"   (refuses foreign checkpoints)
    meta.version = 1
    meta.scorer  = provider name      (validated on import when expected)
    meta.n       = dataset length     (validated against the live dataset)

Import reconstructs the tree from the manifest itself — no template needed —
and re-validates through ``checkpoint.restore`` so shape/dtype drift fails
loudly rather than silently rescoring a different dataset.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro import checkpoint
from repro.checkpoint.checkpoint import MANIFEST

KIND = "dataopt.scores"
VERSION = 1


def export_scores(
    path: str,
    scores: np.ndarray,
    *,
    scorer: str,
    mask: Optional[np.ndarray] = None,
    meta: Optional[Dict[str, Any]] = None,
) -> str:
    """Write scores (and optionally a keep mask) with a validated manifest."""

    scores = np.asarray(scores, np.float32)
    if scores.ndim != 1:
        raise ValueError(f"scores must be 1-D, got shape {scores.shape}")
    if not np.all(np.isfinite(scores)):
        raise ValueError("refusing to export non-finite scores")
    tree = {"scores": scores}
    if mask is not None:
        mask = np.asarray(mask, dtype=bool)
        if mask.shape != scores.shape:
            raise ValueError(f"mask shape {mask.shape} != scores shape {scores.shape}")
        tree["mask"] = mask
    manifest_meta = {"kind": KIND, "version": VERSION, "scorer": scorer,
                     "n": int(len(scores))}
    if meta:
        overlap = set(meta) & set(manifest_meta)
        if overlap:
            raise ValueError(f"meta keys {sorted(overlap)} are reserved")
        manifest_meta.update(meta)
    checkpoint.save(path, tree, meta=manifest_meta)
    return path


def import_scores(
    path: str,
    *,
    expect_n: Optional[int] = None,
    expect_scorer: Optional[str] = None,
) -> Tuple[np.ndarray, Optional[np.ndarray], Dict[str, Any]]:
    """Load ``(scores, mask_or_None, manifest_meta)`` with validation."""

    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    meta = manifest.get("meta", {})
    if meta.get("kind") != KIND:
        raise ValueError(f"{path} is not a dataopt score export "
                         f"(manifest kind={meta.get('kind')!r})")
    if meta.get("version") != VERSION:
        raise ValueError(f"{path}: unsupported score-export version {meta.get('version')!r}")

    # rebuild the template from the manifest so restore() can shape-check
    like: Dict[str, np.ndarray] = {}
    for name, shape, dtype in zip(manifest["names"], manifest["shapes"], manifest["dtypes"]):
        key = name.strip("[]'\"")
        if key not in ("scores", "mask"):
            raise ValueError(f"{path}: unexpected entry {name!r} in score export")
        like[key] = np.zeros(shape, dtype=dtype)
    tree, _ = checkpoint.restore(path, like)

    scores = np.asarray(tree["scores"])
    mask = np.asarray(tree["mask"]) if "mask" in tree else None
    if meta.get("n") != len(scores):
        raise ValueError(f"{path}: manifest n={meta.get('n')} but scores have "
                         f"length {len(scores)} — corrupt export")
    if expect_n is not None and len(scores) != expect_n:
        raise ValueError(f"{path}: scores are for a dataset of {len(scores)} "
                         f"examples, caller's dataset has {expect_n}")
    if expect_scorer is not None and meta.get("scorer") != expect_scorer:
        raise ValueError(f"{path}: scored by {meta.get('scorer')!r}, "
                         f"expected {expect_scorer!r}")
    return scores, mask, meta
