"""Online reweighted / curriculum batch iteration (DESIGN.md §8).

``ReweightedIterator`` extends ``data.BatchIterator`` — same
``(base_batches[K], meta_batch)`` protocol, same sharding behavior — but
draws base examples from a score-proportional distribution instead of
uniformly (the ``_base_idx`` hook). That turns any per-example score array
(meta-learned weights, negated EL2N, ...) into an ONLINE data optimizer:
no retraining run needed, the sampler soft-prunes as it feeds the very
training loop that may be refreshing the scores (``update_scores``
between meta steps).

Curriculum: the sampling sharpness follows a temperature schedule
``T(step)``. T -> inf is uniform sampling (early: see everything), T -> 0
is argmax-like (late: concentrate on the highest-scored data). Pass
``temperature=(T0, T1, steps)`` for a linear anneal or a callable.

Sharding: a ``mesh`` builds the production batch NamedShardings
(``launch.sharding.batch_spec``) over its data axes; explicit ``shard=``
(a meta-batch NamedSharding) also works, exactly as on ``BatchIterator``.

The meta split stays uniformly sampled — reweighting the meta/dev set
would bias the outer objective, not the data curation.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple, Union

import numpy as np
from jax.sharding import NamedSharding

from repro.data import BatchIterator
from repro.launch.sharding import batch_spec

TemperatureLike = Union[float, Tuple[float, float, int], Callable[[int], float]]


def _temperature_fn(temperature: TemperatureLike) -> Callable[[int], float]:
    if callable(temperature):
        return temperature
    if isinstance(temperature, tuple):
        t0, t1, steps = temperature
        if steps <= 0:
            raise ValueError(f"curriculum steps must be positive, got {steps}")
        return lambda i: t0 + (t1 - t0) * min(i / steps, 1.0)
    return lambda i: float(temperature)


def sampling_probs(scores: np.ndarray, temperature: float) -> np.ndarray:
    """Sampling distribution at a given temperature: a softmax over scores
    normalized to their own range, ``p_i ∝ exp((s_i - max s) / (range * T))``
    — scale-invariant, so scores on any axis (sigmoid weights, negated EL2N)
    behave the same. T -> inf flattens to uniform (every example keeps
    nonzero mass), T -> 0 concentrates on the top scores."""

    s = np.asarray(scores, np.float64)
    if not np.all(np.isfinite(s)):
        raise ValueError("scores must be finite to derive sampling probabilities")
    span = s.max() - s.min()
    if span <= 0.0:  # all-equal scores: uniform
        return np.full(len(s), 1.0 / len(s))
    z = (s - s.max()) / span  # in [-1, 0]
    p = np.exp(z / max(temperature, 1e-6))
    return p / p.sum()


class ReweightedIterator(BatchIterator):
    """``BatchIterator`` with score-weighted base sampling."""

    def __init__(
        self,
        base_data: Dict[str, np.ndarray],
        meta_data: Dict[str, np.ndarray],
        scores: np.ndarray,
        *,
        temperature: TemperatureLike = 1.0,
        mesh=None,
        shard=None,
        **kwargs,
    ):
        if shard is None and mesh is not None:
            shard = NamedSharding(mesh, batch_spec(mesh))
        super().__init__(base_data, meta_data, shard=shard, **kwargs)
        self.temperature_fn = _temperature_fn(temperature)
        self.step = 0
        self.update_scores(scores)

    def update_scores(self, scores: np.ndarray):
        """Swap in fresh scores mid-stream (online reweighting)."""

        scores = np.asarray(scores)
        if scores.shape != (self.n,):
            raise ValueError(f"scores shape {scores.shape} != ({self.n},)")
        self.scores = scores.astype(np.float32)

    def _base_idx(self) -> np.ndarray:
        p = sampling_probs(self.scores, self.temperature_fn(self.step))
        self.step += 1
        return self.rng.choice(self.n, size=(self.k, self.bs), p=p)
