"""Score providers: one number per training example (DESIGN.md §8).

The uniform contract every provider obeys:

    scorer(ctx: ScoreContext) -> np.ndarray of shape (N,), float32,
    where HIGHER score = HIGHER keep-priority.

Providers register under a string name (``register_scorer``, mirroring
``core.methods``), so swapping ``scorer="meta"`` for ``"el2n"`` or
``"random"`` is a one-argument change everywhere — the acceptance bar for
this subsystem. Heuristic scorers whose raw quantity measures *hardness*
(el2n, grand, loss) default to the noise-robust orientation (keep easy,
i.e. score = -hardness) and expose ``keep_hard=True`` for the classic
clean-data pruning direction.

Built-ins:

* ``meta`` — the paper's Sec. 4.3 scorer: MetaWeightNet importance learned
  by bilevel meta-training through ANY registered hypergradient method
  (``method="sama"`` by default — the whole ``core.methods`` registry is a
  knob here), with optional cross-meta-step EMA score tracking.
* ``el2n`` — ||softmax(logits) - onehot||_2 from an early-trained model
  (Paul et al., Deep Learning on a Data Diet).
* ``grand`` — exact per-example gradient norm (vmap'd grad) from an
  early-trained model.
* ``margin`` — p_y - max_{c != y} p_c (positive = confidently correct).
* ``loss`` — negative per-example cross-entropy.
* ``random`` — seeded uniform scores (the control arm).

This module also owns the paper's EMA machinery that used to be stranded in
benchmark code: ``EMATracker`` (cross-meta-step exponential moving averages
of any per-example array) and ``ema_disagreement`` (uncertainty as the
divergence between the model's current predictive distribution and its EMA
across meta steps — high when predictions keep flipping, the signal the
paper feeds to MetaWeightNet next to the loss).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.api import MetaLearner
from repro.core import problems
from repro.core.meta_modules import apply_weight_net, weight_features
from repro.data import BatchIterator
from repro.dataopt.distributed import map_batches, score_dataset

PyTree = Any


# ---------------------------------------------------------------------------
# EMA tracking + EMA-disagreement uncertainty
# ---------------------------------------------------------------------------


class EMATracker:
    """Exponential moving average of a per-example array across meta steps.

    ``decay`` close to 1 remembers long histories; the first ``update``
    initializes the average to the observed value (no zero-bias)."""

    def __init__(self, decay: float = 0.9):
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.decay = decay
        self.value: Optional[np.ndarray] = None
        self.updates = 0

    def update(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        if self.value is None:
            self.value = x.copy()
        else:
            if self.value.shape != x.shape:
                raise ValueError(f"EMA shape changed: {self.value.shape} -> {x.shape}")
            self.value = self.decay * self.value + (1.0 - self.decay) * x
        self.updates += 1
        return self.value


def ema_disagreement(probs: np.ndarray, ema_probs: np.ndarray) -> np.ndarray:
    """The paper's uncertainty signal: 1 - <p_t, p_ema> per example.

    Zero when the current predictive distribution agrees with its own
    running average (stable, confident examples); near 1 when predictions
    keep moving across meta steps (ambiguous or mislabeled examples)."""

    probs = np.asarray(probs, np.float32)
    ema_probs = np.asarray(ema_probs, np.float32)
    return 1.0 - np.sum(probs * ema_probs, axis=-1)


# ---------------------------------------------------------------------------
# the scoring context
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScoreContext:
    """Everything a scorer may need. ``per_example_fn`` maps (theta, batch)
    -> ``problems.PerExample``; ``init_fn`` draws fresh base params. A
    ``mesh`` makes every full-dataset pass shard over its data axes."""

    per_example_fn: Callable[[PyTree, Any], problems.PerExample]
    init_fn: Callable[[Any], PyTree]
    train: Dict[str, np.ndarray]
    meta: Optional[Dict[str, np.ndarray]] = None  # meta/dev split; None = train
    fields: Tuple[str, ...] = ("tokens", "y")
    mesh: Any = None
    batch_size: int = 128
    seed: int = 0
    theta: Optional[PyTree] = None  # pre-trained params, reused when given
    num_classes: Optional[int] = None  # needed by label correction
    obs: Any = None  # repro.obs.Obs; None = silent (legacy print fallback)

    @property
    def n(self) -> int:
        return len(next(iter(self.train.values())))

    @property
    def meta_data(self) -> Dict[str, np.ndarray]:
        return self.train if self.meta is None else self.meta

    def per_example_all(self, theta) -> problems.PerExample:
        """PerExample over the FULL train set — sharded when a mesh is set."""

        return score_dataset(
            self.per_example_fn, theta, self.train,
            fields=self.fields, batch_size=self.batch_size, mesh=self.mesh,
        )


class ScoreProvider:
    """Base class: set ``name``, implement ``__call__(ctx) -> (N,) scores``
    (higher = keep). Plain callables work too; this class is the documented
    protocol anchor."""

    name: str = "abstract"

    def __call__(self, ctx: ScoreContext) -> np.ndarray:
        raise NotImplementedError

    def __repr__(self):  # pragma: no cover - debugging nicety
        return f"{type(self).__name__}(name={self.name!r})"


# ---------------------------------------------------------------------------
# registry (mirrors core.methods.register_method)
# ---------------------------------------------------------------------------

#: name -> factory(**knobs) -> scorer callable.
ScorerFactory = Callable[..., Callable[[ScoreContext], np.ndarray]]

_REGISTRY: Dict[str, ScorerFactory] = {}


def register_scorer(name: str, factory: Optional[Any] = None, *, overwrite: bool = False):
    """Register a score provider under ``name``.

        @register_scorer("mine")                # decorator on factory(**knobs)
        def _make(**knobs): return MyScorer(...)

        register_scorer("mine", MyScorer())     # an instance (knobs must be empty)
        register_scorer("mine", _make)          # a plain factory
    """

    def _install(f: ScorerFactory) -> ScorerFactory:
        if not overwrite and name in _REGISTRY:
            raise ValueError(f"scorer {name!r} already registered "
                             "(pass overwrite=True to replace)")
        _REGISTRY[name] = f
        return f

    if factory is None:
        return _install
    if isinstance(factory, ScoreProvider):
        instance = factory

        def _from_instance(**knobs):
            if knobs:
                raise TypeError(f"scorer {name!r} was registered as an instance "
                                f"and takes no knobs, got {sorted(knobs)}")
            return instance

        return _install(_from_instance)
    return _install(factory)


def unregister_scorer(name: str):
    """Remove a registered scorer (test hygiene)."""
    _REGISTRY.pop(name, None)


def available_scorers() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_scorer(scorer: Any, **knobs) -> Callable[[ScoreContext], np.ndarray]:
    """Turn a scorer name / provider / callable into a scorer callable."""

    if isinstance(scorer, str):
        if scorer not in _REGISTRY:
            raise ValueError(f"unknown scorer {scorer!r}; registered: {available_scorers()}")
        return _REGISTRY[scorer](**knobs)
    if callable(scorer):
        if knobs:
            raise TypeError(f"knobs {sorted(knobs)} given with an already-built scorer")
        return scorer
    raise TypeError(f"scorer must be a name or callable, got {type(scorer).__name__}")


# ---------------------------------------------------------------------------
# plain training (shared by the heuristic scorers and the retrain harness)
# ---------------------------------------------------------------------------


def fit_plain(
    per_example_fn,
    theta0: PyTree,
    train: Dict[str, np.ndarray],
    *,
    steps: int,
    seed: int = 0,
    batch: int = 32,
    lr: float = 1e-3,
    fields: Tuple[str, ...] = ("tokens", "y"),
) -> PyTree:
    """Minimal no-meta training loop: adam on mean per-example loss. The one
    implementation behind every example/benchmark "plain finetune" baseline
    and the heuristic scorers' early-trained model."""

    opt = optim.adam(lr)
    st = opt.init(theta0)
    rng = np.random.default_rng(seed)
    n = len(next(iter(train.values())))

    @jax.jit
    def step(p, s, b):
        g = jax.grad(lambda pp: jnp.mean(per_example_fn(pp, b).loss))(p)
        upd, s = opt.update(g, s, p)
        return optim.apply_updates(p, upd), s

    theta = theta0
    for _ in range(steps):
        idx = rng.integers(0, n, batch)
        b = {k: jnp.asarray(train[k][idx]) for k in fields if k in train}
        theta, st = step(theta, st, b)
    return theta


def _early_theta(ctx: ScoreContext, train_steps: int, lr: float) -> PyTree:
    """The early-trained model the heuristic scorers probe (reuses
    ``ctx.theta`` when the caller already has one)."""

    if ctx.theta is not None:
        return ctx.theta
    theta0 = ctx.init_fn(jax.random.PRNGKey(ctx.seed))
    return fit_plain(ctx.per_example_fn, theta0, ctx.train,
                     steps=train_steps, seed=ctx.seed, fields=ctx.fields)


def _oriented(hardness: np.ndarray, keep_hard: bool) -> np.ndarray:
    """Map a raw hardness quantity onto the keep-priority axis."""

    h = np.asarray(hardness, np.float32)
    return h if keep_hard else -h


# ---------------------------------------------------------------------------
# heuristic providers
# ---------------------------------------------------------------------------


@register_scorer("el2n")
def _make_el2n(train_steps: int = 20, keep_hard: bool = False, lr: float = 1e-3):
    def el2n(ctx: ScoreContext) -> np.ndarray:
        theta = _early_theta(ctx, train_steps, lr)
        pe = ctx.per_example_all(theta)
        p = jax.nn.softmax(jnp.asarray(pe.logits), axis=-1)
        norm = np.asarray(jnp.linalg.norm(p - jnp.asarray(pe.label_onehot), axis=-1))
        return _oriented(norm, keep_hard)

    return el2n


@register_scorer("grand")
def _make_grand(train_steps: int = 20, keep_hard: bool = False, lr: float = 1e-3,
                grad_batch: int = 16):
    def grand(ctx: ScoreContext) -> np.ndarray:
        theta = _early_theta(ctx, train_steps, lr)

        def one_grad_norm(b_row):
            # vmap over singleton batches: exact per-example gradient norm
            g = jax.grad(lambda p: jnp.sum(ctx.per_example_fn(p, b_row).loss))(theta)
            sq = sum(jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(g))
            return jnp.sqrt(sq)

        def batch_fn(b):
            singletons = jax.tree_util.tree_map(lambda x: x[:, None], b)
            return jax.vmap(one_grad_norm)(singletons)

        norm = map_batches(batch_fn, ctx.train, fields=ctx.fields,
                           batch_size=grad_batch, mesh=ctx.mesh)
        return _oriented(norm, keep_hard)

    return grand


@register_scorer("margin")
def _make_margin(train_steps: int = 20, keep_hard: bool = False, lr: float = 1e-3):
    def margin(ctx: ScoreContext) -> np.ndarray:
        theta = _early_theta(ctx, train_steps, lr)
        pe = ctx.per_example_all(theta)
        p = np.asarray(jax.nn.softmax(jnp.asarray(pe.logits), axis=-1))
        onehot = np.asarray(pe.label_onehot)
        p_y = np.sum(p * onehot, axis=-1)
        p_rival = np.max(np.where(onehot > 0, -np.inf, p), axis=-1)
        m = p_y - p_rival  # positive = confidently correct (easy)
        return _oriented(m, keep_hard=not keep_hard)  # margin is an EASINESS axis

    return margin


@register_scorer("loss")
def _make_loss(train_steps: int = 20, keep_hard: bool = False, lr: float = 1e-3):
    def loss(ctx: ScoreContext) -> np.ndarray:
        theta = _early_theta(ctx, train_steps, lr)
        pe = ctx.per_example_all(theta)
        return _oriented(np.asarray(pe.loss), keep_hard)

    return loss


@register_scorer("random")
def _make_random(seed: Optional[int] = None):
    def random_scores(ctx: ScoreContext) -> np.ndarray:
        rng = np.random.default_rng(ctx.seed if seed is None else seed)
        return rng.random(ctx.n).astype(np.float32)

    return random_scores


# ---------------------------------------------------------------------------
# the meta-learned provider (the paper's Sec. 4.3 scorer)
# ---------------------------------------------------------------------------


def fit_meta(
    ctx: ScoreContext,
    *,
    method: Any = "sama",
    steps: int = 80,
    unroll: int = 2,
    reweight: bool = True,
    correct: bool = False,
    use_uncertainty: bool = False,
    base_lr: float = 1e-3,
    meta_lr: float = 1e-3,
    batch: int = 32,
    meta_batch: int = 32,
    log_every: int = 0,
    ema_decay: float = 0.0,
    score_every: int = 10,
    schedule: str = "auto",
    scale: Optional[Any] = None,  # repro.scale.ScaleConfig
    learner_kwargs: Optional[Dict[str, Any]] = None,
) -> Tuple[MetaLearner, Optional[EMATracker], Optional[EMATracker]]:
    """Meta-train MetaWeightNet (+ optional label corrector) on ``ctx.train``
    against ``ctx.meta_data`` through ANY registered hypergradient method.

    A ``ctx.mesh`` is forwarded to the MetaLearner (its "auto" schedule
    picks the single-sync shard_map path), so meta-training shards exactly
    like the scoring passes; ``learner_kwargs`` overrides it. ``scale``
    (a ``repro.scale.ScaleConfig``) applies a precision policy and/or
    microbatch accumulation to the scoring meta-train — the way to fit a
    big scorer model into a device: scores don't change (SAMA's
    microbatched estimator is exact in f32) but peak memory drops ~M-fold.

    With ``ema_decay > 0``, every ``score_every`` meta steps the full train
    set is re-scored (sharded when ctx.mesh is set) and two EMAs advance:
    the MWN weight EMA (cross-meta-step score tracking) and the predictive
    probability EMA that ``ema_disagreement`` consumes. Returns
    ``(learner, weight_ema, prob_ema)`` — the trackers are None when EMA
    tracking is off."""

    spec = problems.make_data_optimization_spec(
        ctx.per_example_fn, reweight=reweight, correct=correct,
        use_uncertainty=use_uncertainty,
    )
    lam = problems.init_data_optimization_lam(
        jax.random.PRNGKey(ctx.seed + 10), reweight=reweight, correct=correct,
        use_uncertainty=use_uncertainty, num_classes=ctx.num_classes,
    )
    kwargs = {"mesh": ctx.mesh, **(learner_kwargs or {})}
    if scale is not None:  # repro.scale knobs for the scoring meta-train
        kwargs.setdefault("scale", scale)
    if ctx.obs is not None:  # scoring meta-train reports through the caller's obs
        kwargs.setdefault("obs", ctx.obs)
    learner = MetaLearner(
        spec, base_opt="adam", base_lr=base_lr, meta_opt="adam", meta_lr=meta_lr,
        method=method, unroll_steps=unroll, schedule=schedule,
        **kwargs,
    )
    theta0 = ctx.theta if ctx.theta is not None else ctx.init_fn(jax.random.PRNGKey(ctx.seed))
    learner.init(theta0, lam)
    it = BatchIterator(ctx.train, ctx.meta_data, batch_size=batch,
                       meta_batch_size=meta_batch, unroll=unroll, seed=ctx.seed,
                       fields=ctx.fields)

    obs = ctx.obs
    obs_on = obs is not None and obs.enabled

    def fit_chunk(n_steps):
        # a stalled meta-train must be distinguishable from a healthy one:
        # with an obs pipeline, run_loop already emits metrics/scale/gate
        # events at the log_every cadence (the console sink renders them);
        # without one, keep the legacy history print
        history = learner.fit(it, n_steps, log_every=log_every)
        if not obs_on:
            for row in history:
                print({k: round(v, 4) for k, v in row.items()})

    if ema_decay <= 0.0:
        fit_chunk(steps)
        return learner, None, None

    if score_every < 1:
        raise ValueError(f"score_every must be >= 1 with EMA tracking, got {score_every}")
    weight_ema, prob_ema = EMATracker(ema_decay), EMATracker(ema_decay)
    done = 0
    while done < steps:
        chunk = min(score_every, steps - done)
        fit_chunk(chunk)
        done += chunk
        pe = ctx.per_example_all(learner.state.theta)
        if reweight:
            feats = weight_features(
                jnp.asarray(pe.loss),
                jnp.asarray(pe.uncertainty) if use_uncertainty else None,
            )
            weight_ema.update(np.asarray(
                apply_weight_net(learner.state.lam["reweight"], feats)))
        if pe.logits is not None:
            prob_ema.update(np.asarray(jax.nn.softmax(jnp.asarray(pe.logits), -1)))
    return learner, weight_ema, prob_ema


def meta_train(
    model,
    train: Dict[str, np.ndarray],
    meta: Optional[Dict[str, np.ndarray]] = None,
    *,
    seed: int = 0,
    mesh=None,
    batch_size: int = 128,
    fields: Tuple[str, ...] = ("tokens", "y"),
    **fit_knobs,
) -> MetaLearner:
    """Model-object convenience over ``fit_meta``: meta-train MWN (+optional
    corrector) for a ``repro.models.Model`` and return the MetaLearner (its
    ``.state.theta`` is the reweighting-trained base model). This is what the
    WRENCH/ablation benchmarks' hand-rolled ``train_meta`` loops collapsed
    into."""

    ctx = ScoreContext(
        per_example_fn=model.classifier_per_example, init_fn=model.init,
        train=train, meta=meta, fields=fields, mesh=mesh,
        batch_size=batch_size, seed=seed,
        num_classes=getattr(model.cfg, "num_labels", None),
    )
    learner, _, _ = fit_meta(ctx, **fit_knobs)
    return learner


@register_scorer("meta")
def _make_meta(uncertainty: str = "entropy", **fit_knobs):
    """``uncertainty``: which signal rides next to the loss in the final MWN
    scoring pass — "none", "entropy" (in-batch predictive entropy), or "ema"
    (the paper's EMA-disagreement; forces EMA tracking on)."""

    if uncertainty not in ("none", "entropy", "ema"):
        raise ValueError(f"uncertainty must be none|entropy|ema, got {uncertainty!r}")

    def meta(ctx: ScoreContext) -> np.ndarray:
        knobs = dict(fit_knobs)
        if knobs.get("reweight") is False:
            raise ValueError("the meta scorer needs reweight=True — the MWN "
                             "weight IS the score")
        # the MWN's input width must match between training and the final
        # scoring pass, so use_uncertainty is derived from `uncertainty`
        # and an explicit contradiction is refused up front (it would only
        # surface as a matmul shape error AFTER the whole training run)
        want_unc = uncertainty != "none"
        if knobs.setdefault("use_uncertainty", want_unc) != want_unc:
            raise ValueError(
                f"use_uncertainty={knobs['use_uncertainty']} contradicts "
                f"uncertainty={uncertainty!r}; drop the use_uncertainty knob"
            )
        if uncertainty == "ema" and knobs.get("ema_decay", 0.0) <= 0.0:
            knobs["ema_decay"] = 0.9
        learner, weight_ema, prob_ema = fit_meta(ctx, **knobs)
        pe = ctx.per_example_all(learner.state.theta)
        if uncertainty == "ema":
            probs = np.asarray(jax.nn.softmax(jnp.asarray(pe.logits), -1))
            unc = jnp.asarray(ema_disagreement(probs, prob_ema.value))
        elif uncertainty == "entropy":
            unc = jnp.asarray(pe.uncertainty)
        else:
            unc = None
        feats = weight_features(jnp.asarray(pe.loss), unc)
        w = np.asarray(apply_weight_net(learner.state.lam["reweight"], feats))
        if weight_ema is not None:
            w = weight_ema.update(w)
        return w.astype(np.float32)

    return meta
