"""Sharded full-dataset scoring (DESIGN.md §8).

Scoring a dataset is embarrassingly data-parallel: every score in this
subsystem is a per-example quantity with no cross-example reduction, so the
batch axis shards over the mesh data axes exactly like training batches do
(``launch.sharding.batch_spec``) and the per-shard math is untouched. That
makes sharded scoring *bitwise identical* to single-device scoring — pinned
by tests/test_dataopt.py on a forced 1xN CPU mesh.

``map_batches`` is the one primitive: drive a jit'ed batch function over a
dataset in fixed-size batches (padding the tail so jit sees ONE shape),
optionally device_put-ing each batch with the mesh's batch NamedSharding.
Everything in ``dataopt.scores`` funnels through it, so every scorer —
including third-party ``register_scorer`` providers built on it — scales
with devices for free.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.launch.sharding import batch_spec, dp_size

PyTree = Any

# jax.jit's trace cache lives on the wrapper, so repeated full-dataset
# passes over the SAME function (the EMA-tracking loop re-scores every few
# meta steps) must reuse one wrapper or every pass recompiles. Bounded LRU,
# not a weak map: the jit wrapper strongly references its function, so weak
# keys would never collect; eviction caps what throwaway closures (e.g. the
# grand scorer's per-call batch_fn) can accumulate.


@functools.lru_cache(maxsize=64)
def _jitted_cached(fn):
    return jax.jit(fn)


def _jitted(fn):
    try:
        return _jitted_cached(fn)
    except TypeError:  # unhashable callable: jit without caching
        return jax.jit(fn)


def batch_sharding(mesh) -> Optional[NamedSharding]:
    """NamedSharding for a (B, ...) batch over the mesh's data axes."""

    if mesh is None:
        return None
    return NamedSharding(mesh, batch_spec(mesh))


def _pad_to(n: int, batch_size: int, mesh) -> int:
    """Padded dataset length: a multiple of batch_size, with batch_size a
    multiple of the data-parallel degree so every shard is non-ragged."""

    if mesh is not None and batch_size % dp_size(mesh) != 0:
        raise ValueError(
            f"batch_size {batch_size} must divide over the mesh data axes "
            f"(dp={dp_size(mesh)}) for sharded scoring"
        )
    return ((n + batch_size - 1) // batch_size) * batch_size


def map_batches(
    batch_fn: Callable[..., PyTree],
    dataset: Dict[str, np.ndarray],
    *,
    args: Tuple = (),
    fields: Tuple[str, ...],
    batch_size: int = 128,
    mesh=None,
) -> PyTree:
    """Apply ``batch_fn(*args, batch)`` (batch dict -> pytree of (B, ...)
    arrays) over the whole dataset and concatenate the results along the
    leading axis. ``args`` carries traced leading arguments (params), so a
    STABLE ``batch_fn`` keeps one compiled executable across calls — pass
    changing values through ``args``, not a fresh closure.

    The tail batch is padded by wrapping around to index 0 (results trimmed),
    so one shape is compiled per (batch_fn, batch_size). With a ``mesh``,
    each batch is device_put with the batch NamedSharding before the call
    and the step runs under the mesh context — XLA executes it
    data-parallel with zero collectives (per-example outputs never cross
    shards).
    """

    n = len(next(iter(dataset.values())))
    npad = _pad_to(n, batch_size, mesh)
    idx = np.arange(npad) % n
    shard = batch_sharding(mesh)
    fn = _jitted(batch_fn)

    chunks = []
    for start in range(0, npad, batch_size):
        rows = idx[start : start + batch_size]
        batch = {k: jnp.asarray(dataset[k][rows]) for k in fields if k in dataset}
        if shard is not None:
            batch = jax.tree_util.tree_map(lambda x: jax.device_put(x, shard), batch)
            with mesh:
                out = fn(*args, batch)
        else:
            out = fn(*args, batch)
        chunks.append(jax.tree_util.tree_map(np.asarray, out))
    stacked = jax.tree_util.tree_map(lambda *xs: np.concatenate(xs, axis=0), *chunks)
    return jax.tree_util.tree_map(lambda x: x[:n], stacked)


def score_dataset(
    per_example_fn: Callable[[PyTree, Dict[str, jnp.ndarray]], Any],
    theta: PyTree,
    dataset: Dict[str, np.ndarray],
    *,
    fields: Tuple[str, ...] = ("tokens", "y"),
    batch_size: int = 128,
    mesh=None,
):
    """Run a ``PerExample`` adapter over the full dataset (sharded when a
    mesh is given). Returns the PerExample pytree with stacked (N, ...)
    numpy leaves. ``per_example_fn`` is the jit-cache key — theta rides as
    a traced argument, so repeated scoring passes (EMA tracking) compile
    once."""

    return map_batches(
        per_example_fn, dataset, args=(theta,),
        fields=fields, batch_size=batch_size, mesh=mesh,
    )
