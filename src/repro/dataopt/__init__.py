"""repro.dataopt — first-class data optimization (DESIGN.md §8).

The application layer the paper's Sec. 4 experiments run on: per-example
scoring (meta-learned importance through any registered hypergradient
method, plus EL2N / GraNd / margin / loss / random heuristics), prune
schedules with a retrain harness, online score-proportional reweighting,
distributed sharded full-dataset scoring, and manifest-validated score
export — all behind the ``DataOptimizer`` facade where the scorer is one
string argument.
"""

from repro.dataopt.distributed import batch_sharding, map_batches, score_dataset
from repro.dataopt.export import export_scores, import_scores
from repro.dataopt.optimizer import DataOptimizer
from repro.dataopt.prune import (
    accuracy,
    apply_mask,
    class_balanced_mask,
    keep_mask,
    model_accuracy,
    retrain,
    train_plain,
)
from repro.dataopt.reweight import ReweightedIterator, sampling_probs
from repro.dataopt.scores import (
    EMATracker,
    ScoreContext,
    ScoreProvider,
    available_scorers,
    ema_disagreement,
    fit_meta,
    fit_plain,
    meta_train,
    register_scorer,
    resolve_scorer,
    unregister_scorer,
)

__all__ = [
    "DataOptimizer",
    "EMATracker",
    "ReweightedIterator",
    "ScoreContext",
    "ScoreProvider",
    "accuracy",
    "apply_mask",
    "available_scorers",
    "batch_sharding",
    "class_balanced_mask",
    "ema_disagreement",
    "export_scores",
    "fit_meta",
    "fit_plain",
    "import_scores",
    "keep_mask",
    "map_batches",
    "meta_train",
    "model_accuracy",
    "register_scorer",
    "resolve_scorer",
    "retrain",
    "sampling_probs",
    "score_dataset",
    "train_plain",
    "unregister_scorer",
]
