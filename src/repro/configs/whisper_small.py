"""whisper-small [audio] — enc-dec, 12L+12L d_model=768 12H d_ff=3072
vocab=51865. Conv/mel frontend is a STUB: input_specs() supplies precomputed
frame embeddings (B, 1500, 768). [arXiv:2212.04356]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=12,
    encoder_layers=12,
    is_encoder_decoder=True,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    encoder_seq=1500,
    norm="layernorm",
    act="gelu",
    mlp_type="mlp",
    use_rope=False,
    pos_embed="learned",
    max_position=32_768,  # shape exercise; real whisper decodes <= 448
    supports_long_context=False,  # enc-dec, full attention
)

SMOKE = CONFIG.replace(
    num_layers=2,
    encoder_layers=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=2,
    head_dim=64,
    d_ff=256,
    vocab_size=512,
    encoder_seq=32,
    max_position=256,
    param_dtype="float32",
    dtype="float32",
)
