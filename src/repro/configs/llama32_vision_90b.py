"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; every 5th layer is a gated cross-attention layer over (stubbed)
ViT patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    cross_attn_every=5,
    vision_dim=1280,
    vision_tokens=1601,
    rope_theta=500_000.0,
    supports_long_context=False,  # full attention
)

SMOKE = CONFIG.replace(
    num_layers=2,
    cross_attn_every=2,  # 1 self + 1 cross layer
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    vision_dim=64,
    vision_tokens=16,
    param_dtype="float32",
    dtype="float32",
)
