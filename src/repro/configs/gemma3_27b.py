"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global, 128k. [hf:google/gemma-3-1b-pt (family card)]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    act="gelu",
    supports_long_context=True,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    sliding_window=8,
    attn_pattern=("local", "global"),
    param_dtype="float32",
    dtype="float32",
)
