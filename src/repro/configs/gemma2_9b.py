"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000, local/global alternating, attn+final logit softcaps.
[arXiv:2408.00118]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    attn_pattern=("local", "global"),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    supports_long_context=True,  # alternating sliding-window layers
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    sliding_window=8,
    param_dtype="float32",
    dtype="float32",
)
