"""Architecture config registry: the 10 assigned architectures + the paper's
own BERT-base, each with a full config and a CPU-smoke reduction."""

import importlib

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "zamba2-7b": "zamba2_7b",
    "whisper-small": "whisper_small",
    "kimi-k2-1t-a32b": "kimi_k2",
    "rwkv6-1.6b": "rwkv6_1b6",
    "gemma2-9b": "gemma2_9b",
    "qwen2-moe-a2.7b": "qwen2_moe",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "gemma3-27b": "gemma3_27b",
    "minicpm3-4b": "minicpm3_4b",
    "bert-base": "bert_base",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "bert-base")


def _mod(name: str):
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _mod(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _mod(name).SMOKE


def list_archs():
    return sorted(_MODULES)


__all__ = [
    "ASSIGNED_ARCHS",
    "ArchConfig",
    "INPUT_SHAPES",
    "InputShape",
    "get_config",
    "get_smoke_config",
    "list_archs",
]
