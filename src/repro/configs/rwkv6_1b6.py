"""rwkv6-1.6b [ssm] — "Finch": 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536, data-dependent per-channel decay WKV. [arXiv:2404.05892]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65_536,
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
    ssm_chunk=32,  # (Q,Q,channel) intra block stays VMEM-sized
    norm="layernorm",
    use_rope=False,
    pos_embed="none",
    supports_long_context=True,  # O(1) recurrent state
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=128,
    d_ff=256,
    vocab_size=512,
    rwkv_head_dim=32,
    rwkv_decay_lora=16,
    ssm_chunk=8,
    param_dtype="float32",
    dtype="float32",
)
