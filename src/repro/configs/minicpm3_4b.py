"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448 with MLA
(multi-head latent attention: compressed KV cache). [hf:openbmb/MiniCPM3-4B]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    use_mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    head_dim=64,
    supports_long_context=False,  # pure full attention
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    q_lora_rank=64,
    kv_lora_rank=32,
    qk_nope_head_dim=32,
    qk_rope_head_dim=16,
    v_head_dim=32,
    head_dim=32,
    param_dtype="float32",
    dtype="float32",
)
