"""ArchConfig: one immutable description per architecture, plus the assigned
input-shape registry. Every full config cites its source; every arch also has
a ``smoke()`` reduction (<=2 layers, d_model <= 512, <= 4 experts) used by CPU
tests, per the assignment rules.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str  # citation
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # --- attention pattern ---
    # cycle of layer kinds, tiled over depth: "global" | "local"
    attn_pattern: Tuple[str, ...] = ("global",)
    sliding_window: int = 0  # for "local" layers
    attn_logit_softcap: float = 0.0  # gemma2
    final_logit_softcap: float = 0.0  # gemma2
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    mlp_type: str = "glu"  # glu | mlp
    tie_embeddings: bool = True
    use_rope: bool = True
    pos_embed: str = "rope"  # rope | sinusoidal | learned
    max_position: int = 131_072

    # --- MLA (minicpm3 / deepseek-style) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    first_k_dense: int = 0  # leading dense layers before the MoE stack
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01  # load-balance loss coefficient

    # --- SSM ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block applied every k mamba layers
    hybrid_attn_every: int = 0

    # --- RWKV ---
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # 30 s of audio after the (stubbed) conv frontend

    # --- VLM (llama-3.2-vision) ---
    cross_attn_every: int = 0  # every k-th layer is a vision cross-attn layer
    vision_dim: int = 0
    vision_tokens: int = 1601  # stubbed ViT patch embeddings per image

    # --- encoder-only classification (paper's BERT-base) ---
    num_labels: int = 0

    # --- numerics / memory ---
    dtype: str = "bfloat16"  # activation dtype
    param_dtype: str = "float32"
    remat: bool = True  # checkpoint each scanned layer body (recompute in bwd)

    # --- beyond-paper performance variants (see EXPERIMENTS.md §Perf) ---
    # CE over vocab-sharded logits without take_along_axis: the gather forces
    # XLA to all-gather full (tokens, V) logits; the one-hot-reduction form
    # keeps all collectives at (tokens,)-size psums.
    sharded_ce: bool = False
    # blockwise online-softmax attention (scan over KV chunks): removes the
    # (B, H, S, T) f32 score materialization for long-sequence prefill/train.
    attn_chunk: int = 0  # 0 = off; e.g. 1024

    # serving legality for the long-context shape
    supports_long_context: bool = False

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer attention kind, attn_pattern tiled over depth."""
        p = self.attn_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
