"""zamba2-7b [hybrid] — 81L d_model=3584 vocab=32000, Mamba2 backbone
(ssm_state=64) + ONE shared attention/MLP block (32H, d_ff=14336) applied
after every 6 Mamba layers (81 = 13x6 + 3 leading). [arXiv:2411.15242]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    hybrid_attn_every=6,
    supports_long_context=True,  # O(1)-state Mamba decode
)

SMOKE = CONFIG.replace(
    num_layers=3,  # 1 leading mamba + 1 group of 2 + shared attn
    hybrid_attn_every=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=2,
    head_dim=64,
    d_ff=256,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=16,
    param_dtype="float32",
    dtype="float32",
)
