"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    sliding_window=512,
    rope_theta=1_000_000.0,
    act="gelu",
    supports_long_context=True,  # 5:1 sliding-window layers
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=256,
    num_heads=2,
    num_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    sliding_window=8,
    attn_pattern=("local", "global"),
    param_dtype="float32",
    dtype="float32",
)
