"""bert-base [encoder] — the paper's OWN base model (WRENCH noisy-finetuning
experiments, Sec. 4.1): 12L d_model=768 12H d_ff=3072 vocab=30522, encoder-
only classifier. [arXiv:1810.04805 / paper Sec. 4.1]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="bert-base",
    family="encoder",
    source="paper Sec 4.1 / arXiv:1810.04805",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30_522,
    norm="layernorm",
    act="gelu",
    mlp_type="mlp",
    use_rope=False,
    pos_embed="learned",
    max_position=512,
    num_labels=4,
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=2,
    head_dim=64,
    d_ff=256,
    vocab_size=512,
    max_position=128,
    num_labels=4,
    param_dtype="float32",
    dtype="float32",
)
