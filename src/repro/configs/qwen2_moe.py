"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) vocab=151936,
60 routed experts top-4 (d_ff_expert=1408) + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,
    vocab_size=151_936,
    num_experts=60,
    top_k=4,
    moe_d_ff=1408,
    num_shared_experts=4,
    shared_d_ff=5632,
    first_k_dense=0,
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    num_experts=4,
    top_k=2,
    moe_d_ff=128,
    num_shared_experts=2,
    shared_d_ff=256,
    param_dtype="float32",
    dtype="float32",
)
