"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8 (d_ff_expert=2048) + 1 shared expert, 1 leading dense
layer. Trillion-param MoE (paper-table entry). [arXiv:2501.kimi2]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,  # the single leading dense layer
    vocab_size=163_840,
    num_experts=384,
    top_k=8,
    moe_d_ff=2048,
    num_shared_experts=1,
    shared_d_ff=2048,
    first_k_dense=1,
    supports_long_context=False,  # full attention
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    num_experts=4,
    top_k=2,
    moe_d_ff=128,
    num_shared_experts=1,
    shared_d_ff=128,
    first_k_dense=1,
    param_dtype="float32",
    dtype="float32",
)
