"""Model: the user-facing handle tying an ArchConfig to init/forward/decode
and to the SAMA data-optimization problem builders.

The per-example adapter returns mean-per-token cross-entropy per *sequence*
(the unit the paper reweights: an utterance / document / image-text pair),
plus predictive-entropy uncertainty for the Sec. 4.3 pruning variant.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.problems import PerExample
from repro.models import transformer as tf
from repro.kernels import dispatch as kdispatch
from repro.kernels import ops as kops

PyTree = Any


def token_cross_entropy(
    logits: jnp.ndarray, targets: jnp.ndarray, use_kernel: bool = False, sharded: bool = False
):
    """logits: (B, S, V) f32; targets: (B, S) int. Returns per-token CE (B, S).

    ``sharded=True`` uses the one-hot-reduction form: lse via local max/sum
    (SPMD lowers the V-axis reductions to (token,)-sized psums) and the target
    logit via a compare-select reduction instead of take_along_axis, whose
    gather over a vocab-sharded axis all-gathers the full logits tensor.

    Unsharded large vocabularies (V >= ``kernels.CE_VOCAB_THRESHOLD``) route
    through the dispatched blockwise ``weighted_ce`` kernel automatically;
    ``use_kernel=True`` forces that route for any size (which backend then
    runs — compiled Pallas, interpreter, or jnp ref — is the dispatch
    registry's call, docs/kernels.md). The kernel route returns f32 CE
    regardless of logits dtype (the kernels compute in f32); the small-vocab
    path keeps logits dtype.
    """

    if sharded:
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        tgt = jnp.sum(jnp.where(ids == targets[..., None], logits, 0.0), axis=-1)
        return lse - tgt
    if use_kernel or logits.shape[-1] >= kdispatch.CE_VOCAB_THRESHOLD:
        return kops.cross_entropy(logits, targets)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


@dataclasses.dataclass(eq=False)  # identity hash/eq: Model instances key
class Model:                      # per-model jit caches (dataopt.prune)
    cfg: Any
    use_ce_kernel: bool = False

    # -- params / caches --
    def init(self, key) -> PyTree:
        return tf.init_params(self.cfg, key)

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16) -> PyTree:
        return tf.init_cache(self.cfg, batch, cache_len, dtype)

    # -- compute paths --
    def forward(self, params, batch):
        return tf.forward(self.cfg, params, batch)

    def decode_step(self, params, cache, tokens, pos):
        return tf.decode_step(self.cfg, params, cache, tokens, pos)

    # -- losses --
    def lm_loss(self, params, batch) -> jnp.ndarray:
        """Next-token LM loss (scalar) + MoE aux. batch: tokens (B,S) [+ modality]."""
        logits, aux = self.forward(params, batch)
        ce = token_cross_entropy(
            logits[:, :-1], batch["tokens"][:, 1:], self.use_ce_kernel, self.cfg.sharded_ce
        )
        return jnp.mean(ce) + aux

    def per_example(self, params, batch) -> PerExample:
        """Per-sequence loss for data-optimization meta learning."""
        logits, aux = self.forward(params, batch)
        del aux  # aux load-balance is added by train_loss wrappers, not reweighted
        ce = token_cross_entropy(
            logits[:, :-1], batch["tokens"][:, 1:], self.use_ce_kernel, self.cfg.sharded_ce
        )
        loss = jnp.mean(ce, axis=-1)  # (B,)
        logp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        entropy = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
        return PerExample(loss=loss, uncertainty=entropy)

    def classifier_per_example(self, params, batch) -> PerExample:
        """family == 'encoder': batch = {tokens (B,S), y (B,)}. Label spaces
        at ``kernels.CE_VOCAB_THRESHOLD``+ route the per-sample CE through
        the dispatched ``weighted_ce`` kernel (docs/kernels.md)."""
        logits, _ = self.forward(params, batch)
        onehot = jax.nn.one_hot(batch["y"], logits.shape[-1], dtype=logits.dtype)
        logp = jax.nn.log_softmax(logits, axis=-1)
        if logits.shape[-1] >= kdispatch.CE_VOCAB_THRESHOLD:
            loss = kops.cross_entropy(logits, batch["y"])
        else:
            loss = -jnp.sum(onehot * logp, axis=-1)
        p = jnp.exp(logp)
        entropy = -jnp.sum(p * logp, axis=-1)
        return PerExample(loss=loss, logits=logits, label_onehot=onehot, uncertainty=entropy)

    def num_params(self, params) -> int:
        return sum(x.size for x in jax.tree_util.tree_leaves(params))
