"""Shared model building blocks: norms, MLPs, RoPE, initializers.

Everything is pure-functional over nested-dict params. Layer stacks are
*stacked* along a leading axis and executed with ``lax.scan`` so HLO size
(and compile time) is O(1) in depth — essential for the 100-layer dry-runs.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

PyTree = Any


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def stacked_init(init_fn: Callable, key, num: int) -> PyTree:
    """vmap an init over a leading layer axis."""
    keys = jax.random.split(key, num)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) / jnp.sqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP / GLU
# ---------------------------------------------------------------------------


def _act(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def init_mlp(cfg, key, d_in=None, d_ff=None, dtype=jnp.float32):
    d_in = d_in or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": dense_init(k1, (d_in, d_ff), dtype=dtype),
        "down": dense_init(k3, (d_ff, d_in), dtype=dtype),
    }
    if cfg.mlp_type == "glu":
        p["gate"] = dense_init(k2, (d_in, d_ff), dtype=dtype)
    return p


def apply_mlp(cfg, p, x):
    act = _act(cfg.act)
    up = x @ p["up"].astype(x.dtype)
    if cfg.mlp_type == "glu":
        up = up * act(x @ p["gate"].astype(x.dtype))
    else:
        up = act(up)
    return up @ p["down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def unstack_layer(params: PyTree, idx) -> PyTree:
    """Select one layer's params from a stacked pytree (used by decode loops
    and inspection utilities; scan does this implicitly)."""
    return jax.tree_util.tree_map(lambda x: x[idx], params)
