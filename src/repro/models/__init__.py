"""Architecture substrate: the 10 assigned architectures + the paper's own
BERT-base, as pure-functional JAX models with scan-compiled layer stacks."""

from repro.models.model import Model, token_cross_entropy
from repro.models import attention, common, moe, ssm, transformer

__all__ = ["Model", "attention", "common", "moe", "ssm", "token_cross_entropy", "transformer"]
