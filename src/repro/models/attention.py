"""Attention variants: GQA self-attention (with sliding-window / global mix,
logit softcap), MLA (compressed-latent KV), and cross-attention — each with a
training path and a one-token decode path over an explicit KV cache.

Layout conventions:
  activations x: (B, S, D)
  q/k/v:        (B, S, H, Dh)
  KV cache:     {"k": (B, T, KV, Dh), "v": (B, T, KV, Dh)}  (T = cache length)
  MLA cache:    {"ckv": (B, T, r), "krope": (B, T, Dr)}      (compressed!)

``local_flag`` is a traced scalar bool so that heterogeneous local/global
patterns run inside a single lax.scan over stacked layer params.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models import common as cm

PyTree = Any

#: ISSUE 9 dispatch seams: GQA self-attention routes through the kernel
#: registry (flash Pallas kernels on TPU / forced backends, the literal
#: pre-kernel jnp ops on the always-eligible ``ref`` path).
_flash_attention = dispatch.get_kernel("flash_attention")
_flash_decode = dispatch.get_kernel("flash_decode")


def make_mask(q_pos, kv_pos, *, causal=True, local_flag=None, window=0):
    """q_pos: (B,S) int; kv_pos: (T,) int. Returns (B,1,S,T) bool (True=keep)."""
    q = q_pos[:, :, None]  # (B,S,1)
    k = kv_pos[None, None, :]  # (1,1,T)
    mask = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        mask = k <= q
    if window and local_flag is not None:
        local = (q - k) < window
        mask = mask & jnp.where(local_flag, local, True)
    return mask[:, None]  # (B,1,S,T)


def _chunked_sdpa(q, k, v, q_pos, kv_pos, *, chunk, softcap=0.0, local_flag=None,
                  window=0, causal=True):
    """Blockwise online-softmax attention (flash-style, KV-chunked scan).

    Never materializes the (B, H, S, T) score tensor: each scan step holds
    one (B, H, S, chunk) block plus running (max, sum, acc) statistics. The
    body is checkpointed so the backward pass recomputes blocks instead of
    saving them. This is the §Perf memory-term optimization for long-sequence
    prefill/train; on TPU the block working set is VMEM-sized by chunk.
    """

    B, S, KV, G, Dh = q.shape
    T = k.shape[1]
    nc = -(-T // chunk)
    if nc * chunk != T:  # ragged T: pad KV with -1-position sentinel rows
        pad = nc * chunk - T
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    k_c = jnp.moveaxis(k.reshape(B, nc, chunk, KV, Dh), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, nc, chunk, KV, Dh), 1, 0)
    pos_c = kv_pos.reshape(nc, chunk)
    scale = 1.0 / jnp.sqrt(Dh).astype(q.dtype)

    NEG = -1e30  # finite sentinel: keeps exp/max arithmetic nan-free when a
    # query's valid keys haven't appeared yet (e.g. sliding-window + early chunks)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, pc = inp
        s = jnp.einsum("bskgd,btkd->bkgst", q, kc) * scale  # (B,KV,G,S,C)
        s = cm.softcap(s.astype(jnp.float32), softcap)
        mask = make_mask(q_pos, pc, causal=causal, local_flag=local_flag, window=window)
        mask = mask & (pc >= 0)[None, None, None, :]  # drop padded sentinel rows
        mask_b = jnp.broadcast_to(mask[:, :, None], s.shape)  # (B,1,1,S,C)->(B,KV,G,S,C)
        s = jnp.where(mask_b, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask_b, jnp.exp(s - m_new[..., None]), 0.0)
        scale_old = jnp.exp(jnp.minimum(m - m_new, 0.0))
        scale_old = jnp.where(m <= NEG, 0.0, scale_old)  # nothing accumulated yet
        l_new = l * scale_old + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), vc)
        acc_new = acc * jnp.moveaxis(scale_old, -1, 1)[..., None].astype(q.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    acc0 = jnp.zeros((B, S, KV, G, Dh), q.dtype)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), (m0, l0, acc0), (k_c, v_c, pos_c)
    )
    denom = jnp.moveaxis(jnp.maximum(l, 1e-30), -1, 1)[..., None]
    return (acc / denom.astype(q.dtype)).reshape(B, S, KV * G, Dh)


def _sdpa(q, k, v, mask, *, softcap=0.0):
    """Grouped scaled-dot-product attention.
    q: (B,S,H,Dh), k/v: (B,T,KV,Dh); H = KV * G."""

    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) / jnp.sqrt(Dh).astype(q.dtype)
    scores = cm.softcap(scores.astype(jnp.float32), softcap)
    if mask is not None:
        scores = jnp.where(mask[:, :, None], scores, -1e30)  # mask (B,1,S,T)->(B,1,1,S,T)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, Dh)


# ---------------------------------------------------------------------------
# GQA self-attention
# ---------------------------------------------------------------------------


def init_self_attn(cfg, key, dtype=jnp.float32):
    H, KV, Dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": cm.dense_init(k1, (D, H * Dh), dtype=dtype),
        "wk": cm.dense_init(k2, (D, KV * Dh), dtype=dtype),
        "wv": cm.dense_init(k3, (D, KV * Dh), dtype=dtype),
        "wo": cm.dense_init(k4, (H * Dh, D), dtype=dtype),
    }


def self_attention(
    cfg,
    p: PyTree,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    local_flag=None,
    causal: bool = True,
    cache: Optional[Dict] = None,
    cache_pos=None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, KV, Dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, KV, Dh)
    if cfg.use_rope:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        kv_pos = positions[0] if positions.ndim == 2 else positions
        q_pos = (positions if positions.ndim == 2
                 else jnp.broadcast_to(positions[None], (B, S)))
        # ISSUE 9: training/prefill attention dispatches through the kernel
        # registry. The ref backend reproduces the pre-kernel ops literally
        # (including the chunk-gated _sdpa/_chunked_sdpa selection), so the
        # default CPU path is unchanged; TPU / forced backends lower the
        # blockwise flash Pallas kernel with its recompute-based VJP.
        out = _flash_attention(
            q, k, v, q_pos, kv_pos, local_flag,
            softcap=cfg.attn_logit_softcap, window=cfg.sliding_window,
            causal=causal, chunk=cfg.attn_chunk,
        )
        new_cache = None
    else:
        # decode: insert the S new k/v rows at cache_pos, attend over the
        # cache. cache_pos is a scalar start (uniform batch — a contiguous
        # dynamic_update_slice) or a (B,) vector of per-lane starts
        # (continuous batching with staggered sequence lengths — a scatter).
        T = cache["k"].shape[1]
        if jnp.ndim(cache_pos) == 0:
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        else:
            lane = jnp.arange(B)[:, None]
            idx = cache_pos[:, None] + jnp.arange(S)
            ck = cache["k"].at[lane, idx].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[lane, idx].set(v.astype(cache["v"].dtype))
        if S == 1:
            # one-token decode: the split-KV kernel consumes per-lane
            # positions directly (continuous batching's ragged lanes); the
            # ref backend is the exact make_mask + _sdpa ops from before.
            out = _flash_decode(
                q, ck.astype(q.dtype), cv.astype(q.dtype), positions,
                local_flag, softcap=cfg.attn_logit_softcap,
                window=cfg.sliding_window,
            )
        else:
            kv_pos = jnp.arange(T)
            mask = make_mask(positions, kv_pos, causal=True, local_flag=local_flag, window=cfg.sliding_window)
            out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask, softcap=cfg.attn_logit_softcap)
        new_cache = {"k": ck, "v": cv}

    out = out.reshape(B, S, H * Dh) @ p["wo"].astype(x.dtype)
    return out, new_cache


def init_kv_cache(cfg, batch: int, length: int, dtype=jnp.bfloat16):
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, KV, Dh), dtype),
        "v": jnp.zeros((batch, length, KV, Dh), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek/MiniCPM3-style multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(cfg, key, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.num_heads
    r, rq = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": cm.dense_init(ks[0], (D, r + dr), dtype=dtype),
        "kv_norm": jnp.ones((r,), jnp.float32),
        "wkv_b": cm.dense_init(ks[1], (r, H * (dn + dv)), dtype=dtype),
        "wo": cm.dense_init(ks[2], (H * dv, D), dtype=dtype),
    }
    if rq:
        p["wq_a"] = cm.dense_init(ks[3], (D, rq), dtype=dtype)
        p["q_norm"] = jnp.ones((rq,), jnp.float32)
        p["wq_b"] = cm.dense_init(ks[4], (rq, H * (dn + dr)), dtype=dtype)
    else:
        p["wq"] = cm.dense_init(ks[5], (D, H * (dn + dr)), dtype=dtype)
    return p


def _rmsnorm_vec(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf**2, -1, keepdims=True) + eps) * scale).astype(x.dtype)


def mla_attention(cfg, p, x, positions, *, cache=None, cache_pos=None):
    B, S, D = x.shape
    H = cfg.num_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if "wq_a" in p:
        q = _rmsnorm_vec(x @ p["wq_a"].astype(x.dtype), p["q_norm"]) @ p["wq_b"].astype(x.dtype)
    else:
        q = x @ p["wq"].astype(x.dtype)
    q = q.reshape(B, S, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = cm.apply_rope(qr, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"].astype(x.dtype)  # (B,S,r+dr)
    ckv, krope = kv_a[..., :r], kv_a[..., r:]
    krope = cm.apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]  # shared head

    if cache is not None:
        if jnp.ndim(cache_pos) == 0:
            ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_pos, 0))
            krope = jax.lax.dynamic_update_slice(
                cache["krope"], krope.astype(cache["krope"].dtype), (0, cache_pos, 0)
            )
        else:  # per-lane starts (continuous batching): scatter rows
            lane = jnp.arange(B)[:, None]
            idx = cache_pos[:, None] + jnp.arange(S)
            ckv = cache["ckv"].at[lane, idx].set(ckv.astype(cache["ckv"].dtype))
            krope = cache["krope"].at[lane, idx].set(krope.astype(cache["krope"].dtype))
        new_cache = {"ckv": ckv, "krope": krope}
        T = ckv.shape[1]
        kv_pos = jnp.arange(T)
    else:
        new_cache = None
        T = S
        kv_pos = positions[0] if positions.ndim == 2 else positions

    kv = _rmsnorm_vec(ckv.astype(x.dtype), p["kv_norm"]) @ p["wkv_b"].astype(x.dtype)
    kv = kv.reshape(B, T, H, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]

    scale = 1.0 / jnp.sqrt(dn + dr).astype(x.dtype)
    scores = (
        jnp.einsum("bshd,bthd->bhst", qn, kn)
        + jnp.einsum("bshd,btd->bhst", qr, krope.astype(x.dtype))
    ) * scale
    mask = make_mask(positions, kv_pos, causal=True)  # (B,1,S,T)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, H * dv)
    return out @ p["wo"].astype(x.dtype), new_cache


def init_mla_cache(cfg, batch: int, length: int, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder, llama-3.2-vision layers)
# ---------------------------------------------------------------------------


def init_cross_attn(cfg, key, dtype=jnp.float32, kv_dim=None):
    H, KV, Dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    kv_dim = kv_dim or D
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": cm.dense_init(k1, (D, H * Dh), dtype=dtype),
        "wk": cm.dense_init(k2, (kv_dim, KV * Dh), dtype=dtype),
        "wv": cm.dense_init(k3, (kv_dim, KV * Dh), dtype=dtype),
        "wo": cm.dense_init(k4, (H * Dh, D), dtype=dtype),
    }


def cross_attention(cfg, p, x, *, memory=None, memory_kv=None):
    """memory: (B, M, D_mem) encoder/vision states, or precomputed memory_kv
    {"k","v"} (decode path — computed once at prefill)."""

    B, S, D = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    if memory_kv is None:
        k = (memory @ p["wk"].astype(memory.dtype)).reshape(B, -1, KV, Dh).astype(x.dtype)
        v = (memory @ p["wv"].astype(memory.dtype)).reshape(B, -1, KV, Dh).astype(x.dtype)
    else:
        k, v = memory_kv["k"].astype(x.dtype), memory_kv["v"].astype(x.dtype)
    out = _sdpa(q, k, v, None)
    return out.reshape(B, S, H * Dh) @ p["wo"].astype(x.dtype)


def cross_kv(cfg, p, memory):
    B = memory.shape[0]
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    k = (memory @ p["wk"].astype(memory.dtype)).reshape(B, -1, KV, Dh)
    v = (memory @ p["wv"].astype(memory.dtype)).reshape(B, -1, KV, Dh)
    return {"k": k, "v": v}
