"""Mixture-of-Experts layer: top-k router with capacity-based dispatch
(GShard/Switch-style one-hot einsum dispatch — the TPU-native formulation),
optional always-on shared experts (Qwen-MoE / Kimi-K2 style), and an
auxiliary load-balance loss surfaced to the training objective.

Expert weights carry a leading E axis so they shard naturally over the
``model`` mesh axis (expert parallelism); dispatch/combine einsums lower to
all-to-alls under pjit when tokens are data-sharded.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm

PyTree = Any


def init_moe(cfg, key, dtype=jnp.float32):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    k_router, k_experts, k_shared = jax.random.split(key, 3)

    def one_expert(k):
        return init_expert_ffn(cfg, k, D, F, dtype)

    p = {
        "router": cm.dense_init(k_router, (D, E), dtype=jnp.float32),
        "experts": cm.stacked_init(one_expert, k_experts, E),
    }
    if cfg.num_shared_experts:
        p["shared"] = cm.init_mlp(
            cfg, k_shared, d_in=D, d_ff=cfg.shared_d_ff or cfg.num_shared_experts * F, dtype=dtype
        )
    return p


def init_expert_ffn(cfg, key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": cm.dense_init(k1, (d, f), dtype=dtype),
        "down": cm.dense_init(k3, (f, d), dtype=dtype),
    }
    if cfg.mlp_type == "glu":
        p["gate"] = cm.dense_init(k2, (d, f), dtype=dtype)
    return p


def _expert_ffn(cfg, p, x):
    """x: (E, C, D) with per-expert stacked weights (E, ...)."""
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
    up = jnp.einsum("ecd,edf->ecf", x, p["up"].astype(x.dtype))
    if cfg.mlp_type == "glu":
        up = up * act(jnp.einsum("ecd,edf->ecf", x, p["gate"].astype(x.dtype)))
    else:
        up = act(up)
    return jnp.einsum("ecf,efd->ecd", up, p["down"].astype(x.dtype))


MOE_GROUP = 1024  # tokens per dispatch group (GShard-style); bounds the
# one-hot dispatch tensor to (G, E, C) with C ~ k*G/E, so dispatch/combine
# einsum overhead stays ~O(G/6F) relative to expert FLOPs.


def _group_dispatch(cfg, probs_g, tokens_g, experts, capacity):
    """One dispatch group. probs_g: (G, E) f32; tokens_g: (G, D)."""

    G, E = probs_g.shape
    K = cfg.top_k
    gate_vals, expert_idx = jax.lax.top_k(probs_g, K)  # (G, K)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (G,K,E)
    # choice-major flattening: all 1st choices get capacity slots before 2nd…
    flat = assign.transpose(1, 0, 2).reshape(K * G, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.sum(pos * flat, axis=-1)  # (K*G,)
    keep = (pos < capacity) & (jnp.sum(flat, -1) > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32) * keep[:, None]
    # contract over the choice axis without materializing (K,G,E,C)
    flat_k = flat.reshape(K, G, E)
    pos_oh_k = pos_oh.reshape(K, G, capacity)
    dispatch = jnp.einsum("kge,kgc->gec", flat_k, pos_oh_k)  # (G,E,C) 0/1
    gates_k = gate_vals.transpose(1, 0)  # (K,G)
    combine = jnp.einsum("kge,kgc->gec", flat_k * gates_k[:, :, None], pos_oh_k)

    expert_in = jnp.einsum("gec,gd->ecd", dispatch.astype(tokens_g.dtype), tokens_g)
    expert_out = _expert_ffn(cfg, experts, expert_in)  # (E,C,D)
    out = jnp.einsum("gec,ecd->gd", combine.astype(tokens_g.dtype), expert_out)
    return out


def apply_moe(cfg, p: PyTree, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D). Returns (out, aux_load_balance_loss)."""

    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    group = min(MOE_GROUP, T)
    n_groups = T // group
    assert n_groups * group == T, f"token count {T} not divisible by group {group}"
    capacity = max(int(cfg.capacity_factor * K * group / E), 4)

    tokens = x.reshape(n_groups, group, D)
    router_logits = jnp.einsum(
        "ngd,de->nge", tokens.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)  # (n, G, E)

    out = jax.vmap(lambda pr, tk: _group_dispatch(cfg, pr, tk, p["experts"], capacity))(
        probs, tokens
    )

    flat_tokens = x.reshape(T, D)
    if cfg.num_shared_experts:
        out = out.reshape(T, D) + cm.apply_mlp(cfg, p["shared"], flat_tokens)

    # GShard aux loss: E * sum_e f_e * p_e over the whole batch
    probs_flat = probs.reshape(T, E)
    _, expert_idx = jax.lax.top_k(probs_flat, K)
    assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    me = jnp.mean(probs_flat, axis=0)
    ce = jnp.mean(jnp.sum(assign, axis=1), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    return out.reshape(B, S, D), aux.astype(jnp.float32)
