"""State-space / linear-recurrence layers.

* Mamba2 (SSD) — chunkwise-parallel scan: intra-chunk attention-like masked
  matmuls + lax.scan over chunks carrying the (B, H, P, N) state. All decay
  exponents are differences of a monotone cumsum with i >= j, hence <= 0 and
  numerically safe to exponentiate in f32.
* RWKV6 ("Finch") — data-dependent per-channel decay. Intra-chunk term needs
  a per-(i, j, k) exponent, materialized blockwise per chunk (the TPU/VMEM
  analogue of flash-linear-attention's SRAM blocks).

Both expose a one-token ``*_decode`` with O(1) state — this is what makes the
``long_500k`` shape legal for rwkv6/zamba2.

TPU adaptation note (DESIGN.md §7): the chunk size trades VMEM footprint of
the (Q, Q) intra-chunk blocks against the length of the sequential
chunk-scan; defaults are picked so a chunk's working set fits VMEM.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm

PyTree = Any


def causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C); w: (C, K) depthwise causal filter."""
    K = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[:, i] for i in range(K))
    return out + b


# ===========================================================================
# Mamba2 / SSD
# ===========================================================================


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(cfg, key, dtype=jnp.float32):
    D = cfg.d_model
    d_inner, H, P, N = mamba_dims(cfg)
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": cm.dense_init(ks[0], (D, 2 * d_inner + 2 * N + H), dtype=dtype),
        "conv_w": cm.dense_init(ks[1], (conv_dim, cfg.ssm_conv), scale=1.0, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1 init
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus(-2) ~ 0.12
        "norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": cm.dense_init(ks[2], (d_inner, D), dtype=dtype),
    }


def _mamba_preproj(cfg, p, x):
    d_inner, H, P, N = mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : 2 * d_inner + 2 * N]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * N :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    return z, xBC, dt


def _mamba_postproc(cfg, p, y, z):
    d_inner, H, P, N = mamba_dims(cfg)
    B, S = y.shape[:2]
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf**2, -1, keepdims=True) + 1e-6) * p["norm"]).astype(y.dtype)
    return y @ p["out_proj"].astype(y.dtype)


def apply_mamba(cfg, p: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    """Training/prefill path. x: (B, S, D)."""

    d_inner, H, P, N = mamba_dims(cfg)
    Bsz, S, _ = x.shape
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by ssm chunk {Q}"
    nc = S // Q

    z, xBC, dt = _mamba_preproj(cfg, p, x)
    xBC = jax.nn.silu(causal_depthwise_conv(xBC, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype)))
    xs = xBC[..., :d_inner].reshape(Bsz, S, H, P)
    Bm = xBC[..., d_inner : d_inner + N]  # (B,S,N) shared across heads
    Cm = xBC[..., d_inner + N :]

    A = -jnp.exp(p["A_log"])  # (H,)
    a = dt * A  # (B,S,H) <= 0

    # chunked views
    def ch(t):
        return t.reshape((Bsz, nc, Q) + t.shape[2:])

    a_c, dt_c = ch(a), ch(dt)
    x_c, B_c, C_c = ch(xs), ch(Bm), ch(Cm)
    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]

    def body(S_prev, inp):
        """One chunk: intra-chunk masked matmuls + inter-chunk from carried
        state. All per-chunk intermediates are transient (VMEM-sized)."""
        a_q, dt_q, x_q, B_q, C_q = inp  # (B,Q,H), (B,Q,H), (B,Q,H,P), (B,Q,N), (B,Q,N)
        cum = jnp.cumsum(a_q, axis=1)  # (B,Q,H), decreasing

        scores = jnp.einsum("bin,bjn->bij", C_q, B_q)  # (B,Q,Q)
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,H): <=0 for i>=j
        L = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        M = scores[:, :, :, None] * L * dt_q[:, None, :, :]  # (B,i,j,H)
        y = jnp.einsum("bijh,bjhp->bihp", M.astype(x_q.dtype), x_q)

        decay_in = jnp.exp(cum).astype(S_prev.dtype)  # (B,Q,H)
        y = y + jnp.einsum("bin,bhpn,bih->bihp", C_q, S_prev, decay_in)

        decay_out = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
        w_j = (decay_out * dt_q).astype(x_q.dtype)
        S_loc = jnp.einsum("bjh,bjn,bjhp->bhpn", w_j, B_q, x_q)
        S_new = jnp.exp(cum[:, -1, :])[:, :, None, None].astype(S_prev.dtype) * S_prev + S_loc
        return S_new, y

    S0 = jnp.zeros((Bsz, H, P, N), x.dtype)
    xs_scan = tuple(jnp.moveaxis(t, 1, 0) for t in (a_c, dt_c, x_c, B_c, C_c))
    _, y = jax.lax.scan(body, S0, xs_scan)  # (nc,B,Q,H,P)
    y = jnp.moveaxis(y, 0, 1) + x_c * p["D"].astype(x.dtype)[None, None, None, :, None]
    y = y.reshape(Bsz, S, H, P)
    return _mamba_postproc(cfg, p, y, z)


def init_mamba_state(cfg, batch: int, dtype=jnp.bfloat16) -> Dict:
    d_inner, H, P, N = mamba_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def mamba_decode(cfg, p: PyTree, x: jnp.ndarray, state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. x: (B, 1, D)."""

    d_inner, H, P, N = mamba_dims(cfg)
    Bsz = x.shape[0]
    z, xBC, dt = _mamba_preproj(cfg, p, x)  # (B,1,...)
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), xBC], axis=1)  # (B,K,conv_dim)
    xBC_t = jax.nn.silu(
        jnp.sum(conv_in * p["conv_w"].astype(x.dtype).T[None], axis=1) + p["conv_b"].astype(x.dtype)
    )  # (B,conv_dim)
    new_conv = conv_in[:, 1:]

    xt = xBC_t[:, :d_inner].reshape(Bsz, H, P)
    Bt = xBC_t[:, d_inner : d_inner + N]
    Ct = xBC_t[:, d_inner + N :]
    dt_t = dt[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_t * A).astype(x.dtype)  # (B,H)

    S = state["ssm"].astype(x.dtype)
    S = decay[:, :, None, None] * S + jnp.einsum(
        "bh,bn,bhp->bhpn", dt_t.astype(x.dtype), Bt, xt
    )
    y = jnp.einsum("bn,bhpn->bhp", Ct, S) + xt * p["D"].astype(x.dtype)[None, :, None]
    out = _mamba_postproc(cfg, p, y[:, None].reshape(Bsz, 1, H, P), z)
    return out, {"ssm": S.astype(state["ssm"].dtype), "conv": new_conv.astype(state["conv"].dtype)}


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================


def rwkv_dims(cfg):
    H = cfg.d_model // cfg.rwkv_head_dim
    return H, cfg.rwkv_head_dim


def init_rwkv_time_mix(cfg, key, dtype=jnp.float32):
    D = cfg.d_model
    H, K = rwkv_dims(cfg)
    L = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 8)
    return {
        "mu": {n: jnp.full((D,), 0.5, jnp.float32) for n in ("r", "k", "v", "g", "w")},
        "wr": cm.dense_init(ks[0], (D, D), dtype=dtype),
        "wk": cm.dense_init(ks[1], (D, D), dtype=dtype),
        "wv": cm.dense_init(ks[2], (D, D), dtype=dtype),
        "wg": cm.dense_init(ks[3], (D, D), dtype=dtype),
        "wo": cm.dense_init(ks[4], (D, D), dtype=dtype),
        "w0": jnp.full((D,), -1.0, jnp.float32),  # decay bias: w ~ exp(-exp(-1+...))
        "wA": cm.dense_init(ks[5], (D, L), dtype=dtype),
        "wB": cm.dense_init(ks[6], (L, D), scale=0.1, dtype=dtype),
        "u": jnp.zeros((H, K), jnp.float32),  # "bonus" for the current token
        "ln_x": {"scale": jnp.ones((D,), jnp.float32), "bias": jnp.zeros((D,), jnp.float32)},
    }


def init_rwkv_channel_mix(cfg, key, dtype=jnp.float32):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": {n: jnp.full((D,), 0.5, jnp.float32) for n in ("k", "r")},
        "wk": cm.dense_init(ks[0], (D, F), dtype=dtype),
        "wv": cm.dense_init(ks[1], (F, D), dtype=dtype),
        "wr": cm.dense_init(ks[2], (D, D), dtype=dtype),
    }


def _token_shift(x, x_prev_last=None):
    """x_{t-1} with zeros (or carried state) at t=0. x: (B,S,D)."""
    if x_prev_last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev_last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xprev, mu):
    return x + (xprev - x) * mu.astype(x.dtype)


def _rwkv_proj(cfg, p, x, xprev):
    H, K = rwkv_dims(cfg)
    B, S, D = x.shape
    r = (_mix(x, xprev, p["mu"]["r"]) @ p["wr"].astype(x.dtype)).reshape(B, S, H, K)
    k = (_mix(x, xprev, p["mu"]["k"]) @ p["wk"].astype(x.dtype)).reshape(B, S, H, K)
    v = (_mix(x, xprev, p["mu"]["v"]) @ p["wv"].astype(x.dtype)).reshape(B, S, H, K)
    g = jax.nn.silu(_mix(x, xprev, p["mu"]["g"]) @ p["wg"].astype(x.dtype))
    xw = _mix(x, xprev, p["mu"]["w"])
    lora = jnp.tanh(xw @ p["wA"].astype(x.dtype)) @ p["wB"].astype(x.dtype)
    logw = -jnp.exp(jnp.clip(p["w0"] + lora.astype(jnp.float32), -8.0, 3.0))  # (B,S,D) < 0
    return r, k, v, g, logw.reshape(B, S, H, K)


def _rwkv_out(cfg, p, y, g, x_dtype):
    """Per-head groupnorm, gate, output proj. y: (B,S,H,K) f32."""
    B, S, H, K = y.shape
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    yn = yn.reshape(B, S, H * K) * p["ln_x"]["scale"] + p["ln_x"]["bias"]
    out = (yn.astype(x_dtype) * g) @ p["wo"].astype(x_dtype)
    return out


def apply_rwkv_time_mix(cfg, p: PyTree, x: jnp.ndarray, x_prev_last=None) -> jnp.ndarray:
    """Chunkwise WKV6. x: (B,S,D)."""

    H, K = rwkv_dims(cfg)
    B, S, D = x.shape
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0
    nc = S // Q

    xprev = _token_shift(x, x_prev_last)
    r, k, v, g, logw = _rwkv_proj(cfg, p, x, xprev)

    def ch(t):
        return t.reshape((B, nc, Q) + t.shape[2:])

    r_c, k_c, v_c, w_c = ch(r), ch(k), ch(v), ch(logw)
    ii = jnp.arange(Q)
    strict = ii[:, None] > ii[None, :]

    def body(S_prev, inp):
        """One chunk. The per-(i,j,channel) decay tensor exists only inside
        this body — (B,Q,Q,H,K) is the VMEM-resident block, per the FLA
        blockwise formulation."""
        r_q, k_q, v_q, w_q = (t.astype(jnp.float32) for t in inp)  # (B,Q,H,K)
        cw = jnp.cumsum(w_q, axis=1)  # decreasing
        q_shift = jnp.pad(cw[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0)))  # cw_{t-1}

        # intra: y_t = sum_{j<t} (r_t . e^{cw_{t-1}-cw_j} k_j) v_j + bonus_t
        diff = q_shift[:, :, None] - cw[:, None, :]  # (B,i,j,H,K) <= 0 where j<i
        dec = jnp.where(strict[None, :, :, None, None], jnp.exp(diff), 0.0)
        att = jnp.einsum("bihk,bijhk,bjhk->bijh", r_q, dec, k_q)
        y = jnp.einsum("bijh,bjhk->bihk", att, v_q)
        bonus = jnp.einsum("bihk,hk,bihk->bih", r_q, p["u"], k_q)
        y = y + bonus[..., None] * v_q

        # inter: from the carried state
        rd = r_q * jnp.exp(q_shift)  # exponent <= 0
        y = y + jnp.einsum("bihk,bhkv->bihv", rd, S_prev)

        decay_out = jnp.exp(cw[:, -1:] - cw)  # (B,Q,H,K)
        S_loc = jnp.einsum("bjhk,bjhv->bhkv", decay_out * k_q, v_q)
        S_new = jnp.exp(cw[:, -1])[..., None] * S_prev + S_loc
        return S_new, y

    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    xs_scan = tuple(jnp.moveaxis(t, 1, 0) for t in (r_c, k_c, v_c, w_c))
    _, y = jax.lax.scan(body, S0, xs_scan)  # (nc,B,Q,H,K)
    y = jnp.moveaxis(y, 0, 1).reshape(B, S, H, K)
    return _rwkv_out(cfg, p, y, g, x.dtype)


def init_rwkv_state(cfg, batch: int, dtype=jnp.float32) -> Dict:
    H, K = rwkv_dims(cfg)
    D = cfg.d_model
    return {
        "S": jnp.zeros((batch, H, K, K), jnp.float32),  # f32: recurrent state
        "x_att": jnp.zeros((batch, D), dtype),
        "x_ffn": jnp.zeros((batch, D), dtype),
    }


def rwkv_time_mix_decode(cfg, p, x, state):
    """x: (B,1,D); returns (out, new_state fragments)."""

    H, K = rwkv_dims(cfg)
    B = x.shape[0]
    xprev = state["x_att"][:, None].astype(x.dtype)
    r, k, v, g, logw = _rwkv_proj(cfg, p, x, xprev)
    r1, k1, v1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v))  # (B,H,K)
    w1 = jnp.exp(logw[:, 0].astype(jnp.float32))  # (B,H,K)
    S = state["S"]
    kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
    y = jnp.einsum("bhk,bhkv->bhv", r1, S + p["u"][None, :, :, None] * kv)
    S_new = w1[..., None] * S + kv
    out = _rwkv_out(cfg, p, y[:, None], g, x.dtype)
    return out, {"S": S_new, "x_att": x[:, 0].astype(state["x_att"].dtype)}


def apply_rwkv_channel_mix(cfg, p, x, x_prev_last=None):
    xprev = _token_shift(x, x_prev_last)
    k = _mix(x, xprev, p["mu"]["k"]) @ p["wk"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(k))
    kv = k @ p["wv"].astype(x.dtype)
    rgate = jax.nn.sigmoid(_mix(x, xprev, p["mu"]["r"]) @ p["wr"].astype(x.dtype))
    return rgate * kv


def rwkv_channel_mix_decode(cfg, p, x, state):
    out = apply_rwkv_channel_mix(cfg, p, x, state["x_ffn"])
    return out, {"x_ffn": x[:, 0].astype(state["x_ffn"].dtype)}
