"""Architecture assembly: init / forward / one-token decode per family.

Families
  dense   — GQA or MLA attention + MLP, heterogeneous local/global patterns
            expressed as a per-layer flag array inside ONE lax.scan.
  moe     — leading dense layers + scanned MoE stack (aux loss accumulated
            in the scan carry).
  ssm     — RWKV6 blocks (time-mix + channel-mix).
  hybrid  — Zamba2: groups of k Mamba2 layers + ONE shared attention block
            applied after each group (shared weights = scan closure constant,
            per-application KV caches).
  audio   — Whisper: bidirectional encoder over (stubbed) frame embeddings +
            causal decoder with cross-attention.
  vlm     — Llama-3.2-Vision: groups of (k-1) self layers + 1 gated
            cross-attention layer over (stubbed) patch embeddings.
  encoder — BERT-style classifier (the paper's own base model for the
            WRENCH experiments).

All stacks are scanned, so HLO size is independent of depth. Decode caches
are pytrees whose leaves carry the stacked layer axis, so the same scan
pattern threads them.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod

PyTree = Any


# ---------------------------------------------------------------------------
# shared block helpers
# ---------------------------------------------------------------------------


def _init_dense_layer(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": cm.init_norm(cfg),
        "ln2": cm.init_norm(cfg),
        "mlp": cm.init_mlp(cfg, k2, dtype=dtype),
    }
    if cfg.use_mla:
        p["attn"] = attn.init_mla(cfg, k1, dtype=dtype)
    else:
        p["attn"] = attn.init_self_attn(cfg, k1, dtype=dtype)
    return p


def _dense_layer(cfg, p, x, positions, flag, cache=None, cache_pos=None, causal=True):
    h = cm.apply_norm(cfg, p["ln1"], x)
    if cfg.use_mla:
        out, new_cache = attn.mla_attention(cfg, p["attn"], h, positions, cache=cache, cache_pos=cache_pos)
    else:
        out, new_cache = attn.self_attention(
            cfg, p["attn"], h, positions, local_flag=flag, cache=cache, cache_pos=cache_pos,
            causal=causal,
        )
    x = x + out
    x = x + cm.apply_mlp(cfg, p["mlp"], cm.apply_norm(cfg, p["ln2"], x))
    return x, new_cache


def _init_moe_layer(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": cm.init_norm(cfg),
        "ln2": cm.init_norm(cfg),
        "attn": attn.init_self_attn(cfg, k1, dtype=dtype),
        "moe": moe_mod.init_moe(cfg, k2, dtype=dtype),
    }


def _moe_layer(cfg, p, x, positions, cache=None, cache_pos=None):
    h = cm.apply_norm(cfg, p["ln1"], x)
    out, new_cache = attn.self_attention(cfg, p["attn"], h, positions, cache=cache, cache_pos=cache_pos)
    x = x + out
    h2, aux = moe_mod.apply_moe(cfg, p["moe"], cm.apply_norm(cfg, p["ln2"], x))
    return x + h2, aux, new_cache


def _flags(cfg) -> jnp.ndarray:
    return jnp.asarray([k == "local" for k in cfg.layer_kinds], bool)


def _maybe_remat(cfg, body):
    """Checkpoint a scan body: activations inside a layer are recomputed in
    the backward pass, so live memory is O(1) in depth instead of O(L)."""
    return jax.checkpoint(body, prevent_cse=False) if cfg.remat else body


def _embed(cfg, params, tokens, dtype, positions=None):
    """positions: (B, S) absolute positions for learned pos-embed lookup;
    None means tokens start at position 0 (the train/prefill case). Decode
    MUST pass real positions — indexing ``pos_embed[:S]`` there would add
    the position-0 embedding to every generated token."""
    x = params["embed"][tokens].astype(dtype) * jnp.sqrt(cfg.d_model).astype(dtype)
    if cfg.pos_embed == "learned":
        if positions is None:
            x = x + params["pos_embed"][: tokens.shape[1]].astype(dtype)
        else:
            x = x + params["pos_embed"][positions].astype(dtype)
    return x


def _unembed(cfg, params, x):
    logits = x @ params["embed"].T.astype(x.dtype)
    return cm.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


# ===========================================================================
# init
# ===========================================================================


def init_params(cfg, key) -> PyTree:
    dtype = cm.dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": cm.dense_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype=dtype),
        "final_norm": cm.init_norm(cfg),
    }
    if cfg.pos_embed == "learned":
        params["pos_embed"] = cm.dense_init(keys[6], (cfg.max_position, cfg.d_model), dtype=dtype)

    fam = cfg.family
    if fam in ("dense",):
        params["layers"] = cm.stacked_init(
            lambda k: _init_dense_layer(cfg, k, dtype), keys[1], cfg.num_layers
        )
    elif fam == "moe":
        nd = cfg.first_k_dense
        if nd:
            params["dense_layers"] = cm.stacked_init(
                lambda k: _init_dense_layer(cfg, k, dtype), keys[2], nd
            )
        params["layers"] = cm.stacked_init(
            lambda k: _init_moe_layer(cfg, k, dtype), keys[1], cfg.num_layers - nd
        )
    elif fam == "ssm":  # rwkv6
        def init_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": cm.init_norm(cfg),
                "ln2": cm.init_norm(cfg),
                "tmix": ssm_mod.init_rwkv_time_mix(cfg, k1, dtype),
                "cmix": ssm_mod.init_rwkv_channel_mix(cfg, k2, dtype),
            }

        params["layers"] = cm.stacked_init(init_block, keys[1], cfg.num_layers)
    elif fam == "hybrid":  # zamba2
        k_grp = cfg.hybrid_attn_every
        n_extra = cfg.num_layers % k_grp
        n_groups = cfg.num_layers // k_grp

        def init_mamba_block(k):
            return {"ln1": cm.init_norm(cfg), "mamba": ssm_mod.init_mamba(cfg, k, dtype)}

        if n_extra:
            params["mamba_head"] = cm.stacked_init(init_mamba_block, keys[2], n_extra)
        params["mamba_groups"] = jax.vmap(
            lambda k: cm.stacked_init(init_mamba_block, k, k_grp)
        )(jax.random.split(keys[1], n_groups))
        params["shared_attn"] = _init_dense_layer(cfg, keys[3], dtype)
    elif fam == "audio":  # whisper
        def init_enc(k):
            return _init_dense_layer(cfg, k, dtype)

        def init_dec(k):
            k1, k2 = jax.random.split(k)
            p = _init_dense_layer(cfg, k1, dtype)
            p["ln_x"] = cm.init_norm(cfg)
            p["xattn"] = attn.init_cross_attn(cfg, k2, dtype=dtype)
            return p

        params["encoder"] = {
            "layers": cm.stacked_init(init_enc, keys[2], cfg.encoder_layers),
            "norm": cm.init_norm(cfg),
        }
        params["layers"] = cm.stacked_init(init_dec, keys[1], cfg.num_layers)
    elif fam == "vlm":  # llama-3.2-vision
        k_grp = cfg.cross_attn_every
        n_groups = cfg.num_layers // k_grp
        n_self = k_grp - 1

        def init_self_group(k):
            return cm.stacked_init(lambda kk: _init_dense_layer(cfg, kk, dtype), k, n_self)

        def init_cross(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": cm.init_norm(cfg),
                "ln2": cm.init_norm(cfg),
                "xattn": attn.init_cross_attn(cfg, k1, dtype=dtype),
                "mlp": cm.init_mlp(cfg, k2, dtype=dtype),
                "gate_attn": jnp.zeros((), jnp.float32),
                "gate_mlp": jnp.zeros((), jnp.float32),
            }

        params["self_groups"] = jax.vmap(init_self_group)(jax.random.split(keys[1], n_groups))
        params["cross_layers"] = cm.stacked_init(init_cross, keys[2], n_groups)
        params["projector"] = cm.dense_init(keys[3], (cfg.vision_dim, cfg.d_model), dtype=dtype)
    elif fam == "encoder":  # bert-style classifier
        params["layers"] = cm.stacked_init(
            lambda k: _init_dense_layer(cfg, k, dtype), keys[1], cfg.num_layers
        )
        params["cls_head"] = {
            "w": cm.dense_init(keys[4], (cfg.d_model, cfg.num_labels), dtype=dtype),
            "b": jnp.zeros((cfg.num_labels,), jnp.float32),
        }
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ===========================================================================
# forward (train / prefill)
# ===========================================================================


def forward(cfg, params: PyTree, batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss). batch: tokens (B,S) [+ patches | frames]."""

    dtype = cm.dtype_of(cfg.dtype)
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)

    if fam == "audio":
        return _whisper_forward(cfg, params, batch)

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    if fam in ("dense", "encoder"):
        flags = _flags(cfg)
        causal = fam != "encoder"  # BERT-style encoders are bidirectional

        def body(h, inp):
            lp, fl = inp
            h, _ = _dense_layer(cfg, lp, h, positions, fl, causal=causal)
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, (params["layers"], flags))
        if fam == "encoder":
            x = cm.apply_norm(cfg, params["final_norm"], x)
            cls = x[:, 0]
            logits = cls @ params["cls_head"]["w"].astype(x.dtype) + params["cls_head"]["b"]
            return logits.astype(jnp.float32), aux

    elif fam == "moe":
        if cfg.first_k_dense:
            def dbody(h, lp):
                h, _ = _dense_layer(cfg, lp, h, positions, jnp.asarray(False))
                return h, None

            x, _ = jax.lax.scan(_maybe_remat(cfg, dbody), x, params["dense_layers"])

        def mbody(carry, lp):
            h, a = carry
            h, aux_l, _ = _moe_layer(cfg, lp, h, positions)
            return (h, a + aux_l), None

        (x, aux), _ = jax.lax.scan(_maybe_remat(cfg, mbody), (x, aux), params["layers"])

    elif fam == "ssm":
        def rbody(h, lp):
            h = h + ssm_mod.apply_rwkv_time_mix(cfg, lp["tmix"], cm.apply_norm(cfg, lp["ln1"], h))
            h = h + ssm_mod.apply_rwkv_channel_mix(cfg, lp["cmix"], cm.apply_norm(cfg, lp["ln2"], h))
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, rbody), x, params["layers"])

    elif fam == "hybrid":
        def mamba_block(h, lp):
            h = h + ssm_mod.apply_mamba(cfg, lp["mamba"], cm.apply_norm(cfg, lp["ln1"], h))
            return h, None

        if "mamba_head" in params:
            x, _ = jax.lax.scan(_maybe_remat(cfg, mamba_block), x, params["mamba_head"])

        shared = params["shared_attn"]

        def gbody(h, grp):
            h, _ = jax.lax.scan(mamba_block, h, grp)
            h, _ = _dense_layer(cfg, shared, h, positions, jnp.asarray(False))
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, gbody), x, params["mamba_groups"])

    elif fam == "vlm":
        memory = (batch["patches"].astype(dtype)) @ params["projector"].astype(dtype)

        def self_block(h, lp):
            h, _ = _dense_layer(cfg, lp, h, positions, jnp.asarray(False))
            return h, None

        def vgroup(h, inp):
            sg, cl = inp
            h, _ = jax.lax.scan(self_block, h, sg)
            a = attn.cross_attention(cfg, cl["xattn"], cm.apply_norm(cfg, cl["ln1"], h), memory=memory)
            h = h + jnp.tanh(cl["gate_attn"]).astype(h.dtype) * a
            m = cm.apply_mlp(cfg, cl["mlp"], cm.apply_norm(cfg, cl["ln2"], h))
            h = h + jnp.tanh(cl["gate_mlp"]).astype(h.dtype) * m
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, vgroup), x, (params["self_groups"], params["cross_layers"]))

    else:
        raise ValueError(fam)

    x = cm.apply_norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x), aux


def _whisper_forward(cfg, params, batch):
    dtype = cm.dtype_of(cfg.dtype)
    frames = batch["frames"].astype(dtype)  # (B, F, D) stubbed conv/mel output
    F = frames.shape[1]
    enc = frames + cm.sinusoidal_pos(F, cfg.d_model, dtype)[None]
    enc_pos = jnp.broadcast_to(jnp.arange(F), (frames.shape[0], F))

    def ebody(h, lp):
        hh = cm.apply_norm(cfg, lp["ln1"], h)
        out, _ = attn.self_attention(cfg, lp["attn"], hh, enc_pos, causal=False)
        h = h + out
        h = h + cm.apply_mlp(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], h))
        return h, None

    enc, _ = jax.lax.scan(_maybe_remat(cfg, ebody), enc, params["encoder"]["layers"])
    memory = cm.apply_norm(cfg, params["encoder"]["norm"], enc)

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def dbody(h, lp):
        h, _ = _dense_layer_with_cross(cfg, lp, h, positions, memory=memory)
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, dbody), x, params["layers"])
    x = cm.apply_norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x), jnp.zeros((), jnp.float32)


def _dense_layer_with_cross(cfg, p, x, positions, memory=None, memory_kv=None, cache=None, cache_pos=None):
    h = cm.apply_norm(cfg, p["ln1"], x)
    out, new_cache = attn.self_attention(cfg, p["attn"], h, positions, cache=cache, cache_pos=cache_pos)
    x = x + out
    x = x + attn.cross_attention(
        cfg, p["xattn"], cm.apply_norm(cfg, p["ln_x"], x), memory=memory, memory_kv=memory_kv
    )
    x = x + cm.apply_mlp(cfg, p["mlp"], cm.apply_norm(cfg, p["ln2"], x))
    return x, new_cache

# ===========================================================================
# decode (serve_step: ONE new token against a seq_len cache/state)
# ===========================================================================


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16) -> PyTree:
    """Allocate the decode cache pytree (leaves stacked over layers)."""

    fam = cfg.family

    def kv(n_stack, length=cache_len, extra=()):
        base = attn.init_kv_cache(cfg, batch, length, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(extra + (n_stack,) + x.shape if n_stack else x.shape, x.dtype), base
        )

    def stack(n, tree):
        return jax.tree_util.tree_map(lambda x: jnp.zeros((n,) + x.shape, x.dtype), tree)

    if fam == "dense":
        if cfg.use_mla:
            one = attn.init_mla_cache(cfg, batch, cache_len, dtype)
        else:
            one = attn.init_kv_cache(cfg, batch, cache_len, dtype)
        return {"kv": stack(cfg.num_layers, one)}
    if fam == "moe":
        one = attn.init_kv_cache(cfg, batch, cache_len, dtype)
        c = {"kv": stack(cfg.num_layers - cfg.first_k_dense, one)}
        if cfg.first_k_dense:
            c["dense_kv"] = stack(cfg.first_k_dense, one)
        return c
    if fam == "ssm":
        one = ssm_mod.init_rwkv_state(cfg, batch, dtype)
        return {"layers": stack(cfg.num_layers, one)}
    if fam == "hybrid":
        k_grp = cfg.hybrid_attn_every
        n_extra = cfg.num_layers % k_grp
        n_groups = cfg.num_layers // k_grp
        one = ssm_mod.init_mamba_state(cfg, batch, dtype)
        c = {
            "groups": stack(n_groups, stack(k_grp, one)),
            "attn_kv": stack(n_groups, attn.init_kv_cache(cfg, batch, cache_len, dtype)),
        }
        if n_extra:
            c["head"] = stack(n_extra, one)
        return c
    if fam == "audio":
        enc_kv = {
            "k": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
        return {
            "kv": stack(cfg.num_layers, attn.init_kv_cache(cfg, batch, cache_len, dtype)),
            "cross_kv": enc_kv,
        }
    if fam == "vlm":
        k_grp = cfg.cross_attn_every
        n_groups = cfg.num_layers // k_grp
        n_self = k_grp - 1
        one = attn.init_kv_cache(cfg, batch, cache_len, dtype)
        cross = {
            "k": jnp.zeros((n_groups, batch, cfg.vision_tokens, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((n_groups, batch, cfg.vision_tokens, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
        return {"self_kv": stack(n_groups, stack(n_self, one)), "cross_kv": cross}
    raise ValueError(f"no decode cache for family {fam}")


def decode_step(cfg, params: PyTree, cache: PyTree, tokens: jnp.ndarray, pos) -> Tuple[jnp.ndarray, PyTree]:
    """tokens: (B, S) int32 — S = 1 for one-token decode, S > 1 for a
    chunked teacher-forced prefill block (attention-cache families only;
    the recurrent families advance their state one token per call).

    pos: int32 position of tokens[:, 0] — a scalar when every lane is at
    the same position, or a (B,) vector of per-lane positions (continuous
    batching over staggered sequences; K/V rows scatter per lane). Token j
    of the chunk lands at position pos + j.

    Returns (logits (B,S,V) f32, new_cache)."""

    dtype = cm.dtype_of(cfg.dtype)
    fam = cfg.family
    B, S = tokens.shape
    if S != 1 and fam in ("ssm", "hybrid"):
        raise ValueError(
            f"family {fam!r} is recurrent: decode_step advances one token per "
            "call (chunked prefill uses the token-scan path, repro.serve.prefill)"
        )
    pos_col = pos[:, None] if jnp.ndim(pos) else jnp.full((B, 1), pos, jnp.int32)
    positions = pos_col + jnp.arange(S, dtype=jnp.int32)[None]
    x = _embed(cfg, params, tokens, dtype, positions=positions)

    if fam == "dense":
        flags = _flags(cfg)

        def body(h, inp):
            lp, fl, lc = inp
            h, newc = _dense_layer(cfg, lp, h, positions, fl, cache=lc, cache_pos=pos)
            return h, newc

        x, new_kv = jax.lax.scan(body, x, (params["layers"], flags, cache["kv"]))
        new_cache = {"kv": new_kv}

    elif fam == "moe":
        new_cache = {}
        if cfg.first_k_dense:
            def dbody(h, inp):
                lp, lc = inp
                h, newc = _dense_layer(cfg, lp, h, positions, jnp.asarray(False), cache=lc, cache_pos=pos)
                return h, newc

            x, ndkv = jax.lax.scan(dbody, x, (params["dense_layers"], cache["dense_kv"]))
            new_cache["dense_kv"] = ndkv

        def mbody(h, inp):
            lp, lc = inp
            h, _, newc = _moe_layer(cfg, lp, h, positions, cache=lc, cache_pos=pos)
            return h, newc

        x, nkv = jax.lax.scan(mbody, x, (params["layers"], cache["kv"]))
        new_cache["kv"] = nkv

    elif fam == "ssm":
        def rbody(h, inp):
            lp, st = inp
            out, st_att = ssm_mod.rwkv_time_mix_decode(
                cfg, lp["tmix"], cm.apply_norm(cfg, lp["ln1"], h), st
            )
            h = h + out
            out, st_ffn = ssm_mod.rwkv_channel_mix_decode(
                cfg, lp["cmix"], cm.apply_norm(cfg, lp["ln2"], h), st
            )
            h = h + out
            return h, {**st_att, **st_ffn}

        x, new_states = jax.lax.scan(rbody, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_states}

    elif fam == "hybrid":
        new_cache = {}

        def mdec(h, inp):
            lp, st = inp
            out, newst = ssm_mod.mamba_decode(cfg, lp["mamba"], cm.apply_norm(cfg, lp["ln1"], h), st)
            return h + out, newst

        if "mamba_head" in params:
            x, nh = jax.lax.scan(mdec, x, (params["mamba_head"], cache["head"]))
            new_cache["head"] = nh

        shared = params["shared_attn"]

        def gbody(h, inp):
            grp_params, grp_state, akv = inp
            h, new_states = jax.lax.scan(mdec, h, (grp_params, grp_state))
            h, new_akv = _dense_layer(
                cfg, shared, h, positions, jnp.asarray(False), cache=akv, cache_pos=pos
            )
            return h, (new_states, new_akv)

        x, (ngs, nakv) = jax.lax.scan(
            gbody, x, (params["mamba_groups"], cache["groups"], cache["attn_kv"])
        )
        new_cache["groups"] = ngs
        new_cache["attn_kv"] = nakv

    elif fam == "audio":
        def dbody(h, inp):
            lp, lc, xkv = inp
            h, newc = _dense_layer_with_cross(
                cfg, lp, h, positions, memory_kv=xkv, cache=lc, cache_pos=pos
            )
            return h, newc

        x, nkv = jax.lax.scan(dbody, x, (params["layers"], cache["kv"], cache["cross_kv"]))
        new_cache = {"kv": nkv, "cross_kv": cache["cross_kv"]}

    elif fam == "vlm":
        def self_block(h, inp):
            lp, lc = inp
            h, newc = _dense_layer(cfg, lp, h, positions, jnp.asarray(False), cache=lc, cache_pos=pos)
            return h, newc

        def vgroup(h, inp):
            sg, cl, skv, xkv = inp
            h, nskv = jax.lax.scan(self_block, h, (sg, skv))
            a = attn.cross_attention(cfg, cl["xattn"], cm.apply_norm(cfg, cl["ln1"], h), memory_kv=xkv)
            h = h + jnp.tanh(cl["gate_attn"]).astype(h.dtype) * a
            m = cm.apply_mlp(cfg, cl["mlp"], cm.apply_norm(cfg, cl["ln2"], h))
            h = h + jnp.tanh(cl["gate_mlp"]).astype(h.dtype) * m
            return h, nskv

        x, nskv = jax.lax.scan(
            vgroup, x, (params["self_groups"], params["cross_layers"], cache["self_kv"], cache["cross_kv"])
        )
        new_cache = {"self_kv": nskv, "cross_kv": cache["cross_kv"]}

    else:
        raise ValueError(f"no decode path for family {fam}")

    x = cm.apply_norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x), new_cache
