"""The HypergradMethod protocol and registry (DESIGN.md §2-3).

A hypergradient estimator is a first-class object with a declared
communication contract, so the Engine (single device / pjit) and the
single-sync distributed schedule (launch.distributed) can both drive ANY
method through the same three-stage lifecycle:

    1. ``local_terms(spec, ctx)``  — strictly shard-local math. No
       collectives may appear here; the schedule owns all communication.
       Returns a dict of named terms; ``"hypergrad"`` and ``"meta_loss"``
       are mandatory, anything else (e.g. SAMA's ``v``/``eps``) is method
       state that the finalize stage needs.
    2. reduction — owned by the CALLER. The Engine's single-device path is
       an identity reduce; the manual schedule pmean-buckets exactly the
       terms named by ``reduce_contract.terms`` in its ONE meta-level
       all-reduce.
    3. ``finalize(terms, ctx)`` — consumes (possibly reduced) terms and
       returns ``(hypergrad, theta_post)``. Post-update hooks that must see
       replica-consistent values live here (SAMA's base nudge).

New estimators register a factory under a string name and immediately work
everywhere an ``EngineConfig.method`` string is accepted — Engine,
``make_manual_step``, ``repro.api.MetaLearner`` — without touching core.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelSpec
from repro.optim import Optimizer, OptState

PyTree = Any

#: A method's per-shard output: named jax values. "hypergrad" (pytree like
#: lam) and "meta_loss" (scalar) are mandatory; extra keys are method state.
LocalTerms = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ReduceContract:
    """What the distributed schedule is allowed to do with local terms.

    ``terms``: the LocalTerms keys that ride the single bucketed all-reduce
      (an unweighted mean over data shards). Must include "hypergrad" and
      "meta_loss"; SAMA additionally buckets ("v", "eps") so the base nudge
      stays replica-consistent without a second sync point.
    ``linear``: True when the shard-mean of local terms IS the method's own
      estimator on the global batch (up to identical-shard equality) —
      i.e. every reduced term is an average of per-example quantities.
      Iterative solvers (CG, Neumann) and unrolled differentiation are
      nonlinear in the shard data, so averaging their local estimates is a
      different (local-solve) estimator; the manual schedule refuses them
      unless explicitly overridden.
    """

    terms: Tuple[str, ...] = ("hypergrad", "meta_loss")
    linear: bool = True

    def __post_init__(self):
        for required in ("hypergrad", "meta_loss"):
            if required not in self.terms:
                raise ValueError(f"reduce contract must include {required!r}, got {self.terms}")


@dataclasses.dataclass(frozen=True)
class MethodContext:
    """Everything the base-level unroll hands to a hypergradient method.

    Built once per meta step by the caller (Engine or manual schedule) after
    the K-step base unroll; all array members are traced values.
    """

    base_opt: Optimizer
    theta0: PyTree  # base params BEFORE the unroll (iterdiff re-unrolls from here)
    theta: PyTree  # base params AFTER the unroll (theta*)
    lam: PyTree
    g_base: Optional[PyTree]  # last base gradient (synced on the manual path)
    base_opt_state: OptState  # optimizer state AT WHICH g_base was computed
    base_batches: Any  # full unroll batches, leading axis K
    last_batch: Any  # base_batches[-1]
    meta_batch: Any
    #: live dynamic loss scale (scalar) under an f16 policy, else None.
    #: Methods that differentiate through the low-precision spec SHOULD
    #: scale their losses by it before the backward pass and unscale the
    #: results (SAMA does, both plain and microbatched) so cotangents stay
    #: representable in the compute dtype — see repro.scale.policy.
    loss_scale: Optional[Any] = None


class HypergradMethod:
    """Base class for hypergradient estimators. Subclasses set ``name`` and
    ``reduce_contract`` and implement ``local_terms``; ``finalize`` defaults
    to the identity post-update (no theta change)."""

    name: str = "abstract"
    reduce_contract: ReduceContract = ReduceContract()

    def local_terms(self, spec: BilevelSpec, ctx: MethodContext) -> LocalTerms:
        raise NotImplementedError

    def finalize(self, terms: LocalTerms, ctx: MethodContext) -> Tuple[PyTree, PyTree]:
        return terms["hypergrad"], ctx.theta

    # -- convenience -------------------------------------------------------
    def metrics(self, terms: LocalTerms) -> Dict[str, jnp.ndarray]:
        """Per-method scalar metrics merged into the step's metric dict.
        Keys must be stable across steps (jit)."""
        return {}

    def __repr__(self):  # pragma: no cover - debugging nicety
        return f"{type(self).__name__}(name={self.name!r})"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: name -> factory(engine_cfg) -> HypergradMethod. The factory receives the
#: EngineConfig so built-ins can read their knobs from it; custom factories
#: are free to ignore it.
MethodFactory = Callable[[Any], HypergradMethod]

_REGISTRY: Dict[str, MethodFactory] = {}


def register_method(name: str, factory: Optional[Any] = None, *, overwrite: bool = False):
    """Register a hypergradient method under ``name``.

    Usable three ways::

        @register_method("mine")            # decorator on a factory(cfg)
        def _make(cfg): return MyMethod()

        register_method("mine", MyMethod()) # an instance (cfg ignored)
        register_method("mine", _make)      # a plain factory

    Returns the factory (decorator-compatible).
    """

    def _install(f: MethodFactory) -> MethodFactory:
        if not overwrite and name in _REGISTRY:
            raise ValueError(f"hypergrad method {name!r} already registered "
                             "(pass overwrite=True to replace)")
        _REGISTRY[name] = f
        return f

    if factory is None:
        return _install
    if isinstance(factory, HypergradMethod):
        instance = factory
        return _install(lambda cfg, _m=instance: _m)
    return _install(factory)


def unregister_method(name: str):
    """Remove a registered method (test hygiene)."""
    _REGISTRY.pop(name, None)


def available_methods() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_method(method: Any, cfg: Any = None) -> HypergradMethod:
    """Turn an EngineConfig.method value (string name or HypergradMethod
    instance) into a method object."""

    if isinstance(method, HypergradMethod):
        return method
    if isinstance(method, str):
        if method not in _REGISTRY:
            raise ValueError(
                f"unknown hypergrad method {method!r}; registered: {available_methods()}"
            )
        m = _REGISTRY[method](cfg)
        if not isinstance(m, HypergradMethod):
            raise TypeError(f"factory for {method!r} returned {type(m).__name__}, "
                            "expected a HypergradMethod")
        return m
    raise TypeError(f"method must be a name or HypergradMethod, got {type(method).__name__}")


def validate_terms(method: HypergradMethod, terms: LocalTerms) -> LocalTerms:
    """Trace-time structural check: mandatory keys + contract coverage."""

    for required in ("hypergrad", "meta_loss"):
        if required not in terms:
            raise ValueError(f"{method.name}: local_terms missing {required!r}")
    missing = [t for t in method.reduce_contract.terms if t not in terms]
    if missing:
        raise ValueError(
            f"{method.name}: reduce contract names terms {missing} that "
            f"local_terms did not produce (got {sorted(terms)})"
        )
    return terms
