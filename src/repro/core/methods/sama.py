"""SAMA and SAMA-NA as HypergradMethod objects (paper Sec. 3).

The math lives in ``repro.core.sama`` (pure, shard-local); this module only
adapts it to the protocol. The reduce contract is the paper's single-sync
schedule in one line: the hypergradient, the perturbation direction ``v``,
its step size ``eps`` and the meta loss all ride ONE bucketed all-reduce, so
the base nudge in ``finalize`` sees replica-consistent values without a
second synchronization point.
"""

from __future__ import annotations

import dataclasses

from repro.core import sama as sama_mod
from repro.core.methods.base import (
    HypergradMethod,
    LocalTerms,
    MethodContext,
    ReduceContract,
    register_method,
)


@dataclasses.dataclass(frozen=True)
class SAMAMethod(HypergradMethod):
    """Paper Eq. 3-5. ``cfg.adapt=False`` is the SAMA-NA ablation."""

    cfg: sama_mod.SAMAConfig = sama_mod.SAMAConfig()
    name: str = "sama"

    reduce_contract = ReduceContract(terms=("hypergrad", "v", "eps", "meta_loss"), linear=True)

    def local_terms(self, spec, ctx: MethodContext) -> LocalTerms:
        meta_loss, v, v_sumsq = sama_mod.perturbation_direction(
            spec, ctx.theta, ctx.lam, ctx.meta_batch,
            base_opt=ctx.base_opt, base_opt_state=ctx.base_opt_state,
            g_base=ctx.g_base, cfg=self.cfg,
        )
        hyper, eps = sama_mod.central_difference_hypergrad(
            spec, ctx.theta, ctx.lam, ctx.last_batch, v, cfg=self.cfg,
            v_sumsq=v_sumsq,
        )
        return {"hypergrad": hyper, "meta_loss": meta_loss, "v": v, "eps": eps}

    def finalize(self, terms: LocalTerms, ctx: MethodContext):
        theta = sama_mod.apply_base_nudge(ctx.theta, terms["v"], terms["eps"], self.cfg)
        return terms["hypergrad"], theta

    def metrics(self, terms: LocalTerms):
        return {"eps": terms["eps"]}


@register_method("sama")
def _make_sama(cfg) -> SAMAMethod:
    return SAMAMethod(cfg=_sama_cfg(cfg, adapt=True), name="sama")


@register_method("sama_na")
def _make_sama_na(cfg) -> SAMAMethod:
    return SAMAMethod(cfg=_sama_cfg(cfg, adapt=False), name="sama_na")


def _sama_cfg(cfg, *, adapt: bool) -> sama_mod.SAMAConfig:
    if cfg is None:
        return sama_mod.SAMAConfig(adapt=adapt)
    return sama_mod.SAMAConfig(
        alpha=cfg.alpha,
        adapt=adapt,
        base_nudge=cfg.base_nudge,
        adapt_clip=cfg.adapt_clip,
    )
