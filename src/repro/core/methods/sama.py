"""SAMA and SAMA-NA as HypergradMethod objects (paper Sec. 3).

The math lives in ``repro.core.sama`` (pure, shard-local); this module only
adapts it to the protocol. The reduce contract is the paper's single-sync
schedule in one line: the hypergradient, the perturbation direction ``v``,
its step size ``eps`` and the meta loss all ride ONE bucketed all-reduce, so
the base nudge in ``finalize`` sees replica-consistent values without a
second synchronization point.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core import sama as sama_mod
from repro.core.methods.base import (
    HypergradMethod,
    LocalTerms,
    MethodContext,
    ReduceContract,
    register_method,
)


@dataclasses.dataclass(frozen=True)
class SAMAMethod(HypergradMethod):
    """Paper Eq. 3-5. ``cfg.adapt=False`` is the SAMA-NA ablation."""

    cfg: sama_mod.SAMAConfig = sama_mod.SAMAConfig()
    name: str = "sama"

    reduce_contract = ReduceContract(terms=("hypergrad", "v", "eps", "meta_loss"), linear=True)

    def local_terms(self, spec, ctx: MethodContext) -> LocalTerms:
        meta_loss, v, v_sumsq = sama_mod.perturbation_direction(
            spec, ctx.theta, ctx.lam, ctx.meta_batch,
            base_opt=ctx.base_opt, base_opt_state=ctx.base_opt_state,
            g_base=ctx.g_base, cfg=self.cfg, loss_scale=ctx.loss_scale,
        )
        hyper, eps = sama_mod.central_difference_hypergrad(
            spec, ctx.theta, ctx.lam, ctx.last_batch, v, cfg=self.cfg,
            v_sumsq=v_sumsq, loss_scale=ctx.loss_scale,
        )
        return {"hypergrad": hyper, "meta_loss": meta_loss, "v": v, "eps": eps}

    def finalize(self, terms: LocalTerms, ctx: MethodContext):
        theta = sama_mod.apply_base_nudge(ctx.theta, terms["v"], terms["eps"], self.cfg)
        return terms["hypergrad"], theta

    def metrics(self, terms: LocalTerms):
        return {"eps": terms["eps"]}

    def micro_local_terms(self, spec, ctx: MethodContext, m: int, accum_dtype) -> LocalTerms:
        """The EXACT M-way microbatched SAMA stage 1 (repro.scale.accum
        calls this instead of the generic virtual-shard average).

        Every nonlinearity in SAMA's local terms sits BETWEEN two
        batch-linear passes, so staging the accumulation around it
        reproduces the full-batch estimator exactly (up to f32 reduction
        order — pinned by tests/test_scale.py):

        stage A (linear): accumulate ``(meta_loss, g_meta)`` over M meta
          microbatches — mean of equal-slice gradients == full-batch
          gradient;
        stage B (local):  ``v = du/dg .* g_meta`` and ``eps = alpha/||v||``
          once, from the ACCUMULATED g_meta (this is where the
          virtual-shard average would differ: it takes a per-microbatch
          eps);
        stage C (linear): accumulate the central-difference delta
          ``grad_lam L(theta+) - grad_lam L(theta-)`` over M last-batch
          microbatches at the ONE (theta+, theta-) pair from stage B.

        Peak memory: every model-sized backward pass (meta pass and both
        CD passes) now sees a batch/M slice."""

        from repro.scale import accum  # scale sits above core; import here

        meta_split = accum.split_batch(ctx.meta_batch, m)
        vg = sama_mod.scaled_value_and_grad(spec.meta_scalar, 0, ctx.loss_scale)

        def meta_term(mb):
            loss, g = vg(ctx.theta, ctx.lam, mb)
            return {"meta_loss": loss, "g_meta": g}

        acc = accum.accumulate_mean(meta_term, meta_split, m, accum_dtype)
        meta_loss, g_meta = acc["meta_loss"], acc["g_meta"]
        # master params may be lower-precision in exotic setups; the
        # adaptation kernels expect g_meta in the gradient dtype
        g_meta = jax.tree_util.tree_map(
            lambda g, t: g.astype(t.dtype), g_meta, ctx.theta)

        v, v_sumsq = sama_mod.adaptation_product(
            ctx.base_opt, ctx.base_opt_state, ctx.theta, ctx.g_base, g_meta,
            self.cfg)
        eps = sama_mod.step_size(v, v_sumsq, self.cfg)
        theta_p, theta_m = sama_mod.perturbed_params(ctx.theta, v, eps)

        last_split = accum.split_batch(ctx.last_batch, m)

        def cd_term(mb):
            return sama_mod.central_difference_delta(
                spec, theta_p, theta_m, ctx.lam, mb,
                loss_scale=ctx.loss_scale)

        delta = accum.accumulate_mean(cd_term, last_split, m, accum_dtype)
        hyper = jax.tree_util.tree_map(lambda d: -d / (2.0 * eps), delta)
        return {"hypergrad": hyper, "meta_loss": meta_loss, "v": v, "eps": eps}


@register_method("sama")
def _make_sama(cfg) -> SAMAMethod:
    return SAMAMethod(cfg=_sama_cfg(cfg, adapt=True), name="sama")


@register_method("sama_na")
def _make_sama_na(cfg) -> SAMAMethod:
    return SAMAMethod(cfg=_sama_cfg(cfg, adapt=False), name="sama_na")


def _sama_cfg(cfg, *, adapt: bool) -> sama_mod.SAMAConfig:
    if cfg is None:
        return sama_mod.SAMAConfig(adapt=adapt)
    return sama_mod.SAMAConfig(
        alpha=cfg.alpha,
        adapt=adapt,
        base_nudge=cfg.base_nudge,
        adapt_clip=cfg.adapt_clip,
    )
