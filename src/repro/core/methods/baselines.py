"""Baseline hypergradient estimators as HypergradMethod objects.

T1-T2's exact mixed VJP is an average of per-example terms, so it shares
SAMA's linear reduce contract and runs under the single-sync schedule.
Neumann, CG and iterative differentiation solve/unroll on the local shard —
averaging those local solutions is NOT the global estimator (the solve is
nonlinear in the shard data), so they declare ``linear=False`` and the
manual schedule refuses them unless ``allow_nonlinear=True``.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core import baselines as bl
from repro.core.methods.base import (
    HypergradMethod,
    LocalTerms,
    MethodContext,
    ReduceContract,
    register_method,
)


@dataclasses.dataclass(frozen=True)
class T1T2Config:
    pass  # T1-T2 has no knobs: identity Jacobian, exact mixed VJP


@dataclasses.dataclass(frozen=True)
class NeumannConfig:
    num_terms: int = 5
    scale: float = 0.1


@dataclasses.dataclass(frozen=True)
class CGConfig:
    num_iters: int = 5
    damping: float = 1e-3


@dataclasses.dataclass(frozen=True)
class IterDiffConfig:
    pass  # the unroll length is the Engine's unroll_steps


def _meta_loss(spec, ctx: MethodContext):
    return spec.meta_scalar(ctx.theta, ctx.lam, ctx.meta_batch)


@dataclasses.dataclass(frozen=True)
class T1T2Method(HypergradMethod):
    cfg: T1T2Config = T1T2Config()
    name: str = "t1t2"

    reduce_contract = ReduceContract(linear=True)

    def local_terms(self, spec, ctx: MethodContext) -> LocalTerms:
        hyper = bl.t1t2_hypergrad(spec, ctx.theta, ctx.lam, ctx.last_batch, ctx.meta_batch)
        return {"hypergrad": hyper, "meta_loss": _meta_loss(spec, ctx)}


@dataclasses.dataclass(frozen=True)
class NeumannMethod(HypergradMethod):
    cfg: NeumannConfig = NeumannConfig()
    name: str = "neumann"

    reduce_contract = ReduceContract(linear=False)

    def local_terms(self, spec, ctx: MethodContext) -> LocalTerms:
        hyper = bl.neumann_hypergrad(
            spec, ctx.theta, ctx.lam, ctx.last_batch, ctx.meta_batch,
            num_terms=self.cfg.num_terms, scale=self.cfg.scale,
        )
        return {"hypergrad": hyper, "meta_loss": _meta_loss(spec, ctx)}


@dataclasses.dataclass(frozen=True)
class CGMethod(HypergradMethod):
    cfg: CGConfig = CGConfig()
    name: str = "cg"

    reduce_contract = ReduceContract(linear=False)

    def local_terms(self, spec, ctx: MethodContext) -> LocalTerms:
        hyper = bl.cg_hypergrad(
            spec, ctx.theta, ctx.lam, ctx.last_batch, ctx.meta_batch,
            num_iters=self.cfg.num_iters, damping=self.cfg.damping,
        )
        return {"hypergrad": hyper, "meta_loss": _meta_loss(spec, ctx)}


@dataclasses.dataclass(frozen=True)
class IterDiffMethod(HypergradMethod):
    """MAML-style: differentiate through the whole unroll from theta0
    (memory ~ K backward graphs — the cost the paper argues against)."""

    cfg: IterDiffConfig = IterDiffConfig()
    name: str = "iterdiff"

    reduce_contract = ReduceContract(linear=False)

    def local_terms(self, spec, ctx: MethodContext) -> LocalTerms:
        hyper = bl.iterdiff_hypergrad(
            spec, ctx.theta0, ctx.lam, ctx.base_batches, ctx.meta_batch,
            base_opt=ctx.base_opt,
        )
        return {"hypergrad": hyper, "meta_loss": _meta_loss(spec, ctx)}


@register_method("t1t2")
def _make_t1t2(cfg) -> T1T2Method:
    del cfg
    return T1T2Method()


@register_method("neumann")
def _make_neumann(cfg) -> NeumannMethod:
    if cfg is None:
        return NeumannMethod()
    return NeumannMethod(cfg=NeumannConfig(num_terms=cfg.neumann_terms, scale=cfg.neumann_scale))


@register_method("cg")
def _make_cg(cfg) -> CGMethod:
    if cfg is None:
        return CGMethod()
    return CGMethod(cfg=CGConfig(num_iters=cfg.cg_iters, damping=cfg.cg_damping))


@register_method("iterdiff")
def _make_iterdiff(cfg) -> IterDiffMethod:
    del cfg
    return IterDiffMethod()
