"""First-class hypergradient estimators (DESIGN.md §2-3).

Importing this package registers the six built-in methods. Third-party
estimators call ``register_method`` and then work through ``EngineConfig``
strings, ``Engine``, ``launch.distributed.make_manual_step`` and
``repro.api.MetaLearner`` without touching core.
"""

from repro.core.methods.base import (
    HypergradMethod,
    LocalTerms,
    MethodContext,
    ReduceContract,
    available_methods,
    register_method,
    resolve_method,
    unregister_method,
    validate_terms,
)
from repro.core.methods.sama import SAMAMethod
from repro.core.methods.baselines import (
    CGConfig,
    CGMethod,
    IterDiffConfig,
    IterDiffMethod,
    NeumannConfig,
    NeumannMethod,
    T1T2Config,
    T1T2Method,
)

__all__ = [
    "CGConfig",
    "CGMethod",
    "HypergradMethod",
    "IterDiffConfig",
    "IterDiffMethod",
    "LocalTerms",
    "MethodContext",
    "NeumannConfig",
    "NeumannMethod",
    "ReduceContract",
    "SAMAMethod",
    "T1T2Config",
    "T1T2Method",
    "available_methods",
    "register_method",
    "resolve_method",
    "unregister_method",
    "validate_terms",
]
