"""The meta-training Engine.

One ``meta_step`` = K unrolled base optimizer steps + one meta update, with
the hypergradient algorithm selected by config ("sama", "sama_na", "t1t2",
"neumann", "cg", "iterdiff") — this is the paper's whole ablation surface
(Tables 8/9) behind one switch.

The Engine builds a *pure* step function (state, base_batches, meta_batch) ->
(state, metrics) so it can be jit'ed on one device (benchmarks, examples) or
handed to the launcher which wraps it in pjit/shard_map for the production
mesh. ``base_batches`` carries a leading unroll axis of length K.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import sama as sama_mod
from repro.core.bilevel import BilevelSpec
from repro.optim import Optimizer, OptState, apply_updates

PyTree = Any

METHODS = ("sama", "sama_na", "t1t2", "neumann", "cg", "iterdiff")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    method: str = "sama"
    unroll_steps: int = 1
    alpha: float = 1.0  # SAMA perturbation scale
    base_nudge: bool = True
    adapt_clip: float = 0.0  # see SAMAConfig.adapt_clip
    # baseline-specific knobs
    neumann_terms: int = 5
    neumann_scale: float = 0.1
    cg_iters: int = 5
    cg_damping: float = 1e-3

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"method {self.method!r} not in {METHODS}")

    @property
    def sama_cfg(self) -> sama_mod.SAMAConfig:
        return sama_mod.SAMAConfig(
            alpha=self.alpha,
            adapt=(self.method == "sama"),
            base_nudge=self.base_nudge and self.method in ("sama", "sama_na"),
            adapt_clip=self.adapt_clip,
        )


class EngineState(NamedTuple):
    theta: PyTree
    base_opt_state: OptState
    lam: PyTree
    meta_opt_state: OptState
    step: jnp.ndarray


def init_state(theta: PyTree, lam: PyTree, base_opt: Optimizer, meta_opt: Optimizer) -> EngineState:
    return EngineState(
        theta=theta,
        base_opt_state=base_opt.init(theta),
        lam=lam,
        meta_opt_state=meta_opt.init(lam),
        step=jnp.zeros([], jnp.int32),
    )


def _unroll_base(spec: BilevelSpec, base_opt: Optimizer, theta, opt_state, lam, base_batches):
    """K base optimizer steps via lax.scan. Carries the last base gradient and
    the optimizer state *at which it was computed* — SAMA's adaptation matrix
    is evaluated there (paper footnote 2: no extra backward pass)."""

    g0 = jax.tree_util.tree_map(jnp.zeros_like, theta)

    def step(carry, batch):
        th, st, _, _ = carry
        loss, g = jax.value_and_grad(spec.base_scalar, argnums=0)(th, lam, batch)
        upd, st_new = base_opt.update(g, st, th)
        th_new = apply_updates(th, upd)
        return (th_new, st_new, g, st), loss

    init = (theta, opt_state, g0, opt_state)
    (theta, opt_state, g_last, st_at_g), losses = jax.lax.scan(step, init, base_batches)
    return theta, opt_state, g_last, st_at_g, losses


def make_meta_step(
    spec: BilevelSpec,
    base_opt: Optimizer,
    meta_opt: Optimizer,
    cfg: EngineConfig = EngineConfig(),
) -> Callable[[EngineState, Any, Any], Tuple[EngineState, Dict[str, jnp.ndarray]]]:
    """Build the pure meta-step function."""

    def meta_step(state: EngineState, base_batches, meta_batch):
        theta0 = state.theta

        theta, b_state, g_base, st_at_g, base_losses = _unroll_base(
            spec, base_opt, state.theta, state.base_opt_state, state.lam, base_batches
        )

        last_batch = jax.tree_util.tree_map(lambda x: x[-1], base_batches)
        eps = jnp.zeros([], jnp.float32)

        if cfg.method in ("sama", "sama_na"):
            res = sama_mod.sama_hypergrad(
                spec, theta, state.lam, last_batch, meta_batch,
                base_opt=base_opt, base_opt_state=st_at_g, g_base=g_base,
                cfg=cfg.sama_cfg,
            )
            hyper, meta_loss, eps = res.hypergrad, res.meta_loss, res.eps
            theta = sama_mod.apply_base_nudge(theta, res.v, res.eps, cfg.sama_cfg)
        elif cfg.method == "t1t2":
            meta_loss = spec.meta_scalar(theta, state.lam, meta_batch)
            hyper = bl.t1t2_hypergrad(spec, theta, state.lam, last_batch, meta_batch)
        elif cfg.method == "neumann":
            meta_loss = spec.meta_scalar(theta, state.lam, meta_batch)
            hyper = bl.neumann_hypergrad(
                spec, theta, state.lam, last_batch, meta_batch,
                num_terms=cfg.neumann_terms, scale=cfg.neumann_scale,
            )
        elif cfg.method == "cg":
            meta_loss = spec.meta_scalar(theta, state.lam, meta_batch)
            hyper = bl.cg_hypergrad(
                spec, theta, state.lam, last_batch, meta_batch,
                num_iters=cfg.cg_iters, damping=cfg.cg_damping,
            )
        elif cfg.method == "iterdiff":
            # MAML-style: the hypergradient differentiates through the whole
            # unroll from theta0 (memory ~ K backward graphs).
            meta_loss = spec.meta_scalar(theta, state.lam, meta_batch)
            hyper = bl.iterdiff_hypergrad(
                spec, theta0, state.lam, base_batches, meta_batch, base_opt=base_opt
            )
        else:  # pragma: no cover
            raise AssertionError(cfg.method)

        upd, m_state = meta_opt.update(hyper, state.meta_opt_state, state.lam)
        lam = apply_updates(state.lam, upd)

        metrics = {
            "base_loss": jnp.mean(base_losses),
            "meta_loss": meta_loss,
            "hypergrad_norm": sama_mod.global_norm(hyper),
            "eps": eps,
        }
        new_state = EngineState(
            theta=theta,
            base_opt_state=b_state,
            lam=lam,
            meta_opt_state=m_state,
            step=state.step + 1,
        )
        return new_state, metrics

    return meta_step


class Engine:
    """Convenience single-process driver around the pure step function."""

    def __init__(self, spec, base_opt, meta_opt, cfg: EngineConfig = EngineConfig(), jit: bool = True):
        self.spec = spec
        self.base_opt = base_opt
        self.meta_opt = meta_opt
        self.cfg = cfg
        step = make_meta_step(spec, base_opt, meta_opt, cfg)
        self.step_fn = jax.jit(step) if jit else step

    def init(self, theta, lam) -> EngineState:
        return init_state(theta, lam, self.base_opt, self.meta_opt)

    def run(self, state: EngineState, batch_iter, num_meta_steps: int, log_every: int = 0):
        """batch_iter yields (base_batches[K], meta_batch)."""

        history = []
        for i in range(num_meta_steps):
            base_batches, meta_batch = next(batch_iter)
            state, metrics = self.step_fn(state, base_batches, meta_batch)
            if log_every and (i % log_every == 0 or i == num_meta_steps - 1):
                history.append({k: float(v) for k, v in metrics.items()} | {"step": i})
        return state, history
