"""The meta-training Engine.

One ``meta_step`` = K unrolled base optimizer steps + one meta update, with
the hypergradient estimator resolved through the ``repro.core.methods``
registry — the paper's whole ablation surface (Tables 8/9) behind one
config value, and open to third-party estimators via ``register_method``.

The Engine builds a *pure* step function (state, base_batches, meta_batch) ->
(state, metrics) so it can be jit'ed on one device (benchmarks, examples) or
handed to the launcher which wraps it in pjit/shard_map for the production
mesh. ``base_batches`` carries a leading unroll axis of length K.

The step is method-agnostic: unroll -> ``method.local_terms`` (shard-local
math) -> identity reduce (this is the single-device path) ->
``method.finalize`` (hypergradient + post-update hook). The distributed
single-sync schedule in ``launch.distributed`` drives the SAME protocol,
inserting its one bucketed all-reduce between stages 2 and 3.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import methods as methods_mod
from repro.core.bilevel import BilevelSpec
from repro.core.methods import HypergradMethod, MethodContext
from repro.core.sama import global_norm
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import Optimizer, OptState, apply_updates
from repro.scale import accum as accum_mod
from repro.scale import policy as policy_mod
from repro.scale.policy import LossScaleState, ScaleConfig

PyTree = Any

#: The built-in estimators (kept for back-compat; the authoritative list is
#: ``methods.available_methods()``, which also includes custom registrations).
METHODS = ("sama", "sama_na", "t1t2", "neumann", "cg", "iterdiff")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """``method`` is a registry name or a HypergradMethod instance; the
    remaining per-method knobs feed the built-in factories. ``scale``
    carries the repro.scale knobs (precision policy + microbatch count,
    DESIGN.md §11) — the default is the identity (f32, no microbatching),
    i.e. the paper-exact step."""

    method: Union[str, HypergradMethod] = "sama"
    unroll_steps: int = 1
    alpha: float = 1.0  # SAMA perturbation scale
    base_nudge: bool = True
    adapt_clip: float = 0.0  # see SAMAConfig.adapt_clip
    # baseline-specific knobs
    neumann_terms: int = 5
    neumann_scale: float = 0.1
    cg_iters: int = 5
    cg_damping: float = 1e-3
    # precision policy + microbatch accumulation (repro.scale)
    scale: ScaleConfig = ScaleConfig()

    def __post_init__(self):
        if isinstance(self.method, str) and self.method not in methods_mod.available_methods():
            raise ValueError(
                f"method {self.method!r} not registered; have {methods_mod.available_methods()}"
            )

    def resolve(self) -> HypergradMethod:
        return methods_mod.resolve_method(self.method, self)


class EngineState(NamedTuple):
    theta: PyTree
    base_opt_state: OptState
    lam: PyTree
    meta_opt_state: OptState
    step: jnp.ndarray
    #: dynamic loss-scale automaton (repro.scale); None (an empty subtree,
    #: so old checkpoints keep restoring) unless the policy scales losses.
    scale: Optional[LossScaleState] = None


def init_state(theta: PyTree, lam: PyTree, base_opt: Optimizer, meta_opt: Optimizer,
               *, scale: Optional[ScaleConfig] = None) -> EngineState:
    """``scale``: the EngineConfig's ScaleConfig — needed so a
    loss-scaling policy (f16) gets its LossScaleState seeded; omitting it
    keeps the f32/bf16 default (no scale state)."""

    policy = (scale or ScaleConfig()).resolve()
    return EngineState(
        theta=theta,
        base_opt_state=base_opt.init(theta),
        lam=lam,
        meta_opt_state=meta_opt.init(lam),
        step=jnp.zeros([], jnp.int32),
        scale=policy_mod.init_scale_state(policy),
    )


def _unroll_base(spec: BilevelSpec, base_opt: Optimizer, theta, opt_state, lam,
                 base_batches, *, scale_cfg: Optional[ScaleConfig] = None,
                 scale_state: Optional[LossScaleState] = None, grad_reduce=None):
    """K base optimizer steps via lax.scan. Carries the last base gradient and
    the optimizer state *at which it was computed* — SAMA's adaptation matrix
    is evaluated there (paper footnote 2: no extra backward pass).

    repro.scale hooks (all default to the paper-exact path):
    ``scale_cfg.microbatch`` splits each base batch into M accumulated
    microbatches (collective-free inner scan); ``scale_state`` (with a
    loss-scaling policy) multiplies each microbatch loss by the live scale
    before its backward pass and SKIPS the update on a non-finite gradient
    (params, moments, and the carried (g, state-at-g) pair all keep their
    previous values) while the scale automaton backs off; ``grad_reduce``
    is the distributed schedule's per-step DDP pmean — it runs on the
    ACCUMULATED gradient, so the all-reduce count per base step stays one
    for every M.

    Returns ``(theta, opt_state, g_last, st_at_g, losses, scale_state,
    any_finite)`` — ``any_finite`` (scalar bool, always True without
    scaling) says whether ANY base step of this unroll applied; when every
    step skipped, ``g_last`` is still the zero init and the meta level
    must not consume it (SAMA's adaptation diagonal at a zero gradient and
    cold moments is the lr/eps pathology — finite but garbage), so the
    caller's meta-update guard ANDs this flag in.
    """

    cfg = scale_cfg or ScaleConfig()
    policy = cfg.resolve()
    if policy.dynamic_scaling and scale_state is None:
        raise ValueError(
            f"policy {policy.name!r} scales losses but the state carries no "
            "LossScaleState — build the state with "
            "init_state(..., scale=engine_cfg.scale)"
        )
    g0 = jax.tree_util.tree_map(jnp.zeros_like, theta)

    def step(carry, batch):
        th, st, g_prev, st_prev, ss, ok_prev = carry
        loss, g = accum_mod.microbatch_value_and_grad(
            spec.base_scalar, th, lam, batch, cfg.microbatch, policy.accum_jnp,
            scale=ss,
        )
        if grad_reduce is not None:
            g = grad_reduce(g)
        if ss is None:
            upd, st_new = base_opt.update(g, st, th)
            return (apply_updates(th, upd), st_new, g, st, ss, ok_prev), loss
        finite = policy_mod.all_finite(g)
        g_safe = jax.tree_util.tree_map(
            lambda x: jnp.where(finite, x, jnp.zeros_like(x)), g)
        upd, st_new = base_opt.update(g_safe, st, th)
        th_new = policy_mod.select_tree(finite, apply_updates(th, upd), th)
        st_new = policy_mod.select_tree(finite, st_new, st)
        # a skipped step contributes no usable gradient: keep the previous
        # (g, state-at-g) pair so SAMA's adaptation stays finite
        g_keep = policy_mod.select_tree(finite, g, g_prev)
        st_at_g = policy_mod.select_tree(finite, st, st_prev)
        ss = policy_mod.update_scale(ss, finite, policy)
        return (th_new, st_new, g_keep, st_at_g, ss, jnp.logical_or(ok_prev, finite)), loss

    any0 = jnp.asarray(scale_state is None)  # no scaling: vacuously True
    init = (theta, opt_state, g0, opt_state, scale_state, any0)
    (theta, opt_state, g_last, st_at_g, scale_state, any_finite), losses = jax.lax.scan(
        step, init, base_batches)
    return theta, opt_state, g_last, st_at_g, losses, scale_state, any_finite


def make_context(
    base_opt: Optimizer,
    state: EngineState,
    base_batches,
    meta_batch,
    *,
    theta,
    base_opt_state,
    g_base,
    loss_scale=None,
) -> MethodContext:
    """Assemble the MethodContext a hypergradient method consumes. Shared by
    the Engine step and the distributed schedule so both hand methods the
    exact same view of the unroll. ``loss_scale`` (the POST-unroll dynamic
    scale under an f16 policy) lets methods protect their own backward
    passes — see MethodContext.loss_scale."""

    return MethodContext(
        base_opt=base_opt,
        theta0=state.theta,
        theta=theta,
        lam=state.lam,
        g_base=g_base,
        base_opt_state=base_opt_state,
        base_batches=base_batches,
        last_batch=jax.tree_util.tree_map(lambda x: x[-1], base_batches),
        meta_batch=meta_batch,
        loss_scale=loss_scale,
    )


def step_metrics(method: HypergradMethod, terms, hyper, base_losses) -> Dict[str, jnp.ndarray]:
    """The uniform metric dict. ``eps`` is kept for every method (zero when
    the method has no step-size notion) so logs/benchmarks stay columnar."""

    metrics = {
        "base_loss": jnp.mean(base_losses),
        "meta_loss": terms["meta_loss"],
        "hypergrad_norm": global_norm(hyper),
        "eps": jnp.zeros([], jnp.float32),
    }
    for k, v in method.metrics(terms).items():
        metrics[k] = v
    return metrics


def guarded_meta_update(meta_opt: Optimizer, hyper, theta_post, state: EngineState,
                        *, theta_pre, guard: bool, base_ok=None):
    """The meta-level update, optionally gated on finiteness: under a
    loss-scaling policy the hypergradient path (low-precision CD passes)
    can overflow, and a single non-finite meta step would poison lam and
    the nudged theta permanently. With ``guard`` the whole meta update
    (lam, meta moments, AND the finalize post-update of theta) is skipped
    for that step — the meta-level analogue of the base unroll's
    skip-on-nonfinite. ``base_ok`` (the unroll's any-finite flag) is ANDed
    in: when EVERY base step skipped, g_base is the zero init and the
    hypergradient is finite garbage. Shared by the Engine step and the
    manual schedule so the semantics cannot diverge.

    Returns ``(lam, m_state, theta_post, finite)``; ``finite`` is None
    when unguarded, else the gate — callers feed it to
    ``policy.backoff_on`` so the loss-scale automaton OBSERVES
    hypergradient overflow (otherwise a persistently-overflowing meta
    path would skip forever with no backoff)."""

    upd, m_state = meta_opt.update(hyper, state.meta_opt_state, state.lam)
    lam = apply_updates(state.lam, upd)
    if not guard:
        return lam, m_state, theta_post, None
    finite = policy_mod.all_finite({"hyper": hyper, "theta": theta_post})
    if base_ok is not None:
        finite = jnp.logical_and(finite, base_ok)
    lam = policy_mod.select_tree(finite, lam, state.lam)
    m_state = policy_mod.select_tree(finite, m_state, state.meta_opt_state)
    theta_post = policy_mod.select_tree(finite, theta_post, theta_pre)
    return lam, m_state, theta_post, finite


def make_meta_step(
    spec: BilevelSpec,
    base_opt: Optimizer,
    meta_opt: Optimizer,
    cfg: EngineConfig = EngineConfig(),
) -> Callable[[EngineState, Any, Any], Tuple[EngineState, Dict[str, jnp.ndarray]]]:
    """Build the pure, method-agnostic meta-step function. ``cfg.scale``
    applies the precision policy's cast boundary to BOTH levels (the spec
    is wrapped once, so the unroll and the hypergradient path see the same
    boundary) and microbatch accumulation to every batch-sized backward
    pass (repro.scale.accum)."""

    method = cfg.resolve()
    policy = cfg.scale.resolve()
    spec = policy_mod.apply_to_spec(spec, policy)
    micro = cfg.scale.microbatch

    def meta_step(state: EngineState, base_batches, meta_batch):
        # obs_trace.phase = unconditional jax.named_scope (identical HLO
        # with obs on or off) + a host span iff a Tracer is activated
        with obs_trace.phase("base_unroll"):
            (theta, b_state, g_base, st_at_g, base_losses, scale_state,
             base_ok) = _unroll_base(
                spec, base_opt, state.theta, state.base_opt_state, state.lam,
                base_batches, scale_cfg=cfg.scale, scale_state=state.scale,
            )
        ctx = make_context(
            base_opt, state, base_batches, meta_batch,
            theta=theta, base_opt_state=st_at_g, g_base=g_base,
            loss_scale=scale_state.scale if scale_state is not None else None,
        )
        # local_terms is the phase every method shares (attribution for the
        # baselines); SAMA's own meta_pass/cd_passes scopes nest inside it
        # and win the innermost-phase match in obs.profile
        with obs_trace.phase("local_terms"):
            terms = methods_mod.validate_terms(
                method, accum_mod.microbatch_local_terms(method, spec, ctx, micro,
                                                         policy.accum_jnp))
        # single-device / pjit path: identity reduce between stages 2 and 3
        with obs_trace.phase("finalize"):
            hyper, theta_post = method.finalize(terms, ctx)

        with obs_trace.phase("meta_update"):
            lam, m_state, theta_post, meta_ok = guarded_meta_update(
                meta_opt, hyper, theta_post, state,
                theta_pre=theta, guard=policy.dynamic_scaling, base_ok=base_ok,
            )
            if meta_ok is not None:  # hypergrad overflow must back the scale off
                scale_state = policy_mod.backoff_on(scale_state, meta_ok, policy)

        new_state = EngineState(
            theta=theta_post,
            base_opt_state=b_state,
            lam=lam,
            meta_opt_state=m_state,
            step=state.step + 1,
            scale=scale_state,
        )
        metrics = step_metrics(method, terms, hyper, base_losses)
        if meta_ok is not None:
            # expose the automaton to host-side observers: the post-step
            # scale and the gate verdict ride the existing metric outputs,
            # so obs needs no extra sync (and no obs-conditional tracing —
            # these are present whenever the policy scales, observed or not)
            metrics["loss_scale"] = scale_state.scale
            metrics["meta_skipped"] = 1.0 - meta_ok.astype(jnp.float32)
        return new_state, metrics

    return meta_step


def run_loop(step_fn, state, batch_iter, num_steps: int, log_every: int = 0,
             on_step=None, obs=None):
    """The shared training loop: drive ``step_fn`` over an iterator of
    (base_batches[K], meta_batch), collecting float-cast metric history at
    ``log_every`` cadence. Used by both Engine.run and MetaLearner.fit so
    the logging semantics cannot diverge. ``on_step(i, state)`` runs after
    every step (checkpoint hooks).

    Metric reads happen ONLY at the log cadence and fetch the whole dict
    in one ``jax.device_get`` (``obs.metrics.packed_read``) — one D2H
    transfer per logged step instead of one blocking ``float(v)`` per
    key. ``obs`` (a ``repro.obs.Obs``) receives the same host dict via
    ``observe_step`` at the same boundary, so observability adds no sync
    points to the hot loop; ``obs=None`` logs nothing extra."""

    history = []
    for i in range(num_steps):
        base_batches, meta_batch = next(batch_iter)
        state, metrics = step_fn(state, base_batches, meta_batch)
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            row = {k: float(v)
                   for k, v in obs_metrics.packed_read(metrics).items()}
            history.append(row | {"step": i})
            if obs is not None and obs.enabled:
                obs.observe_step(i, row)
        if on_step is not None:
            on_step(i, state)
    return state, history


class Engine:
    """Convenience single-process driver around the pure step function."""

    def __init__(self, spec, base_opt, meta_opt, cfg: EngineConfig = EngineConfig(), jit: bool = True):
        self.spec = spec
        self.base_opt = base_opt
        self.meta_opt = meta_opt
        self.cfg = cfg
        step = make_meta_step(spec, base_opt, meta_opt, cfg)
        self.step_fn = jax.jit(step) if jit else step

    def init(self, theta, lam) -> EngineState:
        return init_state(theta, lam, self.base_opt, self.meta_opt,
                          scale=self.cfg.scale)

    def run(self, state: EngineState, batch_iter, num_meta_steps: int,
            log_every: int = 0, obs=None):
        """batch_iter yields (base_batches[K], meta_batch)."""

        return run_loop(self.step_fn, state, batch_iter, num_meta_steps,
                        log_every, obs=obs)
