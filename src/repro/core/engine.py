"""The meta-training Engine.

One ``meta_step`` = K unrolled base optimizer steps + one meta update, with
the hypergradient estimator resolved through the ``repro.core.methods``
registry — the paper's whole ablation surface (Tables 8/9) behind one
config value, and open to third-party estimators via ``register_method``.

The Engine builds a *pure* step function (state, base_batches, meta_batch) ->
(state, metrics) so it can be jit'ed on one device (benchmarks, examples) or
handed to the launcher which wraps it in pjit/shard_map for the production
mesh. ``base_batches`` carries a leading unroll axis of length K.

The step is method-agnostic: unroll -> ``method.local_terms`` (shard-local
math) -> identity reduce (this is the single-device path) ->
``method.finalize`` (hypergradient + post-update hook). The distributed
single-sync schedule in ``launch.distributed`` drives the SAME protocol,
inserting its one bucketed all-reduce between stages 2 and 3.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import methods as methods_mod
from repro.core.bilevel import BilevelSpec
from repro.core.methods import HypergradMethod, MethodContext
from repro.core.sama import global_norm
from repro.optim import Optimizer, OptState, apply_updates

PyTree = Any

#: The built-in estimators (kept for back-compat; the authoritative list is
#: ``methods.available_methods()``, which also includes custom registrations).
METHODS = ("sama", "sama_na", "t1t2", "neumann", "cg", "iterdiff")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """``method`` is a registry name or a HypergradMethod instance; the
    remaining per-method knobs feed the built-in factories."""

    method: Union[str, HypergradMethod] = "sama"
    unroll_steps: int = 1
    alpha: float = 1.0  # SAMA perturbation scale
    base_nudge: bool = True
    adapt_clip: float = 0.0  # see SAMAConfig.adapt_clip
    # baseline-specific knobs
    neumann_terms: int = 5
    neumann_scale: float = 0.1
    cg_iters: int = 5
    cg_damping: float = 1e-3

    def __post_init__(self):
        if isinstance(self.method, str) and self.method not in methods_mod.available_methods():
            raise ValueError(
                f"method {self.method!r} not registered; have {methods_mod.available_methods()}"
            )

    def resolve(self) -> HypergradMethod:
        return methods_mod.resolve_method(self.method, self)


class EngineState(NamedTuple):
    theta: PyTree
    base_opt_state: OptState
    lam: PyTree
    meta_opt_state: OptState
    step: jnp.ndarray


def init_state(theta: PyTree, lam: PyTree, base_opt: Optimizer, meta_opt: Optimizer) -> EngineState:
    return EngineState(
        theta=theta,
        base_opt_state=base_opt.init(theta),
        lam=lam,
        meta_opt_state=meta_opt.init(lam),
        step=jnp.zeros([], jnp.int32),
    )


def _unroll_base(spec: BilevelSpec, base_opt: Optimizer, theta, opt_state, lam, base_batches):
    """K base optimizer steps via lax.scan. Carries the last base gradient and
    the optimizer state *at which it was computed* — SAMA's adaptation matrix
    is evaluated there (paper footnote 2: no extra backward pass)."""

    g0 = jax.tree_util.tree_map(jnp.zeros_like, theta)

    def step(carry, batch):
        th, st, _, _ = carry
        loss, g = jax.value_and_grad(spec.base_scalar, argnums=0)(th, lam, batch)
        upd, st_new = base_opt.update(g, st, th)
        th_new = apply_updates(th, upd)
        return (th_new, st_new, g, st), loss

    init = (theta, opt_state, g0, opt_state)
    (theta, opt_state, g_last, st_at_g), losses = jax.lax.scan(step, init, base_batches)
    return theta, opt_state, g_last, st_at_g, losses


def make_context(
    base_opt: Optimizer,
    state: EngineState,
    base_batches,
    meta_batch,
    *,
    theta,
    base_opt_state,
    g_base,
) -> MethodContext:
    """Assemble the MethodContext a hypergradient method consumes. Shared by
    the Engine step and the distributed schedule so both hand methods the
    exact same view of the unroll."""

    return MethodContext(
        base_opt=base_opt,
        theta0=state.theta,
        theta=theta,
        lam=state.lam,
        g_base=g_base,
        base_opt_state=base_opt_state,
        base_batches=base_batches,
        last_batch=jax.tree_util.tree_map(lambda x: x[-1], base_batches),
        meta_batch=meta_batch,
    )


def step_metrics(method: HypergradMethod, terms, hyper, base_losses) -> Dict[str, jnp.ndarray]:
    """The uniform metric dict. ``eps`` is kept for every method (zero when
    the method has no step-size notion) so logs/benchmarks stay columnar."""

    metrics = {
        "base_loss": jnp.mean(base_losses),
        "meta_loss": terms["meta_loss"],
        "hypergrad_norm": global_norm(hyper),
        "eps": jnp.zeros([], jnp.float32),
    }
    for k, v in method.metrics(terms).items():
        metrics[k] = v
    return metrics


def make_meta_step(
    spec: BilevelSpec,
    base_opt: Optimizer,
    meta_opt: Optimizer,
    cfg: EngineConfig = EngineConfig(),
) -> Callable[[EngineState, Any, Any], Tuple[EngineState, Dict[str, jnp.ndarray]]]:
    """Build the pure, method-agnostic meta-step function."""

    method = cfg.resolve()

    def meta_step(state: EngineState, base_batches, meta_batch):
        theta, b_state, g_base, st_at_g, base_losses = _unroll_base(
            spec, base_opt, state.theta, state.base_opt_state, state.lam, base_batches
        )
        ctx = make_context(
            base_opt, state, base_batches, meta_batch,
            theta=theta, base_opt_state=st_at_g, g_base=g_base,
        )
        terms = methods_mod.validate_terms(method, method.local_terms(spec, ctx))
        # single-device / pjit path: identity reduce between stages 2 and 3
        hyper, theta = method.finalize(terms, ctx)

        upd, m_state = meta_opt.update(hyper, state.meta_opt_state, state.lam)
        lam = apply_updates(state.lam, upd)

        new_state = EngineState(
            theta=theta,
            base_opt_state=b_state,
            lam=lam,
            meta_opt_state=m_state,
            step=state.step + 1,
        )
        return new_state, step_metrics(method, terms, hyper, base_losses)

    return meta_step


def run_loop(step_fn, state, batch_iter, num_steps: int, log_every: int = 0, on_step=None):
    """The shared training loop: drive ``step_fn`` over an iterator of
    (base_batches[K], meta_batch), collecting float-cast metric history at
    ``log_every`` cadence. Used by both Engine.run and MetaLearner.fit so
    the logging semantics cannot diverge. ``on_step(i, state)`` runs after
    every step (checkpoint hooks)."""

    history = []
    for i in range(num_steps):
        base_batches, meta_batch = next(batch_iter)
        state, metrics = step_fn(state, base_batches, meta_batch)
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            history.append({k: float(v) for k, v in metrics.items()} | {"step": i})
        if on_step is not None:
            on_step(i, state)
    return state, history


class Engine:
    """Convenience single-process driver around the pure step function."""

    def __init__(self, spec, base_opt, meta_opt, cfg: EngineConfig = EngineConfig(), jit: bool = True):
        self.spec = spec
        self.base_opt = base_opt
        self.meta_opt = meta_opt
        self.cfg = cfg
        step = make_meta_step(spec, base_opt, meta_opt, cfg)
        self.step_fn = jax.jit(step) if jit else step

    def init(self, theta, lam) -> EngineState:
        return init_state(theta, lam, self.base_opt, self.meta_opt)

    def run(self, state: EngineState, batch_iter, num_meta_steps: int, log_every: int = 0):
        """batch_iter yields (base_batches[K], meta_batch)."""

        return run_loop(self.step_fn, state, batch_iter, num_meta_steps, log_every)
