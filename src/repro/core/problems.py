"""Builders that assemble BilevelSpecs for the paper's data-optimization
applications (Sec. 4):

* ``make_data_optimization_spec`` — noisy-data reweighting (+ optional label
  correction), Sec. 4.1 / data pruning Sec. 4.3 (with uncertainty feature).
* ``make_auxiliary_spec`` — continued-pretraining auxiliary-loss reweighting
  (TARTAN-style multitask), Sec. 4.2.

They are model-agnostic: the caller supplies a ``per_example_fn`` that maps
(theta, batch) to per-sample quantities; any architecture in ``repro.models``
plugs in through its loss adapter.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelSpec
from repro.core import meta_modules as mm
from repro.kernels import dispatch as kdispatch
from repro.kernels import ops as kops

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PerExample:
    """Per-sample quantities from the base model on one batch (a pytree, so
    it can cross jit/grad boundaries)."""

    loss: jnp.ndarray  # (B,) per-sample loss under the *observed* labels
    logits: Optional[jnp.ndarray] = None  # (B, C) — needed for label correction
    label_onehot: Optional[jnp.ndarray] = None  # (B, C)
    uncertainty: Optional[jnp.ndarray] = None  # (B,)


PerExampleFn = Callable[[PyTree, Any], PerExample]


def init_data_optimization_lam(
    key,
    *,
    reweight: bool = True,
    correct: bool = False,
    num_classes: Optional[int] = None,
    use_uncertainty: bool = False,
    hidden: int = 100,
) -> PyTree:
    lam = {}
    k1, k2 = jax.random.split(key)
    if reweight:
        in_dim = 2 if use_uncertainty else 1
        lam["reweight"] = mm.init_weight_net(k1, in_dim=in_dim, hidden=hidden)
    if correct:
        assert num_classes is not None, "label correction needs num_classes"
        lam["correct"] = mm.init_label_corrector(k2, num_classes=num_classes)
    return lam


def make_data_optimization_spec(
    per_example_fn: PerExampleFn,
    *,
    reweight: bool = True,
    correct: bool = False,
    use_uncertainty: bool = False,
) -> BilevelSpec:
    """Sec. 4.1:  min_lam L(D_clean; theta*)  s.t.
    theta* = argmin mean_i w(L_i; lam_r) * CE(f(x_i), c(x_i, y_i; lam_c))."""

    def base_loss(theta, lam, batch):
        pe = per_example_fn(theta, batch)
        loss_i = pe.loss
        if correct:
            probs = jax.nn.softmax(pe.logits, axis=-1)
            corrected = mm.apply_label_corrector(lam["correct"], probs, pe.label_onehot)
            logp = jax.nn.log_softmax(pe.logits, axis=-1)
            loss_i = -jnp.sum(corrected * logp, axis=-1)
        if reweight:
            feats = mm.weight_features(
                loss_i, pe.uncertainty if use_uncertainty else None
            )
            w = mm.apply_weight_net(lam["reweight"], feats)
            return jnp.mean(w * loss_i)
        return jnp.mean(loss_i)

    def meta_loss(theta, lam, batch):
        del lam  # the meta loss is plain risk on clean/meta data
        pe = per_example_fn(theta, batch)
        return jnp.mean(pe.loss)

    return BilevelSpec(base_loss=base_loss, meta_loss=meta_loss)


def make_auxiliary_spec(
    ft_loss_fn: Callable[[PyTree, Any], jnp.ndarray],  # (theta, batch)->scalar
    pt_per_example_fn: Callable[[PyTree, Any], PerExample],
    *,
    use_uncertainty: bool = False,
) -> BilevelSpec:
    """Sec. 4.2: one-stage multitask continued pretraining
    base = L_ft + mean_i w(x_i; lam) * L_pt,i ;  meta = L_ft."""

    def base_loss(theta, lam, batch):
        ft_batch, pt_batch = batch["ft"], batch["pt"]
        ft = ft_loss_fn(theta, ft_batch)
        pe = pt_per_example_fn(theta, pt_batch)
        feats = mm.weight_features(pe.loss, pe.uncertainty if use_uncertainty else None)
        w = mm.apply_weight_net(lam["reweight"], feats)
        return ft + jnp.mean(w * pe.loss)

    def meta_loss(theta, lam, batch):
        del lam
        return ft_loss_fn(theta, batch["ft"])

    return BilevelSpec(base_loss=base_loss, meta_loss=meta_loss)


def softmax_per_example(apply_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray]) -> PerExampleFn:
    """Adapter for plain classifiers: batch = {'x': (B, ...), 'y': (B,) int}.
    Uncertainty is in-batch predictive entropy; the paper's cross-meta-step
    EMA-disagreement variant is first-class in ``repro.dataopt.scores``
    (``EMATracker`` / ``ema_disagreement``, or ``scorer="meta"`` with
    ``uncertainty="ema"`` on the ``DataOptimizer`` facade).

    At ``kernels.CE_VOCAB_THRESHOLD`` classes and above the per-sample CE —
    the quantity the reweighting base loss scales per sample — routes
    through the dispatched blockwise ``weighted_ce`` kernel (its custom VJP
    streams the vocabulary once per pass on Pallas backends; docs/
    kernels.md), and comes back f32 regardless of logits dtype (the
    kernels compute in f32). Known trade-off: the entropy feature still
    materializes the full log-prob tensor, so the kernel route buys the
    fused weighted backward here, not the forward memory win — a fused
    entropy emission is the natural follow-up kernel."""

    def fn(theta, batch):
        logits = apply_fn(theta, batch["x"])
        num_classes = logits.shape[-1]
        onehot = jax.nn.one_hot(batch["y"], num_classes, dtype=logits.dtype)
        logp = jax.nn.log_softmax(logits, axis=-1)
        if num_classes >= kdispatch.CE_VOCAB_THRESHOLD:
            loss = kops.cross_entropy(logits, batch["y"])
        else:
            loss = -jnp.sum(onehot * logp, axis=-1)
        p = jnp.exp(logp)
        entropy = -jnp.sum(p * logp, axis=-1)
        return PerExample(loss=loss, logits=logits, label_onehot=onehot, uncertainty=entropy)

    return fn
