"""Baseline hypergradient algorithms the paper compares SAMA against
(Fig. 1 table, Tables 2/8/9): iterative differentiation, Neumann series,
conjugate gradient, and T1-T2 (DARTS one-step).

All of these compute dL_meta/dlam for the same BilevelSpec, so the Engine can
swap them in with a config string — that is exactly the paper's ablation
surface. The second-order ones (Neumann, CG, iterative diff) use exact
autodiff Hessian-vector products, which is what makes them slow and memory
hungry at scale; we keep them exact so the benchmarks reproduce the paper's
efficiency gaps honestly.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelSpec
from repro.optim import Optimizer, apply_updates

PyTree = Any


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def _vdot(a: PyTree, b: PyTree) -> jnp.ndarray:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(x, y) for x, y in zip(leaves_a, leaves_b))


def hvp(loss_theta, theta: PyTree, vec: PyTree) -> PyTree:
    """Hessian-vector product d^2 L/dtheta^2 . vec via forward-over-reverse
    (Pearlmutter). One extra linearization per call — the cost SAMA avoids."""

    return jax.jvp(jax.grad(loss_theta), (theta,), (vec,))[1]


def mixed_vjp(spec: BilevelSpec, theta, lam, base_batch, vec: PyTree) -> PyTree:
    """Exact  d^2 L_base / dlam dtheta . vec  =  grad_lam <grad_theta L_base, vec>."""

    def inner(lam_):
        g_theta = jax.grad(spec.base_scalar, argnums=0)(theta, lam_, base_batch)
        return _vdot(g_theta, vec)

    return jax.grad(inner)(lam)


# ---------------------------------------------------------------------------
# Neumann series [Lorraine et al. 2020]
# ---------------------------------------------------------------------------


def neumann_hypergrad(
    spec: BilevelSpec, theta, lam, base_batch, meta_batch,
    *, num_terms: int = 5, scale: float = 0.1,
):
    """inv(H) g  ~=  scale * sum_i (I - scale*H)^i g, truncated."""

    g_meta = jax.grad(spec.meta_scalar, argnums=0)(theta, lam, meta_batch)
    loss_theta = lambda th: spec.base_scalar(th, lam, base_batch)

    def body(_, carry):
        p, acc = carry
        hp = hvp(loss_theta, theta, p)
        p = _tmap(lambda a, b: a - scale * b, p, hp)
        acc = _tmap(jnp.add, acc, p)
        return p, acc

    p0 = g_meta
    acc0 = g_meta
    _, acc = jax.lax.fori_loop(0, num_terms, body, (p0, acc0))
    inv_hvp = _tmap(lambda x: scale * x, acc)
    return _tmap(jnp.negative, mixed_vjp(spec, theta, lam, base_batch, inv_hvp))


# ---------------------------------------------------------------------------
# Conjugate gradient [Rajeswaran et al. 2019, iMAML]
# ---------------------------------------------------------------------------


def cg_hypergrad(
    spec: BilevelSpec, theta, lam, base_batch, meta_batch,
    *, num_iters: int = 5, damping: float = 1e-3,
):
    """Solve (H + damping I) x = g_meta with CG, then -mixed_vjp(x)."""

    g_meta = jax.grad(spec.meta_scalar, argnums=0)(theta, lam, meta_batch)
    loss_theta = lambda th: spec.base_scalar(th, lam, base_batch)

    def matvec(x):
        h = hvp(loss_theta, theta, x)
        return _tmap(lambda hx, xi: hx + damping * xi, h, x)

    x0 = _tmap(jnp.zeros_like, g_meta)
    r0 = g_meta
    p0 = g_meta
    rs0 = _vdot(r0, r0)

    def body(_, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        alpha = rs / jnp.maximum(_vdot(p, ap), 1e-30)
        x = _tmap(lambda xi, pi: xi + alpha * pi, x, p)
        r = _tmap(lambda ri, api: ri - alpha * api, r, ap)
        rs_new = _vdot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = _tmap(lambda ri, pi: ri + beta * pi, r, p)
        return x, r, p, rs_new

    x, *_ = jax.lax.fori_loop(0, num_iters, body, (x0, r0, p0, rs0))
    return _tmap(jnp.negative, mixed_vjp(spec, theta, lam, base_batch, x))


# ---------------------------------------------------------------------------
# T1-T2 / DARTS one-step [Luketina et al. 2016; Liu et al. 2019]
# ---------------------------------------------------------------------------


def t1t2_hypergrad(spec: BilevelSpec, theta, lam, base_batch, meta_batch):
    """Identity base-Jacobian, *no* optimizer adaptation, exact mixed VJP.
    (SAMA-NA with central difference replaced by the exact second-order
    product — the classical formulation.)"""

    g_meta = jax.grad(spec.meta_scalar, argnums=0)(theta, lam, meta_batch)
    return _tmap(jnp.negative, mixed_vjp(spec, theta, lam, base_batch, g_meta))


# ---------------------------------------------------------------------------
# Iterative differentiation [MAML-style unrolled]
# ---------------------------------------------------------------------------


def iterdiff_hypergrad(
    spec: BilevelSpec, theta, lam, base_batches, meta_batch,
    *, base_opt: Optimizer,
):
    """Differentiate through K unrolled optimizer steps. ``base_batches`` is a
    pytree with a leading unroll axis. Memory grows with K — the point the
    paper makes against iterative differentiation."""

    def unrolled_meta_loss(lam_):
        state = base_opt.init(theta)

        def step(carry, batch):
            th, st = carry
            g = jax.grad(spec.base_scalar, argnums=0)(th, lam_, batch)
            upd, st = base_opt.update(g, st, th)
            return (apply_updates(th, upd), st), None

        (theta_k, _), _ = jax.lax.scan(step, (theta, state), base_batches)
        return spec.meta_scalar(theta_k, lam_, meta_batch)

    return jax.grad(unrolled_meta_loss)(lam)


HYPERGRAD_BASELINES = {
    "neumann": neumann_hypergrad,
    "cg": cg_hypergrad,
    "t1t2": t1t2_hypergrad,
    "iterdiff": iterdiff_hypergrad,
}
