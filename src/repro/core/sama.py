"""SAMA meta-gradient (paper Sec. 3, Eqs. 3-5).

The meta gradient is approximated by

    dL_meta/dlam  ~=  -(d/dlam L_base(theta+, lam) - d/dlam L_base(theta-, lam)) / (2 eps)

with
    theta+- = theta* +- eps * v
    v       = (du/dg) .* dL_meta/dtheta*          (algorithmic adaptation)
    eps     = alpha / ||v||_2                      (DARTS-style step size)

Only *first-order* backward passes appear:
    pass 1: g_meta = grad_theta L_meta          (local, no sync needed)
    pass 2: grad_lam L_base(theta+)             (local)
    pass 3: grad_lam L_base(theta-)             (synced once, in the caller)

The adaptation diagonal du/dg is analytic (repro.optim.Optimizer.adaptation)
and reuses the base gradient stored from the most recent unroll step — no
extra backward pass (paper footnote 2). When the base optimizer exposes a
fused ``adapt_product`` (adam/adamw/lion/adafactor do — the kernel-dispatch
fast path, DESIGN.md §10), the adaptation product AND the sum of squares
that ``eps = alpha/||v||`` needs come out of one pass over the data: the
separate ``global_norm(v)`` sweep is dropped. The single gradient
synchronization point of the distributed schedule lives in
``launch.distributed``, not here: this module is purely local math so that
it composes with pjit and shard_map alike.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.bilevel import BilevelSpec
from repro.obs import trace as obs_trace
from repro.optim import Optimizer, OptState

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SAMAConfig:
    alpha: float = 1.0  # perturbation scale; paper finds 1.0 robust (Sec 3.2)
    adapt: bool = True  # False => SAMA-NA ablation (no algorithmic adaptation)
    base_nudge: bool = True  # theta <- theta - eps*v at meta updates (F2SA/BOME-style)
    eps_floor: float = 1e-12
    # Mitigation for the cold-state Adam pathology (see DESIGN.md §6 note):
    # on coordinates where the base optimizer state is cold (m=v=0, g~0) the
    # exact Adam adaptation diagonal is ~lr/eps_adam (huge), so v concentrates
    # on base-dead coordinates and the central difference underflows. Clipping
    # |du/dg| at adapt_clip bounds their influence. 0 disables (paper-exact).
    adapt_clip: float = 0.0


class SAMAResult(NamedTuple):
    hypergrad: PyTree  # dL_meta/dlam
    v: PyTree  # perturbation direction (du/dg .* g_meta)
    eps: jnp.ndarray  # scalar step size
    meta_loss: jnp.ndarray


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adaptation_product(
    base_opt: Optimizer,
    base_opt_state: OptState,
    theta: PyTree,
    g_base: Optional[PyTree],
    g_meta: PyTree,
    cfg: SAMAConfig,
):
    """The (analytic, backprop-free) adaptation product ``v = du/dg .*
    g_meta`` from an ALREADY-COMPUTED meta gradient — the piece of
    ``perturbation_direction`` that is independent of how g_meta was
    obtained (one meta pass, or microbatch-accumulated by
    ``repro.scale.accum``).

    Returns ``(v, v_sumsq)``. ``v_sumsq`` is ``sum(v^2)`` when it came for
    free from the fused kernel path (``Optimizer.adapt_product``, DESIGN.md
    §10) and ``None`` otherwise — callers fall back to ``global_norm(v)``.
    The fused path is skipped under ``adapt_clip`` (clipping applies to the
    raw diagonal, which the fused kernels never materialize) and for
    optimizers without a registered kernel."""

    if not cfg.adapt:
        return g_meta, None
    if g_base is None:
        raise ValueError("algorithmic adaptation needs the last base gradient g_base")
    if base_opt.adapt_product is not None and not cfg.adapt_clip:
        return base_opt.adapt_product(g_base, base_opt_state, theta, g_meta)
    a = base_opt.adaptation(g_base, base_opt_state, theta)
    if cfg.adapt_clip:
        a = _tmap(lambda ai: jnp.clip(ai, -cfg.adapt_clip, cfg.adapt_clip), a)
    return _tmap(lambda ai, gi: ai * gi, a, g_meta), None


def step_size(v: PyTree, v_sumsq: Optional[jnp.ndarray], cfg: SAMAConfig) -> jnp.ndarray:
    """eps = alpha / ||v|| (DARTS-style), floored. ``v_sumsq`` (from the
    fused adaptation kernel) skips the separate global_norm pass."""

    norm = jnp.sqrt(v_sumsq) if v_sumsq is not None else global_norm(v)
    return cfg.alpha / jnp.maximum(norm, cfg.eps_floor)


def perturbation_direction(
    spec: BilevelSpec,
    theta: PyTree,
    lam: PyTree,
    meta_batch,
    *,
    base_opt: Optimizer,
    base_opt_state: OptState,
    g_base: Optional[PyTree],
    cfg: SAMAConfig,
    loss_scale: Optional[jnp.ndarray] = None,
):
    """Backward pass 1 + ``adaptation_product``. Returns
    ``(meta_loss, v, v_sumsq)`` — see ``adaptation_product`` for the
    v_sumsq contract. ``loss_scale`` (under an f16 policy) multiplies the
    meta loss before its backward pass so low-precision cotangents stay
    representable; the returned loss and gradient are unscaled."""

    with obs_trace.phase("meta_pass"):
        meta_loss, g_meta = scaled_value_and_grad(spec.meta_scalar, 0, loss_scale)(
            theta, lam, meta_batch)
        v, v_sumsq = adaptation_product(base_opt, base_opt_state, theta, g_base, g_meta, cfg)
    return meta_loss, v, v_sumsq


def scaled_value_and_grad(loss_fn, argnums: int, loss_scale: Optional[jnp.ndarray]):
    """``value_and_grad`` with the dynamic loss scale applied INSIDE the
    differentiated function (so every cotangent in the low-precision
    region carries the scale) and divided back out of both results.
    Identity wrapper when ``loss_scale`` is None."""

    if loss_scale is None:
        return jax.value_and_grad(loss_fn, argnums=argnums)

    def scaled(*args):
        return loss_fn(*args) * loss_scale

    def call(*args):
        loss, g = jax.value_and_grad(scaled, argnums=argnums)(*args)
        return loss / loss_scale, _tmap(lambda x: x / loss_scale, g)

    return call


def central_difference_hypergrad(
    spec: BilevelSpec,
    theta: PyTree,
    lam: PyTree,
    base_batch,
    v: PyTree,
    *,
    cfg: SAMAConfig,
    v_sumsq: Optional[jnp.ndarray] = None,
    loss_scale: Optional[jnp.ndarray] = None,
):
    """Backward passes 2+3: the finite-difference mixed second derivative

        d^2 L_base / dlam dtheta . v
            ~= (grad_lam L_base(theta + eps v) - grad_lam L_base(theta - eps v)) / (2 eps)

    ``v_sumsq`` (sum of squares of v, from the fused adaptation kernel)
    skips the separate ``global_norm`` pass over v when provided.
    """

    with obs_trace.phase("cd_passes"):
        eps = step_size(v, v_sumsq, cfg)
        theta_p, theta_m = perturbed_params(theta, v, eps)
        delta = central_difference_delta(spec, theta_p, theta_m, lam, base_batch,
                                         loss_scale=loss_scale)
        hyper = _tmap(lambda d: -d / (2.0 * eps), delta)
    return hyper, eps


def perturbed_params(theta: PyTree, v: PyTree, eps: jnp.ndarray):
    """(theta + eps v, theta - eps v), cast per leaf to theta's dtype."""

    theta_p = _tmap(lambda t, vi: t + eps * vi.astype(t.dtype), theta, v)
    theta_m = _tmap(lambda t, vi: t - eps * vi.astype(t.dtype), theta, v)
    return theta_p, theta_m


def central_difference_delta(spec: BilevelSpec, theta_p, theta_m, lam, base_batch,
                             *, loss_scale: Optional[jnp.ndarray] = None):
    """``grad_lam L_base(theta+) - grad_lam L_base(theta-)`` on ONE batch —
    backward passes 2+3. Linear in the batch mean, so microbatch
    accumulation of this delta (repro.scale.accum) reproduces the
    full-batch value exactly; the 1/(2 eps) scaling happens once in the
    caller. ``loss_scale`` scales both backward passes (f16 cotangent
    protection) and is divided back out of the returned delta — which
    lands in the f32 lam-gradient domain, so the unscale is exact."""

    if loss_scale is None:
        scalar = spec.base_scalar
    else:
        def scalar(th, la, b):
            return spec.base_scalar(th, la, b) * loss_scale

    gl_p = jax.grad(scalar, argnums=1)(theta_p, lam, base_batch)
    gl_m = jax.grad(scalar, argnums=1)(theta_m, lam, base_batch)
    delta = _tmap(lambda p, m: p - m, gl_p, gl_m)
    if loss_scale is not None:
        delta = _tmap(lambda d: d / loss_scale, delta)
    return delta


def sama_hypergrad(
    spec: BilevelSpec,
    theta: PyTree,
    lam: PyTree,
    base_batch,
    meta_batch,
    *,
    base_opt: Optimizer,
    base_opt_state: OptState,
    g_base: Optional[PyTree] = None,
    cfg: SAMAConfig = SAMAConfig(),
) -> SAMAResult:
    """The full (single-device / local-shard) SAMA meta gradient."""

    meta_loss, v, v_sumsq = perturbation_direction(
        spec, theta, lam, meta_batch,
        base_opt=base_opt, base_opt_state=base_opt_state, g_base=g_base, cfg=cfg,
    )
    hyper, eps = central_difference_hypergrad(
        spec, theta, lam, base_batch, v, cfg=cfg, v_sumsq=v_sumsq
    )
    return SAMAResult(hypergrad=hyper, v=v, eps=eps, meta_loss=meta_loss)


def apply_base_nudge(theta: PyTree, v: PyTree, eps: jnp.ndarray, cfg: SAMAConfig) -> PyTree:
    """theta <- theta - eps*v (paper Sec. 3.2, final paragraph). The direct
    meta gradient is injected into the base parameters every meta update."""

    if not cfg.base_nudge:
        return theta
    return _tmap(lambda t, vi: (t - eps * vi.astype(t.dtype)).astype(t.dtype), theta, v)
