"""The paper's primary contribution: SAMA — scalable meta learning as
bilevel optimization with (i) identity base-Jacobian approximation,
(ii) analytic algorithmic adaptation for adaptive optimizers, and
(iii) a single-sync distributed schedule (see launch.distributed).

Hypergradient estimators are first-class objects behind the
``repro.core.methods`` registry (DESIGN.md §2-3)."""

from repro.core.bilevel import BilevelSpec
from repro.core.engine import Engine, EngineConfig, EngineState, init_state, make_meta_step
from repro.core.methods import (
    HypergradMethod,
    MethodContext,
    ReduceContract,
    available_methods,
    register_method,
    resolve_method,
)
from repro.core.sama import SAMAConfig, SAMAResult, sama_hypergrad
from repro.core import baselines, meta_modules, methods

__all__ = [
    "BilevelSpec",
    "Engine",
    "EngineConfig",
    "EngineState",
    "HypergradMethod",
    "MethodContext",
    "ReduceContract",
    "SAMAConfig",
    "SAMAResult",
    "available_methods",
    "baselines",
    "init_state",
    "make_meta_step",
    "meta_modules",
    "methods",
    "register_method",
    "resolve_method",
    "sama_hypergrad",
]
