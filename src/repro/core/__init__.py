"""The paper's primary contribution: SAMA — scalable meta learning as
bilevel optimization with (i) identity base-Jacobian approximation,
(ii) analytic algorithmic adaptation for adaptive optimizers, and
(iii) a single-sync distributed schedule (see launch.distributed)."""

from repro.core.bilevel import BilevelSpec
from repro.core.engine import Engine, EngineConfig, EngineState, init_state, make_meta_step
from repro.core.sama import SAMAConfig, SAMAResult, sama_hypergrad
from repro.core import baselines, meta_modules

__all__ = [
    "BilevelSpec",
    "Engine",
    "EngineConfig",
    "EngineState",
    "SAMAConfig",
    "SAMAResult",
    "baselines",
    "init_state",
    "make_meta_step",
    "meta_modules",
    "sama_hypergrad",
]
