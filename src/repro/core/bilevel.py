"""Bilevel problem specification.

A meta-learning program (paper Sec. 2) is

    lam* = argmin_lam  L_meta(D_meta; theta*(lam))
    s.t. theta*(lam) = argmin_theta L_base(D_base; theta, lam)

We capture it as two pure scalar loss functions over pytrees. Everything in
``core`` (SAMA + baseline hypergradient algorithms, the Engine) is generic
over this spec — data reweighting, label correction, auxiliary-loss
reweighting and the biased-regression sanity problem are all instances.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

PyTree = Any
Batch = Any
LossFn = Callable[[PyTree, PyTree, Batch], Any]  # (theta, lam, batch) -> scalar


@dataclasses.dataclass(frozen=True)
class BilevelSpec:
    """The bilevel program. Loss functions must be jit-safe and return a
    scalar (or (scalar, aux) when ``has_aux``)."""

    base_loss: LossFn
    meta_loss: LossFn
    has_aux: bool = False

    def base_scalar(self, theta, lam, batch):
        out = self.base_loss(theta, lam, batch)
        return out[0] if self.has_aux else out

    def meta_scalar(self, theta, lam, batch):
        out = self.meta_loss(theta, lam, batch)
        return out[0] if self.has_aux else out
