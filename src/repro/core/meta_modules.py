"""Meta-learner modules (the lambda side of the bilevel program).

The paper's data-optimization experiments use small MLP meta learners:

* MetaWeightNet [58]-style reweighting net ``w(features; lam_r)`` — here with
  the paper's Sec. 4.3 extension of feeding prediction *uncertainty* next to
  the loss value.
* Label corrector ``c(x, y; lam_c)`` [70] producing a corrected soft label
  from (stop-grad) model beliefs and the observed noisy label.

Both are plain pytrees + pure apply functions, so they ride along with any
architecture and shard trivially (they are tiny and replicated).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

PyTree = Any


def _dense_init(key, n_in, n_out, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(n_in)
    wkey, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(wkey, (n_in, n_out), dtype=jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _dense(p, x):
    return x @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# MetaWeightNet
# ---------------------------------------------------------------------------


def init_weight_net(key, in_dim: int = 2, hidden: int = 100) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {"l1": _dense_init(k1, in_dim, hidden), "l2": _dense_init(k2, hidden, 1)}


def apply_weight_net(params: PyTree, feats: jnp.ndarray) -> jnp.ndarray:
    """feats: (B, in_dim) — typically [loss, uncertainty]. Returns (B,) weights
    in (0, 1). Features are stop-gradiented by the caller (they come from the
    base model); lambda only flows through the MLP."""

    h = jax.nn.relu(_dense(params["l1"], feats))
    return jax.nn.sigmoid(_dense(params["l2"], h))[..., 0]


def weight_features(per_sample_loss: jnp.ndarray, uncertainty: jnp.ndarray = None) -> jnp.ndarray:
    """Assemble (and detach) the MWN input features."""

    feats = [jax.lax.stop_gradient(per_sample_loss)]
    if uncertainty is not None:
        feats.append(jax.lax.stop_gradient(uncertainty))
    return jnp.stack(feats, axis=-1)


# ---------------------------------------------------------------------------
# Label corrector
# ---------------------------------------------------------------------------


def init_label_corrector(key, num_classes: int, hidden: int = 128) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "l1": _dense_init(k1, 2 * num_classes, hidden),
        "l2": _dense_init(k2, hidden, num_classes),
        "mix": _dense_init(k3, hidden, 1),
    }


def apply_label_corrector(
    params: PyTree, model_probs: jnp.ndarray, noisy_onehot: jnp.ndarray
) -> jnp.ndarray:
    """Returns corrected soft labels (B, C): a learned convex mix of the
    observed noisy label and an MLP-proposed distribution."""

    x = jnp.concatenate([jax.lax.stop_gradient(model_probs), noisy_onehot], axis=-1)
    h = jax.nn.relu(_dense(params["l1"], x))
    proposed = jax.nn.softmax(_dense(params["l2"], h), axis=-1)
    gate = jax.nn.sigmoid(_dense(params["mix"], h))  # (B, 1)
    return (1.0 - gate) * noisy_onehot + gate * proposed
