"""The paper's distributed execution schedule (Fig. 2), generalized to any
registered HypergradMethod with a linear reduce contract.

Two implementations of the same meta step:

* ``make_pjit_step`` — "Betty-style DDP" baseline: the Engine's pure step
  under jit; XLA inserts a gradient synchronization wherever the math needs
  one. In particular the meta pass's theta-gradient (pass 1) gets a
  model-sized all-reduce of its own.

* ``make_manual_step`` — the paper's single-sync schedule via shard_map,
  manual over the data axes, auto over "model":
    ``method.local_terms`` runs on LOCAL shards with NO collective;
    ONE bucketed pmean carries exactly the terms the method's
    ``reduce_contract`` declares (SAMA: hypergrad, v, eps, meta_loss —
    the analogue of PyTorch's single overlapped bucketed all-reduce), plus
    the scalar base-loss metric so no second sync is needed for logging;
    ``method.finalize`` then consumes replica-consistent values (SAMA's
    base nudge). The base-level unroll keeps its standard per-step DDP
    pmean (that sync exists in the paper's base level too), so the lowered
    module carries exactly ``unroll_steps`` base all-reduces + ONE
    meta-level all-reduce — pinned by ``count_data_allreduces``.

  Statistically, the manual path averages per-shard local estimates; for a
  method with a LINEAR reduce contract (SAMA, SAMA-NA, T1-T2) the mean of
  mixed second-derivative terms equals the pjit estimator's expectation,
  and with identical per-device batches the two are exactly equal — what
  tests/test_distributed.py pins, along with the collective-count claim,
  by parsing the lowered HLO. Methods with nonlinear contracts (CG,
  Neumann, iterdiff solve/unroll on the shard) are refused unless
  ``allow_nonlinear=True`` opts into the local-solve approximation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.flatten_util
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import methods as methods_mod
from repro.core.bilevel import BilevelSpec
from repro.core.engine import (
    EngineConfig,
    EngineState,
    _unroll_base,
    guarded_meta_update,
    make_context,
    make_meta_step,
    step_metrics,
)
from repro.launch.mesh import data_axes, shard_map
from repro.obs import trace as obs_trace
from repro.optim import Optimizer
from repro.scale import accum as accum_mod
from repro.scale import policy as policy_mod

PyTree = Any

#: What the manual schedule emits per step (static for shard_map out_specs).
#: Under a dynamic-scaling policy the automaton scalars ride along too
#: (see make_manual_step's ``metric_keys``).
METRIC_KEYS = ("base_loss", "meta_loss", "hypergrad_norm", "eps")
SCALE_METRIC_KEYS = ("loss_scale", "meta_skipped")


def flat_pmean(tree: PyTree, axes) -> PyTree:
    """Mean-reduce a pytree over ``axes`` through ONE all-reduce: ravel every
    leaf into a single flat f32 buffer (PyTorch-DDP flat bucket), pmean it,
    and unravel. Relying on XLA's all-reduce combiner would make the paper's
    one-sync claim backend-dependent; the flat bucket makes it structural.
    Leaves must already share a dtype (callers cast to f32 for reduction
    accuracy).

    Only valid when no tensor-parallel auto axis is live: ravel/concat breaks
    per-leaf "model" sharding, which would make the partitioner all-gather
    model-sharded leaves into full-size reduce buffers. Callers pick this
    bucket for pure-DDP meshes and ``tree_pmean`` otherwise."""

    flat, unravel = jax.flatten_util.ravel_pytree(tree)
    return unravel(jax.lax.pmean(flat, axes))


def tree_pmean(tree: PyTree, axes) -> PyTree:
    """Per-leaf mean-reduce: keeps each leaf's auto-axis (tensor-parallel)
    sharding intact. Still ONE logical sync point per call — XLA may lower
    it as several fused all-reduce ops, which its combiner can overlap."""

    return jax.lax.pmean(tree, axes)


def cast_for_reduce(tree: PyTree) -> PyTree:
    """Promote ONLY sub-f32 float leaves (bf16/f16) to f32 before an
    all-reduce; f32/f64 and integer leaves pass through untouched (f32
    identity leaves keep their object identity — pinned by tests).

    Two reasons, both pinned by tests/test_scale_distributed.py:
    1. XLA's AllReducePromotion pass crashes on bf16 VARIADIC all-reduce
       on the CPU backend — a sub-f32 leaf in the reduce bucket must not
       reach the collective at its narrow dtype;
    2. reduction accuracy: accumulating a cross-replica mean in bf16 loses
       the benefit of the f32 master params (this is also what PyTorch DDP
       does for low-precision buckets).

    Callers cast the reduced result back per leaf where the consumer is
    dtype-sensitive."""

    def one(x):
        if jnp.issubdtype(x.dtype, jnp.inexact) and x.dtype.itemsize < 4:
            return x.astype(jnp.float32)
        return x

    return jax.tree_util.tree_map(one, tree)


def make_pjit_step(spec: BilevelSpec, base_opt, meta_opt, cfg: EngineConfig):
    """Naive DDP baseline: correctness by SPMD propagation."""
    return make_meta_step(spec, base_opt, meta_opt, cfg)


def make_manual_step(
    spec: BilevelSpec,
    base_opt: Optimizer,
    meta_opt: Optimizer,
    cfg: EngineConfig,
    mesh,
    axes=None,
    *,
    allow_nonlinear: bool = False,
):
    """The single-sync schedule for any method whose reduce contract is
    linear. Returns a shard_map'ed step with the same signature as the
    Engine step: (state, base_batches[K], meta_batch).

    ``axes``: mesh axes to be *manual* data-parallel over (default: the
    pod/data axes, leaving "model" to the auto partitioner). Passing ALL axes
    gives pure DDP — the right configuration for models that fit per-device
    (see §Perf pair 1).

    ``allow_nonlinear``: run a method whose contract declares
    ``linear=False`` anyway, as the average-of-local-solves approximation
    (each shard solves/unrolls on its own data; only the results are
    averaged). Off by default because that is a *different* estimator from
    the method's own global-batch definition.
    """

    dp = tuple(axes) if axes is not None else data_axes(mesh)
    # the flat single-op bucket is only safe when every non-manual mesh axis
    # is trivial (pure DDP): raveling would break "model" sharding and force
    # all-gathers. With live tensor parallelism, reduce per leaf instead —
    # same single logical sync point, sharding preserved.
    auto_extent = 1
    for a in mesh.axis_names:
        if a not in dp:
            auto_extent *= mesh.shape[a]
    bucket_pmean = flat_pmean if auto_extent == 1 else tree_pmean
    method = cfg.resolve()
    policy = cfg.scale.resolve()
    spec = policy_mod.apply_to_spec(spec, policy)
    micro = cfg.scale.microbatch
    # static metric set (shard_map out_specs): the quartet, plus the
    # loss-scale automaton scalars whenever the policy scales — a config
    # property, NOT an obs switch, so observability never changes the HLO
    metric_keys = METRIC_KEYS + (SCALE_METRIC_KEYS if policy.dynamic_scaling
                                 else ())
    contract = method.reduce_contract
    if not contract.linear and not allow_nonlinear:
        raise ValueError(
            f"hypergrad method {method.name!r} declares a nonlinear reduce contract: "
            "averaging its per-shard estimates is not the method's own estimator on "
            "the global batch. Pass allow_nonlinear=True to accept the "
            "local-solve approximation, or use the pjit path."
        )

    def ddp_grad_reduce(g_loc):
        """The per-base-step DDP sync: one bucketed pmean over the data
        axes, sub-f32 leaves promoted for the collective and restored
        after. With microbatch accumulation this runs on the ACCUMULATED
        gradient — one all-reduce per base step for every M."""

        g_red = bucket_pmean(cast_for_reduce(g_loc), dp)
        return jax.tree_util.tree_map(lambda r, gl: r.astype(gl.dtype), g_red, g_loc)

    def local_step(state: EngineState, base_batches, meta_batch):
        lam = state.lam

        # ---- base unroll: standard DDP (one pmean per base step), shared
        # with the Engine path — microbatch accumulation, precision casts
        # and loss-scale skip semantics are engine._unroll_base's ----
        with obs_trace.phase("base_unroll"):
            (theta, b_state, g_base, st_at_g, losses, scale_state,
             base_ok) = _unroll_base(
                spec, base_opt, state.theta, state.base_opt_state, lam,
                base_batches, scale_cfg=cfg.scale, scale_state=state.scale,
                grad_reduce=ddp_grad_reduce,
            )

        # ---- method stage 1: strictly LOCAL terms (no collective) ----
        ctx = make_context(
            base_opt, state, base_batches, meta_batch,
            theta=theta, base_opt_state=st_at_g, g_base=g_base,
            loss_scale=scale_state.scale if scale_state is not None else None,
        )
        terms = methods_mod.validate_terms(
            method, accum_mod.microbatch_local_terms(method, spec, ctx, micro,
                                                     policy.accum_jnp))

        # ---- THE single synchronization point (one bucketed all-reduce) ----
        # Exactly the contract's terms ride the bucket, plus the scalar
        # base-loss metric so logging costs no extra sync. cast_for_reduce
        # promotes only sub-f32 leaves (see its docstring for why).
        bucket = {k: terms[k] for k in contract.terms}
        bucket["__base_loss__"] = jnp.mean(losses)
        with obs_trace.phase("allreduce_flat"):
            reduced = bucket_pmean(cast_for_reduce(bucket), dp)
        base_loss = reduced.pop("__base_loss__")
        terms = dict(terms, **reduced)

        # ---- method stage 3: finalize on replica-consistent terms ----
        with obs_trace.phase("finalize"):
            hyper, theta_post = method.finalize(terms, ctx)

        with obs_trace.phase("meta_update"):
            lam, m_state, theta_post, meta_ok = guarded_meta_update(
                meta_opt, hyper, theta_post, state,
                theta_pre=theta, guard=policy.dynamic_scaling, base_ok=base_ok,
            )
            if meta_ok is not None:  # hypergrad overflow must back the scale off
                scale_state = policy_mod.backoff_on(scale_state, meta_ok, policy)

        metrics = step_metrics(method, terms, hyper, losses)
        metrics["base_loss"] = base_loss
        if meta_ok is not None:  # see engine.make_meta_step: automaton scalars
            metrics["loss_scale"] = scale_state.scale
            metrics["meta_skipped"] = 1.0 - meta_ok.astype(jnp.float32)
        # the manual schedule reports a static metric set (its out_specs
        # are static); extra per-method metrics live on the Engine path
        metrics = {k: metrics[k] for k in metric_keys}
        new_state = EngineState(
            theta=theta_post, base_opt_state=b_state, lam=lam,
            meta_opt_state=m_state, step=state.step + 1, scale=scale_state,
        )
        return new_state, metrics

    def batch_spec(t):
        nd = len(t.shape)
        return P(*((None, dp) + (None,) * (nd - 2)))  # (K, B, ...) -> shard B

    def meta_spec(t):
        nd = len(t.shape)
        return P(*((dp,) + (None,) * (nd - 1)))

    def wrap(state, base_batches, meta_batch):
        in_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            jax.tree_util.tree_map(batch_spec, base_batches),
            jax.tree_util.tree_map(meta_spec, meta_batch),
        )
        out_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            {k: P() for k in metric_keys},
        )
        fn = shard_map(
            local_step, mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(dp), check=False,
        )
        return fn(state, base_batches, meta_batch)

    return wrap


def count_data_allreduces(hlo_text: str) -> int:
    """Number of all-reduce(-start) ops in a lowered module (structure audit)."""
    import re

    n = 0
    for line in hlo_text.splitlines():
        if re.search(r"=\s+\S.*\s+all-reduce(-start)?\(", line) and "all-reduce-done" not in line:
            n += 1
    return n
